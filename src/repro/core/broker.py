"""Broker subsystem (paper §3.2, §4.1.2, Table 2).

Brokers are HTTP endpoints in the real platform; here they are simulated but
their *work* is real and measurable, mirroring Table 2's three stages:

  receive  -- proportional to platform->broker bytes (ChannelResult.broker_bytes)
  convert  -- "converting to JSON": materialize a wire payload buffer. For the
              original layout that is one record copy per subscription; for the
              aggregated layout one record copy per group + the sID list.
  send     -- per-subscriber dispatch; identical between layouts (Table 2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plans import ChannelResult

HEADER_WORDS = 4  # [row_id, target_idx, member_count, payload_words]


@dataclasses.dataclass
class BrokerRegistry:
    names: Dict[str, int]

    @staticmethod
    def create(*names: str) -> "BrokerRegistry":
        return BrokerRegistry({n: i for i, n in enumerate(names)})

    @property
    def num_brokers(self) -> int:
        return len(self.names)


def pack_payloads(result: ChannelResult, group_sids: jnp.ndarray,
                  payload_words: int, max_pairs: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Materialize the wire payload: (max_pairs, HEADER + cap + payload_words).

    One row per *result pair* (group or subscription). This is the broker's
    "convert" work: in the aggregated layout there are far fewer rows, each
    carrying its sID list; in the original layout there is one row per
    subscription with cap == 1.

    Returns (buffer, delivered, overflow): pairs beyond ``max_pairs`` are
    dropped — never scattered over the last slot — and counted in overflow.
    """
    cap = group_sids.shape[1] if group_sids.ndim == 2 else 1
    rows = result.pair_rows.ravel()
    tgts = result.pair_targets.ravel()
    valid = result.pair_valid.ravel()
    pos = jnp.cumsum(valid.astype(jnp.int32)) - 1
    dest = jnp.where(valid & (pos < max_pairs), pos, max_pairs)
    width = HEADER_WORDS + cap + payload_words
    out = jnp.zeros((max_pairs + 1, width), dtype=jnp.int32)
    tgt_safe = jnp.maximum(tgts, 0)
    sids = group_sids[tgt_safe] if group_sids.ndim == 2 else tgt_safe[:, None]
    members = jnp.sum((sids >= 0).astype(jnp.int32), axis=-1)
    header = jnp.stack([rows, tgts, members,
                        jnp.full_like(rows, payload_words)], axis=-1)
    payload = jnp.broadcast_to(rows[:, None], (rows.shape[0], payload_words))
    line = jnp.concatenate([header, sids, payload], axis=-1)
    out = out.at[dest].set(jnp.where(valid[:, None], line, 0), mode="drop")
    count = jnp.sum(valid.astype(jnp.int32))
    delivered = jnp.minimum(count, max_pairs)
    return out[:max_pairs], delivered, count - delivered


def fanout_sids(result: ChannelResult, group_sids: jnp.ndarray,
                max_notify: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The broker's "send" stage: the flat list of end subscribers to notify.
    Identical volume for original and aggregated layouts (Table 2, row 3).

    Returns (buffer, delivered, overflow) — overflow counts sIDs dropped
    because the notify buffer was full."""
    tgts = result.pair_targets.ravel()
    valid = result.pair_valid.ravel()
    tgt_safe = jnp.maximum(tgts, 0)
    sids = group_sids[tgt_safe] if group_sids.ndim == 2 else tgt_safe[:, None]
    member_valid = (sids >= 0) & valid[:, None]
    flat = jnp.where(member_valid, sids, -1).ravel()
    mask = flat >= 0
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    dest = jnp.where(mask & (pos < max_notify), pos, max_notify)
    out = jnp.full((max_notify + 1,), -1, dtype=jnp.int32)
    out = out.at[dest].set(flat, mode="drop")
    count = jnp.sum(mask.astype(jnp.int32))
    delivered = jnp.minimum(count, max_notify)
    return out[:max_notify], delivered, count - delivered


def broker_traffic_summary(result: ChannelResult) -> Dict[str, np.ndarray]:
    return {
        "bytes_per_broker": np.asarray(result.broker_bytes),
        "results_per_broker": np.asarray(result.broker_results),
        "total_bytes": np.asarray(result.broker_bytes.sum()),
        "total_results": np.asarray(result.num_results),
        "total_notified": np.asarray(result.num_notified),
    }
