"""BADEngine: the host-side orchestrator tying the data plane together.

Responsibilities (paper Fig. 1): data feed ingestion -> ActiveDataset append +
conditionsList evaluation + BAD-index maintenance; channel execution under a
chosen ``ExecutionFlags`` plan; broker accounting; subscription control plane
(Algorithm 1 grouping + UserParameters upkeep).

The engine is deliberately a thin host shell: every per-record code path is a
jitted pure function over fixed-shape arrays.

``use_pallas=True`` routes every predicate / spatial evaluation through the
Pallas kernels (``predicate_filter`` at ingestion AND inside the fused
executor's candidate discovery; ``spatial_match`` in both spatial join
paths); the default jnp oracle is the parity reference, and the two are
result-identical by construction (asserted by the parity suite).

Broker delivery (``deliver=True`` on ``execute_channel`` / ``execute_all``)
runs the broker's convert+send stages (``pack_payloads`` / ``fanout_sids``)
and surfaces dropped-on-overflow counts in ``ExecutionReport.overflow`` — no
silently lost notifications.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bad_index as bidx
from repro.core import plans
from repro.core import records as R
from repro.core import subscriptions as subs
from repro.core.broker import BrokerRegistry, fanout_sids, pack_payloads
from repro.core.channel import ChannelSpec
from repro.core.predicates import (CompiledConditions, compile_conditions,
                                   evaluate_conditions)
from repro.core.user_params import UserParameters


@dataclasses.dataclass
class ChannelState:
    spec: ChannelSpec
    index: int                      # row in the stacked conditionsList / BADIndexState
    aggregator: subs.Aggregator
    user_params: UserParameters
    last_exec_ts: int = 0
    last_exec_size: int = 0
    executions: int = 0
    # device-resident TargetArrays + host group/flat views, cached per channel
    # and explicitly invalidated whenever the subscription set changes;
    # ``version`` keys the engine's stacked multi-channel caches
    version: int = 0
    _targets_flat: Optional[plans.TargetArrays] = None
    _targets_grouped: Optional[plans.TargetArrays] = None
    _groups: Optional[subs.SubscriptionGroups] = None
    _flat: Optional[subs.SubscriptionTable] = None
    _host_targets: Dict[bool, Tuple] = dataclasses.field(default_factory=dict)

    def invalidate_targets(self) -> None:
        self.version += 1
        self._targets_flat = self._targets_grouped = None
        self._groups = self._flat = None
        self._host_targets = {}


@dataclasses.dataclass(frozen=True)
class DeliveryStats:
    """Broker delivery accounting for one executed channel (opt-in via
    ``deliver=True``): result pairs packed by ``pack_payloads`` and end
    subscribers fanned out by ``fanout_sids`` vs dropped on buffer overflow.
    Conservation: delivered + overflow == produced, per stage."""

    delivered_pairs: int
    overflow_pairs: int
    delivered_sids: int
    overflow_sids: int

    @property
    def overflow(self) -> int:
        return self.overflow_pairs + self.overflow_sids


@dataclasses.dataclass
class ExecutionReport:
    channel: str
    flags: plans.ExecutionFlags
    result: plans.ChannelResult
    wall_time_s: float
    num_results: int
    num_notified: int
    scanned: int
    broker_bytes: np.ndarray
    # broker overflow accounting; None unless executed with ``deliver=True``
    overflow: Optional[DeliveryStats] = None


class BADEngine:
    def __init__(self,
                 dataset_capacity: int = 1 << 18,
                 index_capacity: int = 1 << 15,
                 max_window: int = 1 << 15,
                 max_candidates: int = 1 << 13,
                 frame_bytes: int = 40 * 1024,
                 schema: R.Schema = R.ENRICHED_TWEET_SCHEMA,
                 brokers: Tuple[str, ...] = ("BrokerA",),
                 use_pallas: bool = False,
                 group_cap: Optional[int] = None,
                 max_deliver_pairs: int = 1 << 12,
                 max_notify: int = 1 << 14,
                 deliver_payload_words: int = 8):
        self.schema = schema
        self.dataset = R.ActiveDataset.create(dataset_capacity, schema)
        self.index_capacity = index_capacity
        self.max_window = max_window
        self.max_candidates = max_candidates
        self.frame_bytes = frame_bytes
        self.group_cap = group_cap or subs.cap_from_frame_bytes(frame_bytes)
        self.brokers = BrokerRegistry.create(*brokers)
        self.channels: Dict[str, ChannelState] = {}
        self.use_pallas = use_pallas
        self.max_deliver_pairs = max_deliver_pairs
        self.max_notify = max_notify
        self.deliver_payload_words = deliver_payload_words
        self.user_locations = jnp.zeros((1, 2), dtype=jnp.float32)
        self.user_brokers = jnp.zeros((1,), dtype=jnp.int32)
        # keys the stacked-user-set cache; bumped by set_user_locations
        self._user_version = 0
        self.now = 0
        self._conds: Optional[CompiledConditions] = None
        self.index_state = bidx.BADIndexState.create(0, index_capacity)
        self._ingest_fn = None
        # compiled plan caches (single-channel and fused all-channel), keyed
        # on the specs/flags they close over; cleared on channel create/drop
        self._exec_cache: Dict = {}
        # stacked device targets for execute_all: one warm entry per layout
        # (aggregated / flat), each validated by its channel-version key
        self._stacked_cache: Dict = {}

    # ------------------------------------------------------------------
    # control plane
    # ------------------------------------------------------------------

    def create_channel(self, spec: ChannelSpec) -> None:
        if spec.name in self.channels:
            raise ValueError(f"channel {spec.name} exists")
        if self.dataset.size.item() > 0 and spec.fixed_preds:
            # BAD indexes only see records ingested after channel creation —
            # same semantics as the paper (continuous queries over new data).
            pass
        st = ChannelState(
            spec=spec,
            index=len(self.channels),
            aggregator=subs.Aggregator(self.group_cap),
            user_params=UserParameters.create(spec.param_domain),
            last_exec_ts=self.now,
        )
        st.last_exec_size = int(self.dataset.size)
        self.channels[spec.name] = st
        self._rebuild_conditions()

    def drop_channel(self, name: str) -> None:
        del self.channels[name]
        survivors = sorted(self.channels.values(), key=lambda s: s.index)
        old_rows = [st.index for st in survivors]
        for i, st in enumerate(survivors):
            st.index = i
        self._rebuild_conditions(old_rows)

    def subscribe(self, channel: str, param: int, broker: str = "BrokerA",
                  sid: Optional[int] = None) -> int:
        st = self.channels[channel]
        if not 0 <= param < st.user_params.domain:   # before any mutation
            raise ValueError(
                f"param {param} out of [0, {st.user_params.domain}) "
                f"for {channel}")
        bid = self.brokers.names[broker]
        sid = st.aggregator.add_subscription(param, bid, sid)
        st.user_params.add(param)
        st.invalidate_targets()
        return sid

    def subscribe_bulk(self, channel: str, params: np.ndarray,
                       brokers: np.ndarray) -> np.ndarray:
        """Bulk control-plane load through the vectorized ``aggregate`` path:
        Algorithm-1 grouping semantics with no per-subscription Python work.
        Returns the assigned sIDs."""
        st = self.channels[channel]
        params = np.asarray(params, dtype=np.int32).ravel()
        brokers = np.asarray(brokers, dtype=np.int32).ravel()
        # validate BEFORE mutating: a bad param/broker must not leave the
        # aggregator holding subscriptions whose refcounts were never
        # registered (or whose broker id aliases the invalid-pair sentinel)
        if params.size and (int(params.min()) < 0
                            or int(params.max()) >= st.user_params.domain):
            raise ValueError(
                f"params out of [0, {st.user_params.domain}) for {channel}")
        nb = self.brokers.num_brokers
        if brokers.size and (int(brokers.min()) < 0 or int(brokers.max()) >= nb):
            raise ValueError(f"broker ids out of [0, {nb}) for {channel}")
        sids = st.aggregator.add_bulk(params, brokers)
        st.user_params.add_bulk(params)
        st.invalidate_targets()
        return sids

    def unsubscribe(self, channel: str, param: int, broker: str, sid: int) -> bool:
        st = self.channels[channel]
        ok = st.aggregator.remove_subscription(param, self.brokers.names[broker], sid)
        if ok:
            st.user_params.remove(param)
            st.invalidate_targets()
        return ok

    def set_user_locations(self, locations: np.ndarray,
                           brokers: Optional[np.ndarray] = None) -> None:
        self.user_locations = jnp.asarray(locations, dtype=jnp.float32)
        if brokers is None:
            brokers = np.zeros((locations.shape[0],), dtype=np.int32)
        self.user_brokers = jnp.asarray(brokers, dtype=jnp.int32)
        self._user_version += 1  # invalidate stacked user targets

    # ------------------------------------------------------------------
    # data plane: ingestion
    # ------------------------------------------------------------------

    def _rebuild_conditions(self, old_rows: Optional[List[int]] = None) -> None:
        """Recompile the conditionsList and re-shape the BAD index.

        ``old_rows[i]`` is the *previous* index row of the channel now at row
        ``i`` — surviving channels keep their own buffers/watermarks by
        identity, not by position (dropping a middle channel must not hand its
        rows to the next one).
        """
        specs = sorted(self.channels.values(), key=lambda s: s.index)
        self._conds = compile_conditions([list(s.spec.fixed_preds) for s in specs])
        old = self.index_state
        new = bidx.BADIndexState.create(len(specs), self.index_capacity)
        if old_rows is None:  # channel append: surviving rows keep positions
            old_rows = list(range(min(old.num_channels, new.num_channels)))
        assert all(0 <= r < old.num_channels for r in old_rows)
        if old_rows:
            src = jnp.asarray(old_rows, jnp.int32)
            n = len(old_rows)
            new = bidx.BADIndexState(
                new.row_ids.at[:n].set(old.row_ids[src]),
                new.counts.at[:n].set(old.counts[src]),
                new.watermarks.at[:n].set(old.watermarks[src]),
                new.overflowed.at[:n].set(old.overflowed[src]),
            )
        self.index_state = new
        self._ingest_fn = None  # shapes changed; re-trace
        self._exec_cache.clear()  # compiled plans bind conds + channel rows
        # stacked targets are keyed by (name, version); a same-named channel
        # re-created at version 0 would collide, so drop them here too
        self._stacked_cache.clear()

    def _build_ingest(self):
        conds = self._conds
        use_pallas = self.use_pallas

        @jax.jit
        def ingest_step(ds, index_state, batch):
            ds, row_ids = _append(ds, batch)
            if use_pallas:
                from repro.kernels.predicate_filter import ops as pf_ops
                matches = pf_ops.predicate_filter(batch.fields, conds)
            else:
                matches = evaluate_conditions(batch.fields, conds)
            index_state = _insert(index_state, row_ids, matches)
            return ds, index_state, row_ids

        return ingest_step

    def ingest(self, batch: R.RecordBatch) -> np.ndarray:
        """Data feed entry point: append + BAD-index maintenance (Algorithm 2)."""
        if self._ingest_fn is None:
            self._ingest_fn = self._build_ingest()
        self.dataset, self.index_state, row_ids = self._ingest_fn(
            self.dataset, self.index_state, batch)
        ts = batch.fields[:, R.TIMESTAMP]
        self.now = max(self.now, int(jnp.max(ts))) if batch.num_records else self.now
        return np.asarray(row_ids)

    # ------------------------------------------------------------------
    # data plane: channel execution
    # ------------------------------------------------------------------

    def _targets_host(self, st: ChannelState, aggregated: bool) -> Tuple:
        """Host-side (numpy) join targets: (params, brokers, counts, by_param,
        by_param_count). Shared by the per-channel and stacked device caches."""
        cached = st._host_targets.get(aggregated)
        if cached is not None:
            return cached
        if aggregated:
            groups = st._groups or st.aggregator.build()
            st._groups = groups
            params = np.asarray(groups.group_params, np.int32)
            brokers = np.asarray(groups.group_brokers, np.int32)
            counts = np.asarray(groups.group_counts, np.int32)
        else:
            flat = self._flat_table(st)
            params = np.asarray(flat.params, np.int32)
            brokers = np.asarray(flat.brokers, np.int32)
            counts = np.ones_like(params)
        by_param, by_count = subs.param_to_targets(params, st.spec.param_domain)
        out = (params, brokers, counts, by_param, by_count)
        st._host_targets[aggregated] = out
        return out

    def _targets(self, st: ChannelState, aggregated: bool) -> plans.TargetArrays:
        cached = st._targets_grouped if aggregated else st._targets_flat
        if cached is None:
            p, b, c, bp, bc = self._targets_host(st, aggregated)
            cached = plans.TargetArrays(jnp.asarray(p), jnp.asarray(b),
                                        jnp.asarray(c), jnp.asarray(bp),
                                        jnp.asarray(bc))
            if aggregated:
                st._targets_grouped = cached
            else:
                st._targets_flat = cached
        return cached

    def _flat_table(self, st: ChannelState) -> subs.SubscriptionTable:
        if st._flat is None:
            groups = st._groups or st.aggregator.build()
            st._groups = groups
            st._flat = subs.flatten_groups(groups)
        return st._flat

    def group_sids_array(self, channel: str, aggregated: bool) -> jnp.ndarray:
        st = self.channels[channel]
        if aggregated:
            groups = st._groups or st.aggregator.build()
            st._groups = groups
            return jnp.asarray(groups.group_sids)
        flat = self._flat_table(st)
        return jnp.asarray(flat.sids)[:, None]

    def _exec_fn(self, channel: str, flags: plans.ExecutionFlags,
                 spatial: bool, max_cand: Optional[int] = None) -> Callable:
        """Compiled single-channel plan, cached by everything it closes over:
        the (frozen) spec, flags, and the channel's index row. Keying on the
        spec — not the name — means re-creating a same-named channel with new
        predicates can never be served a stale plan; the cache itself lives on
        the engine and is cleared on channel create/drop."""
        st = self.channels[channel]
        key = (st.spec, flags, spatial, max_cand, st.index)
        cached = self._exec_cache.get(key)
        if cached is not None:
            return cached
        spec = st.spec
        conds_one = compile_conditions([list(spec.fixed_preds)])
        best_pred = int(np.argmax([_pred_rank(p) for p in spec.fixed_preds])) \
            if spec.fixed_preds else 0
        max_window = self.max_window
        max_cand = max_cand or self.max_candidates
        num_brokers = self.brokers.num_brokers
        use_pallas = self.use_pallas
        ch_idx = st.index

        def run(ds, index_state, targets, up_mask, last_ts, last_size,
                user_locations, user_brokers):
            if flags.scan_mode == "full":
                cand = plans.candidates_full_scan(ds, conds_one, last_ts, max_cand)
            elif flags.scan_mode == "window":
                cand = plans.candidates_window(ds, conds_one, last_size, max_window)
            elif flags.scan_mode == "trad_index":
                cand = plans.candidates_trad_index(ds, conds_one, best_pred,
                                                   last_size, max_window, max_cand)
            else:
                cand = plans.candidates_bad_index(ds, index_state, ch_idx, max_cand)
            if spatial:
                spatial_fn = None
                if use_pallas:
                    from repro.kernels.spatial_match import ops as sm_ops
                    spatial_fn = sm_ops.spatial_match
                return plans.join_spatial(ds, cand, user_locations, user_brokers,
                                          spec.spatial_radius, spec.payload_bytes,
                                          num_brokers, spatial_fn)
            return plans.join_param_targets(
                ds, cand, targets, spec.param_field, spec.payload_bytes,
                num_brokers, up_mask if flags.param_pushdown else None,
                flags.aggregation)

        fn = jax.jit(run)
        self._cache_put(key, fn)
        return fn

    def _cache_put(self, key, fn: Callable, cap: int = 256) -> None:
        """Insert into the plan cache with FIFO eviction — superseded shape
        buckets / flag combos must not pin dead XLA executables forever."""
        if len(self._exec_cache) >= cap:
            self._exec_cache.pop(next(iter(self._exec_cache)))
        self._exec_cache[key] = fn

    def _deliver(self, st: ChannelState, result: plans.ChannelResult,
                 aggregated: bool) -> DeliveryStats:
        """Run the broker convert+send stages on one channel's result and
        account overflow (ROADMAP: surface drops instead of losing them)."""
        if st.spec.join == "spatial":
            # spatial targets ARE end-user ids; any 1-D table selects the
            # brokers' identity fanout (they read targets directly and never
            # index a 1-D table's values), so pass an empty shape-only flag
            sids = jnp.zeros((0,), dtype=jnp.int32)
        else:
            sids = self.group_sids_array(st.spec.name, aggregated)
        _, dp, op = pack_payloads(result, sids, self.deliver_payload_words,
                                  self.max_deliver_pairs)
        _, ds_, os_ = fanout_sids(result, sids, self.max_notify)
        return DeliveryStats(int(dp), int(op), int(ds_), int(os_))

    def execute_channel(self, channel: str,
                        flags: plans.ExecutionFlags,
                        advance: bool = True,
                        timed: bool = True,
                        deliver: bool = False) -> ExecutionReport:
        st = self.channels[channel]
        spatial = st.spec.join == "spatial"
        # The BAD index knows its exact candidate count before execution (the
        # watermark delta) — unlike scans/traditional indexes — so downstream
        # buffers are shape-bucketed to the real volume ("early result
        # filtering" paying off structurally, not just in rows scanned).
        max_cand = None
        if flags.scan_mode == "bad_index":
            pending = int(self.index_state.counts[st.index]
                          - self.index_state.watermarks[st.index])
            bucket = _pow2_bucket(pending, 6)
            max_cand = min(bucket, self.max_candidates)
        fn = self._exec_fn(channel, flags, spatial, max_cand)
        targets = self._targets(st, flags.aggregation)
        up_mask = st.user_params.mask()
        args = (self.dataset, self.index_state, targets, up_mask,
                jnp.asarray(st.last_exec_ts, jnp.int32),
                jnp.asarray(st.last_exec_size, jnp.int32),
                self.user_locations, self.user_brokers)
        if timed:  # warm the trace so wall time measures execution, not tracing
            jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        result = fn(*args)
        jax.block_until_ready(result.num_results)
        wall = time.perf_counter() - t0
        if advance:
            self.index_state = bidx.advance_watermark(self.index_state, st.index)
            st.last_exec_ts = self.now
            st.last_exec_size = int(self.dataset.size)
            st.executions += 1
        overflow = self._deliver(st, result, flags.aggregation) if deliver else None
        return ExecutionReport(
            channel=channel, flags=flags, result=result, wall_time_s=wall,
            num_results=int(result.num_results),
            num_notified=int(result.num_notified),
            scanned=int(result.scanned),
            broker_bytes=np.asarray(result.broker_bytes),
            overflow=overflow)

    # ------------------------------------------------------------------
    # data plane: fused multi-channel execution
    # ------------------------------------------------------------------

    def _stacked_inputs(self, chs: List[ChannelState], aggregated: bool):
        """Device-resident shape-bucketed targets for all param channels.

        Per-channel targets are padded to shared power-of-two buckets (max
        target count / join fan-out across channels, real max domain) so the
        fused trace survives subscription growth; -1 / 0 padding can never
        form a valid pair. Cached until any channel's subscription version
        moves.
        """
        key = tuple((st.spec.name, st.version) for st in chs)
        hit = self._stacked_cache.get(aggregated)
        if hit is not None and hit[0] == key:
            return hit[1]
        hosts = [self._targets_host(st, aggregated) for st in chs]
        n = len(chs)
        tmax = _pow2_bucket(max(h[0].shape[0] for h in hosts), 3)
        dmax = max(st.spec.param_domain for st in chs)
        mmax = _pow2_bucket(max(h[3].shape[1] for h in hosts), 3)
        params = np.zeros((n, tmax), np.int32)
        brokers = np.zeros((n, tmax), np.int32)
        counts = np.zeros((n, tmax), np.int32)
        by_param = np.full((n, dmax, mmax), -1, np.int32)
        by_count = np.zeros((n, dmax), np.int32)
        up_masks = np.zeros((n, dmax), bool)
        domains = np.zeros((n,), np.int32)
        for i, (st, (p, b, c, bp, bc)) in enumerate(zip(chs, hosts)):
            t, (d, m) = p.shape[0], bp.shape
            params[i, :t] = p
            brokers[i, :t] = b
            counts[i, :t] = c
            by_param[i, :d, :m] = bp
            by_count[i, :d] = bc
            up_masks[i, :d] = st.user_params.refcount > 0
            domains[i] = st.spec.param_domain
        targets = plans.TargetArrays(
            jnp.asarray(params), jnp.asarray(brokers), jnp.asarray(counts),
            jnp.asarray(by_param), jnp.asarray(by_count))
        val = (targets, jnp.asarray(up_masks), jnp.asarray(domains))
        self._stacked_cache[aggregated] = (key, val)
        return val

    def _stacked_spatial_inputs(self, chs: List[ChannelState]):
        """Stacked per-channel user sets for the fused spatial join.

        The user count is shape-bucketed (power of two) so the fused trace
        survives user-set growth; padded users sit at the far sentinel and can
        never fall inside any radius. There is one global UserLocations
        dataset today, so every channel row carries the same users — the
        stacked layout keeps the plan ready for per-channel user cohorts.
        Cached until ``set_user_locations`` (version bump) or channel
        create/drop (cache clear)."""
        from repro.kernels.spatial_match.ops import FAR
        key = (tuple(st.spec.name for st in chs), self._user_version)
        hit = self._stacked_cache.get("spatial")
        if hit is not None and hit[0] == key:
            return hit[1]
        u = self.user_locations.shape[0]
        ub = _pow2_bucket(u, 3)
        n = len(chs)
        locs = np.full((n, ub, 2), -FAR, np.float32)
        brokers = np.zeros((n, ub), np.int32)
        locs[:, :u] = np.asarray(self.user_locations)[None]
        brokers[:, :u] = np.asarray(self.user_brokers)[None]
        val = (jnp.asarray(locs), jnp.asarray(brokers))
        self._stacked_cache["spatial"] = (key, val)
        return val

    def _exec_all_fn(self, param_chs: List[ChannelState],
                     spatial_chs: List[ChannelState],
                     flags: plans.ExecutionFlags, max_cand: int) -> Callable:
        """ONE compiled plan for every channel: stacked candidate discovery
        per join group (param / spatial), vmapped joins, fused broker
        accounting. With ``use_pallas`` the discovery runs the Pallas
        ``predicate_filter`` kernel and the spatial join the Pallas
        ``spatial_match`` kernel (both batched over the channel axis)."""
        key = ("all", flags, max_cand,
               tuple((st.spec, st.index) for st in param_chs),
               tuple((st.spec, st.index) for st in spatial_chs))
        cached = self._exec_cache.get(key)
        if cached is not None:
            return cached
        conds = self._conds
        max_window = self.max_window
        num_brokers = self.brokers.num_brokers
        scan_mode = flags.scan_mode
        pushdown = flags.param_pushdown
        aggregated = flags.aggregation
        use_pallas = self.use_pallas
        if use_pallas:
            from repro.kernels.predicate_filter import ops as pf_ops
            from repro.kernels.spatial_match import ops as sm_ops
            spatial_fn = sm_ops.spatial_match
        else:
            spatial_fn = None

        def group_statics(chs):
            rows = [st.index for st in chs]
            conds_sub = CompiledConditions(
                conds.field_idx[rows], conds.op[rows],
                conds.value[rows], conds.npreds[rows])
            best = jnp.asarray(
                [int(np.argmax([_pred_rank(p) for p in st.spec.fixed_preds]))
                 if st.spec.fixed_preds else 0 for st in chs], jnp.int32)
            match_fn = match_rows_fn = None
            if use_pallas:
                match_fn = lambda f, cs=conds_sub: pf_ops.predicate_filter(f, cs)
                match_rows_fn = (
                    lambda f, cs=conds_sub: pf_ops.predicate_filter_rows(f, cs))
            return (conds_sub, best, jnp.asarray(rows, jnp.int32),
                    match_fn, match_rows_fn)

        p_static = group_statics(param_chs) if param_chs else None
        s_static = group_statics(spatial_chs) if spatial_chs else None
        radii = jnp.asarray([st.spec.spatial_radius for st in spatial_chs],
                            jnp.float32)

        def discover(ds, index_state, static, last_ts, last_size):
            conds_sub, best, ch_rows, match_fn, match_rows_fn = static
            if scan_mode == "full":
                return plans.candidates_full_scan_all(ds, conds_sub, last_ts,
                                                      max_cand, match_fn)
            if scan_mode == "window":
                return plans.candidates_window_all(ds, conds_sub, last_size,
                                                   max_window, match_rows_fn)
            if scan_mode == "trad_index":
                return plans.candidates_trad_index_all(
                    ds, conds_sub, best, last_size, max_window, max_cand,
                    match_rows_fn)
            return plans.candidates_bad_index_all(index_state, ch_rows,
                                                  max_cand)

        def run(ds, index_state, p_in, s_in):
            res_p = res_s = None
            if p_static is not None:
                cand = discover(ds, index_state, p_static,
                                p_in["last_ts"], p_in["last_size"])
                res_p = plans.join_param_targets_all(
                    ds, cand, p_in["targets"], p_in["param_field"],
                    p_in["payload"], num_brokers,
                    p_in["up_masks"] if pushdown else None, aggregated,
                    p_in["domains"])
            if s_static is not None:
                cand = discover(ds, index_state, s_static,
                                s_in["last_ts"], s_in["last_size"])
                res_s = plans.join_spatial_all(
                    ds, cand, s_in["locs"], s_in["brokers"], radii,
                    s_in["payload"], num_brokers, spatial_fn)
            return res_p, res_s

        fn = jax.jit(run)
        self._cache_put(key, fn)
        return fn

    def execute_all(self, flags: plans.ExecutionFlags, advance: bool = True,
                    timed: bool = True,
                    deliver: bool = False) -> Dict[str, ExecutionReport]:
        """Execute EVERY channel — param-join AND spatial — in one jitted
        call: stacked candidate discovery per join group, vmapped param join,
        vmapped spatial join (per-channel radii over the stacked user sets),
        fused broker accounting. No per-channel host round-trips remain on
        the hot path.

        Result-for-result equivalent to looping ``execute_channel`` — each
        channel's report carries its own counts/bytes; ``wall_time_s`` is the
        fused wall time amortized per channel. ``deliver=True`` additionally
        runs broker packing per channel and surfaces drop counts in
        ``report.overflow``.
        """
        ordered = sorted(self.channels.values(), key=lambda s: s.index)
        reports: Dict[str, ExecutionReport] = {}
        if not ordered:
            return reports
        param_chs = [st for st in ordered if st.spec.join == "param"]
        spatial_chs = [st for st in ordered if st.spec.join == "spatial"]
        max_cand = self.max_candidates
        if flags.scan_mode == "bad_index":
            # shared shape bucket: the largest per-channel watermark delta
            # (two bulk host reads, not 2 device reads per channel)
            counts = np.asarray(self.index_state.counts)
            wms = np.asarray(self.index_state.watermarks)
            pending = max(int(counts[st.index] - wms[st.index])
                          for st in ordered)
            bucket = _pow2_bucket(pending, 6)
            max_cand = min(bucket, self.max_candidates)
        fn = self._exec_all_fn(param_chs, spatial_chs, flags, max_cand)
        p_in = s_in = None
        if param_chs:
            targets, up_masks, domains = self._stacked_inputs(
                param_chs, flags.aggregation)
            p_in = dict(
                targets=targets, up_masks=up_masks, domains=domains,
                param_field=jnp.asarray(
                    [st.spec.param_field for st in param_chs], jnp.int32),
                payload=jnp.asarray(
                    [st.spec.payload_bytes for st in param_chs], jnp.int32),
                last_ts=jnp.asarray(
                    [st.last_exec_ts for st in param_chs], jnp.int32),
                last_size=jnp.asarray(
                    [st.last_exec_size for st in param_chs], jnp.int32))
        if spatial_chs:
            locs, ubrokers = self._stacked_spatial_inputs(spatial_chs)
            s_in = dict(
                locs=locs, brokers=ubrokers,
                payload=jnp.asarray(
                    [st.spec.payload_bytes for st in spatial_chs], jnp.int32),
                last_ts=jnp.asarray(
                    [st.last_exec_ts for st in spatial_chs], jnp.int32),
                last_size=jnp.asarray(
                    [st.last_exec_size for st in spatial_chs], jnp.int32))
        args = (self.dataset, self.index_state, p_in, s_in)
        if timed:  # warm the trace so wall time measures execution
            jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        res_p, res_s = fn(*args)
        jax.block_until_ready((res_p, res_s))
        wall = time.perf_counter() - t0
        if advance:
            self.index_state = bidx.advance_watermarks(
                self.index_state,
                jnp.asarray([st.index for st in ordered], jnp.int32))
            for st in ordered:
                st.last_exec_ts = self.now
                st.last_exec_size = int(self.dataset.size)
                st.executions += 1
        # One bulk device->host transfer per join group, then per-channel
        # numpy views: the per-channel path's int()/slice pattern would cost
        # dozens of device round-trips here.
        share = wall / len(ordered)
        for chs, res in ((param_chs, res_p), (spatial_chs, res_s)):
            if not chs:
                continue
            host = jax.tree.map(np.asarray, res)
            for i, st in enumerate(chs):
                overflow = None
                if deliver:
                    overflow = self._deliver(
                        st, jax.tree.map(lambda a, i=i: a[i], res),
                        flags.aggregation)
                reports[st.spec.name] = ExecutionReport(
                    channel=st.spec.name, flags=flags,
                    result=jax.tree.map(lambda a, i=i: a[i], host),
                    wall_time_s=share,
                    num_results=int(host.num_results[i]),
                    num_notified=int(host.num_notified[i]),
                    scanned=int(host.scanned[i]),
                    broker_bytes=host.broker_bytes[i],
                    overflow=overflow)
        return reports


def _pow2_bucket(n: int, floor_bits: int) -> int:
    """Smallest power of two >= n, clamped below by 2**floor_bits. Shared by
    every shape-bucketing site so fused and per-channel traces agree."""
    return 1 << max(floor_bits, (max(n, 1) - 1).bit_length())


def _pred_rank(p) -> int:
    """Heuristic selectivity rank for picking the traditional-index field."""
    from repro.core.predicates import EQ
    return 2 if p.op == EQ else 1


# jit-compiled shared helpers (module-level so lru caches are shared)
_append = R.append
_insert = bidx.insert
