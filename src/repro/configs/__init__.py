"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, reduced  # noqa: F401

_MODULES = {
    "qwen2-1.5b": "qwen2_1_5b",
    "llama3-405b": "llama3_405b",
    "qwen2-7b": "qwen2_7b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "dbrx-132b": "dbrx_132b",
    "xlstm-125m": "xlstm_125m",
    "pixtral-12b": "pixtral_12b",
    "zamba2-2.7b": "zamba2_2_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.get_config()


def get_reduced(arch: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch), **overrides)


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
