import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""§Perf hillclimb runner: re-lower a cell with config overrides and record
the variant next to its baseline.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch dbrx-132b \
      --shape decode_32k --variant ws+carry \
      --set weight_stationary_decode=True decode_loop=carry
"""
import argparse
import json


from repro.launch.dryrun import run_cell

_TYPES = {"True": True, "False": False}


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    if v in _TYPES:
        return k, _TYPES[v]
    try:
        return k, int(v)
    except ValueError:
        pass
    try:
        return k, float(v)
    except ValueError:
        return k, v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()
    overrides = dict(parse_override(kv) for kv in args.set)
    os.makedirs(args.out, exist_ok=True)
    res = run_cell(args.arch, args.shape, args.multi_pod, overrides=overrides)
    name = f"{args.arch}__{args.shape}__{res['mesh']}__{args.variant}.json"
    with open(os.path.join(args.out, name), "w") as f:
        json.dump(res, f, indent=2)
    mem = res["full"]["memory"]
    t = res["totals"]
    print(f"{args.arch} x {args.shape} [{args.variant}]: "
          f"peak={mem['peak_estimate_bytes']/2**30:.2f} GiB "
          f"flops/dev={t['flops']:.3e} bytes/dev={t['bytes']:.3e} "
          f"coll/dev={t['collective_bytes']:.3e}")
    print("per-kind:", {k: f"{v:.2e}" for k, v in
                        res["full"]["collectives"]["bytes_per_kind"].items()
                        if v})
    if "probe" in res and "collectives" in res.get("probe", {}):
        print("probe per-kind:", {k: f"{v:.2e}" for k, v in
                                  res["probe"]["collectives"]["bytes_per_kind"].items()
                                  if v})


if __name__ == "__main__":
    main()
