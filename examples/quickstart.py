"""Quickstart: create a channel, subscribe, ingest tweets, execute, deliver.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import records as R
from repro.core.channel import tweets_about_drugs
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags
from repro.data.synthetic import drug_tweak, tweet_batch


def main():
    rng = np.random.default_rng(0)
    eng = BADEngine(dataset_capacity=1 << 14, index_capacity=1 << 13,
                    max_window=1 << 13, max_candidates=1 << 10,
                    brokers=("BrokerA", "BrokerB"))

    # Developer: CREATE CONTINUOUS PUSH CHANNEL TweetsAboutDrugs(MyState)
    eng.create_channel(tweets_about_drugs())

    # Subscribers: SUBSCRIBE TO TweetsAboutDrugs("CA") ON BrokerA; ...
    for state, broker in [(4, "BrokerA"), (4, "BrokerA"), (4, "BrokerB"),
                          (27, "BrokerA")]:
        sid = eng.subscribe("TweetsAboutDrugs", state, broker)
        print(f"subscribed sid={sid} state={state} via {broker}")

    # Data feed: one period of tweets (fixed predicates are evaluated at
    # ingestion; matching PKs land in the channel's BAD index).
    batch = tweet_batch(rng, 4096, t0=1)
    fields = drug_tweak(np.asarray(batch.fields).copy(), rng, 0.05)
    eng.ingest(R.RecordBatch.from_numpy(fields, np.asarray(batch.location)))

    # Channel execution under the fully optimized plan.
    rep = eng.execute_channel("TweetsAboutDrugs",
                              ExecutionFlags.fully_optimized())
    print(f"\nresults (group records): {rep.num_results}")
    print(f"subscribers notified:    {rep.num_notified}")
    print(f"records scanned:         {rep.scanned} (BAD index window)")
    print(f"bytes to brokers:        {rep.broker_bytes.tolist()}")

    # Compare against the original (pre-optimization) plan.
    eng2 = BADEngine(dataset_capacity=1 << 14, index_capacity=1 << 13,
                     max_window=1 << 13, max_candidates=1 << 10,
                     brokers=("BrokerA", "BrokerB"))
    eng2.create_channel(tweets_about_drugs())
    for state, broker in [(4, "BrokerA"), (4, "BrokerA"), (4, "BrokerB"),
                          (27, "BrokerA")]:
        eng2.subscribe("TweetsAboutDrugs", state, broker)
    eng2.ingest(R.RecordBatch.from_numpy(fields, np.asarray(batch.location)))
    rep0 = eng2.execute_channel("TweetsAboutDrugs", ExecutionFlags.original())
    print(f"\noriginal plan: scanned={rep0.scanned} results={rep0.num_results} "
          f"(same {rep0.num_notified} notified)")


if __name__ == "__main__":
    main()
