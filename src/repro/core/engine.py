"""BADEngine: the host-side orchestrator tying the data plane together.

Responsibilities (paper Fig. 1): data feed ingestion -> ActiveDataset append +
conditionsList evaluation + BAD-index maintenance; channel execution under a
chosen ``ExecutionFlags`` plan; broker accounting; subscription control plane
(Algorithm 1 grouping + UserParameters upkeep).

The engine is deliberately a thin host shell: every per-record code path is a
jitted pure function over fixed-shape arrays.

``use_pallas=True`` routes every predicate / spatial evaluation through the
Pallas kernels (``predicate_filter`` at ingestion AND inside the fused
executor's candidate discovery; ``spatial_match`` in both spatial join
paths); the default jnp oracle is the parity reference, and the two are
result-identical by construction (asserted by the parity suite).

Broker delivery (``deliver=True`` on ``execute_channel`` / ``execute_all``)
runs the broker's convert+send stages and surfaces per-stage accounting in
``ExecutionReport.overflow`` (a ``DeliveryStats``). On ``execute_all`` the
delivery is FUSED: ``broker.deliver_all`` runs inside the same jitted call as
candidate discovery and the joins, so a multi-channel tick never leaves the
device between discovery and subscriber fanout. No notification is silently
lost: pairs/sIDs that miss a delivery buffer are captured — with their
channel identity — into the bounded host-side ``SpillQueue`` and re-delivered
exactly once by ``drain_spilled()`` on subsequent ticks; only spill-buffer
exhaustion drops, and drops are counted
(delivered + spilled + dropped == produced, per stage).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bad_index as bidx
from repro.core import plans
from repro.core import records as R
from repro.core import subscriptions as subs
from repro.core.broker import (BrokerRegistry, DeliveryStats, FusedDelivery,
                               deliver_all, fanout_sids, pack_payloads)
from repro.core.channel import ChannelSpec
from repro.core.predicates import (CompiledConditions, compile_conditions,
                                   evaluate_conditions)
from repro.core.user_params import UserParameters


@dataclasses.dataclass
class ChannelState:
    spec: ChannelSpec
    index: int                      # row in the stacked conditionsList / BADIndexState
    aggregator: subs.Aggregator
    user_params: UserParameters
    last_exec_ts: int = 0
    last_exec_size: int = 0
    executions: int = 0
    # device-resident TargetArrays + host group/flat views, cached per channel
    # and explicitly invalidated whenever the subscription set changes;
    # ``version`` keys the engine's stacked multi-channel caches
    version: int = 0
    _targets_flat: Optional[plans.TargetArrays] = None
    _targets_grouped: Optional[plans.TargetArrays] = None
    _groups: Optional[subs.SubscriptionGroups] = None
    _flat: Optional[subs.SubscriptionTable] = None
    _host_targets: Dict[bool, Tuple] = dataclasses.field(default_factory=dict)

    def invalidate_targets(self) -> None:
        self.version += 1
        self._targets_flat = self._targets_grouped = None
        self._groups = self._flat = None
        self._host_targets = {}


class SpillQueue:
    """Bounded host-side capture of overflowed notifications.

    Two lanes, mirroring the broker's two delivery stages: *pairs* (result
    pairs that missed the convert-stage wire buffer, keyed by channel and
    target layout so a drain re-packs against the right table) and *sids*
    (end-subscriber ids that missed the send-stage notify buffer). Entries
    keep their channel identity; each lane is bounded by ``capacity`` —
    pushes past it are rejected (the caller counts them as dropped, so
    nothing is ever lost *silently*).

    Pair entries record the channel's subscription ``version`` at spill time:
    target indices are only meaningful against the table they were produced
    from, so a drain discards (and counts as dropped) entries whose channel
    re-subscribed in between. Raw sIDs never go stale.
    """

    def __init__(self, capacity: int = 1 << 16):
        self.capacity = capacity
        self._pairs: Dict[Tuple[str, bool], Deque] = {}
        self._sids: Dict[str, Deque] = {}
        self._n_pairs = 0
        self._n_sids = 0

    def push_pairs(self, channel: str, aggregated: bool, rows: np.ndarray,
                   targets: np.ndarray, version: int) -> int:
        """Append up to the remaining capacity; returns entries accepted."""
        n = min(len(rows), self.capacity - self._n_pairs)
        if n > 0:
            q = self._pairs.setdefault((channel, aggregated),
                                       collections.deque())
            q.append((np.asarray(rows[:n]), np.asarray(targets[:n]), version))
            self._n_pairs += n
        return max(n, 0)

    def _push_front_pairs(self, channel: str, aggregated: bool,
                          rows: np.ndarray, targets: np.ndarray,
                          version: int) -> None:
        """Requeue a just-popped tail at the FRONT (drain order preserved,
        no capacity check — the pop already released the room)."""
        if len(rows):
            q = self._pairs.setdefault((channel, aggregated),
                                       collections.deque())
            q.appendleft((np.asarray(rows), np.asarray(targets), version))
            self._n_pairs += len(rows)

    def pop_pairs(self, channel: str, aggregated: bool, n: int,
                  current_version: Optional[int]
                  ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Remove up to ``n`` entries in FIFO order. Entries whose version no
        longer matches ``current_version`` are discarded and counted in the
        returned ``stale`` (they index a table that no longer exists).
        Returns (rows, targets, stale)."""
        q = self._pairs.get((channel, aggregated))
        rows, tgts, stale, taken = [], [], 0, 0
        while q and taken < n:
            r, t, v = q.popleft()
            take = min(len(r), n - taken)
            if take < len(r):
                q.appendleft((r[take:], t[take:], v))
            self._n_pairs -= take
            if v != current_version:
                stale += take
            else:
                rows.append(r[:take])
                tgts.append(t[:take])
            taken += take
        if q is not None and not q:
            del self._pairs[(channel, aggregated)]
        cat = lambda xs: (np.concatenate(xs) if xs
                          else np.zeros((0,), np.int32))
        return cat(rows), cat(tgts), stale

    def push_sids(self, channel: str, sids: np.ndarray) -> int:
        n = min(len(sids), self.capacity - self._n_sids)
        if n > 0:
            self._sids.setdefault(channel, collections.deque()).append(
                np.asarray(sids[:n]))
            self._n_sids += n
        return max(n, 0)

    def _push_front_sids(self, channel: str, sids: np.ndarray) -> None:
        if len(sids):
            self._sids.setdefault(channel, collections.deque()).appendleft(
                np.asarray(sids))
            self._n_sids += len(sids)

    def pop_sids(self, channel: str, n: int) -> np.ndarray:
        q = self._sids.get(channel)
        out, taken = [], 0
        while q and taken < n:
            s = q.popleft()
            take = min(len(s), n - taken)
            if take < len(s):
                q.appendleft(s[take:])
            self._n_sids -= take
            out.append(s[:take])
            taken += take
        if q is not None and not q:
            del self._sids[channel]
        return np.concatenate(out) if out else np.zeros((0,), np.int32)

    def pair_keys(self) -> List[Tuple[str, bool]]:
        return list(self._pairs.keys())

    def sid_keys(self) -> List[str]:
        return list(self._sids.keys())

    def pending_pairs(self, channel: Optional[str] = None) -> int:
        if channel is None:
            return self._n_pairs
        return sum(sum(len(r) for r, _, _ in q)
                   for (name, _), q in self._pairs.items() if name == channel)

    def pending_sids(self, channel: Optional[str] = None) -> int:
        if channel is None:
            return self._n_sids
        return sum(len(s) for s in self._sids.get(channel, ()))

    def clear(self) -> None:
        self._pairs.clear()
        self._sids.clear()
        self._n_pairs = self._n_sids = 0


@dataclasses.dataclass
class DrainReport:
    """One channel's ``drain_spilled`` round: ``stats`` accounts the retry
    (delivered = re-delivered this round, spilled = still queued, dropped =
    stale/unroutable); ``payload`` / ``notify`` are the re-packed wire buffer
    and re-sent sID buffer (delivered prefix meaningful)."""

    stats: DeliveryStats
    payload: Optional[np.ndarray] = None
    notify: Optional[np.ndarray] = None


@dataclasses.dataclass
class ExecutionReport:
    channel: str
    flags: plans.ExecutionFlags
    result: plans.ChannelResult
    wall_time_s: float
    num_results: int
    num_notified: int
    scanned: int
    broker_bytes: np.ndarray
    # broker overflow accounting; None unless executed with ``deliver=True``
    overflow: Optional[DeliveryStats] = None


class BADEngine:
    def __init__(self,
                 dataset_capacity: int = 1 << 18,
                 index_capacity: int = 1 << 15,
                 max_window: int = 1 << 15,
                 max_candidates: int = 1 << 13,
                 frame_bytes: int = 40 * 1024,
                 schema: R.Schema = R.ENRICHED_TWEET_SCHEMA,
                 brokers: Tuple[str, ...] = ("BrokerA",),
                 use_pallas: bool = False,
                 group_cap: Optional[int] = None,
                 max_deliver_pairs: int = 1 << 12,
                 max_notify: int = 1 << 14,
                 deliver_payload_words: int = 8,
                 max_spill: int = 1 << 13,
                 spill_capacity: int = 1 << 16):
        self.schema = schema
        self.dataset = R.ActiveDataset.create(dataset_capacity, schema)
        self.index_capacity = index_capacity
        self.max_window = max_window
        self.max_candidates = max_candidates
        self.frame_bytes = frame_bytes
        self.group_cap = group_cap or subs.cap_from_frame_bytes(frame_bytes)
        self.brokers = BrokerRegistry.create(*brokers)
        self.channels: Dict[str, ChannelState] = {}
        self.use_pallas = use_pallas
        self.max_deliver_pairs = max_deliver_pairs
        self.max_notify = max_notify
        self.deliver_payload_words = deliver_payload_words
        # device-side spill capture buffer per delivery call (flat across the
        # call's channels) and the host-side bounded retry queue
        self.max_spill = max_spill
        self.spill = SpillQueue(spill_capacity)
        self._deliver_jit: Optional[Callable] = None
        self.user_locations = jnp.zeros((1, 2), dtype=jnp.float32)
        self.user_brokers = jnp.zeros((1,), dtype=jnp.int32)
        # keys the stacked-user-set cache; bumped by set_user_locations
        self._user_version = 0
        self.now = 0
        self._conds: Optional[CompiledConditions] = None
        self.index_state = bidx.BADIndexState.create(0, index_capacity)
        self._ingest_fn = None
        # compiled plan caches (single-channel and fused all-channel), keyed
        # on the specs/flags they close over; cleared on channel create/drop
        self._exec_cache: Dict = {}
        # stacked device targets for execute_all: one warm entry per layout
        # (aggregated / flat), each validated by its channel-version key
        self._stacked_cache: Dict = {}

    # ------------------------------------------------------------------
    # control plane
    # ------------------------------------------------------------------

    def create_channel(self, spec: ChannelSpec) -> None:
        if spec.name in self.channels:
            raise ValueError(f"channel {spec.name} exists")
        if self.dataset.size.item() > 0 and spec.fixed_preds:
            # BAD indexes only see records ingested after channel creation —
            # same semantics as the paper (continuous queries over new data).
            pass
        st = ChannelState(
            spec=spec,
            index=len(self.channels),
            aggregator=subs.Aggregator(self.group_cap),
            user_params=UserParameters.create(spec.param_domain),
            last_exec_ts=self.now,
        )
        st.last_exec_size = int(self.dataset.size)
        self.channels[spec.name] = st
        self._rebuild_conditions()

    def drop_channel(self, name: str) -> None:
        del self.channels[name]
        survivors = sorted(self.channels.values(), key=lambda s: s.index)
        old_rows = [st.index for st in survivors]
        for i, st in enumerate(survivors):
            st.index = i
        self._rebuild_conditions(old_rows)

    def subscribe(self, channel: str, param: int, broker: str = "BrokerA",
                  sid: Optional[int] = None) -> int:
        st = self.channels[channel]
        if not 0 <= param < st.user_params.domain:   # before any mutation
            raise ValueError(
                f"param {param} out of [0, {st.user_params.domain}) "
                f"for {channel}")
        bid = self.brokers.names[broker]
        sid = st.aggregator.add_subscription(param, bid, sid)
        st.user_params.add(param)
        st.invalidate_targets()
        return sid

    def subscribe_bulk(self, channel: str, params: np.ndarray,
                       brokers: np.ndarray) -> np.ndarray:
        """Bulk control-plane load through the vectorized ``aggregate`` path:
        Algorithm-1 grouping semantics with no per-subscription Python work.
        Returns the assigned sIDs."""
        st = self.channels[channel]
        params = np.asarray(params, dtype=np.int32).ravel()
        brokers = np.asarray(brokers, dtype=np.int32).ravel()
        # validate BEFORE mutating: a bad param/broker must not leave the
        # aggregator holding subscriptions whose refcounts were never
        # registered (or whose broker id aliases the invalid-pair sentinel)
        if params.size and (int(params.min()) < 0
                            or int(params.max()) >= st.user_params.domain):
            raise ValueError(
                f"params out of [0, {st.user_params.domain}) for {channel}")
        nb = self.brokers.num_brokers
        if brokers.size and (int(brokers.min()) < 0 or int(brokers.max()) >= nb):
            raise ValueError(f"broker ids out of [0, {nb}) for {channel}")
        sids = st.aggregator.add_bulk(params, brokers)
        st.user_params.add_bulk(params)
        st.invalidate_targets()
        return sids

    def unsubscribe(self, channel: str, param: int, broker: str, sid: int) -> bool:
        st = self.channels[channel]
        ok = st.aggregator.remove_subscription(param, self.brokers.names[broker], sid)
        if ok:
            st.user_params.remove(param)
            st.invalidate_targets()
        return ok

    def set_user_locations(self, locations: np.ndarray,
                           brokers: Optional[np.ndarray] = None) -> None:
        self.user_locations = jnp.asarray(locations, dtype=jnp.float32)
        if brokers is None:
            brokers = np.zeros((locations.shape[0],), dtype=np.int32)
        self.user_brokers = jnp.asarray(brokers, dtype=jnp.int32)
        self._user_version += 1  # invalidate stacked user targets

    # ------------------------------------------------------------------
    # data plane: ingestion
    # ------------------------------------------------------------------

    def _rebuild_conditions(self, old_rows: Optional[List[int]] = None) -> None:
        """Recompile the conditionsList and re-shape the BAD index.

        ``old_rows[i]`` is the *previous* index row of the channel now at row
        ``i`` — surviving channels keep their own buffers/watermarks by
        identity, not by position (dropping a middle channel must not hand its
        rows to the next one).
        """
        specs = sorted(self.channels.values(), key=lambda s: s.index)
        self._conds = compile_conditions([list(s.spec.fixed_preds) for s in specs])
        old = self.index_state
        new = bidx.BADIndexState.create(len(specs), self.index_capacity)
        if old_rows is None:  # channel append: surviving rows keep positions
            old_rows = list(range(min(old.num_channels, new.num_channels)))
        assert all(0 <= r < old.num_channels for r in old_rows)
        if old_rows:
            src = jnp.asarray(old_rows, jnp.int32)
            n = len(old_rows)
            new = bidx.BADIndexState(
                new.row_ids.at[:n].set(old.row_ids[src]),
                new.counts.at[:n].set(old.counts[src]),
                new.watermarks.at[:n].set(old.watermarks[src]),
                new.overflowed.at[:n].set(old.overflowed[src]),
            )
        self.index_state = new
        self._ingest_fn = None  # shapes changed; re-trace
        self._exec_cache.clear()  # compiled plans bind conds + channel rows
        # stacked targets are keyed by (name, version); a same-named channel
        # re-created at version 0 would collide, so drop them here too
        self._stacked_cache.clear()

    def _build_ingest(self):
        conds = self._conds
        use_pallas = self.use_pallas

        @jax.jit
        def ingest_step(ds, index_state, batch):
            ds, row_ids = _append(ds, batch)
            if use_pallas:
                from repro.kernels.predicate_filter import ops as pf_ops
                matches = pf_ops.predicate_filter(batch.fields, conds)
            else:
                matches = evaluate_conditions(batch.fields, conds)
            index_state = _insert(index_state, row_ids, matches)
            return ds, index_state, row_ids

        return ingest_step

    def ingest(self, batch: R.RecordBatch) -> np.ndarray:
        """Data feed entry point: append + BAD-index maintenance (Algorithm 2)."""
        if self._ingest_fn is None:
            self._ingest_fn = self._build_ingest()
        self.dataset, self.index_state, row_ids = self._ingest_fn(
            self.dataset, self.index_state, batch)
        ts = batch.fields[:, R.TIMESTAMP]
        self.now = max(self.now, int(jnp.max(ts))) if batch.num_records else self.now
        return np.asarray(row_ids)

    # ------------------------------------------------------------------
    # data plane: channel execution
    # ------------------------------------------------------------------

    def _targets_host(self, st: ChannelState, aggregated: bool) -> Tuple:
        """Host-side (numpy) join targets: (params, brokers, counts, by_param,
        by_param_count). Shared by the per-channel and stacked device caches."""
        cached = st._host_targets.get(aggregated)
        if cached is not None:
            return cached
        if aggregated:
            groups = st._groups or st.aggregator.build()
            st._groups = groups
            params = np.asarray(groups.group_params, np.int32)
            brokers = np.asarray(groups.group_brokers, np.int32)
            counts = np.asarray(groups.group_counts, np.int32)
        else:
            flat = self._flat_table(st)
            params = np.asarray(flat.params, np.int32)
            brokers = np.asarray(flat.brokers, np.int32)
            counts = np.ones_like(params)
        by_param, by_count = subs.param_to_targets(params, st.spec.param_domain)
        out = (params, brokers, counts, by_param, by_count)
        st._host_targets[aggregated] = out
        return out

    def _targets(self, st: ChannelState, aggregated: bool) -> plans.TargetArrays:
        cached = st._targets_grouped if aggregated else st._targets_flat
        if cached is None:
            p, b, c, bp, bc = self._targets_host(st, aggregated)
            cached = plans.TargetArrays(jnp.asarray(p), jnp.asarray(b),
                                        jnp.asarray(c), jnp.asarray(bp),
                                        jnp.asarray(bc))
            if aggregated:
                st._targets_grouped = cached
            else:
                st._targets_flat = cached
        return cached

    def _flat_table(self, st: ChannelState) -> subs.SubscriptionTable:
        if st._flat is None:
            groups = st._groups or st.aggregator.build()
            st._groups = groups
            st._flat = subs.flatten_groups(groups)
        return st._flat

    def group_sids_array(self, channel: str, aggregated: bool) -> jnp.ndarray:
        st = self.channels[channel]
        if aggregated:
            groups = st._groups or st.aggregator.build()
            st._groups = groups
            return jnp.asarray(groups.group_sids)
        flat = self._flat_table(st)
        return jnp.asarray(flat.sids)[:, None]

    def _exec_fn(self, channel: str, flags: plans.ExecutionFlags,
                 spatial: bool, max_cand: Optional[int] = None) -> Callable:
        """Compiled single-channel plan, cached by everything it closes over:
        the (frozen) spec, flags, and the channel's index row. Keying on the
        spec — not the name — means re-creating a same-named channel with new
        predicates can never be served a stale plan; the cache itself lives on
        the engine and is cleared on channel create/drop."""
        st = self.channels[channel]
        key = (st.spec, flags, spatial, max_cand, st.index)
        cached = self._exec_cache.get(key)
        if cached is not None:
            return cached
        spec = st.spec
        conds_one = compile_conditions([list(spec.fixed_preds)])
        best_pred = int(np.argmax([_pred_rank(p) for p in spec.fixed_preds])) \
            if spec.fixed_preds else 0
        max_window = self.max_window
        max_cand = max_cand or self.max_candidates
        num_brokers = self.brokers.num_brokers
        use_pallas = self.use_pallas
        ch_idx = st.index

        def run(ds, index_state, targets, up_mask, last_ts, last_size,
                user_locations, user_brokers):
            if flags.scan_mode == "full":
                cand = plans.candidates_full_scan(ds, conds_one, last_ts, max_cand)
            elif flags.scan_mode == "window":
                cand = plans.candidates_window(ds, conds_one, last_size, max_window)
            elif flags.scan_mode == "trad_index":
                cand = plans.candidates_trad_index(ds, conds_one, best_pred,
                                                   last_size, max_window, max_cand)
            else:
                cand = plans.candidates_bad_index(ds, index_state, ch_idx, max_cand)
            if spatial:
                spatial_fn = None
                if use_pallas:
                    from repro.kernels.spatial_match import ops as sm_ops
                    spatial_fn = sm_ops.spatial_match
                return plans.join_spatial(ds, cand, user_locations, user_brokers,
                                          spec.spatial_radius, spec.payload_bytes,
                                          num_brokers, spatial_fn)
            return plans.join_param_targets(
                ds, cand, targets, spec.param_field, spec.payload_bytes,
                num_brokers, up_mask if flags.param_pushdown else None,
                flags.aggregation)

        fn = jax.jit(run)
        self._cache_put(key, fn)
        return fn

    def _cache_put(self, key, fn: Callable, cap: int = 256) -> None:
        """Insert into the plan cache with FIFO eviction — superseded shape
        buckets / flag combos must not pin dead XLA executables forever."""
        if len(self._exec_cache) >= cap:
            self._exec_cache.pop(next(iter(self._exec_cache)))
        self._exec_cache[key] = fn

    def _delivery_fn(self) -> Callable:
        """The per-channel reference delivery: the SAME fused kernels as
        ``execute_all(deliver=True)`` run on a C==1 stack, so the two paths
        are stats-identical by construction."""
        if self._deliver_jit is None:
            pw, mp = self.deliver_payload_words, self.max_deliver_pairs
            mn, sc = self.max_notify, self.max_spill
            nb = self.brokers.num_brokers
            self._deliver_jit = jax.jit(
                lambda res, sids, tb: deliver_all(
                    res, sids, pw, mp, mn, sc,
                    target_brokers=tb, num_brokers=nb))
        return self._deliver_jit

    def _deliver(self, st: ChannelState, result: plans.ChannelResult,
                 aggregated: bool) -> DeliveryStats:
        """Run the broker convert+send stages on one channel's result,
        capture overflow into the spill queue, and account every pair/sID
        (delivered + spilled + dropped == produced, per stage)."""
        res1 = jax.tree.map(lambda a: a[None], result)
        if st.spec.join == "spatial":
            # spatial targets ARE end-user ids; a 0-wide table selects the
            # brokers' identity fanout (they read targets directly and never
            # index the table's values)
            sids = jnp.zeros((1, 0), dtype=jnp.int32)
            tb = self.user_brokers[None]
        else:
            sids = self.group_sids_array(st.spec.name, aggregated)[None]
            tb = self._targets(st, aggregated).brokers[None]
        d = self._delivery_fn()(res1, sids, tb)
        return self._spill_and_stats([st], aggregated, d)[st.spec.name]

    def _spill_and_stats(self, chs: List[ChannelState], aggregated: bool,
                         d: FusedDelivery) -> Dict[str, DeliveryStats]:
        """Host side of a delivery: push the captured flat spill streams into
        the SpillQueue per channel (entries past the queue's capacity — or
        past the device capture buffer — become counted drops) and assemble
        each channel's conserving DeliveryStats."""
        pack_d = np.asarray(d.pack.delivered)
        pack_p = np.asarray(d.pack.produced)
        fan_d = np.asarray(d.fan.delivered)
        fan_p = np.asarray(d.fan.produced)
        per_broker = np.asarray(d.pack.per_broker)
        pvalid = np.asarray(d.pair_spill.valid)
        prows = np.asarray(d.pair_spill.rows)[pvalid]
        pchan = np.asarray(d.pair_spill.channels)[pvalid]
        ptgts = np.asarray(d.pair_spill.targets)[pvalid]
        svalid = np.asarray(d.sid_spill.valid)
        svals = np.asarray(d.sid_spill.values)[svalid]
        schan = np.asarray(d.sid_spill.channels)[svalid]
        out: Dict[str, DeliveryStats] = {}
        for i, st in enumerate(chs):
            name = st.spec.name
            sel = pchan == i
            spilled_p = self.spill.push_pairs(name, aggregated, prows[sel],
                                              ptgts[sel], st.version)
            sel = schan == i
            spilled_s = self.spill.push_sids(name, svals[sel])
            ov_p = int(pack_p[i] - pack_d[i])
            ov_s = int(fan_p[i] - fan_d[i])
            out[name] = DeliveryStats(
                delivered_pairs=int(pack_d[i]), spilled_pairs=spilled_p,
                dropped_pairs=ov_p - spilled_p,
                delivered_sids=int(fan_d[i]), spilled_sids=spilled_s,
                dropped_sids=ov_s - spilled_s,
                delivered_pairs_broker=tuple(int(x) for x in per_broker[i]))
        return out

    def execute_channel(self, channel: str,
                        flags: plans.ExecutionFlags,
                        advance: bool = True,
                        timed: bool = True,
                        deliver: bool = False) -> ExecutionReport:
        st = self.channels[channel]
        spatial = st.spec.join == "spatial"
        # The BAD index knows its exact candidate count before execution (the
        # watermark delta) — unlike scans/traditional indexes — so downstream
        # buffers are shape-bucketed to the real volume ("early result
        # filtering" paying off structurally, not just in rows scanned).
        max_cand = None
        if flags.scan_mode == "bad_index":
            pending = int(self.index_state.counts[st.index]
                          - self.index_state.watermarks[st.index])
            bucket = _pow2_bucket(pending, 6)
            max_cand = min(bucket, self.max_candidates)
        fn = self._exec_fn(channel, flags, spatial, max_cand)
        targets = self._targets(st, flags.aggregation)
        up_mask = st.user_params.mask()
        args = (self.dataset, self.index_state, targets, up_mask,
                jnp.asarray(st.last_exec_ts, jnp.int32),
                jnp.asarray(st.last_exec_size, jnp.int32),
                self.user_locations, self.user_brokers)
        if timed:  # warm the trace so wall time measures execution, not tracing
            jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        result = fn(*args)
        jax.block_until_ready(result.num_results)
        wall = time.perf_counter() - t0
        if advance:
            self.index_state = bidx.advance_watermark(self.index_state, st.index)
            st.last_exec_ts = self.now
            st.last_exec_size = int(self.dataset.size)
            st.executions += 1
        overflow = self._deliver(st, result, flags.aggregation) if deliver else None
        return ExecutionReport(
            channel=channel, flags=flags, result=result, wall_time_s=wall,
            num_results=int(result.num_results),
            num_notified=int(result.num_notified),
            scanned=int(result.scanned),
            broker_bytes=np.asarray(result.broker_bytes),
            overflow=overflow)

    # ------------------------------------------------------------------
    # data plane: fused multi-channel execution
    # ------------------------------------------------------------------

    def _stacked_inputs(self, chs: List[ChannelState], aggregated: bool):
        """Device-resident shape-bucketed targets for all param channels.

        Per-channel targets are padded to shared power-of-two buckets (max
        target count / join fan-out across channels, real max domain) so the
        fused trace survives subscription growth; -1 / 0 padding can never
        form a valid pair. Cached until any channel's subscription version
        moves.
        """
        key = tuple((st.spec.name, st.version) for st in chs)
        hit = self._stacked_cache.get(aggregated)
        if hit is not None and hit[0] == key:
            return hit[1]
        hosts = [self._targets_host(st, aggregated) for st in chs]
        n = len(chs)
        tmax = _pow2_bucket(max(h[0].shape[0] for h in hosts), 3)
        dmax = max(st.spec.param_domain for st in chs)
        mmax = _pow2_bucket(max(h[3].shape[1] for h in hosts), 3)
        params = np.zeros((n, tmax), np.int32)
        brokers = np.zeros((n, tmax), np.int32)
        counts = np.zeros((n, tmax), np.int32)
        by_param = np.full((n, dmax, mmax), -1, np.int32)
        by_count = np.zeros((n, dmax), np.int32)
        up_masks = np.zeros((n, dmax), bool)
        domains = np.zeros((n,), np.int32)
        for i, (st, (p, b, c, bp, bc)) in enumerate(zip(chs, hosts)):
            t, (d, m) = p.shape[0], bp.shape
            params[i, :t] = p
            brokers[i, :t] = b
            counts[i, :t] = c
            by_param[i, :d, :m] = bp
            by_count[i, :d] = bc
            up_masks[i, :d] = st.user_params.refcount > 0
            domains[i] = st.spec.param_domain
        targets = plans.TargetArrays(
            jnp.asarray(params), jnp.asarray(brokers), jnp.asarray(counts),
            jnp.asarray(by_param), jnp.asarray(by_count))
        val = (targets, jnp.asarray(up_masks), jnp.asarray(domains))
        self._stacked_cache[aggregated] = (key, val)
        return val

    def _stacked_spatial_inputs(self, chs: List[ChannelState]):
        """Stacked per-channel user sets for the fused spatial join.

        The user count is shape-bucketed (power of two) so the fused trace
        survives user-set growth; padded users sit at the far sentinel and can
        never fall inside any radius. There is one global UserLocations
        dataset today, so every channel row carries the same users — the
        stacked layout keeps the plan ready for per-channel user cohorts.
        Cached until ``set_user_locations`` (version bump) or channel
        create/drop (cache clear)."""
        from repro.kernels.spatial_match.ops import FAR
        key = (tuple(st.spec.name for st in chs), self._user_version)
        hit = self._stacked_cache.get("spatial")
        if hit is not None and hit[0] == key:
            return hit[1]
        u = self.user_locations.shape[0]
        ub = _pow2_bucket(u, 3)
        n = len(chs)
        locs = np.full((n, ub, 2), -FAR, np.float32)
        brokers = np.zeros((n, ub), np.int32)
        locs[:, :u] = np.asarray(self.user_locations)[None]
        brokers[:, :u] = np.asarray(self.user_brokers)[None]
        val = (jnp.asarray(locs), jnp.asarray(brokers))
        self._stacked_cache["spatial"] = (key, val)
        return val

    def _stacked_sids(self, chs: List[ChannelState],
                      aggregated: bool) -> jnp.ndarray:
        """Stacked device group-sID tables (C, Tmax, cap) for fused delivery,
        -1 padded, shape-bucketed alongside ``_stacked_inputs`` and cached by
        the same channel-version key."""
        key = tuple((st.spec.name, st.version) for st in chs)
        hit = self._stacked_cache.get(("sids", aggregated))
        if hit is not None and hit[0] == key:
            return hit[1]
        hosts = []
        for st in chs:
            if aggregated:
                groups = st._groups or st.aggregator.build()
                st._groups = groups
                hosts.append(np.asarray(groups.group_sids, np.int32))
            else:
                hosts.append(np.asarray(self._flat_table(st).sids,
                                        np.int32)[:, None])
        n = len(chs)
        tmax = _pow2_bucket(max(h.shape[0] for h in hosts), 3)
        cap = max(h.shape[1] for h in hosts)
        sids = np.full((n, tmax, cap), -1, np.int32)
        for i, h in enumerate(hosts):
            sids[i, :h.shape[0], :h.shape[1]] = h
        val = jnp.asarray(sids)
        self._stacked_cache[("sids", aggregated)] = (key, val)
        return val

    def _exec_all_fn(self, param_chs: List[ChannelState],
                     spatial_chs: List[ChannelState],
                     flags: plans.ExecutionFlags, max_cand: int,
                     deliver: bool = False) -> Callable:
        """ONE compiled plan for every channel: stacked candidate discovery
        per join group (param / spatial), vmapped joins, fused broker
        accounting. With ``use_pallas`` the discovery runs the Pallas
        ``predicate_filter`` kernel and the spatial join the Pallas
        ``spatial_match`` kernel (both batched over the channel axis). With
        ``deliver`` the broker convert+send stages (``deliver_all``) run in
        the SAME call — no host round-trip between discovery and fanout."""
        key = ("all", flags, max_cand, deliver,
               tuple((st.spec, st.index) for st in param_chs),
               tuple((st.spec, st.index) for st in spatial_chs))
        cached = self._exec_cache.get(key)
        if cached is not None:
            return cached
        conds = self._conds
        max_window = self.max_window
        num_brokers = self.brokers.num_brokers
        scan_mode = flags.scan_mode
        pushdown = flags.param_pushdown
        aggregated = flags.aggregation
        use_pallas = self.use_pallas
        if use_pallas:
            from repro.kernels.predicate_filter import ops as pf_ops
            from repro.kernels.spatial_match import ops as sm_ops
            spatial_fn = sm_ops.spatial_match
        else:
            spatial_fn = None

        def group_statics(chs):
            rows = [st.index for st in chs]
            conds_sub = CompiledConditions(
                conds.field_idx[rows], conds.op[rows],
                conds.value[rows], conds.npreds[rows])
            best = jnp.asarray(
                [int(np.argmax([_pred_rank(p) for p in st.spec.fixed_preds]))
                 if st.spec.fixed_preds else 0 for st in chs], jnp.int32)
            match_fn = match_rows_fn = None
            if use_pallas:
                match_fn = lambda f, cs=conds_sub: pf_ops.predicate_filter(f, cs)
                match_rows_fn = (
                    lambda f, cs=conds_sub: pf_ops.predicate_filter_rows(f, cs))
            return (conds_sub, best, jnp.asarray(rows, jnp.int32),
                    match_fn, match_rows_fn)

        p_static = group_statics(param_chs) if param_chs else None
        s_static = group_statics(spatial_chs) if spatial_chs else None
        radii = jnp.asarray([st.spec.spatial_radius for st in spatial_chs],
                            jnp.float32)

        def discover(ds, index_state, static, last_ts, last_size):
            conds_sub, best, ch_rows, match_fn, match_rows_fn = static
            if scan_mode == "full":
                return plans.candidates_full_scan_all(ds, conds_sub, last_ts,
                                                      max_cand, match_fn)
            if scan_mode == "window":
                return plans.candidates_window_all(ds, conds_sub, last_size,
                                                   max_window, match_rows_fn)
            if scan_mode == "trad_index":
                return plans.candidates_trad_index_all(
                    ds, conds_sub, best, last_size, max_window, max_cand,
                    match_rows_fn)
            return plans.candidates_bad_index_all(index_state, ch_rows,
                                                  max_cand)

        pw, mp = self.deliver_payload_words, self.max_deliver_pairs
        mn, sc = self.max_notify, self.max_spill

        def run(ds, index_state, p_in, s_in):
            res_p = res_s = del_p = del_s = None
            if p_static is not None:
                cand = discover(ds, index_state, p_static,
                                p_in["last_ts"], p_in["last_size"])
                res_p = plans.join_param_targets_all(
                    ds, cand, p_in["targets"], p_in["param_field"],
                    p_in["payload"], num_brokers,
                    p_in["up_masks"] if pushdown else None, aggregated,
                    p_in["domains"])
                if deliver:
                    del_p = deliver_all(
                        res_p, p_in["sids"], pw, mp, mn, sc,
                        target_brokers=p_in["targets"].brokers,
                        num_brokers=num_brokers)
            if s_static is not None:
                cand = discover(ds, index_state, s_static,
                                s_in["last_ts"], s_in["last_size"])
                res_s = plans.join_spatial_all(
                    ds, cand, s_in["locs"], s_in["brokers"], radii,
                    s_in["payload"], num_brokers, spatial_fn)
                if deliver:
                    del_s = deliver_all(
                        res_s, s_in["sids"], pw, mp, mn, sc,
                        target_brokers=s_in["brokers"],
                        num_brokers=num_brokers)
            return res_p, res_s, del_p, del_s

        fn = jax.jit(run)
        self._cache_put(key, fn)
        return fn

    def execute_all(self, flags: plans.ExecutionFlags, advance: bool = True,
                    timed: bool = True,
                    deliver: bool = False) -> Dict[str, ExecutionReport]:
        """Execute EVERY channel — param-join AND spatial — in one jitted
        call: stacked candidate discovery per join group, vmapped param join,
        vmapped spatial join (per-channel radii over the stacked user sets),
        fused broker accounting. No per-channel host round-trips remain on
        the hot path.

        Result-for-result equivalent to looping ``execute_channel`` — each
        channel's report carries its own counts/bytes; ``wall_time_s`` is the
        fused wall time amortized per channel. ``deliver=True`` runs the
        broker convert+send stages (``broker.deliver_all``) INSIDE the same
        jitted call — stacked wire packing, stacked sID fanout, one-hot
        per-broker accounting, flat spill capture — and surfaces per-channel
        ``DeliveryStats`` in ``report.overflow``, stats-identical to the
        per-channel ``_deliver`` path.
        """
        ordered = sorted(self.channels.values(), key=lambda s: s.index)
        reports: Dict[str, ExecutionReport] = {}
        if not ordered:
            return reports
        param_chs = [st for st in ordered if st.spec.join == "param"]
        spatial_chs = [st for st in ordered if st.spec.join == "spatial"]
        max_cand = self.max_candidates
        if flags.scan_mode == "bad_index":
            # shared shape bucket: the largest per-channel watermark delta
            # (two bulk host reads, not 2 device reads per channel)
            counts = np.asarray(self.index_state.counts)
            wms = np.asarray(self.index_state.watermarks)
            pending = max(int(counts[st.index] - wms[st.index])
                          for st in ordered)
            bucket = _pow2_bucket(pending, 6)
            max_cand = min(bucket, self.max_candidates)
        fn = self._exec_all_fn(param_chs, spatial_chs, flags, max_cand,
                               deliver)
        p_in = s_in = None
        if param_chs:
            targets, up_masks, domains = self._stacked_inputs(
                param_chs, flags.aggregation)
            p_in = dict(
                targets=targets, up_masks=up_masks, domains=domains,
                param_field=jnp.asarray(
                    [st.spec.param_field for st in param_chs], jnp.int32),
                payload=jnp.asarray(
                    [st.spec.payload_bytes for st in param_chs], jnp.int32),
                last_ts=jnp.asarray(
                    [st.last_exec_ts for st in param_chs], jnp.int32),
                last_size=jnp.asarray(
                    [st.last_exec_size for st in param_chs], jnp.int32))
            if deliver:
                p_in["sids"] = self._stacked_sids(param_chs, flags.aggregation)
        if spatial_chs:
            locs, ubrokers = self._stacked_spatial_inputs(spatial_chs)
            s_in = dict(
                locs=locs, brokers=ubrokers,
                payload=jnp.asarray(
                    [st.spec.payload_bytes for st in spatial_chs], jnp.int32),
                last_ts=jnp.asarray(
                    [st.last_exec_ts for st in spatial_chs], jnp.int32),
                last_size=jnp.asarray(
                    [st.last_exec_size for st in spatial_chs], jnp.int32))
            if deliver:
                s_in["sids"] = jnp.zeros((len(spatial_chs), 0), jnp.int32)
        args = (self.dataset, self.index_state, p_in, s_in)
        if timed:  # warm the trace so wall time measures execution
            jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        res_p, res_s, del_p, del_s = fn(*args)
        jax.block_until_ready((res_p, res_s, del_p, del_s))
        wall = time.perf_counter() - t0
        if advance:
            self.index_state = bidx.advance_watermarks(
                self.index_state,
                jnp.asarray([st.index for st in ordered], jnp.int32))
            for st in ordered:
                st.last_exec_ts = self.now
                st.last_exec_size = int(self.dataset.size)
                st.executions += 1
        # One bulk device->host transfer per join group, then per-channel
        # numpy views: the per-channel path's int()/slice pattern would cost
        # dozens of device round-trips here. Delivery stats arrive the same
        # way: the fused call already packed/fanned out every channel, so the
        # host only pushes spills and reads (C,)-shaped counters.
        share = wall / len(ordered)
        for chs, res, dlv in ((param_chs, res_p, del_p),
                              (spatial_chs, res_s, del_s)):
            if not chs:
                continue
            host = jax.tree.map(np.asarray, res)
            stats = (self._spill_and_stats(chs, flags.aggregation, dlv)
                     if deliver else {})
            for i, st in enumerate(chs):
                reports[st.spec.name] = ExecutionReport(
                    channel=st.spec.name, flags=flags,
                    result=jax.tree.map(lambda a, i=i: a[i], host),
                    wall_time_s=share,
                    num_results=int(host.num_results[i]),
                    num_notified=int(host.num_notified[i]),
                    scanned=int(host.scanned[i]),
                    broker_bytes=host.broker_bytes[i],
                    overflow=stats.get(st.spec.name))
        return reports

    # ------------------------------------------------------------------
    # spill retry
    # ------------------------------------------------------------------

    def _synthetic_result(self, rows: np.ndarray,
                          tgts: np.ndarray) -> plans.ChannelResult:
        """A shape-bucketed ChannelResult holding exactly the given (row,
        target) pairs — the drain path's re-entry into the broker kernels."""
        n = len(rows)
        bucket = _pow2_bucket(n, 6)
        r = np.full((bucket,), -1, np.int32)
        t = np.full((bucket,), -1, np.int32)
        r[:n], t[:n] = rows, tgts
        valid = np.arange(bucket) < n
        z = jnp.zeros((), jnp.int32)
        nb = self.brokers.num_brokers
        return plans.ChannelResult(
            jnp.asarray(r)[:, None], jnp.asarray(t)[:, None],
            jnp.asarray(valid)[:, None], jnp.asarray(r), jnp.asarray(valid),
            z, z, z, jnp.zeros((nb,), jnp.float32), jnp.zeros((nb,), jnp.int32))

    def drain_spilled(self) -> Dict[str, DrainReport]:
        """Re-deliver spilled notifications, exactly once per stage.

        Pairs lane: pop up to ``max_deliver_pairs`` for ONE (channel, layout)
        lane per channel per round (layouts re-pack against different tables
        with different wire widths, so a round's ``DrainReport.payload`` is
        always one coherent buffer; a channel spilled under both layouts
        drains the other lane next round) and re-run the convert stage
        against the channel's CURRENT table of that layout; entries whose
        channel version moved (or whose channel was dropped) are unroutable
        and counted as dropped. Sids lane: pop up to ``max_notify`` per
        channel and re-run the send stage (raw sIDs never go stale).
        Anything that misses this round's buffers is requeued at the front —
        never duplicated, never lost. Call once per tick until
        ``spill.pending_pairs() + spill.pending_sids() == 0``.
        """
        out: Dict[str, DrainReport] = {}

        def merge(name: str, rep: DrainReport) -> None:
            prev = out.get(name)
            if prev is None:
                out[name] = rep
            else:
                out[name] = DrainReport(
                    prev.stats.merged(rep.stats),
                    rep.payload if prev.payload is None else prev.payload,
                    rep.notify if prev.notify is None else prev.notify)

        drained_pairs = set()
        for name, aggregated in self.spill.pair_keys():
            if name in drained_pairs:
                # one pair lane per channel per round: a channel spilled
                # under BOTH layouts re-packs against different tables with
                # different wire widths — its other lane drains next round,
                # so DrainReport.payload is always a single coherent buffer
                continue
            drained_pairs.add(name)
            st = self.channels.get(name)
            version = st.version if st is not None else None
            rows, tgts, stale = self.spill.pop_pairs(
                name, aggregated, self.max_deliver_pairs, version)
            dropped = stale
            payload = None
            delivered = respilled = 0
            if st is None:
                dropped += len(rows)
            elif len(rows):
                res = self._synthetic_result(rows, tgts)
                if st.spec.join == "spatial":
                    sids = jnp.zeros((0,), dtype=jnp.int32)
                else:
                    sids = self.group_sids_array(name, aggregated)
                buf, dlv, _ = pack_payloads(res, sids,
                                            self.deliver_payload_words,
                                            self.max_deliver_pairs)
                delivered = int(dlv)
                payload = np.asarray(buf)
                if delivered < len(rows):   # exact in-order prefix delivered
                    self.spill._push_front_pairs(
                        name, aggregated, rows[delivered:], tgts[delivered:],
                        st.version)
                    respilled = len(rows) - delivered
            if delivered or dropped or respilled:
                merge(name, DrainReport(
                    DeliveryStats(delivered, respilled, dropped, 0, 0, 0),
                    payload=payload))

        for name in self.spill.sid_keys():
            sids = self.spill.pop_sids(name, self.max_notify)
            if not len(sids):
                continue
            # identity fanout: targets ARE the sIDs, so the send stage
            # re-emits them verbatim in spill order
            res = self._synthetic_result(sids, sids)
            buf, dlv, _ = fanout_sids(res, jnp.zeros((0,), jnp.int32),
                                      self.max_notify)
            delivered = int(dlv)
            respilled = len(sids) - delivered
            if respilled:
                self.spill._push_front_sids(name, sids[delivered:])
            merge(name, DrainReport(
                DeliveryStats(0, 0, 0, delivered, respilled, 0),
                notify=np.asarray(buf)))
        return out


def _pow2_bucket(n: int, floor_bits: int) -> int:
    """Smallest power of two >= n, clamped below by 2**floor_bits. Shared by
    every shape-bucketing site so fused and per-channel traces agree."""
    return 1 << max(floor_bits, (max(n, 1) - 1).bit_length())


def _pred_rank(p) -> int:
    """Heuristic selectivity rank for picking the traditional-index field."""
    from repro.core.predicates import EQ
    return 2 if p.op == EQ else 1


# jit-compiled shared helpers (module-level so lru caches are shared)
_append = R.append
_insert = bidx.insert
