"""shard_map collectives: sequence-parallel flash-decode attention and the
sharded BAD engine's cross-shard notification shuffle.

The KV cache for serving is sharded over the `model` axis on the *sequence*
dimension (works for every GQA geometry — head counts never need to divide
the axis). Each model shard computes flash partials (acc, m, l) over its local
KV slice; the merge is an exact log-sum-exp combine using one pmax + one psum
of (B, H, D)-sized tensors — O(B·H·D) bytes instead of re-reading the cache.

This is the TPU analogue of FlashDecoding split-KV, expressed as a collective
schedule instead of a grid.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map
from repro.distributed.partition import Rules, sanitize_spec
from repro.kernels.flash_decode import ref as fd_ref


def sp_decode_attention(rules: Rules, q: jnp.ndarray, k: jnp.ndarray,
                        v: jnp.ndarray, kv_len: jnp.ndarray,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q (B, H, D); k/v (B, KH, S, D) seq-sharded; kv_len (B,) -> (B, H, D)."""
    mesh = rules.mesh
    m_axis = rules.model_axis
    if m_axis is None:
        return fd_ref.decode_attention(q, k, v, kv_len, scale)
    n_shards = mesh.shape[m_axis]
    b, h, d = q.shape
    s = k.shape[2]
    b_spec = rules.batch_axes if rules.batch_axes else None
    bq = sanitize_spec(P(b_spec, None, None), q.shape, mesh)
    bkv = sanitize_spec(P(b_spec, None, m_axis, None), k.shape, mesh)
    blen = sanitize_spec(P(b_spec), kv_len.shape, mesh)
    shard_size = s // n_shards

    def local(qs, ks, vs, lens):
        # Local slice covers absolute kv positions [idx*shard, (idx+1)*shard).
        idx = jax.lax.axis_index(m_axis)
        local_len = jnp.clip(lens - idx * shard_size, 0, shard_size)
        acc, m, l = fd_ref.decode_attention_partial(qs, ks, vs, local_len, scale)
        m_g = jax.lax.pmax(m, m_axis)
        m_safe = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
        c = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        acc = jax.lax.psum(acc * c[..., None], m_axis)
        l = jax.lax.psum(l * c, m_axis)
        return fd_ref.normalize(acc, l, qs.dtype)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(bq, bkv, bkv, blen),
                   out_specs=bq)
    return fn(q, k, v, kv_len)


# ---------------------------------------------------------------------------
# cross-shard notification routing (the sharded BAD engine, core/sharded.py)
#
# Each shard's fused delivery emits a notify buffer of end-subscriber sIDs;
# the subscription lives on the shard its sID hashes to, but its BROKER
# endpoint lives on ``partition.broker_owner(bid) % S`` — a different shard
# for most (sid, broker) combinations. ``shuffle_notify`` regroups every
# shard's delivered sIDs by owner shard in one collective over the ("shard",)
# mesh axis, so outbound broker traffic leaves from the shard that hosts the
# endpoint. Deterministic order (source-shard-major, then slot order) makes
# the result exactly comparable against the pure-host reference.
# ---------------------------------------------------------------------------


def notify_mesh(num_shards: int) -> Optional[Mesh]:
    """A ("shard",)-axis mesh over the first ``num_shards`` devices, or None
    when the runtime has too few devices (callers fall back to
    ``shuffle_notify_ref``). On CPU CI the devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    devices = jax.devices()
    if num_shards < 2 or len(devices) < num_shards:
        return None
    return Mesh(np.array(devices[:num_shards]), ("shard",))


def shuffle_notify_ref(sids: np.ndarray, owners: np.ndarray,
                       num_shards: int) -> np.ndarray:
    """Host reference for ``shuffle_notify``: sids/owners are (S, cap) with
    -1 padding; returns (num_shards, S*cap) where row o holds the sIDs owned
    by shard o in source-shard-major order, -1 padded."""
    sids = np.asarray(sids)
    owners = np.asarray(owners)
    s, cap = sids.shape
    out = np.full((num_shards, s * cap), -1, np.int32)
    for o in range(num_shards):
        picked = sids[(owners == o) & (sids >= 0)]
        out[o, :picked.size] = picked
    return out


def shuffle_notify(mesh: Mesh, sids: jnp.ndarray,
                   owners: jnp.ndarray) -> jnp.ndarray:
    """Collective all-gather shuffle: route delivered sIDs to their owner
    shards. ``sids``/``owners`` are (S, cap) int32, -1 padded, one row per
    source shard; the result is (S, S*cap), row o = shard o's inbound sIDs
    (source-shard-major, slot order, -1 padded) — bit-identical to
    ``shuffle_notify_ref``. Output shapes are static (S*cap), so steady
    ticks replay the cached trace."""
    axis = mesh.axis_names[0]
    s, cap = sids.shape
    out_cap = s * cap

    def local(sid_block, owner_block):
        # (1, cap) local block -> full (S, cap) view, then keep what's mine
        sid_all = jax.lax.all_gather(sid_block, axis, tiled=True).ravel()
        owner_all = jax.lax.all_gather(owner_block, axis, tiled=True).ravel()
        me = jax.lax.axis_index(axis)
        mine = (owner_all == me) & (sid_all >= 0)
        pos = jnp.cumsum(mine.astype(jnp.int32)) - 1
        out = jnp.full((out_cap + 1,), -1, jnp.int32)
        out = out.at[jnp.where(mine, pos, out_cap)].set(
            jnp.where(mine, sid_all, -1), mode="drop")
        return out[:out_cap][None, :]

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis, None), P(axis, None)),
                   out_specs=P(axis, None))
    return fn(jnp.asarray(sids, jnp.int32), jnp.asarray(owners, jnp.int32))
