"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state. Single pod: (16, 16) = 256 v5e chips, axes (data, model). Multi-pod:
(2, 16, 16) = 512 chips, axes (pod, data, model); `pod` composes with `data`
for batch sharding (DP across pods) or carries pipeline stages in PP mode.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1):
    """Smoke-scale mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
