"""§Pipelined tick runtime: overlap host control-plane work with in-flight
device execution.

The synchronous tick loop serializes host and device: ``execute_all``
blocks per plan-group, materializes every stat eagerly, and only then lets
the next tick's aggregator/churn numpy work start. JAX dispatch is
asynchronous and per-device execution is in-order, so none of that waiting
is necessary: ``BADEngine.dispatch_all`` enqueues every plan-group's fused
call and returns device-array HANDLES immediately; this module schedules
when those handles are finally read.

``PendingExecution`` is one dispatched tick: an idempotent ``sync()``
materializes its per-channel ``ExecutionReport``s (the first host read of
the call's outputs) and runs the host half of delivery accounting.
``TickPipeline`` keeps a bounded window of them in flight — ``step`` at
depth N dispatches tick t while ticks t-1..t-(N-1) are still executing, and
only syncs the oldest when the window would exceed N-1 pending entries. The
control-plane work between ``step`` calls (subscription churn, batch
synthesis, ingest) therefore runs concurrently with the previous ticks'
joins and delivery.

Correctness under deferral: device results are dispatch-ordered and
bit-identical to the synchronous schedule (rings thread device-side from
dispatch to dispatch; watermarks advance at dispatch), so the ONLY thing
that moves in time is the host SpillQueue. Deferred captures use the
queue's epoch-free RESOLVED lane (``dispatch_all(resolve_spills=True)``):
pair fanout is resolved at sync against the dispatch-time sID tables, so
draining every ``drain_every`` ticks delivers the identical notification
multiset as the synchronous drain-every-tick path — including under
same-channel churn during sustained overflow.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Protocol, Tuple, runtime_checkable


@runtime_checkable
class EngineProtocol(Protocol):
    """The shared ``BADEngine`` / ``ShardedBADEngine`` control surface.

    Everything the tick drivers — ``TickPipeline``, ``core/churn.run_ticks``,
    and the benchmark harnesses — call on "an engine", extracted so they
    type-check against ONE interface instead of duck-typing two classes.
    Both engines satisfy it structurally (asserted by tests/test_enrich.py);
    new driver code should annotate against this, not a concrete engine.

    The contract mirrors the single-device semantics: ``dispatch`` /
    ``dispatch_all`` return a pending handle with an idempotent ``sync()``
    (``ShardedPendingExecution`` merges per-shard reports), ``execute`` /
    ``execute_all`` are their synchronous composition, and the spill/ring
    surface drains per-channel regardless of placement."""

    def create_channel(self, spec) -> None: ...

    def subscribe_bulk(self, channel: str, params) -> None: ...

    def remove_subscriptions(self, channel: str, sids) -> None: ...

    def ingest(self, batch) -> None: ...

    def execute(self, request) -> Dict: ...

    def dispatch(self, request): ...

    def execute_all(self, flags=None, advance: bool = True,
                    timed: bool = True, deliver: bool = False) -> Dict: ...

    def dispatch_all(self, flags=None, advance: bool = True,
                     timed: bool = False, deliver: bool = False,
                     resolve_spills: bool = False): ...

    def drain_spilled(self, channel=None, max_entries=None) -> Dict: ...

    def flush_rings(self) -> None: ...

    def ring_pending_pairs(self, channel: str) -> int: ...

    def ring_pending_sids(self, channel: str) -> int: ...

    def set_plan(self, channel: str, plan) -> None: ...

    def set_enrichment(self, stage) -> bool: ...

    def default_plan(self): ...


class PendingExecution:
    """One dispatched ``dispatch_all`` call awaiting materialization.

    ``sync()`` is idempotent: the first call blocks on the device results,
    runs the host half (report assembly, SpillQueue pushes, conserving
    DeliveryStats) and caches the reports; later calls return them.
    ``latency_s`` records the dispatch-to-materialize latency of the first
    sync."""

    def __init__(self, engine, groups: List):
        self._engine = engine
        self._groups = groups
        self._reports: Optional[Dict] = None
        self._t0 = time.perf_counter()
        self.latency_s: Optional[float] = None

    @property
    def done(self) -> bool:
        return self._reports is not None

    def sync(self) -> Dict:
        if self._reports is None:
            reports: Dict = {}
            for g in self._groups:
                self._engine._materialize_group(g, reports)
            self.latency_s = time.perf_counter() - self._t0
            self._reports = reports
        return self._reports

    @property
    def reports(self) -> Dict:
        return self.sync()


class TickPipeline:
    """Bounded-depth pipeline of engine ticks.

    ``depth`` is the maximum number of ticks simultaneously in flight
    (depth 1 degenerates to the synchronous schedule: every ``step`` syncs
    its own dispatch). ``drain_every`` batches ``drain_spilled`` host
    round-trips every K ticks (default: K == depth) — ``drain_due()``
    tells the driver when; conservation holds because deferred captures go
    through the SpillQueue's resolved lane.

    ``step`` returns the (tick_number, reports) pairs that became ready,
    oldest first — possibly empty while the window fills. ``flush()``
    syncs everything still in flight (end of run, or before an operation
    that must observe a quiesced engine). ``max_in_flight`` is the measured
    pipeline depth actually achieved; ``latencies`` the per-tick
    dispatch-to-materialize seconds."""

    def __init__(self, engine: EngineProtocol, depth: int = 2,
                 drain_every: Optional[int] = None):
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        self.engine = engine
        self.depth = depth
        self.drain_every = drain_every or depth
        self._window: deque = deque()   # (tick_number, PendingExecution)
        self._tick = 0
        self.max_in_flight = 0
        self.latencies: List[float] = []

    @property
    def in_flight(self) -> int:
        return len(self._window)

    def step(self, flags=None, deliver: bool = True,
             timed: bool = False) -> List[Tuple[int, Dict]]:
        """Dispatch one tick; sync (only) what the depth bound forces out."""
        pend = self.engine.dispatch_all(flags, timed=timed, deliver=deliver,
                                        resolve_spills=True)
        self._window.append((self._tick, pend))
        self._tick += 1
        # the dispatch just issued overlaps with every older in-flight tick
        self.max_in_flight = max(self.max_in_flight, len(self._window))
        out: List[Tuple[int, Dict]] = []
        while len(self._window) > self.depth - 1:
            t, p = self._window.popleft()
            out.append((t, p.sync()))
            if p.latency_s is not None:
                self.latencies.append(p.latency_s)
        return out

    def flush(self) -> List[Tuple[int, Dict]]:
        """Sync every in-flight tick, oldest first."""
        out: List[Tuple[int, Dict]] = []
        while self._window:
            t, p = self._window.popleft()
            out.append((t, p.sync()))
            if p.latency_s is not None:
                self.latencies.append(p.latency_s)
        return out

    def drain_due(self) -> bool:
        """True when the batched-drain cadence has come around: the driver
        should loop ``engine.drain_spilled()`` until the queue empties."""
        return self._tick % self.drain_every == 0
