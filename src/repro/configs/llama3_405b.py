"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256. GQA, 128k vocab. [arXiv:2407.21783; unverified]

Memory plan for 256 x v5e-16GB: bf16 params (810 GB -> 3.2 GB/chip with
TP x FSDP), Adafactor (factored 2nd moment + bf16 1st moment), remat per
layer, 8-way gradient accumulation (microbatch 32 x 4096).
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248,
        vocab_size=128256, head_dim=128, qkv_bias=False, rope_theta=5e5,
        block_pattern=("dense",), superlayer_repeat=126,
        param_dtype=jnp.bfloat16, grad_accum=16, optimizer="adafactor",
        adafactor_beta1=0.0,
        remat=True, sub_quadratic=False, seq_shard_activations=True,
    ).validate()
