"""Figs. 12-13: subgroup size vs execution time (the frame-size trade-off).

All subscriptions ask for the same parameter ("CA"); the group cap sweeps
from one-giant-group to one-sub-per-group. The paper finds a U-curve with the
minimum where group record size ~ frame size; on TPU the analogue is the
lane-aligned cap (128-multiples), and the inefficiency at tiny caps is
duplicate result computation, at huge caps lost parallelism (here: gather/
segment work over one huge padded group row).
"""
from __future__ import annotations

import numpy as np

from repro.core.plans import ExecutionFlags
from benchmarks.common import build_drug_engine, emit, exec_time, scale

CA = 4  # encoded state id


def run(rng) -> None:
    n_subs = scale(16_384, 1024)
    caps = sorted({n_subs, n_subs // 4, n_subs // 16, 2048, 512, 128, 32,
                   8, 1} & set(range(1, n_subs + 1)) | {n_subs},
                  reverse=True)
    flags = ExecutionFlags(scan_mode="bad_index", aggregation=True)
    times = {}
    for cap in caps:
        eng = build_drug_engine(rng, n_subs=n_subs, n_new=scale(8192, 1024),
                                match_rate=0.02, group_cap=cap, states=1,
                                preload=0)
        t, info = exec_time(eng, "TweetsAboutDrugs", flags)
        times[cap] = t
        emit(f"group_size/cap_{cap}", t,
             f"results={info['results']};notified={info['notified']}")
    best = min(times, key=times.get)
    emit("group_size/best_cap", times[best], f"argmin_cap={best}")


if __name__ == "__main__":
    run(np.random.default_rng(0))
