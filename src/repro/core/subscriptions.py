"""Subscriptions + Algorithm 1 subscription aggregation (paper §4.1).

Control plane (this module) is host-side numpy — subscriptions arrive one at a
time between channel executions, exactly as in the paper ("all grouping is
completed before the execution of the next channel begins"). The data plane
consumes the dense, padded arrays produced here.

TPU adaptation of the frame-size rule: AsterixDB frames hold whole records, so
the paper caps a subscription-group record at the frame size ``f``. Our frames
are tensor tiles; the analogous rule is a per-group sID capacity ``cap``
rounded to the 128-lane register width so one group occupies whole vector
registers. ``cap_from_frame_bytes`` reproduces the paper's rule (group record
size ~ frame size), ``lane_align`` applies the TPU rounding.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

SID_BYTES = 4          # sIDs are int32
LANE = 128             # TPU vector lane count


def cap_from_frame_bytes(frame_bytes: int, align: bool = True) -> int:
    """Paper rule: optimal subgroup record size == frame size (Figs. 12-13)."""
    cap = max(1, frame_bytes // SID_BYTES)
    return lane_align(cap) if align else cap


def lane_align(cap: int) -> int:
    if cap <= LANE:
        return cap
    return (cap // LANE) * LANE


@dataclasses.dataclass
class SubscriptionTable:
    """Flat (un-aggregated) subscriptions — the *original* BAD layout."""

    sids: np.ndarray      # (S,) int32
    params: np.ndarray    # (S,) int32 -- encoded channel parameter
    brokers: np.ndarray   # (S,) int32 -- broker id

    @property
    def num_subscriptions(self) -> int:
        return int(self.sids.shape[0])

    @staticmethod
    def empty() -> "SubscriptionTable":
        z = np.zeros((0,), dtype=np.int32)
        return SubscriptionTable(z.copy(), z.copy(), z.copy())

    @staticmethod
    def build(params: np.ndarray, brokers: np.ndarray) -> "SubscriptionTable":
        params = np.asarray(params, dtype=np.int32)
        brokers = np.asarray(brokers, dtype=np.int32)
        sids = np.arange(params.shape[0], dtype=np.int32)
        return SubscriptionTable(sids, params, brokers)


@dataclasses.dataclass
class SubscriptionGroups:
    """Aggregated subscription-group records (paper Fig. 7b).

    group_params: (G,) int32     -- the shared parameter
    group_brokers: (G,) int32
    group_sids:   (G, cap) int32 -- member sIDs, padded with -1
    group_counts: (G,) int32
    """

    group_params: np.ndarray
    group_brokers: np.ndarray
    group_sids: np.ndarray
    group_counts: np.ndarray
    cap: int

    @property
    def num_groups(self) -> int:
        return int(self.group_params.shape[0])

    @property
    def num_subscriptions(self) -> int:
        return int(self.group_counts.sum())


@dataclasses.dataclass
class GroupDelta:
    """Control-plane churn since the last ``take_delta()``.

    ``slots`` are group SLOT indices (stable row ids in the aggregator's
    slot space) whose content changed — opened, mutated, or freed; ``params``
    are the parameter values whose live-slot membership changed. The FLAT
    layout has its own slot space (one stable row per subscription):
    ``flat_slots`` are its touched rows and ``flat_cells`` the touched
    (param, position) cells of its per-param join-map rows. Consumers
    re-read the aggregator's CURRENT content for every touched
    slot/param/cell, so consecutive deltas compose by set union
    (``merge``)."""

    slots: Set[int] = dataclasses.field(default_factory=set)
    params: Set[int] = dataclasses.field(default_factory=set)
    flat_slots: Set[int] = dataclasses.field(default_factory=set)
    flat_cells: Set[Tuple[int, int]] = dataclasses.field(default_factory=set)
    # "everything moved" (a whole-table adopt): consumers must rebuild —
    # recorded as a flag instead of enumerating O(S) slots/cells
    full: bool = False

    def merge(self, other: "GroupDelta") -> None:
        self.slots |= other.slots
        self.params |= other.params
        self.flat_slots |= other.flat_slots
        self.flat_cells |= other.flat_cells
        self.full = self.full or other.full

    @property
    def empty(self) -> bool:
        return not (self.slots or self.params or self.flat_slots
                    or self.flat_cells or self.full)


class Aggregator:
    """Incremental Algorithm 1 over a STABLE-SLOT group table.

    Each group occupies a slot row of a dense (slots, cap) member matrix —
    the same layout the device caches hold — so batch mutations are
    vectorized numpy over the touched rows, never per-subscription Python.
    Freed slots (all members removed, or merged away by compaction) go on a
    free list and are reused by later opens, so long-lived churn never leaks
    slot rows into ``build()`` capacity. Every mutation is O(Δ·cap): O(1)
    sid->slot routing per sID, one row rewrite per touched group. Touched
    slots/params accumulate into a ``GroupDelta`` (consumed via
    ``take_delta``) so derived state — device group arrays, join maps — can
    be patched in place instead of rebuilt.

    ``compact_slack``: after removals, a key whose live groups exceed the
    minimal ``ceil(members / cap)`` by at least this many is re-chopped in
    slot order and the surplus slots freed (Algorithm-1 output is preserved
    up to group-boundary choices; the paper fixes group *capacity*, not
    boundary placement)."""

    def __init__(self, cap: int, compact_slack: int = 2):
        if cap < 1:
            raise ValueError("group capacity must be >= 1")
        self.cap = cap
        self.compact_slack = max(1, compact_slack)
        # (param, broker) -> list of LIVE slot indices (fill-scan order)
        self._by_key: Dict[Tuple[int, int], List[int]] = {}
        # (param, broker) -> live member count: O(1) compaction triggering
        self._key_subs: Dict[Tuple[int, int], int] = {}
        # param -> set of LIVE slot indices across brokers (join-map rows)
        self._by_param: Dict[int, Set[int]] = {}
        self._n = 0                       # slot table height (live + free)
        self._params = np.full((8,), -1, np.int32)     # per slot; -1 free
        self._brokers = np.full((8,), -1, np.int32)
        self._counts = np.zeros((8,), np.int32)
        self._msids = np.full((8, cap), -1, np.int32)  # -1-padded prefixes
        self._free: List[int] = []
        # live sID -> slot, as a dense -1-filled array (sIDs are small dense
        # ints): O(1) vectorized routing for whole batches. Grows with the
        # highest sID ever issued (4 bytes per sID) — the O(Δ) removal path
        # trades that bounded memory for zero per-sID Python
        self._sid_map = np.full((1024,), -1, np.int32)
        self._n_subs = 0
        self._next_sid = 0
        self._delta = GroupDelta()
        # FLAT layout: one stable slot per SUBSCRIPTION (the original
        # non-aggregated device rows), with its own free list, and per-param
        # positional join rows (stable (param, position) cells, -1 holes) so
        # flat device caches are patched cell-wise instead of rebuilt
        self._flat_params = np.zeros((8,), np.int32)
        self._flat_brokers = np.zeros((8,), np.int32)
        self._flat_sids = np.full((8,), -1, np.int32)   # -1 == free slot
        self._fpos = np.full((8,), -1, np.int32)        # slot -> row position
        self._flat_n = 0
        self._flat_free: List[int] = []
        self._sid_flat = np.full((1024,), -1, np.int32)  # sid -> flat slot
        self._frow: Dict[int, np.ndarray] = {}   # param -> flat slots, -1 holes
        self._frow_len: Dict[int, int] = {}      # param -> extent (high-water)
        self._frow_free: Dict[int, List[int]] = {}

    # -- slot bookkeeping ------------------------------------------------

    @property
    def num_slots(self) -> int:
        """Slot-table height (live + free) — the capacity derived arrays
        must be padded to."""
        return self._n

    @property
    def num_live_groups(self) -> int:
        return self._n - len(self._free)

    @property
    def num_subscriptions(self) -> int:
        return self._n_subs

    def slot_rows(self, slots) -> Tuple[np.ndarray, np.ndarray,
                                        np.ndarray, np.ndarray]:
        """(params, brokers, counts, sids) rows for the given slots — one
        vectorized gather (free slots read zero-count, all -1 members);
        the delta-patch fill path."""
        sl = np.asarray(slots, dtype=np.int64)
        c = self._counts[sl]
        live = c > 0
        return (np.where(live, self._params[sl], 0).astype(np.int32),
                np.where(live, self._brokers[sl], 0).astype(np.int32),
                c.copy(), self._msids[sl].copy())

    def slot_row(self, gi: int) -> Tuple[int, int, int, np.ndarray]:
        """Current (param, broker, count, padded member sIDs) of one slot;
        free slots read as (0, 0, 0, all -1)."""
        p, b, c, s = self.slot_rows([gi])
        return int(p[0]), int(b[0]), int(c[0]), s[0]

    def slot_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray]:
        """The whole slot table as dense arrays (params, brokers, counts,
        sids) — free slots zero-count. Row index == slot index, so deltas
        patch rows of exactly these arrays."""
        return self.slot_rows(np.arange(self._n, dtype=np.int64))

    def slot_members(self, gi: int) -> np.ndarray:
        return self._msids[gi, :self._counts[gi]].copy()

    def param_slots(self, param: int) -> np.ndarray:
        """Live slots holding groups for ``param`` (any broker), ascending —
        the delta-maintained equivalent of a ``param_to_targets`` row."""
        s = self._by_param.get(int(param), ())
        return np.sort(np.fromiter(s, np.int64, len(s)))

    def param_items(self):
        """(param, ascending live slots) for every param holding live
        groups — the public view of the per-param join-map rows."""
        for p in self._by_param:
            yield p, self.param_slots(p)

    def max_param_fanout(self) -> int:
        """Largest live-slot count any single param value maps to."""
        return max((len(s) for s in self._by_param.values()), default=1)

    def live_sids(self) -> np.ndarray:
        """Every live member sID (group-major order) — vectorized."""
        m = self._msids[:self._n]
        return m[m >= 0]

    def sid_slots(self, sids: np.ndarray) -> np.ndarray:
        """Slot of each sID (-1 for unknown/removed) — one gather."""
        sids = np.asarray(sids, dtype=np.int64).ravel()
        ok = (sids >= 0) & (sids < self._sid_map.shape[0])
        return np.where(ok, self._sid_map[np.where(ok, sids, 0)], -1)

    def _ensure_sid_map(self, max_sid: int) -> None:
        # _grow_to doubles (at least) and no-ops when already large enough
        self._sid_map = self._grow_to(self._sid_map, max_sid + 1, -1)
        self._sid_flat = self._grow_to(self._sid_flat, max_sid + 1, -1)

    # -- flat stable slots ------------------------------------------------

    @property
    def num_flat_slots(self) -> int:
        """Flat slot-table height (live + free) — the capacity flat device
        caches must be padded to."""
        return self._flat_n

    def flat_slot_rows(self, slots) -> Tuple[np.ndarray, np.ndarray,
                                             np.ndarray, np.ndarray]:
        """(params, brokers, live-counts, sids) rows for the given FLAT
        slots — free slots read zero-count / -1 sid; the flat delta-patch
        fill path."""
        sl = np.asarray(slots, dtype=np.int64)
        sids = self._flat_sids[sl]
        live = sids >= 0
        return (np.where(live, self._flat_params[sl], 0).astype(np.int32),
                np.where(live, self._flat_brokers[sl], 0).astype(np.int32),
                live.astype(np.int32), sids.copy())

    def flat_slot_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                        np.ndarray]:
        """The whole flat slot table as dense arrays — row index == flat
        slot, free slots zero-count. The flat analogue of
        ``slot_arrays``."""
        return self.flat_slot_rows(np.arange(self._flat_n, dtype=np.int64))

    def flat_param_rows(self):
        """(param, positional row of flat slots up to its extent) for every
        param that ever held flat positions — -1 holes stay in place so
        (param, position) cells are stable under churn."""
        for p, row in self._frow.items():
            yield p, row[:self._frow_len[p]]

    def flat_row_extent(self, param: int) -> int:
        return self._frow_len.get(int(param), 0)

    def max_flat_extent(self) -> int:
        """Largest positional-row extent any param ever reached."""
        return max(self._frow_len.values(), default=1)

    def flat_cell_rows(self, cells) -> Tuple[np.ndarray, np.ndarray,
                                             np.ndarray]:
        """(params, positions, current flat-slot values) for the given
        (param, position) cells — the cell-wise flat join-map patch read
        (-1 where the cell is a hole)."""
        n = len(cells)
        ps = np.empty((n,), np.int32)
        pos = np.empty((n,), np.int32)
        vals = np.full((n,), -1, np.int32)
        for i, (p, j) in enumerate(cells):
            ps[i], pos[i] = p, j
            row = self._frow.get(p)
            if row is not None and j < self._frow_len.get(p, 0):
                vals[i] = row[j]
        return ps, pos, vals

    @staticmethod
    def _grow_to(arr: np.ndarray, need: int, fill) -> np.ndarray:
        if need <= arr.shape[0]:
            return arr
        new = np.full((max(need, 2 * arr.shape[0]),) + arr.shape[1:], fill,
                      arr.dtype)
        new[:arr.shape[0]] = arr
        return new

    def _flat_add_key(self, param: int, broker: int,
                      sids: np.ndarray) -> None:
        """Assign stable flat slots + positional cells to one key's new
        members — free-list reuse first, then append; O(Δ) numpy."""
        k = len(sids)
        free = self._flat_free
        r = min(k, len(free))
        slots = np.empty((k,), np.int64)
        if r:
            slots[:r] = free[len(free) - r:]
            del free[len(free) - r:]
        if k > r:
            slots[r:] = np.arange(self._flat_n, self._flat_n + k - r)
            self._flat_n += k - r
            self._flat_params = self._grow_to(self._flat_params,
                                              self._flat_n, 0)
            self._flat_brokers = self._grow_to(self._flat_brokers,
                                               self._flat_n, 0)
            self._flat_sids = self._grow_to(self._flat_sids, self._flat_n, -1)
            self._fpos = self._grow_to(self._fpos, self._flat_n, -1)
        self._flat_params[slots] = param
        self._flat_brokers[slots] = broker
        self._flat_sids[slots] = sids
        self._sid_flat[sids] = slots
        row = self._frow.get(param)
        if row is None:
            row = np.full((8,), -1, np.int32)
            self._frow[param] = row
            self._frow_len[param] = 0
            self._frow_free[param] = []
        pf = self._frow_free[param]
        r2 = min(k, len(pf))
        pos = np.empty((k,), np.int64)
        if r2:
            pos[:r2] = pf[len(pf) - r2:]
            del pf[len(pf) - r2:]
        if k > r2:
            ln = self._frow_len[param]
            pos[r2:] = np.arange(ln, ln + k - r2)
            self._frow_len[param] = ln + k - r2
            if self._frow_len[param] > row.shape[0]:
                self._frow[param] = row = self._grow_to(
                    row, self._frow_len[param], -1)
        row[pos] = slots
        self._fpos[slots] = pos
        self._delta.flat_slots.update(slots.tolist())
        self._delta.flat_cells.update(
            (param, int(j)) for j in pos.tolist())

    def _flat_remove_sids(self, sids: np.ndarray) -> None:
        """Free the flat slots + positional cells of removed sIDs (callers
        pass unique, known-live sIDs)."""
        slots = self._sid_flat[np.asarray(sids, np.int64)].astype(np.int64)
        params = self._flat_params[slots]
        pos = self._fpos[slots]
        self._sid_flat[sids] = -1
        self._flat_sids[slots] = -1
        self._fpos[slots] = -1
        self._flat_free.extend(slots.tolist())
        self._delta.flat_slots.update(slots.tolist())
        order = np.argsort(params, kind="stable")
        ps, po = params[order], pos[order]
        starts = np.flatnonzero(np.r_[True, ps[1:] != ps[:-1]])
        for s, e in zip(starts.tolist(),
                        np.append(starts[1:], len(ps)).tolist()):
            p = int(ps[s])
            prun = po[s:e]
            self._frow[p][prun] = -1
            self._frow_free[p].extend(prun.tolist())
            self._delta.flat_cells.update(
                (p, int(j)) for j in prun.tolist())

    def take_delta(self) -> GroupDelta:
        """Pop the accumulated churn record (and reset it)."""
        d = self._delta
        self._delta = GroupDelta()
        return d

    def _touch(self, gi: int, param: int) -> None:
        self._delta.slots.add(gi)
        self._delta.params.add(int(param))

    def _new_slot(self) -> int:
        if self._free:
            return self._free.pop()
        if self._n == self._params.shape[0]:
            grow = max(8, self._params.shape[0])
            self._params = np.concatenate(
                [self._params, np.full((grow,), -1, np.int32)])
            self._brokers = np.concatenate(
                [self._brokers, np.full((grow,), -1, np.int32)])
            self._counts = np.concatenate(
                [self._counts, np.zeros((grow,), np.int32)])
            self._msids = np.concatenate(
                [self._msids, np.full((grow, self.cap), -1, np.int32)])
        gi = self._n
        self._n += 1
        return gi

    def _alloc_slot(self, param: int, broker: int,
                    members: np.ndarray) -> int:
        gi = self._new_slot()
        self._params[gi] = param
        self._brokers[gi] = broker
        self._msids[gi] = -1
        self._msids[gi, :len(members)] = members
        self._counts[gi] = len(members)
        self._by_key.setdefault((param, broker), []).append(gi)
        self._by_param.setdefault(param, set()).add(gi)
        self._touch(gi, param)
        return gi

    def _release_slot(self, gi: int, unregister_key: bool = True) -> None:
        param, broker = int(self._params[gi]), int(self._brokers[gi])
        if unregister_key:
            lst = self._by_key.get((param, broker))
            if lst is not None:
                lst.remove(gi)
                if not lst:
                    del self._by_key[(param, broker)]
        ps = self._by_param.get(param)
        if ps is not None:
            ps.discard(gi)
            if not ps:
                del self._by_param[param]
        self._params[gi] = -1
        self._brokers[gi] = -1
        self._counts[gi] = 0
        self._msids[gi] = -1
        self._free.append(gi)
        self._touch(gi, param)

    # -- mutations -------------------------------------------------------

    def add_subscription(self, param: int, broker: int,
                         sid: Optional[int] = None) -> int:
        """Paper Algorithm 1. Returns the sID assigned."""
        if sid is None:
            sid = self._next_sid
        self._next_sid = max(self._next_sid, sid + 1)
        param, broker = int(param), int(broker)
        key = (param, broker)
        self._ensure_sid_map(sid)
        self._key_subs[key] = self._key_subs.get(key, 0) + 1
        for gi in self._by_key.get(key, ()):           # AddToExistingGroup
            c = int(self._counts[gi])
            if c < self.cap:
                self._msids[gi, c] = sid
                self._counts[gi] = c + 1
                self._sid_map[sid] = gi
                self._n_subs += 1
                self._touch(gi, param)
                self._flat_add_key(param, broker, np.asarray([sid], np.int32))
                return sid
        gi = self._alloc_slot(param, broker,            # open a new group
                              np.asarray([sid], np.int32))
        self._sid_map[sid] = gi
        self._n_subs += 1
        self._flat_add_key(param, broker, np.asarray([sid], np.int32))
        return sid

    def _place_key(self, param: int, broker: int, sids: np.ndarray) -> None:
        """Place one key's new members: top up the key's non-full groups in
        fill order, then chop the remainder into fresh cap-sized groups —
        Algorithm-1 semantics, numpy work per touched GROUP only."""
        pos, n = 0, len(sids)
        self._n_subs += n
        key = (param, broker)
        self._key_subs[key] = self._key_subs.get(key, 0) + n
        self._flat_add_key(param, broker, sids)
        lst = self._by_key.get(key)
        if lst:
            # ONE vectorized fill across every open group of the key:
            # scattered removals leave scattered slack, and walking those
            # groups one by one in Python was the bulk-add hot spot
            arr = np.asarray(lst, dtype=np.int64)
            open_slots = arr[self._counts[arr] < self.cap]
            if open_slots.size:
                cnts = self._counts[open_slots].astype(np.int64)
                rooms = self.cap - cnts
                cum = np.cumsum(rooms)
                take = int(min(n, cum[-1]))
                if take:
                    j = np.arange(take, dtype=np.int64)
                    g = np.searchsorted(cum, j, side="right")
                    col = cnts[g] + j - (cum[g] - rooms[g])
                    rows = open_slots[g]
                    self._msids[rows, col] = sids[:take]
                    filled = np.bincount(g, minlength=open_slots.size)
                    touched = open_slots[filled > 0]
                    self._counts[touched] += filled[filled > 0].astype(
                        np.int32)
                    self._sid_map[sids[:take]] = rows.astype(np.int32)
                    self._delta.slots.update(touched.tolist())
                    self._delta.params.add(int(param))
                    pos = take
        while pos < n:
            chunk = sids[pos:pos + self.cap]
            gi = self._alloc_slot(param, broker, chunk)
            self._sid_map[chunk] = gi
            pos += len(chunk)

    def add_bulk(self, params: np.ndarray, brokers: np.ndarray,
                 sids: Optional[np.ndarray] = None) -> np.ndarray:
        """Incremental bulk load: O(Δ log Δ) sort of the batch, then per
        TOUCHED (param, broker) key only — existing untouched groups are
        never revisited (the pre-churn-engine path re-aggregated old + new
        members from scratch, O(S) per batch). Per-key output is Algorithm-1
        equivalent: non-full groups top up first, the remainder chops into
        minimal cap-sized groups. Returns the sIDs assigned to the batch."""
        params = np.asarray(params, dtype=np.int32).ravel()
        brokers = np.asarray(brokers, dtype=np.int32).ravel()
        if params.shape != brokers.shape:
            raise ValueError("params and brokers must have the same length")
        n = params.shape[0]
        if sids is None:
            sids = self._next_sid + np.arange(n, dtype=np.int32)
        else:
            sids = np.asarray(sids, dtype=np.int32).ravel()
            if sids.shape[0] != n:   # before _next_sid moves: fail unmutated
                raise ValueError("sids must have the same length as params")
        if n == 0:
            return sids
        self._next_sid = max(self._next_sid, int(sids.max()) + 1)
        self._ensure_sid_map(int(sids.max()))
        if self._n == 0:
            # from-empty fast path: the pure vectorized sort+chop (initial
            # bulk loads are the control plane's cold-start hot path and
            # produce the identical partition)
            self._adopt(aggregate(SubscriptionTable(sids, params, brokers),
                                  self.cap))
            return sids
        key = _sort_key(params, brokers)
        order = np.argsort(key, kind="stable")
        k = key[order]
        new_run = np.empty(n, dtype=bool)
        new_run[0] = True
        new_run[1:] = k[1:] != k[:-1]
        starts = np.flatnonzero(new_run)
        ends = np.append(starts[1:], n)
        for s, e in zip(starts.tolist(), ends.tolist()):
            run = order[s:e]
            self._place_key(int(params[run[0]]), int(brokers[run[0]]),
                            sids[run])
        return sids

    def _adopt(self, g: SubscriptionGroups) -> None:
        """Replace the whole slot table with freshly aggregated groups
        (vectorized registration of every index); delta-touches every slot."""
        self._n = g.num_groups
        self._params = g.group_params.copy()
        self._brokers = g.group_brokers.copy()
        self._counts = g.group_counts.copy()
        self._msids = g.group_sids.copy()
        self._free = []
        self._by_key = {}
        self._by_param = {}
        self._key_subs = {}
        for gi, (key, c) in enumerate(zip(zip(self._params.tolist(),
                                              self._brokers.tolist()),
                                          self._counts.tolist())):
            self._by_key.setdefault(key, []).append(gi)
            self._by_param.setdefault(key[0], set()).add(gi)
            self._key_subs[key] = self._key_subs.get(key, 0) + int(c)
        members = self._msids[self._msids >= 0]
        self._ensure_sid_map(int(members.max()) if members.size else 0)
        self._sid_map[members] = np.repeat(
            np.arange(self._n, dtype=np.int32), self._counts)
        self._n_subs = int(self._counts.sum())
        # flat slot table: slot i == i-th member in group-major order;
        # positional rows assigned per param in slot order — all vectorized
        n = self._n_subs
        self._flat_n = n
        size = max(8, n)
        self._flat_params = np.zeros((size,), np.int32)
        self._flat_brokers = np.zeros((size,), np.int32)
        self._flat_sids = np.full((size,), -1, np.int32)
        self._fpos = np.full((size,), -1, np.int32)
        self._flat_free = []
        self._sid_flat.fill(-1)
        self._frow, self._frow_len, self._frow_free = {}, {}, {}
        if n:
            self._flat_params[:n] = np.repeat(g.group_params, g.group_counts)
            self._flat_brokers[:n] = np.repeat(g.group_brokers,
                                               g.group_counts)
            self._flat_sids[:n] = members
            self._sid_flat[members] = np.arange(n, dtype=np.int32)
            order = np.argsort(self._flat_params[:n],
                               kind="stable").astype(np.int64)
            sp = self._flat_params[order]
            starts = np.flatnonzero(np.r_[True, sp[1:] != sp[:-1]])
            ends = np.append(starts[1:], n)
            run_id = np.cumsum(np.r_[True, sp[1:] != sp[:-1]]) - 1
            self._fpos[order] = (np.arange(n, dtype=np.int64)
                                 - starts[run_id]).astype(np.int32)
            for s, e in zip(starts.tolist(), ends.tolist()):
                p = int(sp[s])
                self._frow[p] = order[s:e].astype(np.int32)
                self._frow_len[p] = e - s
                self._frow_free[p] = []
        # everything moved: record a FULL delta instead of enumerating O(S)
        # touched slots/cells — consumers rebuild
        self._delta = GroupDelta(full=True)

    def remove_subscription(self, param: int, broker: int, sid: int) -> bool:
        gi = int(self.sid_slots([sid])[0])
        if gi < 0 or self._params[gi] != int(param) \
                or self._brokers[gi] != int(broker):
            return False
        self._flat_remove_sids(np.asarray([sid], np.int64))
        self._sid_map[sid] = -1
        self._n_subs -= 1
        key = (int(param), int(broker))
        self._key_subs[key] -= 1
        c = int(self._counts[gi])
        row = self._msids[gi]
        pos = int(np.flatnonzero(row[:c] == sid)[0])
        row[pos:c - 1] = row[pos + 1:c]       # keep the -1-padded prefix
        row[c - 1] = -1
        self._counts[gi] = c - 1
        if c == 1:
            self._release_slot(gi)
        else:
            self._touch(gi, int(param))
        self._maybe_compact((int(param), int(broker)))
        return True

    def remove_bulk(self, sids: np.ndarray) -> np.ndarray:
        """Remove a batch of subscriptions by sID — O(Δ·cap) total: O(1)
        sid->slot routing per sID, then ONE vectorized rewrite of the
        touched slot rows. Unknown/already-removed sIDs are ignored.
        Returns the param value of every subscription actually removed (for
        refcount upkeep); freed groups release their slots and fragmented
        keys compact past ``compact_slack``."""
        sids_arr = np.asarray(sids, dtype=np.int32).ravel()
        if sids_arr.size == 0:
            return np.zeros((0,), np.int32)
        slots = self.sid_slots(sids_arr)
        found = slots >= 0
        if not found.any():
            return np.zeros((0,), np.int32)
        rm_sids = sids_arr[found]
        self._flat_remove_sids(np.unique(rm_sids))
        self._sid_map[rm_sids] = -1          # idempotent for batch dupes
        uniq = np.unique(slots[found])
        # one batched row rewrite: mark removed members, stable-compact the
        # survivors to the row front (prefix-sum destinations, no per-row
        # sort), re-pad the tail with -1
        sub = self._msids[uniq]                         # (k, cap)
        hit = np.isin(sub, rm_sids)                     # sids are unique
        keep = ~hit & (sub >= 0)
        dest = np.cumsum(keep, axis=1, dtype=np.int64) - 1
        out = np.full_like(sub, -1)
        rows = np.broadcast_to(
            np.arange(uniq.size, dtype=np.int64)[:, None], sub.shape)
        out[rows[keep], dest[keep]] = sub[keep]
        n_rm = hit.sum(axis=1).astype(np.int32)
        new_c = self._counts[uniq] - n_rm
        self._msids[uniq] = out
        self._counts[uniq] = new_c
        u_params = self._params[uniq]
        u_brokers = self._brokers[uniq]
        removed = np.repeat(u_params, n_rm).astype(np.int32)
        self._n_subs -= int(n_rm.sum())
        self._delta.slots.update(uniq.tolist())
        self._delta.params.update(u_params.tolist())
        # per-key removal totals, vectorized to the ~#keys scale
        kk = (u_params.astype(np.int64) << 32) | (
            u_brokers.astype(np.int64) & 0xFFFFFFFF)
        uk, inv = np.unique(kk, return_inverse=True)
        per_key = np.bincount(inv, weights=n_rm).astype(np.int64)
        touched_keys = []
        for key_pk, k in zip(uk.tolist(), per_key.tolist()):
            b = key_pk & 0xFFFFFFFF
            key = (key_pk >> 32, b - (1 << 32) if b >= 1 << 31 else b)
            touched_keys.append(key)
            self._key_subs[key] -= int(k)
        for gi in uniq[new_c == 0].tolist():
            self._release_slot(gi)
        for key in touched_keys:
            self._maybe_compact(key)
        return removed

    def _maybe_compact(self, key: Tuple[int, int]) -> None:
        """Re-chop one fragmented key in slot order: keep the first
        ``ceil(members / cap)`` slots, free the rest. Triggered only when the
        key carries >= ``compact_slack`` surplus groups, so steady churn is
        not forever re-shuffling group boundaries."""
        slots = self._by_key.get(key)
        if not slots or len(slots) <= 1:
            return
        total = self._key_subs.get(key, 0)
        minimal = -(-total // self.cap)
        if len(slots) - minimal < self.compact_slack:
            return               # O(1) in the common no-compaction case
        param = key[0]
        sl = np.asarray(sorted(slots), dtype=np.int64)
        rows = self._msids[sl]
        members = rows[rows >= 0]            # slot order, then member order
        keep, drop = sl[:minimal], sl[minimal:]
        mat = np.full((minimal, self.cap), -1, np.int32)
        idx = np.arange(total, dtype=np.int64)
        mat[idx // self.cap, idx % self.cap] = members
        self._msids[keep] = mat
        counts = np.diff(np.append(np.arange(0, total, self.cap), total))
        self._counts[keep] = counts.astype(np.int32)
        self._by_key[key] = keep.tolist()
        self._sid_map[members] = np.repeat(keep, counts).astype(np.int32)
        self._delta.slots.update(keep.tolist())
        self._delta.params.add(int(param))
        for gi in drop.tolist():
            self._release_slot(gi, unregister_key=False)

    def rebuild_bulk(self, params: np.ndarray, brokers: np.ndarray,
                     sids: Optional[np.ndarray] = None) -> np.ndarray:
        """The PRE-churn-engine bulk load, kept as the rebuild baseline the
        churn suite measures against: old + new members re-aggregated from
        scratch through ``aggregate`` — O(S) per batch, group identity not
        preserved. Leaves no usable delta (callers must treat every derived
        cache as invalid)."""
        params = np.asarray(params, dtype=np.int32).ravel()
        brokers = np.asarray(brokers, dtype=np.int32).ravel()
        if params.shape != brokers.shape:
            raise ValueError("params and brokers must have the same length")
        n = params.shape[0]
        if sids is None:
            sids = self._next_sid + np.arange(n, dtype=np.int32)
        else:
            sids = np.asarray(sids, dtype=np.int32).ravel()
            if sids.shape[0] != n:   # before _next_sid moves: fail unmutated
                raise ValueError("sids must have the same length as params")
        if n == 0:
            return sids
        self._next_sid = max(self._next_sid, int(sids.max()) + 1)
        old = flatten_groups(self.build())
        table = SubscriptionTable(
            np.concatenate([old.sids, sids]),
            np.concatenate([old.params, params]),
            np.concatenate([old.brokers, brokers]))
        self._adopt(aggregate(table, self.cap))
        self._delta = GroupDelta()   # unusable: everything moved
        return sids

    # -- export ----------------------------------------------------------

    def build(self) -> SubscriptionGroups:
        """Dense live-group arrays, compacted in slot order (free slots are
        skipped, so the k-th built row is the k-th live slot)."""
        live = np.flatnonzero(self._counts[:self._n] > 0)
        return SubscriptionGroups(
            self._params[live].astype(np.int32),
            self._brokers[live].astype(np.int32),
            self._msids[live].copy(),
            self._counts[live].copy(), self.cap)


def _sort_key(params: np.ndarray, brokers: np.ndarray) -> np.ndarray:
    """Fused (param, broker) sort key in the narrowest dtype that holds it —
    numpy's stable sort is radix for narrow integers, comparison otherwise."""
    if params.size and (int(params.min()) < 0 or int(brokers.min()) < 0):
        return (params.astype(np.int64) << 32) | (
            brokers.astype(np.int64) & 0xFFFFFFFF)
    span = int(brokers.max()) + 1 if brokers.size else 1
    key_range = (int(params.max()) + 1) * span if params.size else 1
    if key_range <= (1 << 15):
        return (params * span + brokers).astype(np.int16)
    if key_range <= (1 << 31):
        return (params.astype(np.int64) * span + brokers).astype(np.int32)
    return (params.astype(np.int64) << 32) | brokers.astype(np.int64)


def aggregate(table: SubscriptionTable, cap: int) -> SubscriptionGroups:
    """Bulk aggregation (vectorized equivalent of replaying Algorithm 1).

    Sort by (param, broker) — one stable argsort of a fused 64-bit key — then
    chop each run into cap-sized subgroups. Per-key group counts equal the
    incremental replay's ``ceil(n_key / cap)``; no per-subscription Python.
    """
    n = table.num_subscriptions
    if n == 0:
        return SubscriptionGroups(*(np.zeros((0,), np.int32),) * 2,
                                  np.zeros((0, cap), np.int32),
                                  np.zeros((0,), np.int32), cap)
    key = _sort_key(table.params, table.brokers)
    order = np.argsort(key, kind="stable")   # radix for narrow integer keys
    k = key[order]
    s = table.sids[order]
    new_run = np.empty(n, dtype=bool)
    new_run[0] = True
    new_run[1:] = k[1:] != k[:-1]
    run_starts = np.flatnonzero(new_run)
    run_id = np.cumsum(new_run, dtype=np.int32) - 1
    pos_in_run = np.arange(n, dtype=np.int64) - run_starts[run_id]
    sub_id = pos_in_run // cap
    # a group starts at every run start and every cap boundary within a run
    new_group = new_run.copy()
    new_group[1:] |= sub_id[1:] != sub_id[:-1]
    group_starts = np.flatnonzero(new_group)
    g = group_starts.shape[0]
    gid = np.cumsum(new_group, dtype=np.int32) - 1
    group_sids = np.full((g, cap), -1, dtype=np.int32)
    group_sids[gid, pos_in_run % cap] = s
    group_counts = np.diff(np.append(group_starts, n)).astype(np.int32)
    return SubscriptionGroups(table.params[order[group_starts]],
                              table.brokers[order[group_starts]],
                              group_sids, group_counts, cap)


def flatten_groups(groups: SubscriptionGroups) -> SubscriptionTable:
    """Vectorized inverse of ``aggregate``: groups -> flat member table.

    Rows come out group-by-group in member order — the same order the old
    per-group Python loop produced — with no per-subscription work.
    """
    counts = groups.group_counts.astype(np.int64)
    member_mask = np.arange(groups.cap)[None, :] < counts[:, None]
    return SubscriptionTable(
        groups.group_sids[member_mask].astype(np.int32),
        np.repeat(groups.group_params, counts).astype(np.int32),
        np.repeat(groups.group_brokers, counts).astype(np.int32))


def param_to_targets(params: np.ndarray, domain: int,
                     pad: int = -1) -> Tuple[np.ndarray, np.ndarray]:
    """Dense join map: param value -> row indices of targets holding it.

    Returns (map (domain, maxd) int32 padded, counts (domain,) int32). This is
    the TPU realization of the index nested-loop join in the augmented plan —
    the join against a small categorical domain becomes a gather. Pure numpy:
    a stable argsort ranks each target within its param run, so the scatter
    preserves the ascending-row order the incremental fill produced.
    """
    params = np.asarray(params, dtype=np.int32)
    counts = np.bincount(params, minlength=domain).astype(np.int32)
    maxd = max(1, int(counts.max()) if counts.size else 1)
    out = np.full((domain, maxd), pad, dtype=np.int32)
    if params.size:
        order = np.argsort(params, kind="stable")
        sorted_p = params[order]
        run_start = np.cumsum(counts) - counts          # (domain,)
        pos = np.arange(params.size, dtype=np.int64) - run_start[sorted_p]
        out[sorted_p, pos] = order.astype(np.int32)
    return out, counts
