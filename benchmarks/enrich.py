"""Enrichment/ranking stage overhead (core/enrich.py).

The post-join hook scores every candidate slot and budget-prunes the pair
grid INSIDE the fused tick call, so its cost rides the same jit as join +
delivery. Two phases:

  * parity — a NoopScorer engine (budget never binding) must deliver the
    IDENTICAL per-channel (row, sID) pair multisets and DeliveryStats as a
    scorer-less engine on the same seeded data (asserted, not trended);
  * overhead — steady-state tick wall with the heuristic scorer ranking
    under a binding budget vs the unranked tick, plus a budget sweep
    (tight -> loose) showing the cost is budget-insensitive (one argsort
    per channel, not per kept pair). Zero steady-state retraces are
    asserted with the stage attached.

Acceptance: ranked budgeted delivery within 1.3x of the unranked tick —
tracked in benchmarks/thresholds.json as ``enrich/ranked_tick/speedup``
(the ratio unranked/ranked, >= ~0.77 when the criterion holds).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, fresh_rng, scale
from repro.core import enrich
from repro.core import records as R
from repro.core.broker import payload_notifications
from repro.core.channel import most_threatening_tweets, tweets_about_drugs
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags
from repro.data.synthetic import drug_tweak, tweet_batch

PW = 8    # engine default deliver_payload_words
FLAGS = ExecutionFlags(scan_mode="window", aggregation=True,
                       param_pushdown=True)
TICKS = 10
WARMUP = 4


def _batch(rng, n, t0):
    batch = tweet_batch(rng, n, t0)
    fields = drug_tweak(np.asarray(batch.fields).copy(), rng, 0.3)
    return R.RecordBatch.from_numpy(fields, np.asarray(batch.location))


def _engine(n_subs, stage=None, debug=False):
    rng = fresh_rng("enrich_engine")
    eng = BADEngine(dataset_capacity=1 << 15, index_capacity=1 << 13,
                    max_window=1 << 13, max_candidates=1 << 11,
                    brokers=("Broker1", "Broker2"), group_cap=8,
                    max_deliver_pairs=2048, max_notify=4096,
                    ring_capacity=0)
    eng.debug_delivery_buffers = debug
    eng.create_channel(tweets_about_drugs())
    eng.create_channel(most_threatening_tweets())
    for name in ("TweetsAboutDrugs", "MostThreateningTweets"):
        eng.subscribe_bulk(name, rng.integers(0, 50, n_subs),
                           rng.integers(0, 2, n_subs))
    if stage is not None:
        eng.set_enrichment(stage)
    return eng


def _tick_wall(eng, batch_n, ticks, warmup):
    """Steady-state mean tick wall (ingest excluded); returns (wall_s,
    retraces-in-timed-window)."""
    rng = fresh_rng("enrich_ticks")
    wall = 0.0
    snap = eng.maintenance.snapshot()
    for tick in range(ticks):
        eng.ingest(_batch(rng, batch_n, t0=eng.now + 1))
        if tick == warmup:
            snap = eng.maintenance.snapshot()
        t0 = time.perf_counter()
        reps = eng.execute_all(FLAGS, timed=False, deliver=True)
        next(iter(reps.values()))   # reports are already materialized
        if tick >= warmup:
            wall += time.perf_counter() - t0
    return wall / max(ticks - warmup, 1), eng.maintenance.since(snap).traces


def _delivered(reports):
    out = {}
    for name, rep in reports.items():
        o = rep.overflow
        out[name] = (sorted(map(tuple, payload_notifications(
            np.asarray(rep.payload), o.delivered_pairs, PW).tolist())),
            o)
    return out


def run(rng) -> None:
    n_subs = scale(4000)
    batch_n = scale(2048)

    # --- phase 1: no-op parity (asserted) -----------------------------
    base = _engine(n_subs, debug=True)
    noop = _engine(n_subs, stage=enrich.NoopScorer(budget=1 << 20),
                   debug=True)
    b_rng, n_rng = fresh_rng("enrich_parity"), fresh_rng("enrich_parity")
    base.ingest(_batch(b_rng, batch_n, t0=1))
    noop.ingest(_batch(n_rng, batch_n, t0=1))
    want = _delivered(base.execute_all(FLAGS, deliver=True))
    got = _delivered(noop.execute_all(FLAGS, deliver=True))
    assert got == want, "no-op scorer broke delivery parity"
    emit("enrich/noop_parity/channels", 0.0,
         f"ok={len(want)} delivered_pairs="
         f"{sum(o.delivered_pairs for _, o in want.values())}")

    # --- phase 2: ranked vs unranked steady tick ----------------------
    plain = _engine(n_subs)
    t_plain, r_plain = _tick_wall(plain, batch_n, TICKS, WARMUP)
    budget = scale(256, floor=32)
    ranked = _engine(n_subs, stage=enrich.HeuristicScorer(budget=budget))
    t_ranked, r_ranked = _tick_wall(ranked, batch_n, TICKS, WARMUP)
    assert r_plain == 0 and r_ranked == 0, (
        f"steady-state retraces: plain={r_plain} ranked={r_ranked}")
    ratio = t_plain / t_ranked
    assert t_ranked <= 1.3 * t_plain, (
        f"ranked tick {t_ranked * 1e3:.2f}ms exceeds 1.3x unranked "
        f"{t_plain * 1e3:.2f}ms")
    emit("enrich/ranked_tick/speedup", t_ranked,
         f"x{ratio:.2f} unranked={t_plain * 1e6:.0f}us budget={budget}")

    # --- phase 3: budget sweep (cost is budget-insensitive) -----------
    for b in (scale(32, floor=8), scale(256, floor=32),
              scale(2048, floor=256)):
        eng = _engine(n_subs, stage=enrich.HeuristicScorer(budget=b))
        t_b, _ = _tick_wall(eng, batch_n, TICKS // 2 + WARMUP // 2,
                            WARMUP // 2)
        emit(f"enrich/budget_sweep/b{b}", t_b,
             f"x{t_plain / t_b:.2f} vs unranked")
