"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh):
  compute term    = per-device FLOPs / peak FLOP/s        (197e12 bf16, v5e)
  memory term     = per-device HLO bytes / HBM bandwidth  (819e9 B/s)
  collective term = per-device collective bytes / link bw (50e9 B/s per the
                    task formula: collective_bytes / (chips x link_bw), with
                    collective_bytes summed per device from partitioned HLO)

plus MODEL_FLOPS = 6*N(_active)*D and the useful-compute ratio
MODEL_FLOPS / (HLO_FLOPs x devices).

  PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / link

SHAPE_TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
                "decode_32k": 128, "long_500k": 1}


def analyze(rec: Dict[str, Any]) -> Dict[str, Any]:
    t = rec["totals"]
    n_dev = rec["devices"]
    compute_s = t["flops"] / PEAK_FLOPS
    memory_s = t["bytes"] / HBM_BW
    coll_s = t["collective_bytes"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    # MODEL_FLOPS: 6*N*D for train; 2*N*D for inference (fwd only).
    # Enc-dec: encoder params see src tokens, decoder params see tgt=src/4
    # (cross-attn K/V projections of encoder memory charged to the decoder).
    n_act = rec["active_param_count"]
    tokens = SHAPE_TOKENS[rec["shape"]]
    factor = 6.0 if rec["kind"] == "train" else 2.0
    if rec.get("n_enc_layers"):
        n_layers_total = rec["n_enc_layers"] + rec["superlayer_repeat"]
        n_enc = n_act * rec["n_enc_layers"] / n_layers_total
        n_dec = n_act - n_enc
        model_flops = factor * (n_enc * tokens + n_dec * tokens / 4)
    else:
        model_flops = factor * n_act * tokens
    hlo_global = t["flops"] * n_dev
    useful = model_flops / hlo_global if hlo_global else 0.0
    mfu = model_flops / (step_s * n_dev * PEAK_FLOPS) if step_s else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "bottleneck": bottleneck, "step_s": step_s,
        "model_flops": model_flops, "useful_ratio": useful, "mfu_bound": mfu,
        "peak_gib": rec["full"]["memory"]["peak_estimate_bytes"] / 2 ** 30,
        "fits_16g": rec["full"]["memory"]["peak_estimate_bytes"] <= 16 * 2 ** 30,
        "grad_accum": rec.get("grad_accum", 1),
        "seq_shard": rec.get("seq_shard", False),
    }


def load(dir_: str) -> List[Dict[str, Any]]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") == "ok":
            rows.append(analyze(rec))
        else:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec.get("mesh", "-"), "skipped": True,
                         "reason": rec.get("skip_reason", "?")})
    return rows


def fmt_md(rows: List[Dict[str, Any]]) -> str:
    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "bottleneck | useful | MFU-bound | peak GiB | fits16G |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — "
                       f"| SKIP | — | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['bottleneck']}** "
            f"| {r['useful_ratio']:.2f} | {r['mfu_bound']:.3f} "
            f"| {r['peak_gib']:.2f} | {'Y' if r['fits_16g'] else 'N'} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = load(args.dir)
    if args.mesh:
        rows = [r for r in rows if r.get("mesh") == args.mesh]
    if args.md:
        print(fmt_md(rows))
        return
    print("arch,shape,mesh,compute_s,memory_s,collective_s,bottleneck,"
          "useful_ratio,mfu_bound,peak_gib,fits")
    for r in rows:
        if r.get("skipped"):
            print(f"{r['arch']},{r['shape']},{r['mesh']},,,,SKIP,,,,")
            continue
        print(f"{r['arch']},{r['shape']},{r['mesh']},{r['compute_s']:.4e},"
              f"{r['memory_s']:.4e},{r['collective_s']:.4e},{r['bottleneck']},"
              f"{r['useful_ratio']:.3f},{r['mfu_bound']:.4f},"
              f"{r['peak_gib']:.2f},{int(r['fits_16g'])}")


if __name__ == "__main__":
    main()
