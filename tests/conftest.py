import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_tweets(rng, n, t0=1, match_drugs=0.1):
    from repro.core import records as R
    from repro.data.synthetic import drug_tweak, tweet_batch
    batch = tweet_batch(rng, n, t0)
    fields = np.asarray(batch.fields).copy()
    fields = drug_tweak(fields, rng, match_drugs)
    return R.RecordBatch.from_numpy(fields, np.asarray(batch.location))
