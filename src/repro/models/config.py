"""ModelConfig: one dataclass describing every supported architecture family.

A model is a stack of ``superlayer_repeat`` identical *superlayers*; each
superlayer applies ``block_pattern`` in order (e.g. dense LM: ("dense",) x L;
zamba2: one shared attention block + 6 mamba blocks; xlstm: 1 sLSTM + 3
mLSTM). Superlayers are scanned (stacked params), which keeps HLO size
independent of depth — required for 126-layer dry-runs on a single-core host.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

BLOCK_TYPES = ("dense", "moe", "mamba", "mlstm", "slstm", "shared_attn")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int                    # bookkeeping total (incl. pattern blocks)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    block_pattern: Tuple[str, ...]   # blocks per superlayer
    superlayer_repeat: int           # scan length
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4
    # enc-dec (encoder layers use bidirectional attention; decoder adds cross-attn)
    is_encdec: bool = False
    n_enc_layers: int = 0
    # frontends: "token" (ids -> embed), "embed" (precomputed embeddings stub)
    frontend: str = "token"
    # serving
    sub_quadratic: bool = False      # can run long_500k
    # numerics / memory plan
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    remat: bool = True
    grad_accum: int = 1
    optimizer: str = "adamw"         # adamw | adafactor
    adafactor_beta1: float = 0.9     # 0.0 = momentum-free (T5/405B memory plan)
    # attention implementation: "ref" (einsum; used under pjit) or "flash"
    # (Pallas kernel; the TPU target, validated in interpret mode)
    attn_impl: str = "ref"
    # Megatron-style sequence parallelism: residual-stream activations (and
    # remat-saved layer inputs) shard their seq dim over `model`. Required for
    # the 405B memory plan; costs one extra all-gather per layer.
    seq_shard_activations: bool = False
    # Weight-stationary decode (serving): decode activations shard d_model
    # over the FSDP axis so matmuls contract against resident weight shards
    # (psum of KB-sized activations) instead of all-gathering GB-sized
    # weights per layer per token. §Perf hillclimb.
    weight_stationary_decode: bool = False
    # Decode layer loop: "scan" stacks new caches as scan outputs (double
    # buffer); "carry" threads the cache tree through a fori_loop carry so
    # the while-loop aliases buffers in place. §Perf hillclimb.
    decode_loop: str = "carry"
    max_target_len: int = 1024       # enc-dec decoder length cap

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a 128 multiple (Megatron-style TP-friendly vocab);
        the loss masks padded entries, decode slices them off."""
        return -(-self.vocab_size // 128) * 128

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def validate(self) -> "ModelConfig":
        assert self.n_heads % self.n_kv_heads == 0
        for b in self.block_pattern:
            assert b in BLOCK_TYPES, b
        if "moe" in self.block_pattern:
            assert self.n_experts > 0 and self.moe_top_k > 0
        return self


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Same-family tiny config for CPU smoke tests (one fwd/train step)."""
    small = dict(
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        superlayer_repeat=2,
        n_layers=2 * len(cfg.block_pattern),
        head_dim=16,
        n_experts=4 if cfg.n_experts else 0,
        ssm_state=16,
        ssm_chunk=32,
        ssm_expand=2,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        n_enc_layers=2 if cfg.is_encdec else 0,
        param_dtype=jnp.float32,
        compute_dtype=jnp.float32,
        grad_accum=1,
        remat=False,
        max_target_len=32,
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small).validate()
