"""END-TO-END DRIVER: serve a model inside the Big Active Data loop.

The paper's EnrichedTweets are produced by an upstream enrichment job (its
ref [32]); here the enrichment IS the framework's analytical engine: raw
tweet token payloads are scored by a (reduced) qwen2-family LM in batched
requests, the scores become predicate fields (threatening_rate proxy), the
records flow through ingestion-time BAD indexing, channel execution and
broker fan-out — the full Fig. 1 pipeline with a model in the loop.

    PYTHONPATH=src python examples/enriched_pipeline.py [--periods 3]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import records as R
from repro.core.channel import most_threatening_tweets, tweets_about_drugs
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags
from repro.data.synthetic import tweet_batch
from repro.models.model import ModelApi


def build_scorer():
    """Reduced-config LM scoring head: tokens -> 0..10 'threatening' rate."""
    cfg = configs.get_reduced("qwen2-1.5b")
    api = ModelApi(cfg)
    params = api.init(jax.random.key(0))

    @jax.jit
    def score(tokens):
        from repro.models import lm
        logits, _ = lm.forward(params, cfg, tokens=tokens)
        # pool last-position logits into an 11-bucket score
        pooled = jnp.mean(logits[:, -1, :64], axis=-1)
        return (jnp.clip(jnp.abs(pooled) * 40.0, 0, 10)).astype(jnp.int32)

    return score, cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--periods", type=int, default=3)
    ap.add_argument("--batch", type=int, default=2048)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    score, cfg = build_scorer()

    eng = BADEngine(dataset_capacity=1 << 15, index_capacity=1 << 14,
                    max_window=1 << 14, max_candidates=1 << 11,
                    brokers=("BrokerA", "BrokerB"))
    eng.create_channel(tweets_about_drugs())
    eng.create_channel(most_threatening_tweets())
    params, brokers = (rng.integers(0, 50, 2000).astype(np.int32),
                       rng.integers(0, 2, 2000).astype(np.int32))
    eng.subscribe_bulk("TweetsAboutDrugs", params, brokers)
    eng.subscribe_bulk("MostThreateningTweets", params, brokers)
    print(f"2 channels, {2*len(params)} subscriptions, enrichment model "
          f"{cfg.name}-reduced ({ModelApi(cfg).param_count():,} params)")

    for period in range(args.periods):
        t0 = time.perf_counter()
        # 1. raw feed: tweets with token payloads, no enrichment fields yet
        raw = tweet_batch(rng, args.batch, t0=1 + period * 600)
        payload = rng.integers(0, cfg.vocab_size,
                               (args.batch, 32)).astype(np.int32)
        # 2. enrichment: batched model requests score the payloads
        rates = np.asarray(score(jnp.asarray(payload)))
        fields = np.asarray(raw.fields).copy()
        fields[:, R.THREATENING_RATE] = rates
        fields[rates == 10, R.DRUG_ACTIVITY] = 3     # flag manufacturing
        t_enrich = time.perf_counter() - t0
        # 3. ingestion: conditionsList eval + BAD-index maintenance
        eng.ingest(R.RecordBatch.from_numpy(fields, np.asarray(raw.location)))
        # 4. channel execution + broker fan-out
        for chan in ("TweetsAboutDrugs", "MostThreateningTweets"):
            rep = eng.execute_channel(chan, ExecutionFlags.fully_optimized())
            print(f"period {period} {chan}: matched={rep.scanned} "
                  f"groups={rep.num_results} notified={rep.num_notified} "
                  f"exec={rep.wall_time_s*1e3:.1f}ms enrich={t_enrich*1e3:.0f}ms")


if __name__ == "__main__":
    main()
