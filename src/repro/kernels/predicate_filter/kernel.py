"""Pallas TPU kernel: ingestion-time conditionsList evaluation (paper Alg. 2).

Layout: records arrive as an (N, F) int32 tile stream; conditions are a dense
(C, F) interval table resident in VMEM (C = channels, F = fields; both small —
the table is a few KB). The grid tiles N; each step loads a (TN, F) record
block into VMEM, broadcasts it against the (C, F) bounds and reduces over F,
emitting a (TN, C) int8 match bitmap.

VMEM budget per step (TN=256, F=16, C=128):
  records 256*16*4 = 16 KB; bounds 3*128*16*4 = 24 KB;
  broadcast compare (TN, C, F) int8 ≈ 512 KB; out 32 KB  -> well under 16 MB.
The F-reduction is unrolled (F is static) so the working set stays (TN, C).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.predicate_filter.ref import NEQ_NONE

DEFAULT_TN = 256


def _kernel(fields_ref, lo_ref, hi_ref, neq_ref, out_ref):
    x = fields_ref[...]                       # (TN, F) int32
    lo = lo_ref[...]                          # (C, F)
    hi = hi_ref[...]
    neq = neq_ref[...]
    tn = x.shape[0]
    c = lo.shape[0]
    acc = jnp.ones((tn, c), dtype=jnp.bool_)
    # F is static and small: unrolled per-field compare keeps the live set 2-D.
    for f in range(x.shape[1]):
        xf = x[:, f][:, None]                 # (TN, 1)
        ok = (xf >= lo[:, f][None, :]) & (xf <= hi[:, f][None, :])
        ok &= (xf != neq[:, f][None, :]) | (neq[:, f] == NEQ_NONE)[None, :]
        acc = acc & ok
    out_ref[...] = acc.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def predicate_filter_kernel(fields: jnp.ndarray, lo: jnp.ndarray,
                            hi: jnp.ndarray, neq: jnp.ndarray,
                            tn: int = DEFAULT_TN,
                            interpret: bool = True) -> jnp.ndarray:
    """fields (N, F) int32, bounds (C, F) int32 -> (N, C) int8 bitmap.

    N must be a multiple of tn (ops.py pads).
    """
    n, f = fields.shape
    c = lo.shape[0]
    assert n % tn == 0, (n, tn)
    grid = (n // tn,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, f), lambda i: (i, 0)),
            pl.BlockSpec((c, f), lambda i: (0, 0)),
            pl.BlockSpec((c, f), lambda i: (0, 0)),
            pl.BlockSpec((c, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tn, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.int8),
        interpret=interpret,
    )(fields, lo, hi, neq)
