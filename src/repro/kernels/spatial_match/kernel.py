"""Pallas TPU kernel: blocked spatial join via the MXU distance trick.

dist²(t, u) = ‖t‖² + ‖u‖² − 2·t·uᵀ — the cross term is a matmul, so the
pairwise distance grid runs on the MXU instead of the VPU. Grid tiles
(tweets × users); each step computes a (TR, TU) boolean tile.

VMEM per step (TR=TU=512): tiles 2*512*2*4 = 8 KB, dist grid 512*512*4 = 1 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TR = 256
DEFAULT_TU = 512


def _kernel(r2_ref, t_ref, u_ref, out_ref):
    t = t_ref[...]                                   # (TR, 2)
    u = u_ref[...]                                   # (TU, 2)
    r2 = r2_ref[0, 0]
    cross = jnp.dot(t, u.T, preferred_element_type=jnp.float32)  # MXU
    t2 = jnp.sum(t * t, axis=-1)[:, None]
    u2 = jnp.sum(u * u, axis=-1)[None, :]
    dist2 = t2 + u2 - 2.0 * cross
    out_ref[...] = (dist2 < r2).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("tr", "tu", "interpret"))
def spatial_match_kernel(tweet_locs: jnp.ndarray, user_locs: jnp.ndarray,
                         radius2: jnp.ndarray, tr: int = DEFAULT_TR,
                         tu: int = DEFAULT_TU,
                         interpret: bool = True) -> jnp.ndarray:
    r, _ = tweet_locs.shape
    u, _ = user_locs.shape
    assert r % tr == 0 and u % tu == 0, (r, tr, u, tu)
    grid = (r // tr, u // tu)
    r2 = jnp.reshape(radius2.astype(jnp.float32), (1, 1))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((tr, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((tu, 2), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tr, tu), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, u), jnp.int8),
        interpret=interpret,
    )(r2, tweet_locs, user_locs)
