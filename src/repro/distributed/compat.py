"""JAX version compatibility for the distribution layer.

The distribution code targets the current JAX API (``jax.shard_map``,
``jax.lax.pcast`` vma casts, ``jax.sharding.AxisType``); older releases ship
the same machinery under ``jax.experimental.shard_map`` without varying-mode
annotations. These shims pick whichever exists so the layer runs on both.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    # check_rep=False: the legacy replication checker predates the vma rules
    # the callers are written against and rejects valid collectives.
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def pcast_varying(x, axis):
    """Mark ``x`` device-varying over ``axis`` where vma rules exist.

    Newest JAX spells it ``jax.lax.pcast``, the 0.6.x line ``jax.lax.pvary``
    (both paired with public ``jax.shard_map`` vma checking); the legacy
    experimental shard_map has no varying/replicated distinction, so identity
    is correct there.
    """
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axis, to="varying")
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis)
    return x
