"""Pallas TPU kernel: split-KV decode attention (FlashDecoding on TPU).

One query token per sequence against a long KV cache. Grid (B, nK) — the kv
dimension is innermost/sequential, all heads are processed per step (decode is
memory-bound: each KV byte is read exactly once; the (H, TK) logit tile is
tiny). Emits *unnormalized* partials (acc, m, l) so the sequence-parallel
serving path (shard_map over the kv axis) can merge shards with one small
collective instead of re-reading the cache.

VMEM per step (H=32, KH=8, TK=512, D=128): k/v tiles 2*8*512*128*4 = 4 MB,
logits 32*512*4 = 64 KB, acc 32*128*4 = 16 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TK = 512
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
            m_scr, l_scr, acc_scr, *, scale: float, tk: int, n_k: int,
            kh: int, g: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (H, D)
    k = k_ref[0].astype(jnp.float32)              # (KH, TK, D)
    v = v_ref[0].astype(jnp.float32)
    d = q.shape[-1]
    qg = q.reshape(kh, g, d)
    s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale  # (KH, G, TK)
    kv_len = len_ref[0]
    kpos = ik * tk + jax.lax.broadcasted_iota(jnp.int32, (kh, g, tk), 2)
    s = jnp.where(kpos < kv_len, s, NEG_INF)
    h = kh * g
    s = s.reshape(h, tk)
    m_prev = m_scr[...]                            # (H, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(s <= NEG_INF, 0.0, p)            # dead slots contribute 0
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p.reshape(kh, g, tk), v,
                             (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)  # (KH, G, D)
    acc_scr[...] = acc_scr[...] * corr + pv.reshape(h, d)
    m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        acc_ref[0] = acc_scr[...]
        m_ref[0] = jnp.where(m_scr[...] <= NEG_INF, -jnp.inf, m_scr[...])[:, 0]
        l_ref[0] = l_scr[...][:, 0]


@functools.partial(jax.jit, static_argnames=("scale", "tk", "interpret"))
def flash_decode_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        kv_len: jnp.ndarray, scale: float,
                        tk: int = DEFAULT_TK, interpret: bool = True):
    """q (B, H, D), k/v (B, KH, S, D), kv_len (B,) int32.

    Returns (acc (B, H, D) f32, m (B, H) f32, l (B, H) f32) — unnormalized.
    """
    b, h, d = q.shape
    kh, s = k.shape[1], k.shape[2]
    assert h % kh == 0 and s % tk == 0, (h, kh, s, tk)
    g = h // kh
    n_k = s // tk
    kernel = functools.partial(_kernel, scale=scale, tk=tk, n_k=n_k, kh=kh, g=g)
    return pl.pallas_call(
        kernel,
        grid=(b, n_k),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, ik: (b_,)),
            pl.BlockSpec((1, h, d), lambda b_, ik: (b_, 0, 0)),
            pl.BlockSpec((1, kh, tk, d), lambda b_, ik: (b_, 0, ik, 0)),
            pl.BlockSpec((1, kh, tk, d), lambda b_, ik: (b_, 0, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, d), lambda b_, ik: (b_, 0, 0)),
            pl.BlockSpec((1, h), lambda b_, ik: (b_, 0)),
            pl.BlockSpec((1, h), lambda b_, ik: (b_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, q, k, v)
