"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(count):
        c = count.astype(jnp.float32)
        warm = peak * c / max(1, warmup)
        prog = jnp.clip((c - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(c < warmup, warm, cos)

    return lr


def constant(value: float):
    return lambda count: jnp.asarray(value, jnp.float32)
