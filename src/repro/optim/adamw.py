"""AdamW (decoupled weight decay), pure-pytree implementation."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32   # bf16 moments = ZeRO-friendly memory plan

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        count = state.count + 1
        b1, b2 = self.b1, self.b2
        lr = self.lr(count)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + gf * (1 - b1)
            v32 = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
            mh = m32 / (1 - b1 ** count.astype(jnp.float32))
            vh = v32 / (1 - b2 ** count.astype(jnp.float32))
            step = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:
                step = step + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step
            return (new_p.astype(p.dtype), m32.astype(self.moment_dtype),
                    v32.astype(self.moment_dtype))

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, AdamWState(count, new_m, new_v)

    def state_pspecs(self, param_pspecs):
        from jax.sharding import PartitionSpec as P
        return AdamWState(P(), param_pspecs, param_pspecs)
