"""qwen2-7b [dense] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
GQA with QKV bias. [arXiv:2407.10671; hf]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b", family="dense",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
        vocab_size=152064, head_dim=128, qkv_bias=True, rope_theta=1e6,
        block_pattern=("dense",), superlayer_repeat=28,
        param_dtype=jnp.bfloat16, grad_accum=16, optimizer="adamw",
        sub_quadratic=False,
    ).validate()
