"""Jit'd public wrappers for flash_decode: padding, normalization, dispatch."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode import ref
from repro.kernels.flash_decode.kernel import DEFAULT_TK, flash_decode_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention_partial(q, k, v, kv_len, scale: Optional[float] = None,
                             tk: Optional[int] = None
                             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Kernel-backed partials; same contract as ref.decode_attention_partial."""
    b, h, d = q.shape
    s = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    tk = tk or min(DEFAULT_TK, s)
    pad = -s % tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return flash_decode_kernel(q, k, v, kv_len.astype(jnp.int32), scale=scale,
                               tk=tk, interpret=not _on_tpu())


def decode_attention(q, k, v, kv_len, scale: Optional[float] = None,
                     tk: Optional[int] = None) -> jnp.ndarray:
    """Normalized decode attention (single device / single shard)."""
    acc, m, l = decode_attention_partial(q, k, v, kv_len, scale, tk)
    return ref.normalize(acc, l, q.dtype)
