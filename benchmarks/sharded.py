"""Sharded-engine scaling: delivered-notification throughput, N=4 vs N=1.

The mesh partitions the subscription population, so aggregate delivery
capacity (per-tick delivery caps, retry-ring slots) scales with the shard
count while per-DEVICE resources stay fixed. This suite drives both engines
through the same seeded workload in a sustained-overflow regime — produced
notifications per tick exceed a single device's delivery caps several times
over — then lets each engine drain to empty. The single-device engine needs
~4x the effective ticks (each re-paying the join) and falls back to host
spill once its one ring fills; the 4-shard engine absorbs the same stream
with per-shard rings and 4x the per-tick delivery budget.

Metric: delivered subscription notifications (sIDs) per second over the
whole stream including the drain tail — partition-independent content, so
the suite asserts both engines delivered the IDENTICAL total with zero
drops before quoting a ratio.

Sizing note: this suite runs the SAME size under ``--smoke`` — the measured
quantity is a capacity ratio, which is only meaningful when the
shard-divisible join work (candidates x groups) dominates the fixed
per-engine-call dispatch cost. Shrinking the population pushes the regime
to dispatch-bound, where an N-shard engine on one CPU core pays N
dispatches per tick and the ratio collapses to noise. 32k subscriptions at
group_cap=2 (16k groups) is the smallest validated join-dominant point.

Device-count mechanics: ``--xla_force_host_platform_device_count`` must be
set before jax initializes, and ``benchmarks.run`` imports jax long before
suites execute — so each engine runs in a child process with the flag in
its environment, reporting one CSV line back. ``python -m
benchmarks.sharded --child ...`` is that entry point.
"""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks import common


def _child(num_shards: int, n_subs: int, ingest: int, ticks: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.sharded", "--child",
         str(num_shards), str(n_subs), str(ingest), str(ticks)],
        capture_output=True, text=True, env=env, check=False)
    for line in out.stdout.splitlines():
        if line.startswith("CHILD,"):
            return line
    raise RuntimeError(
        f"sharded child (S={num_shards}) produced no result line:\n"
        f"{out.stdout}\n{out.stderr}")


def run(rng) -> None:
    n_subs, ingest, ticks = 32000, 128, 6    # same under smoke; see above
    rows = {}
    for s in (1, 4):
        tag = _child(s, n_subs, ingest, ticks).split(",")
        rows[s] = dict(delivered=int(tag[2]), dropped=int(tag[3]),
                       wall=float(tag[4]), ticks=int(tag[5]),
                       p50=float(tag[6]), p99=float(tag[7]))
    r1, r4 = rows[1], rows[4]
    # the ratio is only meaningful over identical content, delivered exactly
    assert r1["dropped"] == r4["dropped"] == 0, (r1, r4)
    assert r1["delivered"] == r4["delivered"], (r1, r4)
    rate1 = r1["delivered"] / r1["wall"]
    rate4 = r4["delivered"] / r4["wall"]
    common.emit("sharded/scaling_n1/rate", r1["wall"],
                f"{rate1:.0f} notifications/s over {r1['ticks']} ticks "
                f"(1 shard, drain included)")
    common.emit("sharded/scaling_n4/speedup", r4["wall"],
                f"x{rate4 / rate1:.2f} delivered-notification throughput vs "
                f"1 shard ({rate4:.0f}/s, {r4['ticks']} ticks, fixed "
                f"per-device caps)")
    # dispatch-to-materialize latency of one fused tick across all shards
    # (the window the pipelined runtime overlaps with control-plane work)
    for s in (1, 4):
        common.emit(f"sharded/scaling_n{s}/tick_latency", rows[s]["p50"],
                    f"p50={rows[s]['p50'] * 1e3:.1f}ms;"
                    f"p99={rows[s]['p99'] * 1e3:.1f}ms "
                    f"dispatch-to-materialize, {s} shard(s)")


# ---------------------------------------------------------------------------
# child process: one engine, one measurement
# ---------------------------------------------------------------------------


def _child_main(num_shards: int, n_subs: int, ingest: int,
                ticks: int) -> None:
    import time

    import numpy as np

    from repro.core import records as R
    from repro.core.channel import tweets_about_drugs
    from repro.core.plans import ExecutionFlags
    from repro.core.sharded import ShardedBADEngine
    from repro.data.synthetic import drug_tweak, tweet_batch

    def make_tweets(rng, n, t0):
        batch = tweet_batch(rng, n, t0)
        fields = drug_tweak(np.asarray(batch.fields).copy(), rng, 0.1)
        return R.RecordBatch.from_numpy(fields, np.asarray(batch.location))

    flags = ExecutionFlags(scan_mode="window", aggregation=True,
                           param_pushdown=True)
    rng = np.random.default_rng(common.SEED)
    eng = ShardedBADEngine(
        num_shards=num_shards,
        dataset_capacity=1 << 15, index_capacity=1 << 12,
        max_window=1 << 12, max_candidates=1 << 11,
        brokers=("B1", "B2"), group_cap=2,    # many small groups: the join
        # grid (candidates x groups) is the shard-divisible cost
        max_deliver_pairs=128, max_notify=1024,    # per DEVICE, fixed
        ring_capacity=1 << 14, max_spill=1 << 14,
        spill_capacity=1 << 19)
    eng.create_channel(tweets_about_drugs())
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, n_subs),
                       rng.integers(0, 2, n_subs))
    # warmup: trace/compile + two steady ticks, then settle so the timed
    # window starts from an empty ring on every shard
    for w in range(2):
        eng.ingest(make_tweets(rng, ingest, t0=100 * (w + 1)))
        eng.execute_all(flags, timed=False, deliver=True)
    for _ in range(5000):
        if eng.ring_pending_pairs() + eng.ring_pending_sids() == 0:
            break
        eng.execute_all(flags, timed=False, deliver=True)
    while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
        eng.drain_spilled()

    delivered = dropped = ticks_run = 0

    def account(stats):
        nonlocal delivered, dropped
        delivered += stats.delivered_sids
        dropped += stats.dropped_pairs + stats.dropped_sids

    t0 = time.perf_counter()
    lat = []     # per-tick dispatch-to-materialize seconds
    for tick in range(ticks):
        eng.ingest(make_tweets(rng, ingest, t0=1000 * (tick + 3)))
        # dispatch/sync split so the measured latency is the one the
        # pipelined runtime hides: all shards enqueue before any blocks
        pend = eng.dispatch_all(flags, timed=False, deliver=True)
        reps = pend.sync()
        lat.append(pend.latency_s)
        ticks_run += 1
        for rep in reps.values():
            account(rep.overflow)
    # drain to empty: the capacity-bound engine keeps paying join ticks
    for _ in range(10000):
        if eng.ring_pending_pairs() + eng.ring_pending_sids() == 0:
            break
        reps = eng.execute_all(flags, timed=False, deliver=True)
        ticks_run += 1
        for rep in reps.values():
            account(rep.overflow)
    while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
        for dr in eng.drain_spilled().values():
            account(dr.stats)
    wall = time.perf_counter() - t0
    p50, p99 = (float(np.percentile(lat, q)) for q in (50, 99))
    print(f"CHILD,{num_shards},{delivered},{dropped},{wall:.4f},{ticks_run},"
          f"{p50:.6f},{p99:.6f}")


if __name__ == "__main__":
    if len(sys.argv) >= 6 and sys.argv[1] == "--child":
        _child_main(*(int(a) for a in sys.argv[2:6]))
    else:
        print("usage: python -m benchmarks.sharded "
              "--child <shards> <n_subs> <ingest> <ticks>", file=sys.stderr)
        sys.exit(2)
