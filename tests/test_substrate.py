"""Optimizers, checkpoint manager, fault tolerance, compression, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.data.synthetic import TokenStream, tweet_batch
from repro.launch.mesh import make_mesh
from repro.distributed.compression import (compressed_psum_tree, ef_compress,
                                           dequantize_int8, init_residuals)
from repro.optim import Adafactor, AdamW, constant, make_optimizer
from repro.runtime.failure import (FailureInjector, StepTimer,
                                   largest_valid_mesh, run_with_recovery)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quadratic_params():
    return {"w": jnp.asarray([1.5, -2.0, 3.0]), "b": jnp.asarray([[0.5, -0.5],
                                                                  [1.0, 2.0]])}


@pytest.mark.parametrize("opt", [AdamW(lr=constant(0.05), weight_decay=0.0),
                                 Adafactor(lr=constant(0.5)),
                                 Adafactor(lr=constant(0.5), b1=0.0)])
def test_optimizers_descend_quadratic(opt):
    params = _quadratic_params()
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_factored_state_is_small():
    p = {"w": jnp.zeros((64, 128))}
    st = Adafactor(lr=constant(1e-3)).init(p)
    assert st.v_row["w"].shape == (64,)
    assert st.v_col["w"].shape == (128,)


def test_adafactor_b1_zero_has_no_moment():
    p = {"w": jnp.zeros((64, 128))}
    st = Adafactor(lr=constant(1e-3), b1=0.0).init(p)
    assert st.m["w"].shape == (1,)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_keep(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.asarray([1, 2, 3], jnp.int32)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree))
    assert mgr.all_steps() == [2, 3]
    got = mgr.restore(3, tree)
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree["a"]) + 3)


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    tree = {"w": jnp.ones((128, 128))}
    mgr.save(5, tree)
    mgr.wait()
    assert mgr.latest_step() == 5
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_elastic_restore_new_sharding(tmp_path):
    """Restore onto different shardings (elastic re-mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr.save(1, tree)
    mesh = make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got = mgr.restore(1, tree, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_straggler_detection():
    t = StepTimer(ema_alpha=1.0)
    for w, dt in [("h0", 1.0), ("h1", 1.1), ("h2", 0.9), ("h3", 5.0)]:
        t.record(w, dt)
    assert t.stragglers() == ["h3"]


def test_run_with_recovery_resumes_through_failures(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    injector = FailureInjector(fail_at=(7, 13))
    state = {"step": jnp.zeros(())}

    def restore():
        s = mgr.latest_step()
        return s if s is not None else 0

    def loop(start):
        for step in range(start, 20):
            injector.maybe_fail(step)
            if (step + 1) % 5 == 0:
                mgr.save(step + 1, state)
        return 20

    out = run_with_recovery(loop, lambda s: None, restore, 20, 5)
    assert out["final_step"] == 20
    assert out["restarts"] == 2
    assert injector.failures == 2


def test_largest_valid_mesh():
    assert largest_valid_mesh(256, 16) == (16, 16)
    assert largest_valid_mesh(240, 16) == (8, 16)    # lost a host: shrink DP
    with pytest.raises(ValueError):
        largest_valid_mesh(8, 16)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_ef_compression_unbiased_accumulation(rng):
    """Error feedback: quantization error does not accumulate over steps."""
    x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    residual = jnp.zeros_like(x)
    total_sent = jnp.zeros_like(x)
    for _ in range(50):
        q, scale, residual = ef_compress(x, residual)
        total_sent = total_sent + dequantize_int8(q, scale)
    # mean of sent messages converges to x
    np.testing.assert_allclose(np.asarray(total_sent / 50), np.asarray(x),
                               atol=2e-3)


def test_compressed_psum_tree_single_axis(rng):
    mesh = make_mesh((1,), ("pod",))
    tree = {"g": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    res = init_residuals(tree)
    out, new_res = compressed_psum_tree(tree, res, mesh, "pod")
    np.testing.assert_allclose(np.asarray(out["g"]), np.asarray(tree["g"]),
                               atol=np.abs(np.asarray(tree["g"])).max() / 100)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_tweet_batch_selectivities(rng):
    from repro.core import records as R
    b = tweet_batch(rng, 20000, t0=0)
    f = np.asarray(b.fields)
    assert abs((f[:, R.ABOUT_COUNTRY] == 0).mean() - 0.5) < 0.03      # I
    assert abs((f[:, R.RETWEET_COUNT] > 10000).mean() - 0.5) < 0.03   # II
    assert abs((f[:, R.HATE_SPEECH_RATE] > 5).mean() - 0.5) < 0.03    # III
    assert abs((f[:, R.THREATENING_RATE] > 5).mean() - 0.2) < 0.03    # IV
    assert abs((f[:, R.WEAPON_MENTIONED] == 1).mean() - 0.2) < 0.03   # V
    # combined selectivity ~ 0.5*0.5*0.5*0.2*0.2 = 0.5%
    all5 = ((f[:, R.ABOUT_COUNTRY] == 0) & (f[:, R.RETWEET_COUNT] > 10000)
            & (f[:, R.HATE_SPEECH_RATE] > 5) & (f[:, R.THREATENING_RATE] > 5)
            & (f[:, R.WEAPON_MENTIONED] == 1)).mean()
    assert 0.001 < all5 < 0.012


def test_token_stream_deterministic_and_host_sharded():
    s0 = TokenStream(vocab_size=100, seq_len=16, global_batch=8,
                     num_hosts=2, host_id=0)
    s1 = TokenStream(vocab_size=100, seq_len=16, global_batch=8,
                     num_hosts=2, host_id=1)
    a = s0.batch(3)
    b = s0.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])   # deterministic
    assert a["tokens"].shape == (4, 16)                        # per-host shard
    assert not np.array_equal(a["tokens"], s1.batch(3)["tokens"])
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
