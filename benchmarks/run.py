# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark driver: python -m benchmarks.run [--only fig16,table1,...]
                                              [--smoke] [--json PATH]

CPU-scaled versions of every paper experiment (structure preserved, counts
shrunk — see benchmarks/common.py). The paper's *ratios* are the validation
target; each derived column quotes the paper's number where applicable.

``--smoke`` shrinks every suite ~16x (CI-sized; ratios stay meaningful,
absolute times do not). ``--json PATH`` additionally dumps every emitted row
as JSON — CI uploads ``BENCH_smoke.json`` as the perf-trajectory artifact.
See benchmarks/README.md for the full catalogue.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from benchmarks import (aggregation, bad_index, broker_ops, churn, common,
                        compact_join, enrich, group_size, kernel_perf,
                        max_subscriptions, multi_channel, pipeline,
                        query_plan, real_world, scaling, sharded)

SUITES = {
    "fig12_13_group_size": group_size.run,
    "table1_aggregation": aggregation.run,
    "table2_broker_ops": broker_ops.run,
    "fig14_query_plan": query_plan.run,
    "fig16_bad_index": bad_index.run,
    "fig17_max_subscriptions": max_subscriptions.run,
    "fig18_19_scaling": scaling.run,
    "fig21_real_world": real_world.run,
    "kernel_perf": kernel_perf.run,
    "multi_channel": multi_channel.run,
    "churn_sustained": churn.run,
    "compact_join": compact_join.run,
    "sharded_scaling": sharded.run,
    "pipeline_overlap": pipeline.run,
    "enrich_ranked": enrich.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite substrings")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (see common.scale)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump emitted rows as JSON (e.g. BENCH_smoke.json)")
    args = ap.parse_args()
    if args.smoke:
        common.set_smoke()
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in SUITES.items():
        if args.only and not any(s in name for s in args.only.split(",")):
            continue
        print(f"# --- {name} ---", flush=True)
        fn(np.random.default_rng(0))
    total = time.time() - t0
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"smoke": common.SMOKE, "total_s": round(total, 1),
                       "results": common.RESULTS}, f, indent=1)
        print(f"# wrote {len(common.RESULTS)} rows to {args.json}",
              file=sys.stderr)
    print(f"# total {total:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
