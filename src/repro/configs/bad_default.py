"""The paper's own workload configuration: datasets, channels, rates.

Mirrors §5.1: 2M preloaded EnrichedTweets, 2000 tweets/s ingest, ~30 KB
payloads, 1M subscribers, 10-minute periods, frame sizes 40/80 KB. The
CPU-scale variants used by benchmarks shrink counts, never structure.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class BADWorkload:
    preload_records: int = 2_000_000
    tweets_per_second: int = 2_000
    period_s: int = 600
    payload_bytes: int = 30 * 1024
    num_subscribers: int = 1_000_000
    frame_bytes: int = 40 * 1024
    num_brokers: int = 4
    num_states: int = 50


def get_config() -> BADWorkload:
    return BADWorkload()


def cpu_scale(w: BADWorkload | None = None, factor: int = 64) -> BADWorkload:
    w = w or get_config()
    return dataclasses.replace(
        w,
        preload_records=max(1024, w.preload_records // factor),
        tweets_per_second=max(64, w.tweets_per_second // 4),
        period_s=30,
        num_subscribers=max(4096, w.num_subscribers // factor),
    )
