"""Fig. 14: plan augmentation (UserParameters early semi-join) under varying
fractions of tweets that match some subscriber (10/15/20%) — plus the
``table2/planner`` suite: the adaptive runtime planner vs EVERY static
(scan x layout) configuration on a mixed skewed-selectivity + churn
workload.

The subscription sets cover only a subset of states; incoming tweets are
drawn so the stated fraction matches at least one subscription.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import records as R
from repro.core.channel import most_threatening_tweets, tweets_about_drugs
from repro.core.engine import BADEngine
from repro.core.planner import PlannerConfig, RuntimePlanner
from repro.core.plans import ChannelPlan, ExecutionFlags, enumerate_plans
from repro.data.synthetic import (STATE_WEIGHTS, drug_tweak,
                                  subscriptions_by_population, tweet_batch)
from benchmarks.common import emit, exec_time, fresh_rng, scale


def build(rng, match_frac: float, n_subs=None, n_new=None):
    n_subs = scale(20_000, 1024) if n_subs is None else n_subs
    n_new = scale(16_384, 1024) if n_new is None else n_new
    eng = BADEngine(dataset_capacity=1 << 16, index_capacity=1 << 15,
                    max_window=1 << 15, max_candidates=1 << 12)
    eng.create_channel(most_threatening_tweets())
    # subscribers concentrated on 5 states
    sub_states = rng.integers(0, 5, n_subs).astype(np.int32)
    eng.subscribe_bulk("MostThreateningTweets", sub_states,
                       np.zeros(n_subs, np.int32))
    b = tweet_batch(rng, n_new, t0=100)
    f = np.asarray(b.fields).copy()
    # all records pass the fixed predicate; match_frac land on subscribed states
    f[:, R.THREATENING_RATE] = 10
    hit = rng.random(n_new) < match_frac
    f[hit, R.STATE] = rng.integers(0, 5, int(hit.sum()))
    f[~hit, R.STATE] = rng.integers(5, 50, int((~hit).sum()))
    eng.ingest(R.RecordBatch.from_numpy(f, np.asarray(b.location)))
    return eng


def run_fig14(rng) -> None:
    for frac in (0.10, 0.15, 0.20):
        eng = build(rng, frac)
        t_orig, i_o = exec_time(eng, "MostThreateningTweets",
                                ExecutionFlags(scan_mode="window"))
        t_push, i_p = exec_time(eng, "MostThreateningTweets",
                                ExecutionFlags(scan_mode="window",
                                               param_pushdown=True))
        assert i_o["notified"] == i_p["notified"]
        emit(f"fig14/set{int(frac*100)}/original", t_orig,
             f"results={i_o['results']}")
        emit(f"fig14/set{int(frac*100)}/augmented", t_push,
             f"x{t_orig/max(t_push,1e-9):.2f}")


# ---------------------------------------------------------------------------
# table2/planner: adaptive runtime planner vs every static configuration
# ---------------------------------------------------------------------------
#
# The mixed workload the ISSUE asks for: one dense channel (TweetsAboutDrugs,
# 30% of tweets match, population-skewed subscriptions over all 50 states,
# sustained subscription churn) and one sparse channel (MostThreateningTweets
# subscribed only to the 5 LEAST populous states, so matches with a
# subscriber are rare). Every engine starts from a deliberately bad plan
# (full scan, flat layout); statics are pinned, the adaptive engine carries a
# RuntimePlanner and must re-plan mid-stream — the benchmark asserts the
# conservation identity across those switches and zero retraces/rebuilds
# once the assignment stabilizes.

START_PLAN = ChannelPlan("full", False, False)
TICKS, WARMUP, STEADY = 16, 8, 6


def _mixed_batch(rng, n, t0):
    b = tweet_batch(rng, n, t0=t0)
    f = drug_tweak(np.asarray(b.fields).copy(), rng, 0.30)
    return R.RecordBatch.from_numpy(f, np.asarray(b.location))


def build_mixed_engine():
    """Deterministic regardless of caller state — every candidate config
    must see bit-identical subscriptions and tweets (see ``fresh_rng``)."""
    rng = fresh_rng("planner_engine")
    # capacities sized so even the "full"-scan static's fused delivery
    # stays in int32 rank space (scan bucket x pair width x group cap);
    # delivery caps deliberately tight so flat layouts overflow into the
    # ring/queue and conservation is non-trivial
    eng = BADEngine(dataset_capacity=1 << 15, index_capacity=1 << 14,
                    max_window=1 << 14, max_candidates=1 << 12,
                    brokers=("Broker1", "Broker2"), group_cap=256,
                    max_deliver_pairs=64, max_notify=1 << 12,
                    ring_capacity=1 << 11)
    eng.create_channel(tweets_about_drugs())
    eng.create_channel(most_threatening_tweets())
    n_subs = scale(8_000, 512)
    params, brokers = subscriptions_by_population(rng, n_subs, 2)
    drug_sids = eng.subscribe_bulk("TweetsAboutDrugs", params, brokers)
    low5 = np.argsort(STATE_WEIGHTS)[:5].astype(np.int32)
    eng.subscribe_bulk("MostThreateningTweets",
                       rng.choice(low5, n_subs).astype(np.int32),
                       rng.integers(0, 2, n_subs).astype(np.int32))
    eng.ingest(_mixed_batch(rng, scale(8_192, 1024), 0))
    eng.execute_all(ExecutionFlags.fully_optimized(), timed=False)  # advance
    for name in eng.channels:
        eng.set_plan(name, START_PLAN)
    return eng, drug_sids


def _drive(eng, drug_sids, planner=None):
    """Run the mixed churn workload under the engine's per-channel plans.

    Returns (timed wall seconds per tick, info). Every tick asserts the
    per-channel conservation identity (delivered + spilled + dropped ==
    produced + retried); the run ends with a ring flush + drain-to-empty so
    the TELESCOPED identity — total delivered + dropped == total produced —
    must hold exactly, including across every mid-stream plan switch."""
    rng = fresh_rng("planner_ticks")
    pool = list(map(int, drug_sids))
    k, ingest_n = scale(2_048, 128), scale(1_024, 256)
    prod_p = prod_s = dlv_p = dlv_s = drop_p = drop_s = 0
    wall, steady_snap, late_switches = 0.0, None, 0

    def drain_all():
        nonlocal dlv_p, dlv_s, drop_p, drop_s
        while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
            for drr in eng.drain_spilled().values():
                dlv_p += drr.stats.delivered_pairs
                dlv_s += drr.stats.delivered_sids
                drop_p += drr.stats.dropped_pairs
                drop_s += drr.stats.dropped_sids

    for tick in range(TICKS):
        adds = rng.integers(0, 50, k).astype(np.int32)
        new = eng.subscribe_bulk(
            "TweetsAboutDrugs", adds,
            rng.integers(0, 2, k).astype(np.int32))
        pool.extend(map(int, new))
        rm, pool = pool[:k], pool[k:]
        eng.remove_subscriptions("TweetsAboutDrugs",
                                 np.asarray(rm, np.int32))
        eng.ingest(_mixed_batch(rng, ingest_n, 1_000 + tick * 100))
        t0 = time.perf_counter()
        reports = eng.execute_all(None, timed=False, deliver=True)
        drain_all()
        dt = time.perf_counter() - t0
        if tick >= WARMUP:
            wall += dt
        for rep in reports.values():
            o = rep.overflow
            assert (o.delivered_pairs + o.spilled_pairs + o.dropped_pairs
                    == rep.num_results + o.retried_pairs), rep.channel
            assert (o.delivered_sids + o.spilled_sids + o.dropped_sids
                    == rep.num_notified + o.retried_sids), rep.channel
            prod_p += rep.num_results
            prod_s += rep.num_notified
            dlv_p += o.delivered_pairs
            dlv_s += o.delivered_sids
            drop_p += o.dropped_pairs
            drop_s += o.dropped_sids
        if planner is not None:
            sw = planner.step(reports)
            if steady_snap is not None:
                late_switches += len(sw)
        if tick == TICKS - STEADY:
            steady_snap = eng.maintenance.snapshot()
    eng.flush_rings()
    drain_all()
    assert eng.ring_flush_drops == 0
    assert dlv_p + drop_p == prod_p, (dlv_p, drop_p, prod_p)
    assert dlv_s + drop_s == prod_s, (dlv_s, drop_s, prod_s)
    maint = eng.maintenance.since(steady_snap)
    return wall / (TICKS - WARMUP), dict(
        delivered=dlv_p + dlv_s, produced=prod_p + prod_s,
        steady_maint=maint, late_switches=late_switches)


def _plan_label(p: ChannelPlan) -> str:
    return f"{p.scan_mode}+{'agg' if p.aggregation else 'flat'}"


def run_planner() -> None:
    static_walls = {}
    for plan in enumerate_plans():
        eng, sids = build_mixed_engine()
        for name in eng.channels:
            eng.set_plan(name, plan)
        t, info = _drive(eng, sids)
        static_walls[_plan_label(plan)] = t
        emit(f"table2/planner/static/{_plan_label(plan)}", t,
             f"delivered={info['delivered']}")
    eng, sids = build_mixed_engine()
    planner = RuntimePlanner(eng, PlannerConfig())
    t_adapt, info = _drive(eng, sids, planner=planner)
    maint = info["steady_maint"]
    # acceptance: at least one mid-stream switch, stats-proven stability
    assert len(planner.switches) >= 1, "planner never re-planned"
    assert info["late_switches"] == 0, planner.switches
    assert maint.traces == 0 and maint.rebuilds == 0, maint
    final = {n: _plan_label(eng.channel_plan(n)) for n in eng.channels}
    emit("table2/planner/adaptive", t_adapt,
         f"switches={len(planner.switches)} plans={final} "
         f"steady_traces={maint.traces} steady_rebuilds={maint.rebuilds}")
    best = min(static_walls, key=static_walls.get)
    worst = max(static_walls, key=static_walls.get)
    emit("table2/planner/vs_best_static", static_walls[best],
         f"best={best} x{static_walls[best] / max(t_adapt, 1e-9):.2f}")
    emit("table2/planner/vs_worst_static", static_walls[worst],
         f"worst={worst} x{static_walls[worst] / max(t_adapt, 1e-9):.2f}")


def run(rng) -> None:
    run_fig14(rng)
    run_planner()


if __name__ == "__main__":
    import argparse

    from benchmarks import common
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--only-planner", action="store_true")
    a = ap.parse_args()
    if a.smoke:
        common.set_smoke()
    if a.only_planner:
        run_planner()
    else:
        run(np.random.default_rng(0))
