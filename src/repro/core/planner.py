"""§Adaptive runtime planner: per-channel plan selection from observed stats.

The paper's second optimization — "intelligent modifications to the query
plan" — made adaptive: every channel carries its own ``ChannelPlan`` (scan
mode x layout x backend, ``core/plans.py``), ``execute_all`` partitions
channels into plan-groups (one fused jitted call per distinct plan), and the
``RuntimePlanner`` here closes the loop by observing the per-channel stats
the engine already surfaces — selectivity from ``ExecutionReport``, overflow
pressure from ``DeliveryStats``, churn from epoch advances — and switching a
channel's plan through ``BADEngine.set_plan`` under hysteresis (a proposal
must persist for ``patience`` ticks and a switched channel rests for
``cooldown`` ticks), so plan flapping can't destroy the zero-retrace steady
state the fused executor is built around.

Offline seeding reuses the hillclimb variant-search idiom
(``launch/hillclimb.py``): ``search_plans`` times every candidate plan per
channel and ``save_plans``/``load_plans``/``apply_plans`` persist the winner
assignment as JSON (``launch/plan_search.py`` is the CLI wrapper).
"""
import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import plans
from repro.core.plans import ChannelPlan


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    """Hysteresis + decision thresholds for the runtime planner.

    ``patience`` consecutive identical proposals are required before a
    switch, and after a switch the channel is frozen for ``cooldown`` ticks:
    both guard the fused executor's zero-retrace steady state (every switch
    re-partitions plan-groups, which re-traces once and migrates ring state
    through the SpillQueue — cheap occasionally, fatal every tick)."""

    ema: float = 0.5                 # weight of the newest observation
    patience: int = 2                # identical proposals before switching
    cooldown: int = 4                # ticks a switched channel is frozen
    dense_selectivity: float = 0.5   # results/scanned above -> window scan
    agg_fanout: float = 2.0          # notified/results above -> aggregate
    overflow_pressure: float = 0.25  # (spilled+dropped)/produced above -> agg
    compact_selectivity: float = 0.15  # window-scan sel below -> compact join
    param_pushdown: bool = True      # proposed for every param-join channel
    backend: Optional[str] = None    # force a backend; None keeps current


@dataclasses.dataclass
class ChannelObservation:
    """EMA-smoothed per-channel signals the planner decides from."""

    selectivity: float = 0.0   # num_results / scanned
    fanout: float = 0.0        # num_notified / max(num_results, 1)
    pressure: float = 0.0      # (spilled + dropped) / produced
    ticks: int = 0

    def update(self, sel: float, fan: float, prs: float, ema: float) -> None:
        if self.ticks == 0:
            self.selectivity, self.fanout, self.pressure = sel, fan, prs
        else:
            keep = 1.0 - ema
            self.selectivity = keep * self.selectivity + ema * sel
            self.fanout = keep * self.fanout + ema * fan
            self.pressure = keep * self.pressure + ema * prs
        self.ticks += 1


@dataclasses.dataclass(frozen=True)
class PlanSwitch:
    tick: int
    channel: str
    old: ChannelPlan
    new: ChannelPlan


class RuntimePlanner:
    """Observes fused-execution reports and re-plans channels in place.

    Drive it one call per engine tick::

        reports = engine.execute_all(None, deliver=True)
        planner.step(reports)

    ``step`` returns the switches applied THIS tick (usually none); the full
    history accumulates in ``planner.switches``. The planner only ever talks
    to the engine through ``set_plan`` — ring/spill migration, cache
    re-keying, and plan-group re-partitioning all ride the ``execute_all``
    machinery on the next tick."""

    def __init__(self, engine, config: Optional[PlannerConfig] = None):
        self.engine = engine
        self.config = config or PlannerConfig()
        self.obs: Dict[str, ChannelObservation] = {}
        self.switches: List[PlanSwitch] = []
        self._streak: Dict[str, Tuple[ChannelPlan, int]] = {}
        self._last_switch: Dict[str, int] = {}
        self._tick = 0

    # -- observation ---------------------------------------------------

    def observe(self, reports: Dict) -> None:
        cfg = self.config
        for name, rep in reports.items():
            sel = rep.num_results / max(rep.scanned, 1)
            fan = rep.num_notified / max(rep.num_results, 1)
            prs = 0.0
            o = rep.overflow
            if o is not None:
                produced = (o.delivered_pairs + o.spilled_pairs
                            + o.dropped_pairs + o.delivered_sids
                            + o.spilled_sids + o.dropped_sids)
                if produced:
                    # ring-resident entries count as spilled EVERY call they
                    # are re-presented (the conservation identity needs
                    # that), so raw spill counts overstate pressure exactly
                    # when the retry ring is absorbing the overflow —
                    # subtract the retried volume so a ring doing its job
                    # doesn't flip the channel to aggregated
                    retried = (getattr(o, "retried_pairs", 0)
                               + getattr(o, "retried_sids", 0))
                    prs = max(0, (o.spilled_pairs + o.dropped_pairs
                                  + o.spilled_sids + o.dropped_sids)
                              - retried) / produced
            self.obs.setdefault(name, ChannelObservation()).update(
                sel, fan, prs, cfg.ema)

    # -- decision ------------------------------------------------------

    def propose(self, name: str) -> ChannelPlan:
        """The plan the current observations argue for — no hysteresis."""
        cfg = self.config
        st = self.engine.channels[name]
        cur = self.engine.channel_plan(name)
        ob = self.obs.get(name)
        if ob is None or ob.ticks == 0:
            return cur
        # sparse channels want the BAD index (watermark-bounded candidate
        # discovery); dense ones can stay on a window scan. The selectivity
        # gate applies on ENTRY only: once on bad_index the observed
        # selectivity is measured against the index's own pre-filtered
        # candidate set (it reads ~1.0 exactly when the index filters
        # perfectly), so an exit threshold on the same signal would evict
        # the index for doing its job and flap every cooldown. "full" is
        # never proposed: it only exists as the paper's unoptimized
        # baseline.
        if not st.spec.fixed_preds:
            scan = "window"
        elif (cur.scan_mode == "bad_index"
              or ob.selectivity < cfg.dense_selectivity):
            scan = "bad_index"
        else:
            scan = "window"
        # aggregation collapses per-subscription rows into per-group slots:
        # worth it when fanout amortizes the group join, or when flat-layout
        # volume is overflowing the delivery caps
        agg = (ob.fanout >= cfg.agg_fanout
               or ob.pressure >= cfg.overflow_pressure)
        pushdown = cfg.param_pushdown and st.spec.join == "param"
        backend = cfg.backend or cur.backend
        if cfg.backend is None:
            # the compact join pays off when a wide scan yields few live
            # candidates but the channel cannot use the BAD index (no fixed
            # predicates pins it to a window scan): the padded grid is
            # mostly dead slots and the CSR stream collapses it. Dense
            # channels propose the padded fused join of the same backend
            # family (compaction would just add scatter overhead).
            if (scan == "window" and not st.spec.fixed_preds
                    and ob.selectivity < cfg.compact_selectivity):
                backend = plans.compact_variant(backend)
            else:
                backend = ("pallas"
                           if plans.backend_family(backend) == "pallas"
                           else "oracle")
        return ChannelPlan(scan, agg, pushdown, backend)

    def step(self, reports: Dict) -> List[PlanSwitch]:
        """Observe one tick's reports, then switch any channel whose
        proposal survived ``patience`` ticks and is out of ``cooldown``."""
        self._tick += 1
        self.observe(reports)
        applied: List[PlanSwitch] = []
        for name in reports:
            if name not in self.engine.channels:
                continue
            cur = self.engine.channel_plan(name)
            want = self.propose(name)
            if want == cur:
                self._streak.pop(name, None)
                continue
            prev, n = self._streak.get(name, (None, 0))
            n = n + 1 if prev == want else 1
            self._streak[name] = (want, n)
            if n < self.config.patience:
                continue
            last = self._last_switch.get(name)
            if last is not None and self._tick - last < self.config.cooldown:
                continue
            self.engine.set_plan(name, want)
            self._streak.pop(name, None)
            self._last_switch[name] = self._tick
            sw = PlanSwitch(self._tick, name, cur, want)
            self.switches.append(sw)
            applied.append(sw)
        return applied

    def stable_since(self) -> Optional[int]:
        """Tick of the last switch (None if never switched) — benchmarks
        snapshot ``engine.maintenance`` after this to prove zero
        retraces/rebuilds under a stable assignment."""
        return self.switches[-1].tick if self.switches else None


# ---------------------------------------------------------------------------
# offline plan seeding (hillclimb variant-search idiom) + persistence
# ---------------------------------------------------------------------------

def search_plans(engine, candidates: Optional[Tuple[ChannelPlan, ...]] = None,
                 repeats: int = 2) -> Dict[str, dict]:
    """Time every candidate plan per channel and return the winners.

    The offline analogue of the runtime planner: measures real per-channel
    ``execute_channel`` wall time (best of ``repeats``, post-warm) for each
    candidate, like ``launch/hillclimb.py`` measures re-lowered variants
    against a baseline. One untimed warmup execution per candidate compiles
    its trace (and, for the compact backends, converges the stream-capacity
    bucket) BEFORE the timed repeats, so winners are chosen by execution
    time, never by compile time. Candidates default to every (scan x layout)
    under the engine's backend family plus its compact variant; each
    candidate runs under its own ``plan.backend`` via the
    ``execute_channel`` backend override. Watermarks are left untouched
    (``advance=False``): searching must not consume the BAD index's pending
    deltas."""
    if candidates is None:
        backend = "pallas" if engine.use_pallas else "oracle"
        candidates = plans.enumerate_plans(
            backends=(backend, plans.compact_variant(backend)))
    out: Dict[str, dict] = {}
    for name in engine.channels:
        rows = []
        for cand in candidates:
            engine.execute_channel(name, cand.flags, advance=False,
                                   timed=False, backend=cand.backend)
            walls = [engine.execute_channel(name, cand.flags, advance=False,
                                            timed=True,
                                            backend=cand.backend).wall_time_s
                     for _ in range(repeats)]
            rows.append({"plan": cand.to_dict(),
                         "wall_s": float(np.min(walls))})
        rows.sort(key=lambda r: r["wall_s"])
        out[name] = {"best": rows[0]["plan"], "candidates": rows}
    return out


def save_plans(path: str, assignment: Dict[str, ChannelPlan],
               meta: Optional[dict] = None) -> None:
    doc = {"plans": {n: p.to_dict() for n, p in assignment.items()}}
    if meta:
        doc["meta"] = meta
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)


def load_plans(path: str) -> Dict[str, ChannelPlan]:
    with open(path) as f:
        doc = json.load(f)
    return {n: ChannelPlan.from_dict(d) for n, d in doc["plans"].items()}


def apply_plans(engine, assignment: Dict[str, ChannelPlan]) -> int:
    """Set each named channel's plan (unknown names ignored); returns the
    number of channels whose plan actually changed."""
    changed = 0
    for name, plan in assignment.items():
        if name in engine.channels:
            changed += int(engine.set_plan(name, plan))
    return changed
