"""Fused broker delivery + spill/retry: conservation, exactly-once drain,
fused/per-channel parity, flat pair-stream compaction (seeded fuzz; the
hypothesis variants in test_property.py run the same shared checkers)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.broker import fanout_sids, pack_payloads, pack_payloads_all
from repro.core.channel import (most_threatening_tweets, tweets_about_crime,
                                tweets_about_drugs)
from repro.core.engine import BADEngine, SpillQueue
from repro.core.plans import (ExecutionFlags, flatten_pairs_all,
                              flatten_result_pairs, flatten_values_all)

from conftest import (check_deliver_all_invariants,
                      check_delivery_conservation, make_tweets,
                      random_stacked_broker_result)


def _overflow_engine(rng, max_deliver_pairs=16, max_notify=32, max_spill=1024,
                     spill_capacity=1 << 16, **kw):
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("B1", "B2"), group_cap=8,
                    max_deliver_pairs=max_deliver_pairs, max_notify=max_notify,
                    max_spill=max_spill, spill_capacity=spill_capacity, **kw)
    eng.create_channel(tweets_about_drugs())
    eng.create_channel(tweets_about_crime(1))
    eng.set_user_locations((rng.normal(size=(30, 2)) * 30).astype(np.float32),
                           rng.integers(0, 2, 30))
    eng.subscribe_bulk("TweetsAboutDrugs",
                       rng.integers(0, 50, 200), rng.integers(0, 2, 200))
    eng.ingest(make_tweets(rng, 500, match_drugs=0.3))
    return eng


# ---------------------------------------------------------------------------
# flat pair streams (plans.py)
# ---------------------------------------------------------------------------


def test_flatten_pairs_matches_numpy_reference(rng):
    for _ in range(10):
        C, n, t = (int(rng.integers(1, 5)), int(rng.integers(1, 20)),
                   int(rng.integers(1, 4)))
        rows = rng.integers(0, 999, (C, n, t)).astype(np.int32)
        tgts = rng.integers(0, 99, (C, n, t)).astype(np.int32)
        mask = rng.random((C, n, t)) < 0.4
        cap = int(rng.integers(1, C * n * t + 4))
        s = flatten_pairs_all(jnp.asarray(rows), jnp.asarray(tgts),
                              jnp.asarray(mask), cap)
        flat = mask.reshape(C, -1)
        want_rows = rows.reshape(C, -1)[flat]
        want_ch = np.broadcast_to(np.arange(C)[:, None],
                                  flat.shape)[flat]
        want_tgts = tgts.reshape(C, -1)[flat]
        total = int(mask.sum())
        assert int(s.total) == total
        k = min(total, cap)
        got_valid = np.asarray(s.valid)
        assert got_valid.sum() == k
        np.testing.assert_array_equal(np.asarray(s.rows)[:k], want_rows[:k])
        np.testing.assert_array_equal(np.asarray(s.channels)[:k],
                                      want_ch[:k])
        np.testing.assert_array_equal(np.asarray(s.targets)[:k],
                                      want_tgts[:k])
        assert (np.asarray(s.rows)[k:] == -1).all()      # no tail aliasing


def test_flatten_result_pairs_proportional_to_pending(rng):
    """The compacted stream covers every valid pair of a stacked result once,
    channel-major, regardless of how much padding the shape buckets carry."""
    stacked, _, exp_rows, exp_tgts = random_stacked_broker_result(
        rng, 3, 16, 3, 4, 2)
    total = sum(len(r) for r in exp_rows)
    s = flatten_result_pairs(stacked, max_total=256)
    assert int(s.total) == total
    v = np.asarray(s.valid)
    assert v.sum() == total
    off = 0
    for c in range(3):
        n = len(exp_rows[c])
        np.testing.assert_array_equal(np.asarray(s.rows)[off:off + n],
                                      exp_rows[c])
        np.testing.assert_array_equal(np.asarray(s.targets)[off:off + n],
                                      exp_tgts[c])
        assert (np.asarray(s.channels)[off:off + n] == c).all()
        off += n


def test_flatten_values_truncation(rng):
    vals = rng.integers(0, 100, (2, 10)).astype(np.int32)
    mask = np.ones((2, 10), bool)
    s = flatten_values_all(jnp.asarray(vals), jnp.asarray(mask), 7)
    assert int(s.total) == 20
    np.testing.assert_array_equal(np.asarray(s.values)[:7], vals.ravel()[:7])
    assert (np.asarray(s.channels)[:7] == 0).all()   # first 7 from channel 0


# ---------------------------------------------------------------------------
# fused delivery kernels (broker.py)
# ---------------------------------------------------------------------------


def test_deliver_all_random_invariants(rng):
    """Seeded fuzz of the shared fused-delivery checker (the hypothesis
    variant in test_property.py runs the same checker when installed)."""
    for _ in range(15):
        stacked, group_sids, exp_rows, exp_tgts = random_stacked_broker_result(
            rng, int(rng.integers(1, 4)), int(rng.integers(1, 20)),
            int(rng.integers(1, 4)), int(rng.integers(1, 6)),
            int(rng.integers(1, 4)))
        check_deliver_all_invariants(
            stacked, group_sids, exp_rows, exp_tgts,
            max_pairs=int(rng.integers(1, 12)),
            max_notify=int(rng.integers(1, 16)),
            spill_cap=int(rng.integers(1, 32)))


def test_pack_payloads_all_per_channel_caps(rng):
    """caps (C,) bounds delivery per channel independently of the shared
    buffer size; everything past a cap lands in that channel's spill mask."""
    stacked, group_sids, exp_rows, _ = random_stacked_broker_result(
        rng, 3, 12, 2, 4, 2)
    caps = jnp.asarray([1, 5, 100], jnp.int32)
    d = pack_payloads_all(stacked, jnp.asarray(group_sids), 2, 16, caps=caps)
    for c, cap in enumerate([1, 5, 100]):
        produced = len(exp_rows[c])
        want = min(produced, cap, 16)
        assert int(d.delivered[c]) == want
        assert int(d.spill_mask[c].sum()) == produced - want
        np.testing.assert_array_equal(np.asarray(d.payload[c])[:want, 0],
                                      exp_rows[c][:want])


# ---------------------------------------------------------------------------
# engine: conservation, parity, spill queue, drain
# ---------------------------------------------------------------------------


ALL_FLAGS = [ExecutionFlags(scan_mode=m, aggregation=a, param_pushdown=a)
             for m in ("full", "window", "trad_index", "bad_index")
             for a in (False, True)]


@pytest.mark.parametrize(
    "flags", ALL_FLAGS,
    ids=[f"{f.scan_mode}{'+agg' if f.aggregation else ''}" for f in ALL_FLAGS])
def test_forced_overflow_conservation_and_parity(rng, flags):
    """Under forced overflow: delivered + spilled + dropped == produced per
    stage, on BOTH delivery paths, and the fused path's stats (including the
    one-hot per-broker split) are identical to the per-channel loop's."""
    eng = _overflow_engine(rng)
    fused = eng.execute_all(flags, advance=False, timed=False, deliver=True)
    for name in eng.channels:
        rep = eng.execute_channel(name, flags, advance=False, timed=False,
                                  deliver=True)
        check_delivery_conservation(rep.overflow, rep.num_results,
                                    rep.num_notified)
        check_delivery_conservation(fused[name].overflow,
                                    fused[name].num_results,
                                    fused[name].num_notified)
        assert fused[name].overflow == rep.overflow, name
        assert sum(rep.overflow.delivered_pairs_broker) == \
            rep.overflow.delivered_pairs
        assert rep.overflow.overflow > 0       # caps are tiny: spills happen


def test_drain_redelivers_exactly_once(rng):
    """Every spilled pair/sID is re-delivered exactly once, in spill order:
    the concatenation of drain rounds equals the expected overflow tail of
    the original delivery — no duplicates, no loss — and the queue empties.
    (ring disabled: this exercises the host SpillQueue drain path.)"""
    eng = _overflow_engine(rng, ring_capacity=0)
    flags = ExecutionFlags(scan_mode="window", aggregation=True,
                           param_pushdown=True)
    reps = eng.execute_all(flags, advance=False, timed=False, deliver=True)
    # expected tails from an uncapped re-run of both stages on the results
    want_pairs, want_sids = {}, {}
    for name, rep in reps.items():
        st = eng.channels[name]
        sids_tbl = (jnp.zeros((0,), jnp.int32) if st.spec.join == "spatial"
                    else eng.group_sids_array(name, True))
        buf, dlv, ov = pack_payloads(rep.result, sids_tbl, 2, 1 << 14)
        assert int(ov) == 0
        rows_tgts = np.asarray(buf)[:int(dlv), :2]
        want_pairs[name] = rows_tgts[rep.overflow.delivered_pairs:]
        nbuf, ndlv, nov = fanout_sids(rep.result, sids_tbl, 1 << 15)
        assert int(nov) == 0
        want_sids[name] = np.asarray(nbuf)[rep.overflow.delivered_sids:
                                           int(ndlv)]
        assert len(want_pairs[name]) == rep.overflow.spilled_pairs
        assert len(want_sids[name]) == rep.overflow.spilled_sids
    got_pairs = {n: [] for n in reps}
    got_sids = {n: [] for n in reps}
    rounds = 0
    while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
        rounds += 1
        assert rounds < 300
        for name, dr in eng.drain_spilled().items():
            s = dr.stats
            assert s.dropped_pairs == s.dropped_sids == 0
            if dr.payload is not None and s.delivered_pairs:
                got_pairs[name].extend(
                    dr.payload[:s.delivered_pairs, :2].tolist())
            if dr.notify is not None and s.delivered_sids:
                got_sids[name].extend(
                    dr.notify[:s.delivered_sids].tolist())
    for name in reps:
        np.testing.assert_array_equal(np.asarray(got_pairs[name]).reshape(
            -1, 2), want_pairs[name], err_msg=name)
        np.testing.assert_array_equal(np.asarray(got_sids[name]),
                                      want_sids[name], err_msg=name)
    assert not eng.drain_spilled()             # nothing left, no phantom work


def test_spill_queue_capacity_drops_are_counted(rng):
    """A full spill queue degrades to counted drops — conservation still
    holds and only what was actually captured is ever re-delivered."""
    eng = _overflow_engine(rng, spill_capacity=10, ring_capacity=0)
    flags = ExecutionFlags(scan_mode="window")
    reps = eng.execute_all(flags, advance=False, timed=False, deliver=True)
    total_spilled_p = total_spilled_s = 0
    for name, rep in reps.items():
        o = rep.overflow
        check_delivery_conservation(o, rep.num_results, rep.num_notified)
        total_spilled_p += o.spilled_pairs
        total_spilled_s += o.spilled_sids
        assert o.dropped_pairs + o.dropped_sids > 0
    assert total_spilled_p <= 10 and total_spilled_s <= 10
    assert eng.spill.pending_pairs() == total_spilled_p
    assert eng.spill.pending_sids() == total_spilled_s
    redelivered = 0
    while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
        for dr in eng.drain_spilled().values():
            redelivered += dr.stats.delivered_pairs + dr.stats.delivered_sids
    assert redelivered == total_spilled_p + total_spilled_s


def test_device_spill_buffer_truncation_counted(rng):
    """max_spill bounds each channel's capture window: overflow past it is
    dropped (counted), never silently lost or aliased — and because the
    windows are per channel, fused capture equals the per-channel path even
    when every channel overflows past the window (no cross-channel
    crowd-out)."""
    eng = _overflow_engine(rng, max_spill=8, ring_capacity=0)
    # a second param channel in the same fused join group: under a shared
    # spill budget its overflow would be crowded out by TweetsAboutDrugs'
    eng.create_channel(most_threatening_tweets())
    eng.subscribe_bulk("MostThreateningTweets",
                       rng.integers(0, 50, 150), rng.integers(0, 2, 150))
    eng.ingest(make_tweets(rng, 300, match_drugs=0.3))
    flags = ExecutionFlags(scan_mode="window")
    fused = eng.execute_all(flags, advance=False, timed=False, deliver=True)
    for name, rep in fused.items():
        o = rep.overflow
        assert o.spilled_pairs <= 8 and o.spilled_sids <= 8
        check_delivery_conservation(o, rep.num_results, rep.num_notified)
        seq = eng.execute_channel(name, flags, advance=False, timed=False,
                                  deliver=True)
        assert seq.overflow == o, name          # parity even past the window
    assert sum(r.overflow.dropped_pairs + r.overflow.dropped_sids
               for r in fused.values()) > 0


def test_drain_mixed_layouts_coherent_payloads(rng):
    """A channel spilled under BOTH layouts drains one lane per round: every
    DrainReport.payload is a single coherent buffer whose delivered prefix
    matches its stats, and both lanes drain to empty with nothing lost."""
    eng = _overflow_engine(rng)
    for agg in (True, False):
        flags = ExecutionFlags(scan_mode="window", aggregation=agg,
                               param_pushdown=agg)
        eng.execute_channel("TweetsAboutDrugs", flags, advance=False,
                            timed=False, deliver=True)
    want = eng.spill.pending_pairs("TweetsAboutDrugs")
    assert len([k for k in eng.spill.pair_keys()
                if k[0] == "TweetsAboutDrugs"]) == 2
    redelivered = 0
    while eng.spill.pending_pairs() > 0:
        for dr in eng.drain_spilled().values():
            if dr.payload is not None:
                # delivered prefix holds real lines, the rest stays zeroed
                n = dr.stats.delivered_pairs
                assert n <= dr.payload.shape[0]
                assert (dr.payload[:n, 3] > 0).all()   # payload_words word
                redelivered += n
    assert redelivered == want


def test_stale_pair_spills_dropped_on_drain(rng):
    """Pair spills index the subscription table they were produced from; a
    re-subscription between spill and drain makes them unroutable — the
    drain counts them dropped instead of re-packing garbage. Raw sID spills
    never go stale and still re-deliver."""
    eng = _overflow_engine(rng)
    flags = ExecutionFlags(scan_mode="window")
    rep = eng.execute_channel("TweetsAboutDrugs", flags, advance=False,
                              timed=False, deliver=True)
    assert rep.overflow.spilled_pairs > 0
    eng.subscribe("TweetsAboutDrugs", 3, "B1")     # version bump
    dropped = delivered_sids = 0
    while eng.spill.pending_pairs("TweetsAboutDrugs") \
            + eng.spill.pending_sids("TweetsAboutDrugs") > 0:
        dr = eng.drain_spilled().get("TweetsAboutDrugs")
        if dr is None:
            break
        assert dr.stats.delivered_pairs == 0       # no stale re-pack
        dropped += dr.stats.dropped_pairs
        delivered_sids += dr.stats.delivered_sids
    assert dropped == rep.overflow.spilled_pairs
    assert delivered_sids == rep.overflow.spilled_sids


def test_spill_queue_unit(rng):
    q = SpillQueue(capacity=5)
    assert q.push_pairs("A", True, np.arange(3), np.arange(3), 0) == 3
    assert q.push_pairs("A", True, np.arange(4), np.arange(4), 0) == 2
    assert q.pending_pairs() == 5 and q.pending_pairs("A") == 5
    rows, tgts, stale = q.pop_pairs("A", True, 4, current_version=0)
    assert stale == 0 and rows.tolist() == [0, 1, 2, 0]
    q._push_front_pairs("A", True, rows[2:], tgts[2:], 0)  # requeue tail
    rows2, _, _ = q.pop_pairs("A", True, 10, current_version=0)
    assert rows2.tolist() == [2, 0, 1]            # front-requeue kept order
    assert q.pending_pairs() == 0
    # stale version accounting
    q.push_pairs("A", True, np.arange(2), np.arange(2), version=7)
    _, _, stale = q.pop_pairs("A", True, 10, current_version=8)
    assert stale == 2
    # sid lane
    assert q.push_sids("A", np.arange(9)) == 5
    assert q.pop_sids("A", 3).tolist() == [0, 1, 2]
    assert q.pending_sids("A") == 2
    q.clear()
    assert q.pending_pairs() + q.pending_sids() == 0


def test_deliver_false_leaves_no_trace(rng):
    eng = _overflow_engine(rng)
    flags = ExecutionFlags(scan_mode="window")
    reps = eng.execute_all(flags, advance=False, timed=False)
    assert all(r.overflow is None for r in reps.values())
    assert eng.spill.pending_pairs() + eng.spill.pending_sids() == 0
    assert not eng.drain_spilled()
