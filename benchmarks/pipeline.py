"""Pipelined vs synchronous tick loop (core/runtime.py TickPipeline).

The synchronous driver serializes host and device every tick: dispatch all
plan-groups, block, materialize stats, drain, THEN synthesize the next
batch and churn subscriptions. The pipelined driver
(``run_ticks(pipeline_depth=N)``) keeps up to N ticks in flight — the next
tick's control-plane numpy work (churn + batch synthesis + ingest) runs
while the previous ticks' fused joins and delivery execute, and
``drain_spilled`` host round-trips batch every N ticks through the
SpillQueue's epoch-free resolved lane.

Two phases:

  * parity — churn + sustained overflow through tightly capped engines:
    the pipelined run must deliver the IDENTICAL per-channel (row, sID)
    pair / sID multisets as the synchronous run (asserted, not trended)
    with zero steady-state retraces;
  * throughput — a 4-plan-group engine (four param channels, four distinct
    ChannelPlans) under sustained churn: ticks/sec at depth 3 vs depth 1.

Acceptance: >= x1.2 pipelined speedup at >= 4 plan-groups (tracked in
benchmarks/thresholds.json as ``pipeline/overlap/speedup``; the measured
in-flight depth rides in the derived column as ``depth=N`` and
check_trend prints it next to the ratio).
"""
from __future__ import annotations

import numpy as np

from repro.core.broker import payload_notifications
from repro.core.channel import (most_threatening_tweets,
                                trending_tweets_in_country,
                                tweets_about_drugs)
from repro.core.churn import ChurnWorkload, run_ticks
from repro.core.engine import BADEngine
from repro.core.plans import ChannelPlan, ExecutionFlags
from benchmarks.common import emit, fresh_rng, scale

from repro.data.synthetic import drug_tweak, tweet_batch
from repro.core import records as R

PW = 8    # engine default deliver_payload_words
TICKS = 12
# the warm phase absorbs trace/compile AND the slot tables' one-time
# settling into their steady padded capacity bucket (churn.py's regime):
# the timed window then replays cached traces only
WARMUP = 8
DEPTH = 3


def _drug_batch(rng, n, t0):
    batch = tweet_batch(rng, n, t0)
    fields = drug_tweak(np.asarray(batch.fields).copy(), rng, 0.3)
    return R.RecordBatch.from_numpy(fields, np.asarray(batch.location))


# ---------------------------------------------------------------------------
# phase 1: delivered-content parity under churn + sustained overflow
# ---------------------------------------------------------------------------


def _parity_engine(seed):
    rng = fresh_rng(("pipeline-parity", seed))
    eng = BADEngine(dataset_capacity=4096, index_capacity=1024,
                    max_window=2048, max_candidates=512,
                    brokers=("B1", "B2"), group_cap=8,
                    max_deliver_pairs=12, max_notify=24, ring_capacity=24)
    eng.create_channel(tweets_about_drugs())
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, 200),
                       rng.integers(0, 2, 200))
    eng.debug_delivery_buffers = True
    return eng


def _fold_tick(pairs, sids):
    def on_tick(tick, reports):
        for name, rep in reports.items():
            o = rep.overflow
            if o is None or rep.payload is None:
                continue
            pairs.extend((name,) + tuple(x) for x in payload_notifications(
                np.asarray(rep.payload), o.delivered_pairs, PW).tolist())
            sids.extend(np.asarray(rep.notify)[:o.delivered_sids].tolist())

    def on_drain(drained):
        for name, dr in drained.items():
            if dr.payload is not None and dr.stats.delivered_pairs:
                pairs.extend((name,) + tuple(x) for x in
                             payload_notifications(
                                 np.asarray(dr.payload),
                                 dr.stats.delivered_pairs, PW).tolist())
            if dr.notify is not None and dr.stats.delivered_sids:
                sids.extend(dr.notify[:dr.stats.delivered_sids].tolist())
    return on_tick, on_drain


def _parity_run(depth):
    eng = _parity_engine(0)
    drive = fresh_rng("pipeline-parity-drive")
    flags = ExecutionFlags(scan_mode="window", aggregation=True,
                           param_pushdown=True)
    wl = [ChurnWorkload("TweetsAboutDrugs", adds_per_tick=10,
                        removes_per_tick=6)]
    pairs, sids = [], []
    on_tick, on_drain = _fold_tick(pairs, sids)
    rep = run_ticks(eng, wl, 6, drive, flags=flags, deliver=True,
                    ingest_per_tick=96, make_batch=_drug_batch, warmup=2,
                    on_tick=on_tick, on_drain=on_drain,
                    pipeline_depth=depth)
    # settle ring residue so the multisets cover everything produced
    eng.flush_rings()
    while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
        on_drain(eng.drain_spilled())
    return rep, sorted(pairs), sorted(sids)


def bench_parity(rng) -> None:
    rep_sync, pairs_sync, sids_sync = _parity_run(1)
    rep_pipe, pairs_pipe, sids_pipe = _parity_run(DEPTH)
    assert pairs_pipe == pairs_sync, \
        f"pair multiset diverged: {len(pairs_pipe)} vs {len(pairs_sync)}"
    assert sids_pipe == sids_sync, \
        f"sID multiset diverged: {len(sids_pipe)} vs {len(sids_sync)}"
    assert rep_pipe.maintenance.traces == 0, \
        f"steady-state retraces: {rep_pipe.maintenance.traces}"
    emit("pipeline/parity/churn_overflow", 0.0,
         f"pairs={len(pairs_pipe)};sids={len(sids_pipe)};"
         f"depth={rep_pipe.pipeline_depth};"
         f"drains {rep_pipe.drain_calls} vs {rep_sync.drain_calls};"
         f"steady_retraces={rep_pipe.maintenance.traces}")


# ---------------------------------------------------------------------------
# phase 2: ticks/sec, 4 plan-groups, depth 3 vs 1
# ---------------------------------------------------------------------------

# four DISTINCT plans -> dispatch_all partitions the channels into four
# plan-groups per tick (the >= 4-group regime the overlap target is set
# at). All padded: the compact backends' grow protocol reads the live
# total AT DISPATCH (a documented sync point), which would serialize the
# very overlap this suite measures.
_PLANS = (
    ChannelPlan.from_flags(ExecutionFlags(
        scan_mode="window", aggregation=True, param_pushdown=True),
        "oracle"),
    ChannelPlan.from_flags(ExecutionFlags(
        scan_mode="window", aggregation=False), "oracle"),
    ChannelPlan.from_flags(ExecutionFlags(
        scan_mode="full", aggregation=True, param_pushdown=True), "oracle"),
    ChannelPlan.from_flags(ExecutionFlags(
        scan_mode="full", aggregation=False), "oracle"),
)


def _group_engine(n_subs):
    rng = fresh_rng("pipeline-groups")
    eng = BADEngine(dataset_capacity=1 << 14, index_capacity=1 << 12,
                    max_window=1 << 11, max_candidates=1 << 10,
                    brokers=("B1", "B2", "B3", "B4"), group_cap=16,
                    max_deliver_pairs=1 << 12, max_notify=1 << 14,
                    ring_capacity=1 << 9)
    channels = [tweets_about_drugs(), most_threatening_tweets(),
                trending_tweets_in_country(0, "EnglishTrending"),
                trending_tweets_in_country(1, "Lang1Trending")]
    live = {}
    for spec, plan in zip(channels, _PLANS):
        eng.create_channel(spec)
        dom = 200 if "Trending" in spec.name else 50
        live[spec.name] = eng.subscribe_bulk(
            spec.name, rng.integers(0, dom, n_subs),
            rng.integers(0, 4, n_subs))
        eng.set_plan(spec.name, plan)
    return eng, live


def _throughput_run(depth, n_subs, churn):
    eng, live = _group_engine(n_subs)
    drive = fresh_rng("pipeline-drive")   # depth-independent: identical
    # seeds -> identical op/data streams for the A/B comparison
    wl = [ChurnWorkload(name, adds_per_tick=churn,
                        removes_per_tick=churn, num_brokers=4,
                        param_domain=200 if "Trending" in name else 50)
          for name in eng.channels]
    return run_ticks(eng, wl, TICKS + WARMUP, drive, deliver=True,
                     ingest_per_tick=scale(2048), make_batch=_drug_batch,
                     warmup=WARMUP, live_sids=live, use_channel_plans=True,
                     pipeline_depth=depth)


def bench_throughput(rng) -> None:
    import os
    n_subs = scale(6000, 512)
    # churn small relative to the live population: balanced add/remove at
    # ~5% keeps the slot tables inside their padded capacity bucket, so the
    # steady-state window replays cached traces only
    churn = scale(512, 24)
    reps = {}
    for tag, depth in (("sync", 1), ("pipelined", DEPTH)):
        rep = _throughput_run(depth, n_subs, churn)
        reps[tag] = rep
        emit(f"pipeline/{tag}/ticks", rep.wall_s / max(rep.ticks, 1),
             f"ticks_per_s={rep.ticks_per_s:.2f};groups=4;"
             f"depth={rep.pipeline_depth};results={rep.results};"
             f"retraces={rep.maintenance.traces}")
    # identical seeds -> identical subscriber-level outcomes
    assert reps["pipelined"].delivered_sids == reps["sync"].delivered_sids, \
        (reps["pipelined"].delivered_sids, reps["sync"].delivered_sids)
    assert reps["pipelined"].maintenance.traces == 0, \
        f"steady-state retraces: {reps['pipelined'].maintenance.traces}"
    ratio = reps["pipelined"].ticks_per_s / max(reps["sync"].ticks_per_s,
                                                1e-9)
    # the overlap win needs a second core to overlap WITH: on single-core
    # hosts the schedules serialize onto the same hardware and the honest
    # ratio degrades to ~x1.0 (cores ride in the derived column so a CI
    # trend reader can tell the difference from a real regression)
    emit("pipeline/overlap/speedup", 0.0,
         f"x{ratio:.2f} (target >= 1.2x at >= 4 plan-groups, multi-core); "
         f"depth={reps['pipelined'].pipeline_depth}; "
         f"cores={os.cpu_count()}; "
         f"steady retraces={reps['pipelined'].maintenance.traces}")


def run(rng) -> None:
    bench_parity(rng)
    bench_throughput(rng)


if __name__ == "__main__":
    run(np.random.default_rng(0))
