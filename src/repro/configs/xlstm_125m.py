"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304. sLSTM + mLSTM
blocks (xLSTM[3:1] interleave: 1 sLSTM per 3 mLSTM). [arXiv:2405.04517;
unverified]. Constant-state recurrence -> runs long_500k."""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab_size=50304, qkv_bias=False,
        block_pattern=("slstm", "mlstm", "mlstm", "mlstm"),
        superlayer_repeat=3,
        ssm_expand=2, ssm_chunk=256,
        param_dtype=jnp.float32, grad_accum=8, optimizer="adamw",
        sub_quadratic=True,
    ).validate()
