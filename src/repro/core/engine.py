"""BADEngine: the host-side orchestrator tying the data plane together.

Responsibilities (paper Fig. 1): data feed ingestion -> ActiveDataset append +
conditionsList evaluation + BAD-index maintenance; channel execution under a
chosen ``ExecutionFlags`` plan; broker accounting; subscription control plane
(Algorithm 1 grouping + UserParameters upkeep).

The engine is deliberately a thin host shell: every per-record code path is a
jitted pure function over fixed-shape arrays.

``use_pallas=True`` routes every predicate / spatial evaluation through the
Pallas kernels (``predicate_filter`` at ingestion AND inside the fused
executor's candidate discovery; ``spatial_match`` in both spatial join
paths); the default jnp oracle is the parity reference, and the two are
result-identical by construction (asserted by the parity suite).

Broker delivery (``deliver=True`` on ``execute_channel`` / ``execute_all``)
runs the broker's convert+send stages and surfaces per-stage accounting in
``ExecutionReport.overflow`` (a ``DeliveryStats``). On ``execute_all`` the
delivery is FUSED: ``broker.deliver_all`` runs inside the same jitted call as
candidate discovery and the joins, so a multi-channel tick never leaves the
device between discovery and subscriber fanout. No notification is silently
lost: pairs/sIDs that miss a delivery buffer land first in the
device-resident ``RetryRing`` (per join group) and are re-packed and
re-delivered *inside the next fused call* — sustained overflow never
round-trips through the host; only overflow past the ring window cascades —
with its channel identity — into the bounded host-side ``SpillQueue`` (the
ring's last resort) and is re-delivered exactly once by ``drain_spilled()``
on subsequent ticks. Ring pairs whose channel churned go epoch-stale and
drop (counted) instead of indexing a moved table; only window/queue
exhaustion drops, and drops are counted
(delivered + spilled + dropped == produced == fresh + retried, per stage —
an identity that telescopes across ticks).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bad_index as bidx
from repro.core import enrich
from repro.core import plans
from repro.core import records as R
from repro.core import subscriptions as subs
from repro.core.broker import (BrokerRegistry, DeliveryStats, FusedDelivery,
                               RetryRing, deliver_all, empty_ring,
                               fanout_sids, pack_payloads,
                               resolve_pair_sids)
from repro.core.channel import ChannelSpec
from repro.core.predicates import (CompiledConditions, compile_conditions,
                                   evaluate_conditions)
from repro.core.user_params import UserParameters


@dataclasses.dataclass
class MaintenanceStats:
    """Counters for the epoch/delta maintenance machinery.

    ``traces`` counts jit TRACES of engine-owned device functions — the
    increment sits inside the traced Python bodies, so cached executions
    never count; ``rebuilds`` counts full stacked-cache rebuilds;
    ``patches`` counts in-place delta patch applications. Steady-state churn
    should show ``patches`` advancing while ``traces`` and ``rebuilds`` stay
    flat (the churn suite asserts exactly that)."""

    traces: int = 0
    rebuilds: int = 0
    patches: int = 0

    def snapshot(self) -> "MaintenanceStats":
        return dataclasses.replace(self)

    def since(self, prior: "MaintenanceStats") -> "MaintenanceStats":
        return MaintenanceStats(self.traces - prior.traces,
                                self.rebuilds - prior.rebuilds,
                                self.patches - prior.patches)


class UserCohort:
    """Stable-slot set of global user ids subscribed to ONE spatial channel.

    Slot index == row in that channel's stacked user-set (and the pair
    target index its results carry), so cohort churn patches device rows in
    place exactly like the Aggregator's group slots; freed slots are reused,
    never leaked into padded capacity."""

    def __init__(self):
        self._uids: List[int] = []          # per slot; -1 when free
        self._slot: Dict[int, int] = {}     # live uid -> slot
        self._free: List[int] = []

    @property
    def num_slots(self) -> int:
        return len(self._uids)

    @property
    def num_users(self) -> int:
        return len(self._slot)

    def add(self, uids: np.ndarray) -> set:
        """Attach users; returns the slots touched (already-present ids are
        no-ops)."""
        touched = set()
        for u in np.asarray(uids, dtype=np.int32).ravel().tolist():
            if u in self._slot:
                continue
            if self._free:
                s = self._free.pop()
                self._uids[s] = u
            else:
                s = len(self._uids)
                self._uids.append(u)
            self._slot[u] = s
            touched.add(s)
        return touched

    def remove(self, uids: np.ndarray) -> set:
        touched = set()
        for u in np.asarray(uids, dtype=np.int32).ravel().tolist():
            s = self._slot.pop(u, None)
            if s is not None:
                self._uids[s] = -1
                self._free.append(s)
                touched.add(s)
        return touched

    def slot_uids(self) -> np.ndarray:
        """(num_slots,) int32 uid per slot, -1 holes."""
        return np.asarray(self._uids, dtype=np.int32).reshape(-1)


@dataclasses.dataclass
class ChannelState:
    spec: ChannelSpec
    index: int                      # row in the stacked conditionsList / BADIndexState
    aggregator: subs.Aggregator
    user_params: UserParameters
    # the channel's current physical plan (scan mode x layout x backend);
    # None falls back to the engine default. ``execute_all(flags=None)``
    # partitions channels into plan-groups by this value — set it via
    # ``BADEngine.set_plan`` (the runtime planner's switch point)
    plan: Optional[plans.ChannelPlan] = None
    last_exec_ts: int = 0
    last_exec_size: int = 0
    executions: int = 0
    # ``epoch`` is a total order over this channel's subscription state:
    # bumped on EVERY control-plane change. It keys spill staleness and the
    # engine's epoch-tracked device caches; ``delta_log`` holds the
    # (epoch, GroupDelta) records a cache reflecting epoch e applies to
    # catch up to the present — any gap (log overflow, out-of-band mutation)
    # forces that cache to fully rebuild instead.
    epoch: int = 0
    delta_log: Deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=64))
    # spatial channels: explicit subscriber cohort (None = every user, the
    # legacy global-UserLocations semantics), with its own epoch/delta log
    cohort: Optional[UserCohort] = None
    user_epoch: int = 0
    user_delta_log: Deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=64))
    # device-resident TargetArrays + host group/flat views, cached per
    # channel and dropped whenever the subscription set changes (the
    # per-channel path is the from-scratch reference the delta-maintained
    # stacked caches are tested against)
    _targets_flat: Optional[plans.TargetArrays] = None
    _targets_grouped: Optional[plans.TargetArrays] = None
    _groups: Optional[subs.SubscriptionGroups] = None
    _flat: Optional[subs.SubscriptionTable] = None
    _host_targets: Dict[bool, Tuple] = dataclasses.field(default_factory=dict)
    _cohort_users: Optional[Tuple] = None

    def note_change(self) -> None:
        """Advance the epoch and log the aggregator's accumulated delta so
        epoch-tracked caches can patch in place instead of rebuilding."""
        delta = self.aggregator.take_delta()
        self.epoch += 1
        self.delta_log.append((self.epoch, delta))
        self._drop_host_caches()

    def note_user_change(self, touched_slots: set) -> None:
        """Cohort churn: slots remap, so spatial pair spills go stale (epoch
        bump) and the stacked user-set cache gets a patchable delta."""
        self.epoch += 1
        self.user_epoch += 1
        self.user_delta_log.append((self.user_epoch,
                                    frozenset(touched_slots)))
        self._drop_host_caches()

    def invalidate_targets(self) -> None:
        """Out-of-band invalidation (no delta recorded): the safety hatch
        for callers that mutate the aggregator directly — every
        epoch-tracked cache sees the gap and fully rebuilds."""
        self.aggregator.take_delta()
        self.epoch += 1
        self._drop_host_caches()

    def _drop_host_caches(self) -> None:
        self._targets_flat = self._targets_grouped = None
        self._groups = self._flat = None
        self._host_targets = {}
        self._cohort_users = None


@dataclasses.dataclass
class _GroupCache:
    """Epoch-tracked stacked device targets for the fused param-join path.

    Capacity-padded (tmax slots / dmax domain / mmax fan-out / cap members)
    so shapes — and therefore the fused plan's trace — are stable across
    churn; group deltas patch rows in place and ``epochs`` records the
    per-channel subscription epoch the arrays reflect."""

    names: Tuple[str, ...]
    aggregated: bool
    epochs: List[int]
    tmax: int
    dmax: int
    mmax: int
    cap: int
    targets: plans.TargetArrays
    up_masks: jnp.ndarray           # (C, dmax) bool
    domains: jnp.ndarray            # (C,) int32
    sids: jnp.ndarray               # (C, tmax, cap) int32


@dataclasses.dataclass
class _SpatialCache:
    """Epoch-tracked stacked per-channel user sets for the fused spatial
    join; cohort deltas patch slot rows in place. ``identity`` is True when
    every channel serves the full global user set — delivery then uses the
    0-width identity fanout exactly as before cohorts existed."""

    names: Tuple[str, ...]
    user_version: int
    cohorted: Tuple[bool, ...]
    epochs: List[int]               # per-channel user_epoch reflected
    ub: int
    locs: jnp.ndarray               # (C, ub, 2) f32, -FAR holes
    brokers: jnp.ndarray            # (C, ub) int32
    uids: jnp.ndarray               # (C, ub) int32 global uid per slot, -1 holes

    @property
    def identity(self) -> bool:
        return not any(self.cohorted)


class SpillQueue:
    """Bounded host-side capture of overflowed notifications.

    Two lanes, mirroring the broker's two delivery stages: *pairs* (result
    pairs that missed the convert-stage wire buffer, keyed by channel and
    target LAYOUT — False = flat rows, True = compacted group rows,
    "slot" = aggregator slot rows — so a drain re-packs against the right
    table) and *sids* (end-subscriber ids that missed the send-stage notify
    buffer). Entries keep their channel identity; each lane is bounded by
    ``capacity`` — pushes past it are rejected (the caller counts them as
    dropped, so nothing is ever lost *silently*).

    Pair entries record the channel's subscription EPOCH at spill time:
    target indices are only meaningful against the table they were produced
    from, so a drain discards (and counts as dropped) entries whose channel
    churned in between. Raw sIDs never go stale.

    A third *resolved* lane holds pairs whose target->sID fanout was already
    resolved against the producing call's OWN table (the pipelined runtime
    materializes stats ticks after dispatch, when the live table may have
    churned past the dispatch-time epoch — resolving at capture time makes
    the entry epoch-free, so deferred batched drains deliver the identical
    multiset as the synchronous path). Resolved entries share the pairs
    lane's capacity budget and never go stale.
    """

    def __init__(self, capacity: int = 1 << 16):
        self.capacity = capacity
        self._pairs: Dict[Tuple[str, bool], Deque] = {}
        self._sids: Dict[str, Deque] = {}
        self._resolved: Dict[str, Deque] = {}
        self._n_pairs = 0
        self._n_sids = 0

    def push_pairs(self, channel: str, aggregated: bool, rows: np.ndarray,
                   targets: np.ndarray, version: int) -> int:
        """Append up to the remaining capacity; returns entries accepted."""
        n = min(len(rows), self.capacity - self._n_pairs)
        if n > 0:
            q = self._pairs.setdefault((channel, aggregated),
                                       collections.deque())
            q.append((np.asarray(rows[:n]), np.asarray(targets[:n]), version))
            self._n_pairs += n
        return max(n, 0)

    def _push_front_pairs(self, channel: str, aggregated: bool,
                          rows: np.ndarray, targets: np.ndarray,
                          version: int) -> None:
        """Requeue a just-popped tail at the FRONT (drain order preserved,
        no capacity check — the pop already released the room)."""
        if len(rows):
            q = self._pairs.setdefault((channel, aggregated),
                                       collections.deque())
            q.appendleft((np.asarray(rows), np.asarray(targets), version))
            self._n_pairs += len(rows)

    def pop_pairs(self, channel: str, aggregated: bool, n: int,
                  current_version: Optional[int]
                  ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Remove up to ``n`` entries in FIFO order. Entries whose version no
        longer matches ``current_version`` are discarded and counted in the
        returned ``stale`` (they index a table that no longer exists).
        Returns (rows, targets, stale)."""
        q = self._pairs.get((channel, aggregated))
        rows, tgts, stale, taken = [], [], 0, 0
        while q and taken < n:
            r, t, v = q.popleft()
            take = min(len(r), n - taken)
            if take < len(r):
                q.appendleft((r[take:], t[take:], v))
            self._n_pairs -= take
            if v != current_version:
                stale += take
            else:
                rows.append(r[:take])
                tgts.append(t[:take])
            taken += take
        if q is not None and not q:
            del self._pairs[(channel, aggregated)]
        cat = lambda xs: (np.concatenate(xs) if xs
                          else np.zeros((0,), np.int32))
        return cat(rows), cat(tgts), stale

    def push_resolved(self, channel: str, rows: np.ndarray,
                      targets: np.ndarray, sid_rows: np.ndarray) -> int:
        """Append pre-resolved (row, target, sID-row) entries up to the
        remaining PAIR capacity; returns entries accepted. ``sid_rows`` is
        the (n, w) slice of the producing call's sID table for these
        targets (w >= 1; -1 padding never fans out)."""
        n = min(len(rows), self.capacity - self._n_pairs)
        if n > 0:
            q = self._resolved.setdefault(channel, collections.deque())
            q.append((np.asarray(rows[:n]), np.asarray(targets[:n]),
                      np.asarray(sid_rows[:n])))
            self._n_pairs += n
        return max(n, 0)

    def _push_front_resolved(self, channel: str, rows: np.ndarray,
                             targets: np.ndarray,
                             sid_rows: np.ndarray) -> None:
        if len(rows):
            q = self._resolved.setdefault(channel, collections.deque())
            q.appendleft((np.asarray(rows), np.asarray(targets),
                          np.asarray(sid_rows)))
            self._n_pairs += len(rows)

    def pop_resolved(self, channel: str, n: int
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Remove up to ``n`` resolved entries in FIFO order; sID rows from
        entries of different widths are right-padded with -1 to the widest.
        Returns (rows, targets, sid_rows)."""
        q = self._resolved.get(channel)
        rows, tgts, srows, taken = [], [], [], 0
        while q and taken < n:
            r, t, s = q.popleft()
            take = min(len(r), n - taken)
            if take < len(r):
                q.appendleft((r[take:], t[take:], s[take:]))
            self._n_pairs -= take
            rows.append(r[:take])
            tgts.append(t[:take])
            srows.append(s[:take])
            taken += take
        if q is not None and not q:
            del self._resolved[channel]
        if not rows:
            return (np.zeros((0,), np.int32), np.zeros((0,), np.int32),
                    np.zeros((0, 1), np.int32))
        w = max(s.shape[1] for s in srows)
        srows = [np.pad(s, ((0, 0), (0, w - s.shape[1])), constant_values=-1)
                 if s.shape[1] < w else s for s in srows]
        return (np.concatenate(rows), np.concatenate(tgts),
                np.concatenate(srows))

    def push_sids(self, channel: str, sids: np.ndarray) -> int:
        n = min(len(sids), self.capacity - self._n_sids)
        if n > 0:
            self._sids.setdefault(channel, collections.deque()).append(
                np.asarray(sids[:n]))
            self._n_sids += n
        return max(n, 0)

    def _push_front_sids(self, channel: str, sids: np.ndarray) -> None:
        if len(sids):
            self._sids.setdefault(channel, collections.deque()).appendleft(
                np.asarray(sids))
            self._n_sids += len(sids)

    def pop_sids(self, channel: str, n: int) -> np.ndarray:
        q = self._sids.get(channel)
        out, taken = [], 0
        while q and taken < n:
            s = q.popleft()
            take = min(len(s), n - taken)
            if take < len(s):
                q.appendleft(s[take:])
            self._n_sids -= take
            out.append(s[:take])
            taken += take
        if q is not None and not q:
            del self._sids[channel]
        return np.concatenate(out) if out else np.zeros((0,), np.int32)

    def pair_keys(self) -> List[Tuple[str, bool]]:
        return list(self._pairs.keys())

    def sid_keys(self) -> List[str]:
        return list(self._sids.keys())

    def resolved_keys(self) -> List[str]:
        return list(self._resolved.keys())

    def pending_pairs(self, channel: Optional[str] = None) -> int:
        if channel is None:
            return self._n_pairs
        return (sum(sum(len(r) for r, _, _ in q)
                    for (name, _), q in self._pairs.items()
                    if name == channel)
                + sum(len(r) for r, _, _ in self._resolved.get(channel, ())))

    def pending_sids(self, channel: Optional[str] = None) -> int:
        if channel is None:
            return self._n_sids
        return sum(len(s) for s in self._sids.get(channel, ()))

    def clear(self) -> None:
        self._pairs.clear()
        self._sids.clear()
        self._resolved.clear()
        self._n_pairs = self._n_sids = 0


@dataclasses.dataclass
class DrainReport:
    """One channel's ``drain_spilled`` round: ``stats`` accounts the retry
    (delivered = re-delivered this round, spilled = still queued, dropped =
    stale/unroutable); ``payload`` / ``notify`` are the re-packed wire buffer
    and re-sent sID buffer (delivered prefix meaningful)."""

    stats: DeliveryStats
    payload: Optional[np.ndarray] = None
    notify: Optional[np.ndarray] = None


@dataclasses.dataclass
class _PendingGroup:
    """One dispatched plan-group awaiting materialization: the fused call's
    result pytree (device handles, possibly still executing) plus everything
    the host half needs — layouts and DISPATCH-TIME epoch snapshots for
    SpillQueue tagging, and (when spills are being resolved) the
    dispatch-time stacked sID table handles, so deferred stats resolve pair
    fanout against the tables the call actually joined."""

    plan: plans.ChannelPlan
    param_chs: List
    spatial_chs: List
    res: tuple                # (res_p, res_s, del_p, del_s, tots, ranks)
    p_layout: object
    s_layout: object
    deliver: bool
    wall: float                      # timed fused wall; 0.0 when untimed
    t0: float                        # dispatch timestamp (latency fallback)
    p_epochs: List[int]
    s_epochs: List[int]
    p_sids: Optional[jnp.ndarray] = None
    s_sids: Optional[jnp.ndarray] = None


@dataclasses.dataclass
class ExecutionReport:
    channel: str
    flags: plans.ExecutionFlags
    result: plans.ChannelResult
    wall_time_s: float
    num_results: int
    num_notified: int
    scanned: int
    broker_bytes: np.ndarray
    # the full plan (flags + backend) this execution ran under; None on the
    # per-channel ``execute_channel`` path (which stays flags-driven)
    plan: Optional[plans.ChannelPlan] = None
    # broker overflow accounting; None unless executed with ``deliver=True``
    overflow: Optional[DeliveryStats] = None
    # delivered wire buffers (delivered prefix meaningful); only populated
    # by ``execute_all(deliver=True)`` on an engine with
    # ``debug_delivery_buffers`` — the conservation fuzz reads delivered
    # CONTENT, production ticks skip the device->host transfer
    payload: Optional[np.ndarray] = None
    notify: Optional[np.ndarray] = None


class BADEngine:
    def __init__(self,
                 dataset_capacity: int = 1 << 18,
                 index_capacity: int = 1 << 15,
                 max_window: int = 1 << 15,
                 max_candidates: int = 1 << 13,
                 frame_bytes: int = 40 * 1024,
                 schema: R.Schema = R.ENRICHED_TWEET_SCHEMA,
                 brokers: Tuple[str, ...] = ("BrokerA",),
                 use_pallas: bool = False,
                 group_cap: Optional[int] = None,
                 max_deliver_pairs: int = 1 << 12,
                 max_notify: int = 1 << 14,
                 deliver_payload_words: int = 8,
                 max_spill: int = 1 << 13,
                 spill_capacity: int = 1 << 16,
                 incremental: bool = True,
                 ring_capacity: int = 1 << 12,
                 enrichment: Optional[enrich.EnrichmentStage] = None):
        self.schema = schema
        self.dataset = R.ActiveDataset.create(dataset_capacity, schema)
        self.index_capacity = index_capacity
        self.max_window = max_window
        self.max_candidates = max_candidates
        self.frame_bytes = frame_bytes
        self.group_cap = group_cap or subs.cap_from_frame_bytes(frame_bytes)
        self.brokers = BrokerRegistry.create(*brokers)
        self.channels: Dict[str, ChannelState] = {}
        self.use_pallas = use_pallas
        self.max_deliver_pairs = max_deliver_pairs
        self.max_notify = max_notify
        self.deliver_payload_words = deliver_payload_words
        # device-side spill capture buffer per delivery call (flat across the
        # call's channels) and the host-side bounded retry queue
        self.max_spill = max_spill
        self.spill = SpillQueue(spill_capacity)
        # device-resident retry rings (per fused join group): overflow of a
        # fused delivery re-enters the NEXT execute_all call on device;
        # only overflow past the ring window cascades to the host SpillQueue.
        # 0 disables the ring (every overflow goes straight to the queue —
        # the pre-ring behavior, kept as the host-drain baseline)
        self.ring_capacity = ring_capacity
        self._rings: Dict = {}
        self.ring_flush_drops = 0
        self._deliver_jit: Optional[Callable] = None
        # surface delivered wire buffers on ExecutionReport (testing aid)
        self.debug_delivery_buffers = False
        self.user_locations = jnp.zeros((1, 2), dtype=jnp.float32)
        self.user_brokers = jnp.zeros((1,), dtype=jnp.int32)
        # keys the stacked-user-set cache; bumped by set_user_locations
        self._user_version = 0
        self.now = 0
        # host mirror of dataset.size, maintained by ``ingest`` — advance
        # and plan bucketing read it instead of syncing on the device scalar
        # (``int(self.dataset.size)`` would block the host on every tick)
        self.size_host = 0
        self._conds: Optional[CompiledConditions] = None
        self.index_state = bidx.BADIndexState.create(0, index_capacity)
        self._ingest_fn = None
        # (plan-cache key, arg-shape signature) pairs already executed once:
        # ``_warm_if_new`` warms ONLY on an actual trace-cache miss, so a
        # timed call never runs a cached executable twice
        self._warmed: set = set()
        # compiled plan caches (single-channel and fused all-channel), keyed
        # on the specs/flags they close over; cleared on channel create/drop
        self._exec_cache: Dict = {}
        # adaptive compacted-stream capacities (the "compact"/"compact_pallas"
        # backends): per plan-group pow2 buckets, grown on overflow (ONE
        # re-run — the overflowed call reports the exact pre-truncation
        # total) and halved after sustained low occupancy; a converged
        # bucket replays cached traces, preserving the zero-retrace steady
        # state. ``_stream_idle`` counts consecutive low-occupancy runs.
        self._stream_buckets: Dict = {}
        self._stream_idle: Dict = {}
        # stacked device state for execute_all: one epoch-tracked entry per
        # layout (aggregated / flat / spatial). With ``incremental`` the
        # aggregated + spatial entries are patched in place from group /
        # cohort deltas (capacity-padded shapes, so no retrace); without it
        # every epoch move rebuilds from host (the pre-churn-engine
        # behavior, kept as the benchmark baseline)
        self._stacked_cache: Dict = {}
        self.incremental = incremental
        # post-join enrichment/ranking stage (core/enrich.py): scores the
        # fused candidate slots and budget-prunes pairs before deliver_all,
        # inside the same jitted call. Its ``identity`` is stamped into the
        # dispatched plans (``ChannelPlan.scorer``) so every plan-keyed
        # cache — and the retry rings — key on the scorer too.
        self.enrichment = enrichment
        self.maintenance = MaintenanceStats()
        self._patch_groups_jit: Optional[Callable] = None
        self._patch_flat_jit: Optional[Callable] = None
        self._patch_spatial_jit: Optional[Callable] = None

    # ------------------------------------------------------------------
    # control plane
    # ------------------------------------------------------------------

    def create_channel(self, spec: ChannelSpec) -> None:
        if spec.name in self.channels:
            raise ValueError(f"channel {spec.name} exists")
        if self.size_host > 0 and spec.fixed_preds:
            # BAD indexes only see records ingested after channel creation —
            # same semantics as the paper (continuous queries over new data).
            pass
        st = ChannelState(
            spec=spec,
            index=len(self.channels),
            aggregator=subs.Aggregator(self.group_cap),
            user_params=UserParameters.create(spec.param_domain),
            last_exec_ts=self.now,
        )
        st.last_exec_size = self.size_host
        self.channels[spec.name] = st
        self._rebuild_conditions()

    def drop_channel(self, name: str) -> None:
        del self.channels[name]
        survivors = sorted(self.channels.values(), key=lambda s: s.index)
        old_rows = [st.index for st in survivors]
        for i, st in enumerate(survivors):
            st.index = i
        self._rebuild_conditions(old_rows)

    def default_plan(self) -> plans.ChannelPlan:
        """The plan channels run under until one is assigned: the default
        ExecutionFlags with the engine's kernel backend."""
        return plans.ChannelPlan(
            backend="pallas" if self.use_pallas else "oracle")

    def channel_plan(self, name: str) -> plans.ChannelPlan:
        return self.channels[name].plan or self.default_plan()

    def set_plan(self, name: str, plan: plans.ChannelPlan) -> bool:
        """Assign a channel's physical plan; returns True when it changed.

        Purely a host-side assignment: the NEXT ``execute_all(flags=None)``
        call partitions plan-groups from the new value. A switch migrates
        the old plan-group's retry-ring state through the existing
        ``flush_rings`` path (entries land in the host SpillQueue, tagged
        with the layout they were produced under, and re-deliver via
        ``drain_spilled``) — no notification is lost or misrouted across
        the switch."""
        if not isinstance(plan, plans.ChannelPlan):
            raise TypeError(f"expected ChannelPlan, got {type(plan)!r}")
        st = self.channels[name]
        if st.plan == plan:
            return False
        st.plan = plan
        return True

    def plan_assignment(self) -> Dict[str, plans.ChannelPlan]:
        """Every channel's effective plan (assigned or engine default)."""
        return {name: self.channel_plan(name) for name in self.channels}

    def set_enrichment(self,
                       stage: Optional[enrich.EnrichmentStage]) -> bool:
        """Attach (or detach, with None) the post-join enrichment stage;
        returns True when it changed.

        Purely a host-side assignment, like ``set_plan``: the NEXT fused
        dispatch stamps the stage's ``identity`` into every dispatched
        plan, so the previous plan-groups' retry rings (keyed by the
        untagged/differently tagged plans) migrate through the existing
        flush path into the host SpillQueue — no notification is lost or
        re-ranked across the switch."""
        if stage is not None and not callable(getattr(stage, "score", None)):
            raise TypeError(f"expected an EnrichmentStage, got {stage!r}")
        if self.enrichment is stage:
            return False
        self.enrichment = stage
        return True

    def subscribe(self, channel: str, param: int, broker: str = "BrokerA",
                  sid: Optional[int] = None) -> int:
        st = self.channels[channel]
        if not 0 <= param < st.user_params.domain:   # before any mutation
            raise ValueError(
                f"param {param} out of [0, {st.user_params.domain}) "
                f"for {channel}")
        bid = self.brokers.names[broker]
        sid = st.aggregator.add_subscription(param, bid, sid)
        st.user_params.add(param)
        st.note_change()
        return sid

    def subscribe_bulk(self, channel: str, params: np.ndarray,
                       brokers: np.ndarray,
                       sids: Optional[np.ndarray] = None) -> np.ndarray:
        """Bulk control-plane load through the vectorized ``aggregate`` path:
        Algorithm-1 grouping semantics with no per-subscription Python work.
        Returns the assigned sIDs.

        ``sids`` assigns EXPLICIT subscription ids instead of the
        aggregator's sequential allocation — the sharded engine
        (core/sharded.py) allocates globally and hands each shard its
        hash-owned slice, so a sID names the same subscription on every
        shard and across reshards."""
        st = self.channels[channel]
        params = np.asarray(params, dtype=np.int32).ravel()
        brokers = np.asarray(brokers, dtype=np.int32).ravel()
        # validate BEFORE mutating: a bad param/broker must not leave the
        # aggregator holding subscriptions whose refcounts were never
        # registered (or whose broker id aliases the invalid-pair sentinel)
        if params.size and (int(params.min()) < 0
                            or int(params.max()) >= st.user_params.domain):
            raise ValueError(
                f"params out of [0, {st.user_params.domain}) for {channel}")
        nb = self.brokers.num_brokers
        if brokers.size and (int(brokers.min()) < 0 or int(brokers.max()) >= nb):
            raise ValueError(f"broker ids out of [0, {nb}) for {channel}")
        if self.incremental:
            sids = st.aggregator.add_bulk(params, brokers, sids)
            st.user_params.add_bulk(params)
            st.note_change()
        else:
            # the rebuild baseline: O(S) re-aggregation (group identity not
            # preserved) + out-of-band invalidation (full cache rebuild)
            sids = st.aggregator.rebuild_bulk(params, brokers, sids)
            st.user_params.add_bulk(params)
            st.invalidate_targets()
        return sids

    def unsubscribe(self, channel: str, param: int, broker: str, sid: int) -> bool:
        st = self.channels[channel]
        ok = st.aggregator.remove_subscription(param, self.brokers.names[broker], sid)
        if ok:
            st.user_params.remove(param)
            st.note_change()
        return ok

    def remove_subscriptions(self, channel: str, sids: np.ndarray) -> int:
        """Bulk removal by sID: O(Δ) routing through the aggregator's
        sid->slot map, UserParameters refcounts decremented for every
        subscription actually removed (so the early semi-join mask can
        SHRINK as interests lapse), one epoch bump. Unknown sIDs are
        ignored; returns the number removed."""
        st = self.channels[channel]
        params = st.aggregator.remove_bulk(np.asarray(sids))
        if params.size:
            st.user_params.remove_bulk(params)
            st.note_change()
        return int(params.size)

    def subscribe_users(self, channel: str, user_ids: np.ndarray) -> int:
        """Attach users to a spatial channel's cohort. The first call
        converts the channel from the legacy all-users semantics to an
        explicit cohort holding exactly the given ids. Returns the number
        newly attached."""
        st = self.channels[channel]
        if st.spec.join != "spatial":
            raise ValueError(f"{channel} is not a spatial channel")
        uids = np.asarray(user_ids, dtype=np.int32).ravel()
        nu = self.user_locations.shape[0]
        if uids.size and (int(uids.min()) < 0 or int(uids.max()) >= nu):
            raise ValueError(f"user ids out of [0, {nu})")
        created = st.cohort is None
        if created:
            st.cohort = UserCohort()
        touched = st.cohort.add(uids)
        if touched or created:
            # cohort CREATION alone changes semantics (all-users ->
            # explicit cohort) and remaps spill target space: bump even
            # when no id was new
            st.note_user_change(touched)
        return len(touched)

    def unsubscribe_users(self, channel: str, user_ids: np.ndarray) -> int:
        """Detach users from a spatial channel's cohort (no-op for ids not
        in it). Returns the number detached."""
        st = self.channels[channel]
        if st.cohort is None:
            return 0
        touched = st.cohort.remove(np.asarray(user_ids, dtype=np.int32))
        if touched:
            st.note_user_change(touched)
        return len(touched)

    def set_user_locations(self, locations: np.ndarray,
                           brokers: Optional[np.ndarray] = None) -> None:
        self.user_locations = jnp.asarray(locations, dtype=jnp.float32)
        if brokers is None:
            brokers = np.zeros((locations.shape[0],), dtype=np.int32)
        self.user_brokers = jnp.asarray(brokers, dtype=jnp.int32)
        self._user_version += 1  # invalidate stacked user targets

    # ------------------------------------------------------------------
    # data plane: ingestion
    # ------------------------------------------------------------------

    def _rebuild_conditions(self, old_rows: Optional[List[int]] = None) -> None:
        """Recompile the conditionsList and re-shape the BAD index.

        ``old_rows[i]`` is the *previous* index row of the channel now at row
        ``i`` — surviving channels keep their own buffers/watermarks by
        identity, not by position (dropping a middle channel must not hand its
        rows to the next one).
        """
        specs = sorted(self.channels.values(), key=lambda s: s.index)
        self._conds = compile_conditions([list(s.spec.fixed_preds) for s in specs])
        old = self.index_state
        new = bidx.BADIndexState.create(len(specs), self.index_capacity)
        if old_rows is None:  # channel append: surviving rows keep positions
            old_rows = list(range(min(old.num_channels, new.num_channels)))
        assert all(0 <= r < old.num_channels for r in old_rows)
        if old_rows:
            src = jnp.asarray(old_rows, jnp.int32)
            n = len(old_rows)
            new = bidx.BADIndexState(
                new.row_ids.at[:n].set(old.row_ids[src]),
                new.counts.at[:n].set(old.counts[src]),
                new.watermarks.at[:n].set(old.watermarks[src]),
                new.overflowed.at[:n].set(old.overflowed[src]),
            )
        self.index_state = new
        self._ingest_fn = None  # shapes changed; re-trace
        self._exec_cache.clear()  # compiled plans bind conds + channel rows
        self._stream_buckets.clear()  # compact stream caps re-converge
        self._stream_idle.clear()
        # stacked caches track per-channel epochs; a same-named channel
        # re-created at epoch 0 would collide, so drop them here too
        self._stacked_cache.clear()
        self._warmed.clear()   # warm bookkeeping follows the plan caches
        # retry rings are shaped/positioned by the channel set: hand their
        # resident entries to the host queue (dropped channels drop at
        # drain time, counted) rather than silently losing them
        self.flush_rings()

    def _build_ingest(self):
        conds = self._conds
        use_pallas = self.use_pallas
        maint = self.maintenance

        def ingest_step(ds, index_state, batch):
            maint.traces += 1          # Python body runs at trace time only
            ds, row_ids = _append(ds, batch)
            if use_pallas:
                from repro.kernels.predicate_filter import ops as pf_ops
                matches = pf_ops.predicate_filter(batch.fields, conds)
            else:
                matches = evaluate_conditions(batch.fields, conds)
            index_state = _insert(index_state, row_ids, matches)
            return ds, index_state, row_ids

        # steady-state ticks update the dataset + BAD index IN PLACE: the
        # previous tick's buffers are donated, so XLA aliases them into the
        # outputs instead of allocating/copying per tick. The engine never
        # re-presents a pre-ingest handle (self.dataset/index_state are
        # reassigned right here), so donation is externally invisible.
        return jax.jit(ingest_step, donate_argnums=(0, 1))

    def ingest(self, batch: R.RecordBatch) -> np.ndarray:
        """Data feed entry point: append + BAD-index maintenance (Algorithm 2).

        Host-sync free: row ids and the ``now`` watermark are derived on the
        host (``append`` assigns ``size + arange(n)`` and ``size_host``
        mirrors device size exactly), so ingest never blocks on the device
        queue — the returned ids are valid while the append is still in
        flight."""
        if self._ingest_fn is None:
            self._ingest_fn = self._build_ingest()
        n = batch.num_records
        row_ids = np.arange(self.size_host, self.size_host + n,
                            dtype=np.int32)
        self.dataset, self.index_state, _ = self._ingest_fn(
            self.dataset, self.index_state, batch)
        self.size_host += n
        if n:
            # reads the batch INPUT buffer (already materialized), not a
            # computation output — no dispatch-queue sync
            ts = np.asarray(batch.fields)[:, R.TIMESTAMP]
            self.now = max(self.now, int(ts.max()))
        return row_ids

    # ------------------------------------------------------------------
    # data plane: channel execution
    # ------------------------------------------------------------------

    def _targets_host(self, st: ChannelState, aggregated: bool) -> Tuple:
        """Host-side (numpy) join targets: (params, brokers, counts, by_param,
        by_param_count). Shared by the per-channel and stacked device caches."""
        cached = st._host_targets.get(aggregated)
        if cached is not None:
            return cached
        if aggregated:
            groups = st._groups or st.aggregator.build()
            st._groups = groups
            params = np.asarray(groups.group_params, np.int32)
            brokers = np.asarray(groups.group_brokers, np.int32)
            counts = np.asarray(groups.group_counts, np.int32)
        else:
            flat = self._flat_table(st)
            params = np.asarray(flat.params, np.int32)
            brokers = np.asarray(flat.brokers, np.int32)
            counts = np.ones_like(params)
        by_param, by_count = subs.param_to_targets(params, st.spec.param_domain)
        out = (params, brokers, counts, by_param, by_count)
        st._host_targets[aggregated] = out
        return out

    def _targets(self, st: ChannelState, aggregated: bool) -> plans.TargetArrays:
        cached = st._targets_grouped if aggregated else st._targets_flat
        if cached is None:
            p, b, c, bp, bc = self._targets_host(st, aggregated)
            cached = plans.TargetArrays(jnp.asarray(p), jnp.asarray(b),
                                        jnp.asarray(c), jnp.asarray(bp),
                                        jnp.asarray(bc))
            if aggregated:
                st._targets_grouped = cached
            else:
                st._targets_flat = cached
        return cached

    def _flat_table(self, st: ChannelState) -> subs.SubscriptionTable:
        if st._flat is None:
            groups = st._groups or st.aggregator.build()
            st._groups = groups
            st._flat = subs.flatten_groups(groups)
        return st._flat

    def _cohort_device(self, st: ChannelState) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray,
                                                        jnp.ndarray]:
        """One cohort channel's device (locs, brokers, slot->uid table),
        cached on the ChannelState by (user_epoch, user_version) — the
        per-channel join AND the delivery/drain paths read the same upload."""
        key = (st.user_epoch, self._user_version)
        if st._cohort_users is not None and st._cohort_users[0] == key:
            return st._cohort_users[1]
        locs, brokers, uids = self._cohort_rows(st)
        val = (jnp.asarray(locs.reshape(-1, 2)), jnp.asarray(brokers),
               jnp.asarray(uids)[:, None])
        st._cohort_users = (key, val)
        return val

    def _channel_users(self, st: ChannelState) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray]:
        """One channel's user set for the per-channel spatial join: the
        global tables when it has no cohort, else the cohort's slot-shaped
        gather (holes at the far sentinel, so slot indices — the pair
        targets — line up with the fused stacked rows)."""
        if st.spec.join != "spatial" or st.cohort is None:
            return self.user_locations, self.user_brokers
        return self._cohort_device(st)[:2]

    def _spatial_sids_table(self, st: ChannelState) -> Optional[jnp.ndarray]:
        """Slot->uid delivery table for a cohort spatial channel ((U, 1),
        -1 holes); None selects the legacy identity fanout (no cohort:
        targets already ARE global user ids)."""
        if st.cohort is None:
            return None
        return self._cohort_device(st)[2]

    def group_sids_array(self, channel: str, aggregated: bool) -> jnp.ndarray:
        st = self.channels[channel]
        if aggregated:
            groups = st._groups or st.aggregator.build()
            st._groups = groups
            return jnp.asarray(groups.group_sids)
        flat = self._flat_table(st)
        return jnp.asarray(flat.sids)[:, None]

    def _exec_fn(self, channel: str, flags: plans.ExecutionFlags,
                 spatial: bool, max_cand: Optional[int] = None,
                 backend: Optional[str] = None,
                 stream_cap: int = 0) -> Callable:
        """Compiled single-channel plan, cached by everything it closes over:
        the (frozen) spec, flags, and the channel's index row. Keying on the
        spec — not the name — means re-creating a same-named channel with new
        predicates can never be served a stale plan; the cache itself lives on
        the engine and is cleared on channel create/drop.

        ``backend`` overrides the engine backend (so plan search can time
        every backend, compact included); the compact backends run the
        single-channel pipeline as a C==1 compacted stream of ``stream_cap``
        entries. The compiled function returns ``(result, stream_total)`` —
        total is 0 on the padded backends. Returns ``(fn, key)`` so callers
        can warm through ``_warm_if_new`` on actual cache misses only."""
        st = self.channels[channel]
        backend = backend or ("pallas" if self.use_pallas else "oracle")
        key = (st.spec, flags, spatial, max_cand, st.index, backend,
               stream_cap)
        cached = self._exec_cache.get(key)
        if cached is not None:
            return cached, key
        spec = st.spec
        conds_one = compile_conditions([list(spec.fixed_preds)])
        best_pred = int(np.argmax([_pred_rank(p) for p in spec.fixed_preds])) \
            if spec.fixed_preds else 0
        max_window = self.max_window
        max_cand = max_cand or self.max_candidates
        num_brokers = self.brokers.num_brokers
        use_pallas = plans.backend_family(backend) == "pallas"
        compact = plans.is_compact(backend)
        join_fn = None
        if backend == "compact_pallas":
            from repro.kernels.join_compact import ops as jc_ops
            join_fn = jc_ops.join_pairs
        ch_idx = st.index

        maint = self.maintenance

        def run(ds, index_state, targets, up_mask, last_ts, last_size,
                user_locations, user_brokers):
            maint.traces += 1          # trace-time side effect: counts traces
            if flags.scan_mode == "full":
                cand = plans.candidates_full_scan(ds, conds_one, last_ts, max_cand)
            elif flags.scan_mode == "window":
                cand = plans.candidates_window(ds, conds_one, last_size, max_window)
            elif flags.scan_mode == "trad_index":
                cand = plans.candidates_trad_index(ds, conds_one, best_pred,
                                                   last_size, max_window, max_cand)
            else:
                cand = plans.candidates_bad_index(ds, index_state, ch_idx, max_cand)
            if compact:
                # C==1 compacted stream: same code path as the fused groups
                cand1 = jax.tree.map(lambda a: a[None], cand)
                stream = plans.compact_candidates(cand1, stream_cap)
                if spatial:
                    sj = plans.join_spatial_stream(
                        ds, stream, user_locations[None], user_brokers[None],
                        jnp.asarray([spec.spatial_radius], jnp.float32),
                        jnp.asarray([spec.payload_bytes], jnp.int32),
                        num_brokers)
                else:
                    sj = plans.join_param_stream(
                        ds, stream, jax.tree.map(lambda a: a[None], targets),
                        jnp.asarray([spec.param_field], jnp.int32),
                        jnp.asarray([spec.payload_bytes], jnp.int32),
                        num_brokers,
                        up_mask[None] if flags.param_pushdown else None,
                        flags.aggregation,
                        jnp.asarray([targets.by_param.shape[0]], jnp.int32),
                        join_fn)
                width = min(stream_cap, cand.rows.shape[0])
                res1 = plans.stream_to_stacked(sj, stream, cand1.scanned,
                                               width)
                return (jax.tree.map(lambda a: a[0], res1), stream.total)
            if spatial:
                spatial_fn = None
                if use_pallas:
                    from repro.kernels.spatial_match import ops as sm_ops
                    spatial_fn = sm_ops.spatial_match
                return (plans.join_spatial(ds, cand, user_locations,
                                           user_brokers, spec.spatial_radius,
                                           spec.payload_bytes, num_brokers,
                                           spatial_fn),
                        jnp.zeros((), jnp.int32))
            return (plans.join_param_targets(
                ds, cand, targets, spec.param_field, spec.payload_bytes,
                num_brokers, up_mask if flags.param_pushdown else None,
                flags.aggregation), jnp.zeros((), jnp.int32))

        fn = jax.jit(run)
        self._cache_put(key, fn)
        return fn, key

    def _cache_put(self, key, fn: Callable, cap: int = 256) -> None:
        """Insert into the plan cache with FIFO eviction — superseded shape
        buckets / flag combos must not pin dead XLA executables forever."""
        if len(self._exec_cache) >= cap:
            self._exec_cache.pop(next(iter(self._exec_cache)))
        self._exec_cache[key] = fn

    def _warm_if_new(self, key, fn: Callable, args: tuple) -> None:
        """Warm (execute + block) a compiled plan ONLY when this (plan key,
        concrete arg shapes) pair has never executed — i.e. on an actual
        trace-cache miss. Timed callers use this so wall time measures
        execution, not tracing; warming unconditionally would run every
        cached executable twice per timed call. Keyed on the plan-cache key
        plus the argument shape/dtype signature (a new shape bucket on a
        cached key still traces, so it still warms)."""
        leaves = jax.tree_util.tree_leaves(args)
        sig = (key, tuple(
            (leaf.shape, str(leaf.dtype)) if hasattr(leaf, "shape")
            else repr(leaf) for leaf in leaves))
        if sig in self._warmed:
            return
        if len(self._warmed) > 1024:   # follows the plan caches' spirit:
            self._warmed.clear()       # never pin unbounded bookkeeping
        self._warmed.add(sig)
        jax.block_until_ready(fn(*args))

    def _delivery_fn(self) -> Callable:
        """The per-channel reference delivery: the SAME fused kernels as
        ``execute_all(deliver=True)`` run on a C==1 stack, so the two paths
        are stats-identical by construction."""
        if self._deliver_jit is None:
            pw, mp = self.deliver_payload_words, self.max_deliver_pairs
            mn, sc = self.max_notify, self.max_spill
            nb = self.brokers.num_brokers
            maint = self.maintenance

            def deliver(res, sids, tb, counts):
                maint.traces += 1
                return deliver_all(res, sids, pw, mp, mn, sc,
                                   target_brokers=tb, num_brokers=nb,
                                   counts=counts)

            self._deliver_jit = jax.jit(deliver)
        return self._deliver_jit

    def _deliver(self, st: ChannelState, result: plans.ChannelResult,
                 aggregated: bool) -> DeliveryStats:
        """Run the broker convert+send stages on one channel's result,
        capture overflow into the spill queue, and account every pair/sID
        (delivered + spilled + dropped == produced, per stage)."""
        res1 = jax.tree.map(lambda a: a[None], result)
        counts = None
        if st.spec.join == "spatial":
            tbl = self._spatial_sids_table(st)
            if tbl is None:
                # spatial targets ARE end-user ids; a 0-wide table selects
                # the brokers' identity fanout (they read targets directly
                # and never index the table's values)
                sids = jnp.zeros((1, 0), dtype=jnp.int32)
                tb = self.user_brokers[None]
            else:
                # cohort channel: targets are cohort SLOTS; the slot->uid
                # table maps them to global user ids, brokers follow the
                # cohort rows
                sids = tbl[None]
                tb = self._channel_users(st)[1][None]
        else:
            sids = self.group_sids_array(st.spec.name, aggregated)[None]
            targets = self._targets(st, aggregated)
            tb = targets.brokers[None]
            # the member-count pass reads the counts the engine maintains
            # instead of re-deriving them from the sID table
            counts = targets.counts[None]
        d = self._delivery_fn()(res1, sids, tb, counts)
        return self._spill_and_stats([st], aggregated, d)[st.spec.name]

    def _spill_and_stats(self, chs: List[ChannelState], layout,
                         d: FusedDelivery,
                         epochs: Optional[List[int]] = None,
                         resolve_tables: Optional[np.ndarray] = None,
                         ranked: Optional[Tuple[np.ndarray, np.ndarray]] = None
                         ) -> Dict[str, DeliveryStats]:
        """Host side of a delivery: push the captured flat spill streams into
        the SpillQueue per channel (entries past the queue's capacity — or
        past the device capture buffer — become counted drops) and assemble
        each channel's conserving DeliveryStats. ``layout`` tags the pair
        lane with the TARGET INDEX SPACE the producing join used (False =
        flat rows, True = compacted group rows, "slot" = aggregator slot
        rows) so the drain re-packs against the matching table.

        ``epochs`` stamps pair entries with the DISPATCH-time epoch instead
        of the live one (a deferred sync may run after churn moved the
        channel on). ``resolve_tables`` (the dispatch-time stacked sID
        tables, host-materialized) switches pair capture to the epoch-free
        RESOLVED lane: each spilled pair's fanout is resolved here, against
        the table its producing call joined, so deferred batched drains
        cannot go stale.

        ``ranked`` (per-channel pruned pair / member-sID counts from the
        enrichment stage) re-enters budget-pruned pairs as counted drops:
        delivery saw the PRUNED result, so its produced counters undershoot
        the report's by exactly these amounts."""
        pack_d = np.asarray(d.pack.delivered)
        pack_p = np.asarray(d.pack.produced)
        fan_d = np.asarray(d.fan.delivered)
        fan_p = np.asarray(d.fan.produced)
        per_broker = np.asarray(d.pack.per_broker)
        pvalid = np.asarray(d.pair_spill.valid)
        prows = np.asarray(d.pair_spill.rows)[pvalid]
        pchan = np.asarray(d.pair_spill.channels)[pvalid]
        ptgts = np.asarray(d.pair_spill.targets)[pvalid]
        svalid = np.asarray(d.sid_spill.valid)
        svals = np.asarray(d.sid_spill.values)[svalid]
        schan = np.asarray(d.sid_spill.channels)[svalid]
        cnt = d.counters
        if cnt is not None:
            retried_p, stale_p, ring_p, retried_s, ring_s = (
                np.asarray(x) for x in cnt)
        out: Dict[str, DeliveryStats] = {}
        for i, st in enumerate(chs):
            name = st.spec.name
            sel = pchan == i
            if resolve_tables is not None:
                rows_i, tgts_i = prows[sel], ptgts[sel]
                sid_rows = resolve_pair_sids(resolve_tables[i], tgts_i)
                spilled_p = self.spill.push_resolved(name, rows_i, tgts_i,
                                                     sid_rows)
            else:
                epoch = st.epoch if epochs is None else epochs[i]
                spilled_p = self.spill.push_pairs(name, layout, prows[sel],
                                                  ptgts[sel], epoch)
            sel = schan == i
            spilled_s = self.spill.push_sids(name, svals[sel])
            ov_p = int(pack_p[i] - pack_d[i])
            ov_s = int(fan_p[i] - fan_d[i])
            rk_p = int(ranked[0][i]) if ranked is not None else 0
            rk_s = int(ranked[1][i]) if ranked is not None else 0
            if cnt is None:
                out[name] = DeliveryStats(
                    delivered_pairs=int(pack_d[i]), spilled_pairs=spilled_p,
                    dropped_pairs=ov_p - spilled_p + rk_p,
                    delivered_sids=int(fan_d[i]), spilled_sids=spilled_s,
                    dropped_sids=ov_s - spilled_s + rk_s,
                    delivered_pairs_broker=tuple(int(x)
                                                 for x in per_broker[i]),
                    ranked_pairs=rk_p, ranked_sids=rk_s)
            else:
                # ring-resident entries count as spilled; overflow past the
                # ring that also missed the queue (or went epoch-stale in
                # the ring) counts as dropped — conservation per stage:
                # delivered + spilled + dropped == produced (fresh + retried)
                host_want_p = ov_p - int(stale_p[i]) - int(ring_p[i])
                host_want_s = ov_s - int(ring_s[i])
                out[name] = DeliveryStats(
                    delivered_pairs=int(pack_d[i]),
                    spilled_pairs=int(ring_p[i]) + spilled_p,
                    dropped_pairs=(int(stale_p[i]) + host_want_p - spilled_p
                                   + rk_p),
                    delivered_sids=int(fan_d[i]),
                    spilled_sids=int(ring_s[i]) + spilled_s,
                    dropped_sids=host_want_s - spilled_s + rk_s,
                    delivered_pairs_broker=tuple(int(x)
                                                 for x in per_broker[i]),
                    retried_pairs=int(retried_p[i]),
                    retried_sids=int(retried_s[i]),
                    ranked_pairs=rk_p, ranked_sids=rk_s)
        return out

    def execute_channel(self, channel: str,
                        flags: plans.ExecutionFlags,
                        advance: bool = True,
                        timed: bool = True,
                        deliver: bool = False,
                        backend: Optional[str] = None) -> ExecutionReport:
        st = self.channels[channel]
        spatial = st.spec.join == "spatial"
        backend = backend or ("pallas" if self.use_pallas else "oracle")
        # The BAD index knows its exact candidate count before execution (the
        # watermark delta) — unlike scans/traditional indexes — so downstream
        # buffers are shape-bucketed to the real volume ("early result
        # filtering" paying off structurally, not just in rows scanned).
        max_cand = None
        if flags.scan_mode == "bad_index":
            pending = int(self.index_state.counts[st.index]
                          - self.index_state.watermarks[st.index])
            bucket = _pow2_bucket(pending, 6)
            max_cand = min(bucket, self.max_candidates)
        targets = self._targets(st, flags.aggregation)
        up_mask = st.user_params.mask()
        args = (self.dataset, self.index_state, targets, up_mask,
                jnp.asarray(st.last_exec_ts, jnp.int32),
                jnp.asarray(st.last_exec_size, jnp.int32),
                *self._channel_users(st))
        if plans.is_compact(backend):
            # per-channel grow-on-overflow, same protocol as the fused path
            key = ("chan", channel, flags, spatial)
            width = (self.max_window if flags.scan_mode == "window"
                     else (max_cand or self.max_candidates))
            stream_cap = min(self._stream_buckets.get(key, 1 << _STREAM_FLOOR),
                             _pow2_bucket(width, _STREAM_FLOOR))
            while True:
                fn, fkey = self._exec_fn(channel, flags, spatial, max_cand,
                                         backend, stream_cap)
                if timed:  # warm so wall time measures execution, not tracing
                    self._warm_if_new(fkey, fn, args)
                t0 = time.perf_counter()
                result, tot = fn(*args)
                jax.block_until_ready(result.num_results)
                wall = time.perf_counter() - t0
                if int(jax.device_get(tot)) <= stream_cap:
                    break
                stream_cap = _pow2_bucket(int(jax.device_get(tot)),
                                          _STREAM_FLOOR)
            self._stream_buckets[key] = stream_cap
        else:
            fn, fkey = self._exec_fn(channel, flags, spatial, max_cand,
                                     backend)
            if timed:  # warm the trace so wall time measures execution
                self._warm_if_new(fkey, fn, args)
            t0 = time.perf_counter()
            result, _tot = fn(*args)
            jax.block_until_ready(result.num_results)
            wall = time.perf_counter() - t0
        if advance:
            self.index_state = bidx.advance_watermark(self.index_state, st.index)
            st.last_exec_ts = self.now
            st.last_exec_size = self.size_host
            st.executions += 1
        overflow = self._deliver(st, result, flags.aggregation) if deliver else None
        return ExecutionReport(
            channel=channel, flags=flags, result=result, wall_time_s=wall,
            num_results=int(result.num_results),
            num_notified=int(result.num_notified),
            scanned=int(result.scanned),
            broker_bytes=np.asarray(result.broker_bytes),
            overflow=overflow)

    # ------------------------------------------------------------------
    # data plane: fused multi-channel execution
    # ------------------------------------------------------------------

    def _stacked_inputs(self, chs: List[ChannelState], aggregated: bool):
        """Device-resident shape-bucketed targets for all param channels —
        see ``_group_state`` for the epoch/delta maintenance contract."""
        c = self._group_state(chs, aggregated)
        return c.targets, c.up_masks, c.domains

    def _stacked_sids(self, chs: List[ChannelState],
                      aggregated: bool) -> jnp.ndarray:
        """Stacked device group-sID tables (C, tmax, cap) for fused
        delivery; rows align with the target slots of the SAME cache entry
        (one patch updates both)."""
        return self._group_state(chs, aggregated).sids

    def _group_state(self, chs: List[ChannelState],
                     aggregated: bool) -> _GroupCache:
        """The fused path's stacked group state, maintained by the
        epoch/delta protocol.

        Shapes are capacity-padded to shared power-of-two buckets (tmax slot
        rows / real max domain / mmax join fan-out), so the fused trace is
        stable across churn; -1 / 0 padding can never form a valid pair. On
        an epoch move the entry is PATCHED in place from the channels' group
        deltas (O(delta) host work + one jitted scatter per changed channel);
        it fully rebuilds only when padded capacity is exceeded, a delta is
        unavailable (log gap / out-of-band mutation), the channel set
        changed, or the engine runs with ``incremental=False`` — the flat
        layout always rebuilds (per-subscription rows have no stable slot
        identity)."""
        names = tuple(st.spec.name for st in chs)
        epochs = [st.epoch for st in chs]
        # keyed by layout AND the group's channel membership: concurrent
        # plan-groups (heterogeneous assignments) each keep their own
        # patchable entry instead of thrashing a single slot
        cache = self._stacked_cache.get(("groups", aggregated, names))
        if cache is not None and cache.names == names:
            if cache.epochs == epochs:
                return cache
            if self.incremental:
                if aggregated:
                    patches = self._group_patches(cache, chs)
                    if patches is not None:
                        self._apply_group_patches(cache, chs, patches)
                        return cache
                else:
                    patches = self._flat_patches(cache, chs)
                    if patches is not None:
                        self._apply_flat_patches(cache, chs, patches)
                        return cache
        cache = self._build_group_state(chs, aggregated)
        self._stacked_put(("groups", aggregated, names), cache)
        return cache

    def _stacked_put(self, key, cache, cap: int = 32) -> None:
        """Insert a stacked cache entry with FIFO eviction — plan switches
        re-group channels, and superseded groupings must not pin dead
        device arrays forever."""
        if key not in self._stacked_cache and len(self._stacked_cache) >= cap:
            self._stacked_cache.pop(next(iter(self._stacked_cache)))
        self._stacked_cache[key] = cache

    def _build_group_state(self, chs: List[ChannelState],
                           aggregated: bool) -> _GroupCache:
        self.maintenance.rebuilds += 1
        names = tuple(st.spec.name for st in chs)
        n = len(chs)
        dmax = max(st.spec.param_domain for st in chs)
        if aggregated and self.incremental:
            # slot-indexed arrays: row == aggregator slot, free slots
            # zero-count — the layout group deltas patch directly
            hosts = [st.aggregator.slot_arrays() for st in chs]
            tmax = _pow2_bucket(max(h[0].shape[0] for h in hosts), 3)
            mmax = _pow2_bucket(
                max(st.aggregator.max_param_fanout() for st in chs), 3)
            cap = max(st.aggregator.cap for st in chs)
            by_param = np.full((n, dmax, mmax), -1, np.int32)
            by_count = np.zeros((n, dmax), np.int32)
            sids = np.full((n, tmax, cap), -1, np.int32)
            for i, (st, h) in enumerate(zip(chs, hosts)):
                for p, row in st.aggregator.param_items():
                    by_param[i, p, :len(row)] = row
                    by_count[i, p] = len(row)
                sids[i, :h[3].shape[0], :h[3].shape[1]] = h[3]
        elif self.incremental:
            # FLAT stable slots: row == per-subscription flat slot, free
            # slots zero-count; join-map rows are positional ((param, pos)
            # cells stable under churn, -1 holes masked by the join) so the
            # churn engine patches this cache cell-wise instead of
            # rebuilding it per epoch
            hosts = [st.aggregator.flat_slot_arrays() for st in chs]
            tmax = _pow2_bucket(max(h[0].shape[0] for h in hosts), 3)
            mmax = _pow2_bucket(
                max(st.aggregator.max_flat_extent() for st in chs), 3)
            cap = 1
            by_param = np.full((n, dmax, mmax), -1, np.int32)
            by_count = np.zeros((n, dmax), np.int32)
            sids = np.full((n, tmax, cap), -1, np.int32)
            for i, (st, h) in enumerate(zip(chs, hosts)):
                for p, row in st.aggregator.flat_param_rows():
                    by_param[i, p, :len(row)] = row
                    by_count[i, p] = len(row)       # extent, holes masked
                sids[i, :h[3].shape[0], 0] = h[3]
        else:
            # compacted build() rows (the pre-churn-engine layout); the flat
            # table IS this with one row per subscription
            hosts2 = [self._targets_host(st, aggregated) for st in chs]
            hosts = [(h[0], h[1], h[2]) for h in hosts2]
            tmax = _pow2_bucket(max(h[0].shape[0] for h in hosts2), 3)
            mmax = _pow2_bucket(max(h[3].shape[1] for h in hosts2), 3)
            by_param = np.full((n, dmax, mmax), -1, np.int32)
            by_count = np.zeros((n, dmax), np.int32)
            srcs = []
            for st in chs:
                if aggregated:
                    groups = st._groups or st.aggregator.build()
                    st._groups = groups
                    srcs.append(np.asarray(groups.group_sids, np.int32))
                else:
                    srcs.append(np.asarray(self._flat_table(st).sids,
                                           np.int32)[:, None])
            cap = max(h.shape[1] for h in srcs)
            sids = np.full((n, tmax, cap), -1, np.int32)
            for i, (h2, h) in enumerate(zip(hosts2, srcs)):
                d, m = h2[3].shape
                by_param[i, :d, :m] = h2[3]
                by_count[i, :d] = h2[4]
                sids[i, :h.shape[0], :h.shape[1]] = h
        params = np.zeros((n, tmax), np.int32)
        brokers = np.zeros((n, tmax), np.int32)
        counts = np.zeros((n, tmax), np.int32)
        up_masks = np.zeros((n, dmax), bool)
        domains = np.zeros((n,), np.int32)
        for i, (st, (p, b, c, *_)) in enumerate(zip(chs, hosts)):
            t = p.shape[0]
            params[i, :t] = p
            brokers[i, :t] = b
            counts[i, :t] = c
            up_masks[i, :st.spec.param_domain] = st.user_params.refcount > 0
            domains[i] = st.spec.param_domain
        targets = plans.TargetArrays(
            jnp.asarray(params), jnp.asarray(brokers), jnp.asarray(counts),
            jnp.asarray(by_param), jnp.asarray(by_count))
        return _GroupCache(names, aggregated, [st.epoch for st in chs],
                           tmax, dmax, mmax, cap, targets,
                           jnp.asarray(up_masks), jnp.asarray(domains),
                           jnp.asarray(sids))

    def _group_patches(self, cache: _GroupCache, chs: List[ChannelState]):
        """Per-channel (slots, params) patch sets covering every epoch since
        the cache's snapshot, or None if any channel must rebuild (delta gap
        or padded capacity exceeded)."""
        out = []
        for st, cached_e in zip(chs, cache.epochs):
            if st.epoch == cached_e:
                out.append(None)
                continue
            if st.epoch - cached_e > len(st.delta_log):
                return None          # gap certain: don't materialize it
            need = set(range(cached_e + 1, st.epoch + 1))
            slots, params_t = set(), set()
            for e, d in st.delta_log:
                if e in need:
                    need.discard(e)
                    if d.full:
                        return None      # whole-table adopt: rebuild
                    slots |= d.slots
                    params_t |= d.params
            agg = st.aggregator
            if need or agg.num_slots > cache.tmax or agg.cap != cache.cap:
                return None
            if any(len(agg.param_slots(p)) > cache.mmax for p in params_t):
                return None
            out.append((slots, params_t))
        return out

    def _apply_group_patches(self, cache: _GroupCache,
                             chs: List[ChannelState], patches) -> None:
        """One jitted scatter per changed channel: touched slot rows and
        touched by-param rows are re-read from the aggregator (current
        content) and written in place. Patch batches are padded to
        power-of-two buckets with out-of-bounds indices (dropped by the
        scatter), so a steady churn rate replays one cached trace."""
        fn = self._group_patch_fn()
        t = cache.targets
        arrays = (t.params, t.brokers, t.counts, t.by_param,
                  t.by_param_count, cache.up_masks, cache.sids)
        for ci, (st, patch) in enumerate(zip(chs, patches)):
            if patch is None:
                continue
            slots, params_t = patch
            # generous bucket floors: small tick-to-tick delta-size jitter
            # stays inside one bucket (one cached trace), scatter cost of
            # the padding is trivial
            kb = _pow2_bucket(len(slots), 7)
            mb = _pow2_bucket(len(params_t), 5)
            sl = np.sort(np.fromiter(slots, np.int64, len(slots)))
            sl_idx = np.full((kb,), cache.tmax, np.int32)   # OOB pad: dropped
            sl_p = np.zeros((kb,), np.int32)
            sl_b = np.zeros((kb,), np.int32)
            sl_c = np.zeros((kb,), np.int32)
            sl_s = np.full((kb, cache.cap), -1, np.int32)
            sl_idx[:len(sl)] = sl
            (sl_p[:len(sl)], sl_b[:len(sl)], sl_c[:len(sl)],
             sl_s[:len(sl)]) = st.aggregator.slot_rows(sl)
            p_idx = np.full((mb,), cache.dmax, np.int32)
            p_rows = np.full((mb, cache.mmax), -1, np.int32)
            p_cnt = np.zeros((mb,), np.int32)
            p_mask = np.zeros((mb,), bool)
            for j, p in enumerate(sorted(params_t)):
                row = st.aggregator.param_slots(p)
                p_idx[j] = p
                p_rows[j, :len(row)] = row
                p_cnt[j] = len(row)
                p_mask[j] = st.user_params.refcount[p] > 0
            arrays = fn(arrays, jnp.asarray(ci, jnp.int32), sl_idx, sl_p,
                        sl_b, sl_c, sl_s, p_idx, p_rows, p_cnt, p_mask)
            self.maintenance.patches += 1
        cache.targets = plans.TargetArrays(*arrays[:5])
        cache.up_masks = arrays[5]
        cache.sids = arrays[6]
        cache.epochs = [st.epoch for st in chs]

    def _group_patch_fn(self) -> Callable:
        if self._patch_groups_jit is None:
            maint = self.maintenance

            def patch(arrays, ci, sl_idx, sl_p, sl_b, sl_c, sl_s,
                      p_idx, p_rows, p_cnt, p_mask):
                maint.traces += 1
                params, brokers, counts, by_param, by_count, up, sids = arrays
                return (params.at[ci, sl_idx].set(sl_p, mode="drop"),
                        brokers.at[ci, sl_idx].set(sl_b, mode="drop"),
                        counts.at[ci, sl_idx].set(sl_c, mode="drop"),
                        by_param.at[ci, p_idx].set(p_rows, mode="drop"),
                        by_count.at[ci, p_idx].set(p_cnt, mode="drop"),
                        up.at[ci, p_idx].set(p_mask, mode="drop"),
                        sids.at[ci, sl_idx].set(sl_s, mode="drop"))

            self._patch_groups_jit = jax.jit(patch)
        return self._patch_groups_jit

    # -- flat-layout stable slots (per-subscription rows) ----------------

    def _flat_patches(self, cache: _GroupCache, chs: List[ChannelState]):
        """Per-channel (flat slots, join-map cells, params) patch sets
        covering every epoch since the cache's snapshot, or None if any
        channel must rebuild (delta gap, whole-table adopt, or padded
        capacity exceeded)."""
        out = []
        for st, cached_e in zip(chs, cache.epochs):
            if st.epoch == cached_e:
                out.append(None)
                continue
            if st.epoch - cached_e > len(st.delta_log):
                return None          # gap certain: don't materialize it
            need = set(range(cached_e + 1, st.epoch + 1))
            slots, cells, params_t = set(), set(), set()
            for e, d in st.delta_log:
                if e in need:
                    need.discard(e)
                    if d.full:
                        return None  # whole-table adopt: rebuild
                    slots |= d.flat_slots
                    cells |= d.flat_cells
                    params_t |= d.params
            agg = st.aggregator
            if need or agg.num_flat_slots > cache.tmax:
                return None
            if any(agg.flat_row_extent(p) > cache.mmax for p in params_t):
                return None
            out.append((slots, cells, params_t))
        return out

    def _apply_flat_patches(self, cache: _GroupCache,
                            chs: List[ChannelState], patches) -> None:
        """One jitted scatter per changed channel: touched flat-slot rows
        are re-read from the aggregator's flat table and touched join-map
        CELLS ((param, position) — stable under churn) are written in
        place, so the patch cost is O(Δ) cells, never O(subs-per-param) row
        rewrites. Batches are padded to power-of-two buckets with
        out-of-bounds indices (dropped by the scatter)."""
        fn = self._flat_patch_fn()
        t = cache.targets
        arrays = (t.params, t.brokers, t.counts, t.by_param,
                  t.by_param_count, cache.up_masks, cache.sids)
        for ci, (st, patch) in enumerate(zip(chs, patches)):
            if patch is None:
                continue
            slots, cells, params_t = patch
            # generous bucket floors (cells run ~2x the slot count: every
            # add/remove touches one slot AND one join-map cell): small
            # tick-to-tick delta-size jitter stays inside one bucket
            kb = _pow2_bucket(len(slots), 7)
            cb = _pow2_bucket(len(cells), 8)
            mb = _pow2_bucket(len(params_t), 5)
            sl = np.sort(np.fromiter(slots, np.int64, len(slots)))
            sl_idx = np.full((kb,), cache.tmax, np.int32)   # OOB pad: dropped
            sl_p = np.zeros((kb,), np.int32)
            sl_b = np.zeros((kb,), np.int32)
            sl_c = np.zeros((kb,), np.int32)
            sl_s = np.full((kb, 1), -1, np.int32)
            sl_idx[:len(sl)] = sl
            p_, b_, c_, s_ = st.aggregator.flat_slot_rows(sl)
            sl_p[:len(sl)], sl_b[:len(sl)], sl_c[:len(sl)] = p_, b_, c_
            sl_s[:len(sl), 0] = s_
            c_p = np.full((cb,), cache.dmax, np.int32)      # OOB pad: dropped
            c_pos = np.zeros((cb,), np.int32)
            c_val = np.full((cb,), -1, np.int32)
            cp, cpos, cval = st.aggregator.flat_cell_rows(sorted(cells))
            c_p[:len(cp)], c_pos[:len(cp)], c_val[:len(cp)] = cp, cpos, cval
            e_idx = np.full((mb,), cache.dmax, np.int32)
            e_cnt = np.zeros((mb,), np.int32)
            e_mask = np.zeros((mb,), bool)
            for j, p in enumerate(sorted(params_t)):
                e_idx[j] = p
                e_cnt[j] = st.aggregator.flat_row_extent(p)
                e_mask[j] = st.user_params.refcount[p] > 0
            arrays = fn(arrays, jnp.asarray(ci, jnp.int32), sl_idx, sl_p,
                        sl_b, sl_c, sl_s, c_p, c_pos, c_val, e_idx, e_cnt,
                        e_mask)
            self.maintenance.patches += 1
        cache.targets = plans.TargetArrays(*arrays[:5])
        cache.up_masks = arrays[5]
        cache.sids = arrays[6]
        cache.epochs = [st.epoch for st in chs]

    def _flat_patch_fn(self) -> Callable:
        if self._patch_flat_jit is None:
            maint = self.maintenance

            def patch(arrays, ci, sl_idx, sl_p, sl_b, sl_c, sl_s,
                      c_p, c_pos, c_val, e_idx, e_cnt, e_mask):
                maint.traces += 1
                params, brokers, counts, by_param, by_count, up, sids = arrays
                return (params.at[ci, sl_idx].set(sl_p, mode="drop"),
                        brokers.at[ci, sl_idx].set(sl_b, mode="drop"),
                        counts.at[ci, sl_idx].set(sl_c, mode="drop"),
                        by_param.at[ci, c_p, c_pos].set(c_val, mode="drop"),
                        by_count.at[ci, e_idx].set(e_cnt, mode="drop"),
                        up.at[ci, e_idx].set(e_mask, mode="drop"),
                        sids.at[ci, sl_idx].set(sl_s, mode="drop"))

            self._patch_flat_jit = jax.jit(patch)
        return self._patch_flat_jit

    # -- stacked spatial user sets (per-channel cohorts) -----------------

    def _stacked_spatial_inputs(self, chs: List[ChannelState]):
        c = self._spatial_state(chs)
        return c.locs, c.brokers

    def _stacked_spatial_sids(self, chs: List[ChannelState]) -> jnp.ndarray:
        """Delivery sID tables for the spatial group: the legacy 0-width
        identity fanout while every channel serves all users (targets ARE
        end-user ids); with cohorts, a (C, ub, 1) slot->uid table so
        delivered sIDs are GLOBAL user ids, not cohort slots."""
        c = self._spatial_state(chs)
        if c.identity:
            return jnp.zeros((len(chs), 0), jnp.int32)
        return c.uids[:, :, None]

    def _spatial_state(self, chs: List[ChannelState]) -> _SpatialCache:
        """Stacked per-channel user sets, maintained by the same epoch/delta
        protocol as the group caches: cohort churn patches slot rows in
        place; a global ``set_user_locations`` (user-version bump), cohort
        creation, capacity overflow, or a delta gap rebuilds."""
        names = tuple(st.spec.name for st in chs)
        cohorted = tuple(st.cohort is not None for st in chs)
        epochs = [st.user_epoch for st in chs]
        cache = self._stacked_cache.get(("spatial", names))
        if cache is not None and cache.names == names \
                and cache.user_version == self._user_version \
                and cache.cohorted == cohorted:
            if cache.epochs == epochs:
                return cache
            if self.incremental:
                patches = self._spatial_patches(cache, chs)
                if patches is not None:
                    self._apply_spatial_patches(cache, chs, patches)
                    return cache
        cache = self._build_spatial_state(chs)
        self._stacked_put(("spatial", names), cache)
        return cache

    def _cohort_rows(self, st: ChannelState, slots=None):
        """Host (locs, brokers, uids) rows for a cohort channel's slots —
        holes (and uids past the current user table) sit at the far sentinel
        / -1 so they can never match or fan out."""
        from repro.kernels.spatial_match.ops import FAR
        uids = st.cohort.slot_uids()
        if slots is not None:
            uids = uids[slots]
        nu = self.user_locations.shape[0]
        ok = (uids >= 0) & (uids < nu)
        safe = np.where(ok, uids, 0)
        locs = np.where(ok[:, None], np.asarray(self.user_locations)[safe],
                        -FAR).astype(np.float32)
        brokers = np.where(ok, np.asarray(self.user_brokers)[safe],
                           0).astype(np.int32)
        return locs, brokers, np.where(ok, uids, -1).astype(np.int32)

    def _build_spatial_state(self, chs: List[ChannelState]) -> _SpatialCache:
        from repro.kernels.spatial_match.ops import FAR
        self.maintenance.rebuilds += 1
        u = self.user_locations.shape[0]
        rows = [u if st.cohort is None else max(st.cohort.num_slots, 1)
                for st in chs]
        ub = _pow2_bucket(max(rows), 3)
        n = len(chs)
        locs = np.full((n, ub, 2), -FAR, np.float32)
        brokers = np.zeros((n, ub), np.int32)
        uids = np.full((n, ub), -1, np.int32)
        for i, st in enumerate(chs):
            if st.cohort is None:
                locs[i, :u] = np.asarray(self.user_locations)
                brokers[i, :u] = np.asarray(self.user_brokers)
                uids[i, :u] = np.arange(u, dtype=np.int32)
            else:
                k = st.cohort.num_slots
                if k:
                    locs[i, :k], brokers[i, :k], uids[i, :k] = \
                        self._cohort_rows(st)
        return _SpatialCache(
            tuple(st.spec.name for st in chs), self._user_version,
            tuple(st.cohort is not None for st in chs),
            [st.user_epoch for st in chs], ub,
            jnp.asarray(locs), jnp.asarray(brokers), jnp.asarray(uids))

    def _spatial_patches(self, cache: _SpatialCache, chs: List[ChannelState]):
        out = []
        for st, cached_e in zip(chs, cache.epochs):
            if st.user_epoch == cached_e:
                out.append(None)
                continue
            if st.user_epoch - cached_e > len(st.user_delta_log):
                return None          # gap certain: don't materialize it
            need = set(range(cached_e + 1, st.user_epoch + 1))
            slots = set()
            for e, touched in st.user_delta_log:
                if e in need:
                    need.discard(e)
                    slots |= touched
            if need or st.cohort is None \
                    or st.cohort.num_slots > cache.ub:
                return None
            out.append(slots)
        return out

    def _apply_spatial_patches(self, cache: _SpatialCache,
                               chs: List[ChannelState], patches) -> None:
        fn = self._spatial_patch_fn()
        arrays = (cache.locs, cache.brokers, cache.uids)
        for ci, (st, slots) in enumerate(zip(chs, patches)):
            if slots is None:
                continue
            kb = _pow2_bucket(len(slots), 7)
            idx = np.full((kb,), cache.ub, np.int32)        # OOB pad: dropped
            sl = np.asarray(sorted(slots), np.int32)
            idx[:len(sl)] = sl
            l_rows = np.zeros((kb, 2), np.float32)
            b_rows = np.zeros((kb,), np.int32)
            u_rows = np.full((kb,), -1, np.int32)
            l, b, uu = self._cohort_rows(st, sl)
            l_rows[:len(sl)] = l
            b_rows[:len(sl)] = b
            u_rows[:len(sl)] = uu
            arrays = fn(arrays, jnp.asarray(ci, jnp.int32), idx,
                        l_rows, b_rows, u_rows)
            self.maintenance.patches += 1
        cache.locs, cache.brokers, cache.uids = arrays
        cache.epochs = [st.user_epoch for st in chs]

    def _spatial_patch_fn(self) -> Callable:
        if self._patch_spatial_jit is None:
            maint = self.maintenance

            def patch(arrays, ci, idx, l_rows, b_rows, u_rows):
                maint.traces += 1
                locs, brokers, uids = arrays
                return (locs.at[ci, idx].set(l_rows, mode="drop"),
                        brokers.at[ci, idx].set(b_rows, mode="drop"),
                        uids.at[ci, idx].set(u_rows, mode="drop"))

            self._patch_spatial_jit = jax.jit(patch)
        return self._patch_spatial_jit

    def _exec_all_fn(self, param_chs: List[ChannelState],
                     spatial_chs: List[ChannelState],
                     plan: plans.ChannelPlan, max_cand: int,
                     deliver: bool = False, p_stream: int = 0,
                     s_stream: int = 0,
                     donate_rings: bool = False) -> Tuple[Callable, tuple]:
        """ONE compiled plan for every channel of a plan-group: stacked
        candidate discovery per join group (param / spatial), vmapped joins,
        fused broker accounting. With a pallas-family backend the discovery
        runs the Pallas ``predicate_filter`` kernel and the spatial join the
        Pallas ``spatial_match`` kernel (both batched over the channel
        axis). The compact backends additionally compress the discovered
        candidates into a channel-major CSR stream (``p_stream`` /
        ``s_stream`` capacities, chosen by ``_run_compact_group``) and run
        the join + accounting over live entries only, scattering back to the
        stacked layout so delivery is bit-identical to the padded path. With
        ``deliver`` the broker convert+send stages (``deliver_all``) run in
        the SAME call — no host round-trip between discovery and fanout.

        The compiled function returns ``(res_p, res_s, del_p, del_s,
        (tot_p, tot_s), (rank_p, rank_s))`` — the totals are the
        pre-truncation live-candidate counts (0 on the padded backends),
        read by the grow loop to detect stream overflow; the rank entries
        are each ``(ranked_pairs, ranked_sids)`` (C,) counters from the
        enrichment stage's budget prune (None when no stage is active).
        When the dispatched plan carries a ``scorer`` tag the engine's
        ``enrichment`` stage scores each join group's candidate slots and
        prunes the lowest-scoring pairs past the budget BEFORE
        ``deliver_all`` — in the same call, so the hook adds no sync; the
        reports still carry the FULL join result (``num_results`` stays the
        produced count; ranked drops land in DeliveryStats). With
        ``donate_rings`` the retry-ring arguments are donated, so at steady
        state the ring buffers update in place (the dispatcher stores the
        OUTPUT ring and never re-presents the input handle; the compact
        grow loop must NOT donate — it re-presents the same ring to the
        re-run). Returns ``(fn, key)``."""
        key = ("all", plan, max_cand, deliver, p_stream, s_stream,
               donate_rings,
               tuple((st.spec, st.index) for st in param_chs),
               tuple((st.spec, st.index) for st in spatial_chs))
        cached = self._exec_cache.get(key)
        if cached is not None:
            return cached, key
        conds = self._conds
        max_window = self.max_window
        num_brokers = self.brokers.num_brokers
        scan_mode = plan.scan_mode
        pushdown = plan.param_pushdown
        aggregated = plan.aggregation
        use_pallas = plans.backend_family(plan.backend) == "pallas"
        compact = plans.is_compact(plan.backend)
        join_fn = None
        if use_pallas:
            from repro.kernels.predicate_filter import ops as pf_ops
            from repro.kernels.spatial_match import ops as sm_ops
            spatial_fn = sm_ops.spatial_match
            if plan.backend == "compact_pallas":
                from repro.kernels.join_compact import ops as jc_ops
                join_fn = jc_ops.join_pairs
        else:
            spatial_fn = None

        def group_statics(chs):
            rows = [st.index for st in chs]
            conds_sub = CompiledConditions(
                conds.field_idx[rows], conds.op[rows],
                conds.value[rows], conds.npreds[rows])
            best = jnp.asarray(
                [int(np.argmax([_pred_rank(p) for p in st.spec.fixed_preds]))
                 if st.spec.fixed_preds else 0 for st in chs], jnp.int32)
            match_fn = match_rows_fn = None
            if use_pallas:
                match_fn = lambda f, cs=conds_sub: pf_ops.predicate_filter(f, cs)
                match_rows_fn = (
                    lambda f, cs=conds_sub: pf_ops.predicate_filter_rows(f, cs))
            return (conds_sub, best, jnp.asarray(rows, jnp.int32),
                    match_fn, match_rows_fn)

        p_static = group_statics(param_chs) if param_chs else None
        s_static = group_statics(spatial_chs) if spatial_chs else None
        radii = jnp.asarray([st.spec.spatial_radius for st in spatial_chs],
                            jnp.float32)

        def discover(ds, index_state, static, last_ts, last_size):
            conds_sub, best, ch_rows, match_fn, match_rows_fn = static
            if scan_mode == "full":
                return plans.candidates_full_scan_all(ds, conds_sub, last_ts,
                                                      max_cand, match_fn)
            if scan_mode == "window":
                return plans.candidates_window_all(ds, conds_sub, last_size,
                                                   max_window, match_rows_fn)
            if scan_mode == "trad_index":
                return plans.candidates_trad_index_all(
                    ds, conds_sub, best, last_size, max_window, max_cand,
                    match_rows_fn)
            return plans.candidates_bad_index_all(index_state, ch_rows,
                                                  max_cand)

        pw, mp = self.deliver_payload_words, self.max_deliver_pairs
        mn, sc = self.max_notify, self.max_spill
        maint = self.maintenance
        # the enrichment stage binds at trace time, keyed by the plan's
        # scorer tag (stamped by ``dispatch``); a tagged plan on an engine
        # whose stage was detached mid-flight falls back to no-op
        stage = (self.enrichment
                 if deliver and plan.scorer is not None else None)

        def run(ds, index_state, p_in, s_in, p_ring, s_ring):
            maint.traces += 1          # trace-time side effect: counts traces
            res_p = res_s = del_p = del_s = None
            rank_p = rank_s = None
            tot_p = tot_s = jnp.zeros((), jnp.int32)
            if p_static is not None:
                cand = discover(ds, index_state, p_static,
                                p_in["last_ts"], p_in["last_size"])
                if compact:
                    stream = plans.compact_candidates(cand, p_stream)
                    tot_p = stream.total
                    sj = plans.join_param_stream(
                        ds, stream, p_in["targets"], p_in["param_field"],
                        p_in["payload"], num_brokers,
                        p_in["up_masks"] if pushdown else None, aggregated,
                        p_in["domains"], join_fn)
                    res_p = plans.stream_to_stacked(
                        sj, stream, cand.scanned,
                        min(p_stream, cand.rows.shape[1]))
                else:
                    res_p = plans.join_param_targets_all(
                        ds, cand, p_in["targets"], p_in["param_field"],
                        p_in["payload"], num_brokers,
                        p_in["up_masks"] if pushdown else None, aggregated,
                        p_in["domains"])
                if deliver:
                    res_del = res_p
                    if stage is not None:
                        res_del, rkp, rks = enrich.rank_result(
                            stage, ds, res_p, p_static[2], p_in["sids"],
                            counts=p_in["targets"].counts)
                        rank_p = (rkp, rks)
                    del_p = deliver_all(
                        res_del, p_in["sids"], pw, mp, mn, sc,
                        target_brokers=p_in["targets"].brokers,
                        num_brokers=num_brokers,
                        counts=p_in["targets"].counts,
                        ring=p_ring, epochs=p_in.get("epochs"))
            if s_static is not None:
                cand = discover(ds, index_state, s_static,
                                s_in["last_ts"], s_in["last_size"])
                if compact:
                    stream = plans.compact_candidates(cand, s_stream)
                    tot_s = stream.total
                    sj = plans.join_spatial_stream(
                        ds, stream, s_in["locs"], s_in["brokers"], radii,
                        s_in["payload"], num_brokers)
                    res_s = plans.stream_to_stacked(
                        sj, stream, cand.scanned,
                        min(s_stream, cand.rows.shape[1]))
                else:
                    res_s = plans.join_spatial_all(
                        ds, cand, s_in["locs"], s_in["brokers"], radii,
                        s_in["payload"], num_brokers, spatial_fn)
                if deliver:
                    res_del = res_s
                    if stage is not None:
                        res_del, rkp, rks = enrich.rank_result(
                            stage, ds, res_s, s_static[2], s_in["sids"])
                        rank_s = (rkp, rks)
                    del_s = deliver_all(
                        res_del, s_in["sids"], pw, mp, mn, sc,
                        target_brokers=s_in["brokers"],
                        num_brokers=num_brokers,
                        ring=s_ring, epochs=s_in.get("epochs"))
            return (res_p, res_s, del_p, del_s, (tot_p, tot_s),
                    (rank_p, rank_s))

        fn = (jax.jit(run, donate_argnums=(4, 5)) if donate_rings
              else jax.jit(run))
        self._cache_put(key, fn)
        return fn, key

    def execute_all(self, flags: Optional[plans.ExecutionFlags] = None,
                    advance: bool = True, timed: bool = True,
                    deliver: bool = False) -> Dict[str, ExecutionReport]:
        """Execute EVERY channel — param-join AND spatial — in one fused
        jitted call per PLAN-GROUP: stacked candidate discovery per join
        group, vmapped param join, vmapped spatial join (per-channel radii
        over the stacked user sets), fused broker accounting. No per-channel
        host round-trips remain on the hot path.

        ``flags=None`` (the planner-driven mode) partitions channels by
        their assigned ``ChannelPlan`` (``set_plan`` / engine default):
        channels sharing a plan run in ONE fused call, heterogeneous
        assignments run one call per distinct plan, each with its own
        stacked caches and retry ring (keyed by the full plan identity).
        Passing explicit ``flags`` forces the legacy homogeneous path —
        every channel runs that plan under the engine backend (assignments
        are ignored, not overwritten), which for a single plan is exactly
        the pre-planner behavior: one fused call for the whole engine.

        Result-for-result equivalent to looping ``execute_channel`` — each
        channel's report carries its own counts/bytes; ``wall_time_s`` is
        its plan-group's fused wall time amortized per channel.
        ``deliver=True`` runs the broker convert+send stages
        (``broker.deliver_all``) INSIDE each group's jitted call — stacked
        wire packing, stacked sID fanout, one-hot per-broker accounting,
        flat spill capture — and surfaces per-channel ``DeliveryStats`` in
        ``report.overflow``, stats-identical to the per-channel ``_deliver``
        path. A plan switch between calls migrates the superseded group's
        ring state through ``_flush_ring`` into the host SpillQueue, so
        delivered + spilled + dropped == produced telescopes across the
        switch.

        Thin wrapper over ``execute(ExecutionRequest(...))`` — the single
        execution surface; equivalent to ``dispatch_all(...).sync()``. The
        pipelined runtime (``core/runtime.py``) calls ``dispatch_all``
        directly and defers the sync one or more ticks.
        """
        return self.execute(plans.ExecutionRequest(
            flags=flags, advance=advance, timed=timed, deliver=deliver))

    def execute(self, request: plans.ExecutionRequest
                ) -> Dict[str, ExecutionReport]:
        """Run one ``ExecutionRequest`` synchronously: ``dispatch(...)``
        then ``sync()`` — the single execution surface every facade
        (``execute_all``, ``dispatch_all``) routes through."""
        return self.dispatch(request).sync()

    def dispatch_all(self, flags: Optional[plans.ExecutionFlags] = None,
                     advance: bool = True, timed: bool = False,
                     deliver: bool = False,
                     resolve_spills: bool = False):
        """``dispatch`` under the legacy keyword surface (``flags`` forces
        one homogeneous plan; None runs the per-channel assignments)."""
        return self.dispatch(plans.ExecutionRequest(
            flags=flags, advance=advance, timed=timed, deliver=deliver,
            resolve_spills=resolve_spills))

    def dispatch(self, request: plans.ExecutionRequest):
        """Dispatch every plan-group's fused call WITHOUT waiting for the
        device: returns a ``runtime.PendingExecution`` whose ``.sync()``
        materializes the per-channel reports (one bulk device->host transfer
        per join group) and runs the host half of delivery accounting
        (SpillQueue pushes, conserving DeliveryStats).

        The request resolves to one plan per requested channel
        (``ExecutionRequest.forced_plan`` — explicit plan/flags/backend
        override — falling back to each channel's assignment), and channels
        sharing a plan run in ONE fused call; a homogeneous resolution
        reduces to a single group, which is exactly the legacy
        ``execute_all(flags)`` behavior. With an ``enrichment`` stage
        attached and ``deliver=True`` every dispatched plan is stamped with
        the stage's identity, so compiled executables, stream buckets, and
        retry rings all key on the scorer.

        Everything control-plane-visible happens AT DISPATCH: successor
        retry rings are stored (device handles, no sync), watermarks
        advance, ``last_exec_*`` snapshots move — so back-to-back dispatches
        pipeline correctly and a deferred ``sync()`` observes exactly the
        state its call was dispatched against.

        ``resolve_spills`` captures overflowed pairs into the SpillQueue's
        epoch-free RESOLVED lane (fanout resolved against the dispatch-time
        sID tables at sync) — required when syncs are deferred across
        control-plane churn, where the live epoch may have moved past the
        dispatch-time one before stats materialize.

        Remaining host sync points, by design: the ``bad_index`` scan mode
        reads watermark deltas to bucket candidate shapes, and the compact
        backends read the live-candidate total for the grow-on-overflow
        protocol (both documented in docs/ARCHITECTURE.md)."""
        from repro.core.runtime import PendingExecution
        deliver = request.deliver
        ordered = sorted(self.channels.values(), key=lambda s: s.index)
        if request.channels is not None:
            unknown = set(request.channels) - set(self.channels)
            if unknown:
                raise KeyError(f"unknown channels: {sorted(unknown)}")
            want = set(request.channels)
            ordered = [st for st in ordered if st.spec.name in want]
        if not ordered:
            return PendingExecution(self, [])
        forced = request.forced_plan(
            "pallas" if self.use_pallas else "oracle")
        plan_for = {}
        for st in ordered:
            p = forced or (st.plan or self.default_plan())
            if forced is None and request.backend is not None:
                p = dataclasses.replace(p, backend=request.backend)
            plan_for[st.spec.name] = p
        if self.enrichment is not None and deliver:
            tag = self.enrichment.identity
            plan_for = {n: dataclasses.replace(p, scorer=tag)
                        for n, p in plan_for.items()}
        # plan-groups in first-channel order: Dict preserves insertion
        # order, so homogeneous assignments reduce to one group == the
        # legacy single fused call
        groups: Dict[plans.ChannelPlan, Tuple[List, List]] = {}
        for st in ordered:
            g = groups.setdefault(plan_for[st.spec.name], ([], []))
            (g[0] if st.spec.join == "param" else g[1]).append(st)
        # a channel-subset dispatch must not treat the other groups' rings
        # as superseded — only full-engine dispatches prune inactive rings
        use_ring = deliver and self.ring_capacity > 0
        if use_ring and request.channels is None:
            # plan-switch ring migration: a ring keyed by a (kind, plan,
            # membership) no longer executing hands its resident entries to
            # the host SpillQueue — tagged with the layout they were
            # produced under, so the drain re-packs against the matching
            # table — instead of being presented against another plan's
            # tables or silently dropped
            active = set()
            for plan, (pchs, schs) in groups.items():
                if pchs:
                    active.add(("param", plan,
                                tuple(st.spec.name for st in pchs)))
                if schs:
                    active.add(("spatial", plan,
                                tuple(st.spec.name for st in schs)))
            for k in [k for k in self._rings if k not in active]:
                self._flush_ring(*self._rings.pop(k))
        pending = [self._dispatch_plan_group(plan, param_chs, spatial_chs,
                                             request.timed, deliver,
                                             use_ring,
                                             request.resolve_spills)
                   for plan, (param_chs, spatial_chs) in groups.items()]
        if request.advance:
            # watermark advance is a device-side functional update (no
            # sync); the in-flight calls captured the PRE-advance handle
            self.index_state = bidx.advance_watermarks(
                self.index_state,
                jnp.asarray([st.index for st in ordered], jnp.int32))
            for st in ordered:
                st.last_exec_ts = self.now
                st.last_exec_size = self.size_host
                st.executions += 1
        return PendingExecution(self, pending)

    def _dispatch_plan_group(self, plan: plans.ChannelPlan,
                             param_chs: List[ChannelState],
                             spatial_chs: List[ChannelState],
                             timed: bool, deliver: bool,
                             use_ring: bool,
                             resolve_spills: bool) -> "_PendingGroup":
        """Dispatch ONE plan-group's fused call; reports materialize later
        in ``_materialize_group``."""
        chans = param_chs + spatial_chs
        max_cand = self.max_candidates
        if plan.scan_mode == "bad_index":
            # shared shape bucket: the largest watermark delta across THIS
            # group's channels (two bulk host reads, not 2 device reads per
            # channel)
            counts = np.asarray(self.index_state.counts)
            wms = np.asarray(self.index_state.watermarks)
            pending = max(int(counts[st.index] - wms[st.index])
                          for st in chans)
            bucket = _pow2_bucket(pending, 6)
            max_cand = min(bucket, self.max_candidates)
        # The fused aggregated targets of an incremental engine are SLOT
        # indices (free slots padded) and its flat targets are FLAT-slot
        # indices — not build()'s compacted rows — tag their spills with the
        # matching layout so a drain re-packs against the right table.
        # Non-incremental / spatial spills keep the per-channel layouts.
        if self.incremental:
            p_layout = "slot" if plan.aggregation else "flat_slot"
        else:
            p_layout = plan.aggregation
        p_names = tuple(st.spec.name for st in param_chs)
        s_names = tuple(st.spec.name for st in spatial_chs)
        p_in = s_in = p_ring = s_ring = None
        if param_chs:
            targets, up_masks, domains = self._stacked_inputs(
                param_chs, plan.aggregation)
            p_in = dict(
                targets=targets, up_masks=up_masks, domains=domains,
                param_field=jnp.asarray(
                    [st.spec.param_field for st in param_chs], jnp.int32),
                payload=jnp.asarray(
                    [st.spec.payload_bytes for st in param_chs], jnp.int32),
                last_ts=jnp.asarray(
                    [st.last_exec_ts for st in param_chs], jnp.int32),
                last_size=jnp.asarray(
                    [st.last_exec_size for st in param_chs], jnp.int32))
            if deliver:
                p_in["sids"] = self._stacked_sids(param_chs, plan.aggregation)
                if use_ring:
                    p_ring = self._ring_in(
                        ("param", plan, p_names), p_names, len(param_chs))
                    p_in["epochs"] = jnp.asarray(
                        [st.epoch for st in param_chs], jnp.int32)
        if spatial_chs:
            locs, ubrokers = self._stacked_spatial_inputs(spatial_chs)
            s_in = dict(
                locs=locs, brokers=ubrokers,
                payload=jnp.asarray(
                    [st.spec.payload_bytes for st in spatial_chs], jnp.int32),
                last_ts=jnp.asarray(
                    [st.last_exec_ts for st in spatial_chs], jnp.int32),
                last_size=jnp.asarray(
                    [st.last_exec_size for st in spatial_chs], jnp.int32))
            if deliver:
                s_in["sids"] = self._stacked_spatial_sids(spatial_chs)
                if use_ring:
                    s_ring = self._ring_in(
                        ("spatial", plan, s_names), s_names,
                        len(spatial_chs))
                    s_in["epochs"] = jnp.asarray(
                        [st.epoch for st in spatial_chs], jnp.int32)
        args = (self.dataset, self.index_state, p_in, s_in, p_ring, s_ring)
        t0 = time.perf_counter()
        if plans.is_compact(plan.backend):
            # the grow protocol reads the live total (documented sync
            # point); rings are NOT donated — the loop re-presents them
            res, wall = self._run_compact_group(
                plan, param_chs, spatial_chs, max_cand, deliver, args, timed)
        else:
            donate = use_ring and (p_ring is not None or s_ring is not None)
            fn, fkey = self._exec_all_fn(param_chs, spatial_chs, plan,
                                         max_cand, deliver,
                                         donate_rings=donate)
            if timed:
                # warming would CONSUME the donated rings: hand the warm
                # call copies, dispatch the real call the originals
                warm_args = args
                if donate:
                    cp = lambda r: (None if r is None
                                    else jax.tree.map(jnp.copy, r))
                    warm_args = args[:4] + (cp(p_ring), cp(s_ring))
                self._warm_if_new(fkey, fn, warm_args)
                t0 = time.perf_counter()
            res = fn(*args)
            wall = 0.0
            if timed:
                jax.block_until_ready(res)
                wall = time.perf_counter() - t0
        del_p, del_s = res[2], res[3]
        if use_ring:
            # persist the successor rings AT DISPATCH (device-resident
            # handles, no sync) so the next dispatch re-delivers their
            # content while this call is still in flight
            if param_chs:
                self._rings[("param", plan, p_names)] = (
                    p_names, p_layout, del_p.ring)
            if spatial_chs:
                self._rings[("spatial", plan, s_names)] = (
                    s_names, plan.aggregation, del_s.ring)
        return _PendingGroup(
            plan=plan, param_chs=param_chs, spatial_chs=spatial_chs,
            res=res, p_layout=p_layout, s_layout=plan.aggregation,
            deliver=deliver, wall=wall, t0=t0,
            p_epochs=[st.epoch for st in param_chs],
            s_epochs=[st.epoch for st in spatial_chs],
            p_sids=(p_in or {}).get("sids") if resolve_spills else None,
            s_sids=(s_in or {}).get("sids") if resolve_spills else None)

    def _materialize_group(self, g: "_PendingGroup",
                           reports: Dict[str, ExecutionReport]) -> None:
        """Host half of one dispatched plan-group: one bulk device->host
        transfer per join group, then per-channel numpy views — the
        per-channel path's int()/slice pattern would cost dozens of device
        round-trips here. Delivery stats arrive the same way: the fused call
        already packed/fanned out every channel, so the host only pushes
        spills and reads (C,)-shaped counters. ``wall_time_s`` is the timed
        fused wall amortized per channel, or (untimed) the
        dispatch-to-materialize latency share."""
        res_p, res_s, del_p, del_s, _tots, ranks = g.res
        rank_p, rank_s = ranks
        wall = g.wall
        if not wall:
            # every output of one executable completes together, so the
            # totals scalars stand in for the whole call — blocking on the
            # full tree would touch the successor ring handle, which the
            # NEXT dispatch may already have consumed (donated)
            jax.block_until_ready(_tots)
            wall = time.perf_counter() - g.t0
        share = wall / max(len(g.param_chs) + len(g.spatial_chs), 1)
        for chs, res, dlv, layout, epochs, sids, rank in (
                (g.param_chs, res_p, del_p, g.p_layout, g.p_epochs,
                 g.p_sids, rank_p),
                (g.spatial_chs, res_s, del_s, g.s_layout, g.s_epochs,
                 g.s_sids, rank_s)):
            if not chs:
                continue
            host = jax.tree.map(np.asarray, res)
            stats = (self._spill_and_stats(
                chs, layout, dlv, epochs=epochs,
                resolve_tables=None if sids is None else np.asarray(sids),
                ranked=None if rank is None else
                tuple(np.asarray(x) for x in rank))
                if g.deliver else {})
            pay = noti = None
            if g.deliver and self.debug_delivery_buffers:
                pay = np.asarray(dlv.pack.payload)
                noti = np.asarray(dlv.fan.notify)
            for i, st in enumerate(chs):
                reports[st.spec.name] = ExecutionReport(
                    channel=st.spec.name, flags=g.plan.flags, plan=g.plan,
                    result=jax.tree.map(lambda a, i=i: a[i], host),
                    wall_time_s=share,
                    num_results=int(host.num_results[i]),
                    num_notified=int(host.num_notified[i]),
                    scanned=int(host.scanned[i]),
                    broker_bytes=host.broker_bytes[i],
                    overflow=stats.get(st.spec.name),
                    payload=None if pay is None else pay[i],
                    notify=None if noti is None else noti[i])

    def _run_compact_group(self, plan: plans.ChannelPlan,
                           param_chs: List[ChannelState],
                           spatial_chs: List[ChannelState],
                           max_cand: int, deliver: bool,
                           args: tuple, timed: bool):
        """Run one compact plan-group under the adaptive stream-capacity
        protocol (see the ``_STREAM_FLOOR`` note): per (kind, plan,
        membership) key, start from the remembered bucket, grow straight to
        the observed live total's power-of-two bucket when the stream
        overflowed (re-running ONCE — discovery is pure, and a truncated
        run's outputs are discarded before any delivery or ring state
        escapes, so re-presenting the same ring is safe), and halve the
        bucket after ``_STREAM_PATIENCE`` consecutive runs at <= half
        occupancy. Returns the final run's 6-tuple and its wall time."""
        width = self.max_window if plan.scan_mode == "window" else max_cand
        floor = 1 << _STREAM_FLOOR
        p_key = ("param", plan, tuple(st.spec.name for st in param_chs))
        s_key = ("spatial", plan, tuple(st.spec.name for st in spatial_chs))
        p_cap = (min(self._stream_buckets.get(p_key, floor),
                     _pow2_bucket(len(param_chs) * width, _STREAM_FLOOR))
                 if param_chs else 0)
        s_cap = (min(self._stream_buckets.get(s_key, floor),
                     _pow2_bucket(len(spatial_chs) * width, _STREAM_FLOOR))
                 if spatial_chs else 0)
        while True:
            fn, fkey = self._exec_all_fn(param_chs, spatial_chs, plan,
                                         max_cand, deliver, p_cap, s_cap)
            if timed:  # warm the trace so wall time measures execution
                self._warm_if_new(fkey, fn, args)
            t0 = time.perf_counter()
            res = fn(*args)
            jax.block_until_ready(res)
            wall = time.perf_counter() - t0
            tot_p, tot_s = (int(x) for x in jax.device_get(res[4]))
            grew = False
            if param_chs and tot_p > p_cap:
                p_cap, grew = _pow2_bucket(tot_p, _STREAM_FLOOR), True
            if spatial_chs and tot_s > s_cap:
                s_cap, grew = _pow2_bucket(tot_s, _STREAM_FLOOR), True
            if not grew:
                break
        for key, cap, tot, live in ((p_key, p_cap, tot_p, bool(param_chs)),
                                    (s_key, s_cap, tot_s,
                                     bool(spatial_chs))):
            if not live:
                continue
            if cap > floor and tot <= cap // 2:
                idle = self._stream_idle.get(key, 0) + 1
                if idle >= _STREAM_PATIENCE:
                    cap, idle = cap // 2, 0
                self._stream_idle[key] = idle
            else:
                self._stream_idle[key] = 0
            self._stream_buckets[key] = cap
        return res, wall

    # ------------------------------------------------------------------
    # device-resident retry rings
    # ------------------------------------------------------------------

    def _ring_in(self, key, names: Tuple[str, ...],
                 num_channels: int) -> RetryRing:
        """The resident ring for one plan-group, or a fresh empty one when
        the group's channel set changed (the old ring's entries are handed
        to the host queue — dropped channels drop at drain time, counted —
        never silently lost). Rings whose (kind, plan, membership) key is no
        longer active are flushed up front by ``execute_all``: a caller that
        switches plans must find the inactive ring's entries in the host
        queue (drainable), not stranded on device or replayed against
        another plan's slot tables."""
        cur = self._rings.get(key)
        if cur is not None:
            if cur[0] == names:
                return cur[2]
            del self._rings[key]
            self._flush_ring(*cur)
        return empty_ring(num_channels, self.ring_capacity)

    def _flush_ring(self, names: Tuple[str, ...], layout,
                    ring: RetryRing) -> None:
        """Push a ring's resident entries into the host SpillQueue (pairs
        keep their recorded epoch as the staleness version). Entries past
        the queue's capacity are lost — counted in ``ring_flush_drops``."""
        pc = np.asarray(ring.pair_count)
        sc = np.asarray(ring.sid_count)
        rows = np.asarray(ring.pair_rows)
        tgts = np.asarray(ring.pair_targets)
        eps = np.asarray(ring.pair_epochs)
        vals = np.asarray(ring.sid_values)
        for i, name in enumerate(names):
            n = int(pc[i])
            if n:
                for e in np.unique(eps[i, :n]).tolist():
                    sel = eps[i, :n] == e
                    acc = self.spill.push_pairs(name, layout,
                                                rows[i, :n][sel],
                                                tgts[i, :n][sel], int(e))
                    self.ring_flush_drops += int(sel.sum()) - acc
            m = int(sc[i])
            if m:
                acc = self.spill.push_sids(name, vals[i, :m])
                self.ring_flush_drops += m - acc

    def flush_rings(self) -> None:
        """Hand every ring's resident entries to the host SpillQueue (for
        drain via ``drain_spilled``) and drop the rings — used on channel-set
        changes and by callers that want a host-visible queue state."""
        rings, self._rings = self._rings, {}
        for names, layout, ring in rings.values():
            self._flush_ring(names, layout, ring)

    def ring_pending_pairs(self) -> int:
        return sum(int(np.asarray(r.pair_count).sum())
                   for _, _, r in self._rings.values())

    def ring_pending_sids(self) -> int:
        return sum(int(np.asarray(r.sid_count).sum())
                   for _, _, r in self._rings.values())

    def fused_sids_table(self, name: str, aggregated: bool) -> jnp.ndarray:
        """The sID table matching the FUSED path's pair-target space for one
        channel: slot tables on an incremental engine (group slots when
        aggregated, flat per-subscription slots otherwise), the compacted
        build tables on a rebuild engine, and the cohort slot->uid table (or
        the 0-width identity fanout) for spatial channels."""
        st = self.channels[name]
        if st.spec.join == "spatial":
            tbl = self._spatial_sids_table(st)
            return jnp.zeros((0,), jnp.int32) if tbl is None else tbl
        if self.incremental and aggregated:
            return jnp.asarray(st.aggregator.slot_arrays()[3])
        if self.incremental:
            return jnp.asarray(st.aggregator.flat_slot_arrays()[3])[:, None]
        return self.group_sids_array(name, aggregated)

    # ------------------------------------------------------------------
    # spill retry
    # ------------------------------------------------------------------

    def _synthetic_result(self, rows: np.ndarray,
                          tgts: np.ndarray) -> plans.ChannelResult:
        """A shape-bucketed ChannelResult holding exactly the given (row,
        target) pairs — the drain path's re-entry into the broker kernels."""
        n = len(rows)
        bucket = _pow2_bucket(n, 6)
        r = np.full((bucket,), -1, np.int32)
        t = np.full((bucket,), -1, np.int32)
        r[:n], t[:n] = rows, tgts
        valid = np.arange(bucket) < n
        z = jnp.zeros((), jnp.int32)
        nb = self.brokers.num_brokers
        return plans.ChannelResult(
            jnp.asarray(r)[:, None], jnp.asarray(t)[:, None],
            jnp.asarray(valid)[:, None], jnp.asarray(r), jnp.asarray(valid),
            z, z, z, jnp.zeros((nb,), jnp.int32), jnp.zeros((nb,), jnp.int32))

    def drain_spilled(self) -> Dict[str, DrainReport]:
        """Re-deliver spilled notifications, exactly once per stage.

        Pairs lane: pop up to ``max_deliver_pairs`` for ONE (channel, layout)
        lane per channel per round (layouts re-pack against different tables
        with different wire widths, so a round's ``DrainReport.payload`` is
        always one coherent buffer; a channel spilled under both layouts
        drains the other lane next round) and re-run the convert stage
        against the channel's CURRENT table of that layout; entries whose
        channel version moved (or whose channel was dropped) are unroutable
        and counted as dropped. Sids lane: pop up to ``max_notify`` per
        channel and re-run the send stage (raw sIDs never go stale).
        Anything that misses this round's buffers is requeued at the front —
        never duplicated, never lost. Call once per tick until
        ``spill.pending_pairs() + spill.pending_sids() == 0``.
        """
        out: Dict[str, DrainReport] = {}

        def merge(name: str, rep: DrainReport) -> None:
            prev = out.get(name)
            if prev is None:
                out[name] = rep
            else:
                out[name] = DrainReport(
                    prev.stats.merged(rep.stats),
                    rep.payload if prev.payload is None else prev.payload,
                    rep.notify if prev.notify is None else prev.notify)

        drained_pairs = set()
        # resolved lane first: epoch-free entries (fanout captured against
        # the producing call's own table) re-enter the convert stage with
        # their recorded sID rows as the table — immune to churn between
        # spill and drain, which is exactly why the pipelined runtime's
        # deferred syncs capture into this lane. Shares the one-pair-lane-
        # per-channel-per-round rule so the payload stays one coherent
        # buffer.
        for name in self.spill.resolved_keys():
            if name in drained_pairs:
                continue
            drained_pairs.add(name)
            rows, tgts, sid_rows = self.spill.pop_resolved(
                name, self.max_deliver_pairs)
            dropped = 0
            payload = None
            delivered = respilled = 0
            if name not in self.channels:
                dropped = len(rows)
            elif len(rows):
                n = len(rows)
                # synthetic targets index the recorded sID rows directly;
                # the wire header's target word is patched back to the true
                # targets after packing
                res = self._synthetic_result(rows,
                                             np.arange(n, dtype=np.int32))
                tbl = np.full((_pow2_bucket(n, 6), sid_rows.shape[1]), -1,
                              np.int32)
                tbl[:n] = sid_rows
                buf, dlv, _ = pack_payloads(res, jnp.asarray(tbl),
                                            self.deliver_payload_words,
                                            self.max_deliver_pairs)
                delivered = int(dlv)
                payload = np.array(buf)   # writable host copy
                payload[:delivered, 1] = tgts[:delivered]
                if delivered < n:   # exact in-order prefix delivered
                    self.spill._push_front_resolved(
                        name, rows[delivered:], tgts[delivered:],
                        sid_rows[delivered:])
                    respilled = n - delivered
            if delivered or dropped or respilled:
                merge(name, DrainReport(
                    DeliveryStats(delivered, respilled, dropped, 0, 0, 0),
                    payload=payload))

        for name, layout in self.spill.pair_keys():
            if name in drained_pairs:
                # one pair lane per channel per round: a channel spilled
                # under BOTH layouts re-packs against different tables with
                # different wire widths — its other lane drains next round,
                # so DrainReport.payload is always a single coherent buffer
                continue
            drained_pairs.add(name)
            st = self.channels.get(name)
            version = st.epoch if st is not None else None
            rows, tgts, stale = self.spill.pop_pairs(
                name, layout, self.max_deliver_pairs, version)
            dropped = stale
            payload = None
            delivered = respilled = 0
            if st is None:
                dropped += len(rows)
            elif len(rows):
                res = self._synthetic_result(rows, tgts)
                if st.spec.join == "spatial":
                    tbl = self._spatial_sids_table(st)
                    sids = jnp.zeros((0,), dtype=jnp.int32) \
                        if tbl is None else tbl
                elif layout == "slot":
                    # fused incremental-aggregated spills target SLOT rows
                    sids = jnp.asarray(st.aggregator.slot_arrays()[3])
                elif layout == "flat_slot":
                    # fused incremental-flat spills target FLAT slot rows
                    sids = jnp.asarray(
                        st.aggregator.flat_slot_arrays()[3])[:, None]
                else:
                    sids = self.group_sids_array(name, layout)
                buf, dlv, _ = pack_payloads(res, sids,
                                            self.deliver_payload_words,
                                            self.max_deliver_pairs)
                delivered = int(dlv)
                payload = np.asarray(buf)
                if delivered < len(rows):   # exact in-order prefix delivered
                    self.spill._push_front_pairs(
                        name, layout, rows[delivered:], tgts[delivered:],
                        st.epoch)
                    respilled = len(rows) - delivered
            if delivered or dropped or respilled:
                merge(name, DrainReport(
                    DeliveryStats(delivered, respilled, dropped, 0, 0, 0),
                    payload=payload))

        for name in self.spill.sid_keys():
            sids = self.spill.pop_sids(name, self.max_notify)
            if not len(sids):
                continue
            # identity fanout: targets ARE the sIDs, so the send stage
            # re-emits them verbatim in spill order
            res = self._synthetic_result(sids, sids)
            buf, dlv, _ = fanout_sids(res, jnp.zeros((0,), jnp.int32),
                                      self.max_notify)
            delivered = int(dlv)
            respilled = len(sids) - delivered
            if respilled:
                self.spill._push_front_sids(name, sids[delivered:])
            merge(name, DrainReport(
                DeliveryStats(0, 0, 0, delivered, respilled, 0),
                notify=np.asarray(buf)))
        return out


def _pow2_bucket(n: int, floor_bits: int) -> int:
    """Smallest power of two >= n, clamped below by 2**floor_bits. Shared by
    every shape-bucketing site so fused and per-channel traces agree."""
    return 1 << max(floor_bits, (max(n, 1) - 1).bit_length())


# Compacted-stream capacity policy: streams start at 2**_STREAM_FLOOR
# entries, grow straight to the power-of-two bucket of the observed live
# total on overflow (ONE re-run — the truncated run's outputs are discarded,
# never delivered, so re-presenting the same ring to the re-run is safe),
# and halve after _STREAM_PATIENCE consecutive runs at <= half occupancy.
# Buckets converge to the workload's live-candidate envelope, after which
# the (plan, bucket) cache key is stable: zero retraces at steady state.
_STREAM_FLOOR = 7
_STREAM_PATIENCE = 8


def _pred_rank(p) -> int:
    """Heuristic selectivity rank for picking the traditional-index field."""
    from repro.core.predicates import EQ
    return 2 if p.op == EQ else 1


# jit-compiled shared helpers (module-level so lru caches are shared)
_append = R.append
_insert = bidx.insert
