"""Pure-jnp oracle: causal GQA attention (training / prefill shapes)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True,
                    scale: Optional[float] = None) -> jnp.ndarray:
    """q (B, H, S, D), k/v (B, KH, S, D), H % KH == 0 -> (B, H, S, D)."""
    b, h, s, d = q.shape
    kh = k.shape[1]
    g = h // kh
    scale = scale if scale is not None else d ** -0.5
    # Broadcast KV to full heads and stay 4-D: splitting the (sharded) head
    # dim into (kv_heads, group) breaks GSPMD propagation (involuntary
    # remat/replication); the broadcast fuses into the dots. Operands stay in
    # the input dtype (bf16 on the training path) with f32 accumulation —
    # f32 operand upcasts double every attention-path collective.
    kf = jnp.repeat(k, g, axis=1)
    vf = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhld->bhql", q, kf,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhql,bhld->bhqd", w.astype(v.dtype), vf,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
