"""Single-superlayer probe functions for dry-run cost accounting.

``cost_analysis()`` on this backend counts a scan body once (verified in
DESIGN.md), so the dry-run compiles (a) the full step — memory analysis,
collective schedule, multi-pod proof — and (b) these one-superlayer probes
with identical shardings; per-step totals are  full + (repeats-1) x probe
(x accum microbatches for training).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import blocks, encdec
from repro.models.model import ModelApi


def _first_layer(tree):
    return jax.tree.map(lambda x: x[0], tree)


def train_body_fn(api: ModelApi) -> Callable:
    """grad through one superlayer on one microbatch of activations."""
    cfg = api.cfg

    def probe(layer_p, shared_p, x, cos, sin):
        def f(lp, sp, xx):
            out, aux = blocks.superlayer_train(lp, sp, xx, cfg, cos, sin)
            return jnp.sum(out.astype(jnp.float32)) + aux

        if cfg.remat:   # count the remat recompute, as the real step does
            f = jax.checkpoint(f)
        g = jax.grad(f, argnums=(0, 1, 2) if shared_p is not None else (0, 2))
        if shared_p is not None:
            return g(layer_p, shared_p, x)
        return g(layer_p, None, x)

    return probe


def encdec_train_bodies(api: ModelApi):
    cfg = api.cfg

    def enc_probe(layer_p, x, cos, sin):
        def f(lp, xx):
            from repro.models.attention import attn_apply
            from repro.models.layers import mlp_apply, rms_norm
            a = attn_apply(lp["attn"], rms_norm(xx, lp["norm1"], cfg.norm_eps),
                           cfg, cos, sin, causal=False)
            h = xx + a
            m = mlp_apply(lp["mlp"], rms_norm(h, lp["norm2"], cfg.norm_eps),
                          cfg.compute_dtype)
            return jnp.sum((h + m).astype(jnp.float32))

        if cfg.remat:
            f = jax.checkpoint(f)
        return jax.grad(f, argnums=(0, 1))(layer_p, x)

    def dec_probe(layer_p, x, enc_out, cos, sin):
        def f(lp, xx, eo):
            return jnp.sum(encdec._dec_layer(lp, xx, cfg, cos, sin, eo)
                           .astype(jnp.float32))

        if cfg.remat:
            f = jax.checkpoint(f)
        return jax.grad(f, argnums=(0, 1, 2))(layer_p, x, enc_out)

    return enc_probe, dec_probe


def prefill_body_fn(api: ModelApi, max_len: int) -> Callable:
    cfg = api.cfg

    def probe(layer_p, shared_p, x, cos, sin):
        return blocks.superlayer_prefill(layer_p, shared_p, x, cfg, cos, sin,
                                         max_len)

    return probe


def decode_body_fn(api: ModelApi) -> Callable:
    cfg = api.cfg

    def probe(layer_p, shared_p, x, states, cos, sin, pos, kv_len):
        return blocks.superlayer_decode(layer_p, shared_p, x, states, cfg,
                                        cos, sin, pos, kv_len)

    return probe


def encdec_dec_decode_body(api: ModelApi) -> Callable:
    """One enc-dec decoder layer decode step (self-cached + cross attn)."""
    cfg = api.cfg

    def probe(p, x, cache, pos, kv_len, enc_len, cos, sin):
        from repro.kernels.flash_decode import ref as fd_ref
        from repro.models.attention import attn_decode
        from repro.models.layers import mlp_apply, rms_norm

        b = x.shape[0]
        a, new_kv = attn_decode(p["self_attn"],
                                rms_norm(x, p["norm1"], cfg.norm_eps),
                                cfg, cos, sin,
                                {"k": cache["k"], "v": cache["v"]}, pos, kv_len)
        h = x + a
        hq = rms_norm(h, p["norm_c"], cfg.norm_eps)
        q = hq @ p["cross_attn"]["wq"].astype(cfg.compute_dtype)
        q = q.reshape(b, cfg.n_heads, cfg.resolved_head_dim)
        c = fd_ref.decode_attention(q, cache["ck"], cache["cv"], enc_len)
        h = h + c.reshape(b, -1) @ p["cross_attn"]["wo"].astype(cfg.compute_dtype)
        m = mlp_apply(p["mlp"], rms_norm(h, p["norm2"], cfg.norm_eps),
                      cfg.compute_dtype)
        return h + m, new_kv

    return probe


def encdec_prefill_bodies(api: ModelApi):
    """(enc layer fwd, dec layer prefill fwd) for enc-dec prefill scaling."""
    cfg = api.cfg

    def enc_probe(lp, x, cos, sin):
        from repro.models.attention import attn_apply
        from repro.models.layers import mlp_apply, rms_norm
        a = attn_apply(lp["attn"], rms_norm(x, lp["norm1"], cfg.norm_eps),
                       cfg, cos, sin, causal=False)
        h = x + a
        m = mlp_apply(lp["mlp"], rms_norm(h, lp["norm2"], cfg.norm_eps),
                      cfg.compute_dtype)
        return h + m

    def dec_probe(lp, x, enc_out, cos, sin):
        return encdec._dec_layer(lp, x, cfg, cos, sin, enc_out)

    return enc_probe, dec_probe
