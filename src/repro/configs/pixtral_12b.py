"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072. pixtral-ViT + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]

Backbone only per the task spec: the vision frontend is a stub —
``input_specs()`` supplies precomputed patch embeddings (frontend="embed").
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab_size=131072, head_dim=128, qkv_bias=False, rope_theta=1e9,
        block_pattern=("dense",), superlayer_repeat=40,
        frontend="embed",
        param_dtype=jnp.bfloat16, grad_accum=16, optimizer="adafactor",
        sub_quadratic=False,
    ).validate()
