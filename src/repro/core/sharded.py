"""Mesh-sharded BAD engine: N device-local engines behind one control plane.

``ShardedBADEngine`` partitions the subscription population (and spatial
cohorts) over ``num_shards`` device-local ``BADEngine`` instances and
presents the single-engine surface the churn driver, planner, and tests
already speak. The partitioning model:

  channels      replicated — every shard compiles every channel's plan, so
                plan-groups, stacked caches, and retry rings stay keyed by
                (shard, plan) exactly as PR 6/7 left them per engine.
  data plane    replicated — each shard ingests every record batch into its
                own dataset + BAD index, so candidate discovery is local and
                row ids agree across shards (and with a 1-shard oracle).
  subscriptions partitioned — global sIDs are allocated here and assigned to
                shards by the stable hash ``partition.shard_for_sids``; each
                shard aggregates only its own slice (its join/delivery work
                scales with its share of the groups). Explicit-sID
                ``subscribe_bulk`` keeps ids global across shards/reshards.
  cohort users  partitioned by ``partition.shard_for_users``; spatial
                channels always run with explicit per-shard cohorts (the
                legacy all-users semantics would deliver S copies), so
                ``create_channel`` snapshots the current population.
  brokers       endpoints owned round-robin by ``partition.broker_owner``;
                with ``route_cross_shard=True`` every tick's delivered
                notify sIDs are regrouped onto their owner shards by the
                ``collectives.shuffle_notify`` all-gather collective over a
                ("shard",) mesh (host reference fallback when the runtime
                has fewer devices than shards).

Accounting telescopes globally: each shard's DeliveryStats conserves
delivered + spilled + dropped == produced, and the merged per-channel stats
sum shard-wise, so the same identity holds for the whole mesh while
ring-resident entries stay shard-local. ``reshard`` migrates to a new shard
count conservation-exactly: rings flush through each shard's SpillQueue,
the queues drain to empty against the OLD tables (the drained reports are
returned so callers keep the delivered content), and the live population —
re-read from the host registry, the single source of truth — is
re-partitioned under the new hash with its original sIDs.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plans
from repro.core.broker import DeliveryStats
from repro.core.channel import ChannelSpec
from repro.core.engine import BADEngine, DrainReport, MaintenanceStats
from repro.distributed import collectives, partition


@dataclasses.dataclass
class ShardedExecutionReport:
    """One channel's tick merged across shards. Field-compatible with
    ``ExecutionReport`` where downstream readers look (num_results /
    num_notified / scanned / wall_time_s / overflow); ``per_shard`` keeps
    the raw shard reports (payload/notify buffers included when the engine
    runs with ``debug_delivery_buffers``) for content-level parity checks,
    and ``routed`` the owner-shard-grouped notify sIDs when cross-shard
    routing is on."""

    channel: str
    num_results: int
    num_notified: int
    scanned: int
    wall_time_s: float
    overflow: Optional[DeliveryStats]
    per_shard: List
    routed: Optional[np.ndarray] = None


class ShardedPendingExecution:
    """Every shard's in-flight tick behind one handle: ``sync()``
    materializes each shard's ``PendingExecution`` under that shard's
    device context, merges the per-channel reports, and (delivering
    engines with cross-shard routing) runs the notify shuffle — idempotent,
    like the single-engine handle it wraps. ``latency_s`` records the
    dispatch-to-materialize latency of the first sync."""

    def __init__(self, owner, pends: List, deliver: bool):
        self._owner = owner
        self._pends = pends
        self._deliver = deliver
        self._reports: Optional[Dict[str, ShardedExecutionReport]] = None
        self._t0 = time.perf_counter()
        self.latency_s: Optional[float] = None

    @property
    def done(self) -> bool:
        return self._reports is not None

    def sync(self) -> Dict[str, ShardedExecutionReport]:
        if self._reports is None:
            per_shard = []
            for i, p in enumerate(self._pends):
                with self._owner._on(i):
                    per_shard.append(p.sync())
            merged = self._owner._merge_reports(per_shard)
            if self._deliver and self._owner.route_cross_shard:
                self._owner._route(merged)
            self.latency_s = time.perf_counter() - self._t0
            self._reports = merged
        return self._reports

    @property
    def reports(self) -> Dict[str, ShardedExecutionReport]:
        return self.sync()


class _SpillView:
    """Summed SpillQueue facade over every shard (read-only surface the
    churn driver polls)."""

    def __init__(self, owner: "ShardedBADEngine"):
        self._owner = owner

    def pending_pairs(self, channel: Optional[str] = None) -> int:
        return sum(e.spill.pending_pairs(channel)
                   for e in self._owner.shards)

    def pending_sids(self, channel: Optional[str] = None) -> int:
        return sum(e.spill.pending_sids(channel)
                   for e in self._owner.shards)


class _ChannelRegistry:
    """Host-side live-subscription table for one channel, dense by global
    sID: the allocator for new ids and the single source of truth for
    re-partitioning (reshard, drop/re-create). O(1) amortized add, O(Δ)
    remove, vectorized broker lookup for notification routing."""

    def __init__(self):
        self.params = np.zeros((0,), np.int32)
        self.brokers = np.zeros((0,), np.int32)
        self.live = np.zeros((0,), bool)
        self.next_sid = 0

    def _grow(self, n: int) -> None:
        if n <= self.params.shape[0]:
            return
        cap = max(1024, 1 << int(n - 1).bit_length())
        for name in ("params", "brokers"):
            old = getattr(self, name)
            buf = np.zeros((cap,), np.int32)
            buf[:old.shape[0]] = old
            setattr(self, name, buf)
        lv = np.zeros((cap,), bool)
        lv[:self.live.shape[0]] = self.live
        self.live = lv

    def add(self, params: np.ndarray, brokers: np.ndarray) -> np.ndarray:
        n = params.shape[0]
        sids = self.next_sid + np.arange(n, dtype=np.int32)
        self.next_sid += n
        self._grow(self.next_sid)
        self.params[sids] = params
        self.brokers[sids] = brokers
        self.live[sids] = True
        return sids

    def remove(self, sids: np.ndarray) -> np.ndarray:
        """Mark known live sids dead; returns the ones actually removed."""
        sids = np.unique(np.asarray(sids, np.int64))
        sids = sids[(sids >= 0) & (sids < self.next_sid)].astype(np.int32)
        sids = sids[self.live[sids]]
        self.live[sids] = False
        return sids

    def live_sids(self) -> np.ndarray:
        return np.nonzero(self.live[:self.next_sid])[0].astype(np.int32)


class ShardedBADEngine:
    """N-way sharded BAD engine. ``num_shards=1`` is the single-device
    oracle with the identical control surface (the parity harness compares
    against it). Extra keyword arguments configure every per-shard
    ``BADEngine`` identically — per-DEVICE capacities (max_deliver_pairs,
    max_notify, ring_capacity, ...) stay per shard, so aggregate delivery
    capacity scales with the mesh."""

    def __init__(self, num_shards: int = 1, route_cross_shard: bool = False,
                 **engine_kwargs):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.num_shards = num_shards
        self.route_cross_shard = route_cross_shard
        self.engine_kwargs = dict(engine_kwargs)
        self._devices = jax.devices()
        self._debug = False
        self._specs: Dict[str, ChannelSpec] = {}
        self._reg: Dict[str, _ChannelRegistry] = {}
        self._plans: Dict[str, plans.ChannelPlan] = {}
        self._cohorts: Dict[str, set] = {}
        self._user_brokers = np.zeros((1,), np.int32)
        self._enrichment = None
        self.shards: List[BADEngine] = [self._make_engine(i)
                                        for i in range(num_shards)]
        self.spill = _SpillView(self)

    # ------------------------------------------------------------------
    # shard plumbing
    # ------------------------------------------------------------------

    def _on(self, i: int):
        """Device context for shard i: pins the shard's engine state to its
        own XLA device when the runtime exposes several (the forced-host-
        device CI idiom or a real mesh); single-device runtimes share."""
        if len(self._devices) > 1:
            return jax.default_device(self._devices[i % len(self._devices)])
        return contextlib.nullcontext()

    def shard_device(self, i: int):
        return self._devices[i % len(self._devices)]

    def _make_engine(self, i: int) -> BADEngine:
        with self._on(i):
            eng = BADEngine(**self.engine_kwargs)
        eng.debug_delivery_buffers = self._debug or self.route_cross_shard
        if self._enrichment is not None:  # reshard-built shards inherit
            eng.set_enrichment(self._enrichment)
        return eng

    @property
    def debug_delivery_buffers(self) -> bool:
        return self._debug or self.route_cross_shard

    @debug_delivery_buffers.setter
    def debug_delivery_buffers(self, value: bool) -> None:
        self._debug = bool(value)
        for e in self.shards:
            e.debug_delivery_buffers = self._debug or self.route_cross_shard

    @property
    def now(self) -> int:
        return self.shards[0].now

    @property
    def user_locations(self):
        return self.shards[0].user_locations

    @property
    def maintenance(self) -> MaintenanceStats:
        """Mesh-wide maintenance counters (summed). The returned object is a
        plain ``MaintenanceStats``, so ``snapshot()``/``since()`` (the churn
        driver's protocol) work unchanged; per-shard views for the
        zero-retrace-per-shard invariant come from
        ``per_shard_maintenance``."""
        merged = MaintenanceStats()
        for e in self.shards:
            merged.traces += e.maintenance.traces
            merged.rebuilds += e.maintenance.rebuilds
            merged.patches += e.maintenance.patches
        return merged

    def per_shard_maintenance(self) -> List[MaintenanceStats]:
        return [e.maintenance.snapshot() for e in self.shards]

    # ------------------------------------------------------------------
    # control plane
    # ------------------------------------------------------------------

    def create_channel(self, spec: ChannelSpec) -> None:
        if spec.name in self._specs:
            raise ValueError(f"channel {spec.name} exists")
        for i, e in enumerate(self.shards):
            with self._on(i):
                e.create_channel(spec)
        self._specs[spec.name] = spec
        self._reg[spec.name] = _ChannelRegistry()
        if spec.join == "spatial":
            # explicit cohorts always: the legacy all-users semantics would
            # notify every user once PER SHARD. Snapshot the population now;
            # later membership flows through subscribe/unsubscribe_users.
            nu = int(self.shards[0].user_locations.shape[0])
            self._cohorts[spec.name] = set()
            self.subscribe_users(spec.name, np.arange(nu, dtype=np.int32))

    def drop_channel(self, name: str) -> None:
        for i, e in enumerate(self.shards):
            with self._on(i):
                e.drop_channel(name)
        del self._specs[name]
        del self._reg[name]
        self._plans.pop(name, None)
        self._cohorts.pop(name, None)

    def default_plan(self) -> plans.ChannelPlan:
        return self.shards[0].default_plan()

    def channel_plan(self, name: str) -> plans.ChannelPlan:
        return self.shards[0].channel_plan(name)

    def plan_assignment(self) -> Dict[str, plans.ChannelPlan]:
        return self.shards[0].plan_assignment()

    def set_plan(self, name: str, plan: plans.ChannelPlan) -> bool:
        changed = False
        for i, e in enumerate(self.shards):
            with self._on(i):
                changed = e.set_plan(name, plan) or changed
        if changed:
            self._plans[name] = plan
        return changed

    def set_enrichment(self, stage) -> bool:
        """Attach/detach one ``EnrichmentStage`` mesh-wide. Every shard
        scores its OWN candidate slots and applies the budget per shard —
        like every other per-device delivery capacity — so the hook adds no
        cross-shard sync and the merged ``ranked_*`` stats sum shard-wise.
        Survives ``reshard`` (rebuilt shards re-attach)."""
        changed = False
        for i, e in enumerate(self.shards):
            with self._on(i):
                changed = e.set_enrichment(stage) or changed
        self._enrichment = stage
        return changed

    def subscribe(self, channel: str, param: int, broker: str = "BrokerA",
                  sid: Optional[int] = None) -> int:
        if sid is not None:
            raise ValueError("explicit sids are allocated by the sharded "
                             "engine; use subscribe_bulk slices instead")
        bid = self.shards[0].brokers.names[broker]
        return int(self.subscribe_bulk(
            channel, np.asarray([param], np.int32),
            np.asarray([bid], np.int32))[0])

    def subscribe_bulk(self, channel: str, params: np.ndarray,
                       brokers: np.ndarray) -> np.ndarray:
        """Allocate global sIDs, register them in the host registry, and
        hand each shard its hash-owned slice (untouched shards see no call,
        so their epochs/caches stay put). Returns the global sIDs."""
        params = np.asarray(params, dtype=np.int32).ravel()
        brokers = np.asarray(brokers, dtype=np.int32).ravel()
        if params.shape != brokers.shape:
            raise ValueError("params and brokers must have the same length")
        spec = self._specs[channel]
        # validate before ANY shard or registry mutation (same contract as
        # BADEngine.subscribe_bulk: a bad batch leaves nothing half-applied)
        if params.size and (int(params.min()) < 0
                            or int(params.max()) >= spec.param_domain):
            raise ValueError(
                f"params out of [0, {spec.param_domain}) for {channel}")
        nb = self.shards[0].brokers.num_brokers
        if brokers.size and (int(brokers.min()) < 0
                            or int(brokers.max()) >= nb):
            raise ValueError(f"broker ids out of [0, {nb}) for {channel}")
        sids = self._reg[channel].add(params, brokers)
        owner = partition.shard_for_sids(sids, self.num_shards)
        for i, e in enumerate(self.shards):
            mine = owner == i
            if not mine.any():
                continue
            with self._on(i):
                e.subscribe_bulk(channel, params[mine], brokers[mine],
                                 sids=sids[mine])
        return sids

    def remove_subscriptions(self, channel: str, sids: np.ndarray) -> int:
        gone = self._reg[channel].remove(np.asarray(sids))
        owner = partition.shard_for_sids(gone, self.num_shards)
        removed = 0
        for i, e in enumerate(self.shards):
            mine = owner == i
            if not mine.any():
                continue
            with self._on(i):
                removed += e.remove_subscriptions(channel, gone[mine])
        return removed

    def unsubscribe(self, channel: str, param: int, broker: str,
                    sid: int) -> bool:
        return self.remove_subscriptions(
            channel, np.asarray([sid], np.int32)) == 1

    def live_sids(self, channel: str) -> np.ndarray:
        """The registry's live population (sorted global sIDs)."""
        return self._reg[channel].live_sids()

    def shard_live_sids(self, channel: str) -> List[np.ndarray]:
        """Each shard's aggregator-held live sIDs (the device-side truth the
        partition tests reconcile against the registry)."""
        return [np.sort(e.channels[channel].aggregator.live_sids())
                for e in self.shards]

    def set_user_locations(self, locations: np.ndarray,
                           brokers: Optional[np.ndarray] = None) -> None:
        locations = np.asarray(locations, np.float32)
        if brokers is None:
            brokers = np.zeros((locations.shape[0],), np.int32)
        self._user_brokers = np.asarray(brokers, np.int32)
        for i, e in enumerate(self.shards):
            with self._on(i):
                e.set_user_locations(locations, brokers)

    def subscribe_users(self, channel: str, user_ids: np.ndarray) -> int:
        uids = np.asarray(user_ids, dtype=np.int32).ravel()
        nu = int(self.shards[0].user_locations.shape[0])
        if uids.size and (int(uids.min()) < 0 or int(uids.max()) >= nu):
            raise ValueError(f"user ids out of [0, {nu})")
        owner = partition.shard_for_users(uids, self.num_shards)
        attached = 0
        for i, e in enumerate(self.shards):
            with self._on(i):
                # EVERY shard gets the call (possibly empty) so the first
                # one converts all shards to explicit-cohort semantics
                attached += e.subscribe_users(channel, uids[owner == i])
        self._cohorts.setdefault(channel, set()).update(
            int(u) for u in uids)
        return attached

    def unsubscribe_users(self, channel: str, user_ids: np.ndarray) -> int:
        uids = np.asarray(user_ids, dtype=np.int32).ravel()
        owner = partition.shard_for_users(uids, self.num_shards)
        detached = 0
        for i, e in enumerate(self.shards):
            mine = owner == i
            if not mine.any():
                continue
            with self._on(i):
                detached += e.unsubscribe_users(channel, uids[mine])
        cohort = self._cohorts.get(channel)
        if cohort is not None:
            cohort.difference_update(int(u) for u in uids)
        return detached

    # ------------------------------------------------------------------
    # data plane
    # ------------------------------------------------------------------

    def ingest(self, batch) -> np.ndarray:
        rows = None
        for i, e in enumerate(self.shards):
            with self._on(i):
                got = e.ingest(batch)
            if i == 0:
                rows = got
        return rows

    def execute_all(self, flags: Optional[plans.ExecutionFlags] = None,
                    advance: bool = True, timed: bool = True,
                    deliver: bool = False
                    ) -> Dict[str, ShardedExecutionReport]:
        """One mesh tick: every shard's fused ``execute_all`` over its local
        subscriptions (plan-groups, rings, and caches per shard), merged
        per channel. With ``route_cross_shard`` the delivered notify sIDs
        are then regrouped onto their broker-owner shards through the
        collective shuffle.

        Synchronous facade over ``dispatch_all(...).sync()`` — with one
        behavioral improvement inherited from the split: ALL shards'
        fused calls dispatch before any shard's results are read, so the
        per-device queues execute concurrently instead of serializing on
        each shard's materialization."""
        return self.execute(plans.ExecutionRequest(
            flags=flags, advance=advance, timed=timed, deliver=deliver))

    def execute(self, request: plans.ExecutionRequest
                ) -> Dict[str, ShardedExecutionReport]:
        """Run one ``ExecutionRequest`` mesh-wide: ``dispatch`` then
        ``sync()`` — the same single execution surface as ``BADEngine``."""
        return self.dispatch(request).sync()

    def dispatch_all(self, flags: Optional[plans.ExecutionFlags] = None,
                     advance: bool = True, timed: bool = False,
                     deliver: bool = False,
                     resolve_spills: bool = False
                     ) -> "ShardedPendingExecution":
        """``dispatch`` under the legacy keyword surface."""
        return self.dispatch(plans.ExecutionRequest(
            flags=flags, advance=advance, timed=timed, deliver=deliver,
            resolve_spills=resolve_spills))

    def dispatch(self, request: plans.ExecutionRequest
                 ) -> "ShardedPendingExecution":
        """Dispatch every shard's plan-group calls without waiting on any of
        them; the returned handle's ``sync()`` materializes and merges the
        per-channel reports (and runs the cross-shard notify route)."""
        pends = []
        for i, e in enumerate(self.shards):
            with self._on(i):
                pends.append(e.dispatch(request))
        return ShardedPendingExecution(self, pends, request.deliver)

    def _merge_reports(self, per_shard: List[Dict]
                       ) -> Dict[str, ShardedExecutionReport]:
        merged: Dict[str, ShardedExecutionReport] = {}
        for name in self._specs:
            reps = [r[name] for r in per_shard if name in r]
            if not reps:
                continue
            overflow = None
            if any(r.overflow is not None for r in reps):
                overflow = DeliveryStats(0, 0, 0, 0, 0, 0)
                for r in reps:
                    if r.overflow is not None:
                        overflow = overflow.merged(r.overflow)
            merged[name] = ShardedExecutionReport(
                channel=name,
                num_results=sum(r.num_results for r in reps),
                num_notified=sum(r.num_notified for r in reps),
                scanned=sum(r.scanned for r in reps),
                wall_time_s=sum(r.wall_time_s for r in reps),
                overflow=overflow,
                per_shard=reps)
        return merged

    def _route(self, merged: Dict[str, ShardedExecutionReport]) -> None:
        mesh = collectives.notify_mesh(self.num_shards)
        for name, rep in merged.items():
            if any(r.notify is None for r in rep.per_shard):
                continue
            # notify buffers are already fixed-width (-1 padded past the
            # delivered prefix), so the shuffle shapes are tick-stable
            sids = np.stack([np.asarray(r.notify) for r in rep.per_shard])
            owners = np.full(sids.shape, -1, np.int32)
            live = sids >= 0
            if live.any():
                if self._specs[name].join == "spatial":
                    bids = self._user_brokers[sids[live]]
                else:
                    bids = self._reg[name].brokers[sids[live]]
                owners[live] = partition.broker_owner(bids, self.num_shards)
            if mesh is not None:
                rep.routed = np.asarray(
                    collectives.shuffle_notify(mesh, sids, owners))
            else:
                rep.routed = collectives.shuffle_notify_ref(
                    sids, owners, self.num_shards)

    # ------------------------------------------------------------------
    # overflow surface
    # ------------------------------------------------------------------

    def ring_pending_pairs(self) -> int:
        return sum(e.ring_pending_pairs() for e in self.shards)

    def ring_pending_sids(self) -> int:
        return sum(e.ring_pending_sids() for e in self.shards)

    def flush_rings(self) -> None:
        for i, e in enumerate(self.shards):
            with self._on(i):
                e.flush_rings()

    def drain_spilled(self) -> Dict[str, DrainReport]:
        """One drain round on every shard. Keys are suffixed with the shard
        (``chan@s0``) so no shard's DrainReport shadows another's — readers
        that fold over ``.values()`` (the churn driver) are unaffected."""
        out: Dict[str, DrainReport] = {}
        for i, e in enumerate(self.shards):
            with self._on(i):
                for name, rep in e.drain_spilled().items():
                    key = name if self.num_shards == 1 else f"{name}@s{i}"
                    out[key] = rep
        return out

    # ------------------------------------------------------------------
    # resharding
    # ------------------------------------------------------------------

    def reshard(self, num_shards: int) -> Dict[str, DrainReport]:
        """Migrate to ``num_shards`` mid-stream, conservation-exactly.

        Every shard's retry ring flushes through its SpillQueue and the
        queues drain to empty against the OLD engines (correct epochs and
        tables — nothing is re-presented against a re-partitioned layout);
        the accumulated DrainReports are returned so callers keep the
        delivered content and counts. Then fresh engines are built at the
        new count: the replicated data plane (dataset, BAD index,
        watermarks, clock, user locations) transplants from shard 0, and
        the live subscription population re-partitions from the host
        registry under the new hash with its ORIGINAL global sIDs."""
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        drained: Dict[str, DrainReport] = {}
        for i, e in enumerate(self.shards):
            with self._on(i):
                e.flush_rings()
                rounds = 0
                while e.spill.pending_pairs() + e.spill.pending_sids() > 0:
                    for name, rep in e.drain_spilled().items():
                        drained[f"{name}@s{i}#r{rounds}"] = rep
                    rounds += 1
        src = self.shards[0]
        dataset_host = jax.tree.map(np.asarray, src.dataset)
        index_host = jax.tree.map(np.asarray, src.index_state)
        locations = np.asarray(src.user_locations)
        user_brokers = np.asarray(src.user_brokers)
        exec_marks = {name: (src.channels[name].last_exec_ts,
                             src.channels[name].last_exec_size)
                      for name in self._specs}
        self.num_shards = num_shards
        self.shards = [self._make_engine(i) for i in range(num_shards)]
        self.spill = _SpillView(self)
        for i, e in enumerate(self.shards):
            with self._on(i):
                e.now = src.now
                e.set_user_locations(locations, user_brokers)
                for spec in self._specs.values():
                    e.create_channel(spec)
                # channels first: every create_channel re-shapes the BAD
                # index, so the transplanted rows must land on the final
                # C-channel layout (identical creation order -> identical
                # row assignment)
                e.dataset = jax.tree.map(jnp.asarray, dataset_host)
                e.index_state = jax.tree.map(jnp.asarray, index_host)
                e.size_host = int(dataset_host.size)   # host mirror follows
                for name in self._specs:
                    ts, size = exec_marks[name]
                    e.channels[name].last_exec_ts = ts
                    e.channels[name].last_exec_size = size
        for name, reg in self._reg.items():
            sids = reg.live_sids()
            owner = partition.shard_for_sids(sids, num_shards)
            for i, e in enumerate(self.shards):
                mine = sids[owner == i]
                if not mine.size:
                    continue
                with self._on(i):
                    e.subscribe_bulk(name, reg.params[mine],
                                     reg.brokers[mine], sids=mine)
        for name, cohort in self._cohorts.items():
            uids = np.fromiter(sorted(cohort), np.int32, count=len(cohort))
            owner = partition.shard_for_users(uids, num_shards)
            for i, e in enumerate(self.shards):
                with self._on(i):
                    e.subscribe_users(name, uids[owner == i])
        for name, plan in self._plans.items():
            for i, e in enumerate(self.shards):
                with self._on(i):
                    e.set_plan(name, plan)
        return drained
