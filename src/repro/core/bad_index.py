"""The BAD index (paper §4.3): a PK-only partial index fed at ingestion time.

Per channel we keep an append-only buffer of row ids (primary keys) of records
that satisfied *all* of the channel's fixed predicates when they were
ingested, plus a watermark: the buffer length at the previous channel
execution. Entries in ``[watermark, count)`` are exactly the "new since last
execution" records — the LSM time-filter realization of ``is_new``.

Everything here is functional and jit-compatible (fixed-capacity buffers,
masked windows). The ingestion-side predicate evaluation itself lives in
``predicates.evaluate_conditions`` (oracle) / ``kernels.predicate_filter``
(Pallas); this module consumes the (N, C) match bitmap.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BADIndexState:
    """Stacked per-channel index buffers.

    row_ids:    (C, cap) int32 -- appended PKs, -1 padded
    counts:     (C,) int32     -- live entries per channel
    watermarks: (C,) int32     -- counts at last execution (time filter)
    overflowed: (C,) bool      -- capacity exceeded since last execution
    """

    row_ids: jnp.ndarray
    counts: jnp.ndarray
    watermarks: jnp.ndarray
    overflowed: jnp.ndarray

    @property
    def num_channels(self) -> int:
        return self.row_ids.shape[0]

    @property
    def capacity(self) -> int:
        return self.row_ids.shape[1]

    def tree_flatten(self):
        return (self.row_ids, self.counts, self.watermarks, self.overflowed), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @staticmethod
    def create(num_channels: int, capacity: int) -> "BADIndexState":
        return BADIndexState(
            row_ids=jnp.full((num_channels, capacity), -1, dtype=jnp.int32),
            counts=jnp.zeros((num_channels,), dtype=jnp.int32),
            watermarks=jnp.zeros((num_channels,), dtype=jnp.int32),
            overflowed=jnp.zeros((num_channels,), dtype=jnp.bool_),
        )


@partial(jax.jit, donate_argnums=(0,))
def insert(state: BADIndexState, row_ids: jnp.ndarray,
           matches: jnp.ndarray) -> BADIndexState:
    """Append matching row ids to every channel's buffer (Algorithm 2).

    row_ids: (N,) int32 of the just-ingested records
    matches: (N, C) bool from the conditionsList evaluation
    """
    cap = state.capacity

    def one_channel(buf, count, mask):
        # Stable compaction: position of each match among matches.
        pos = jnp.cumsum(mask.astype(jnp.int32)) - 1          # (N,)
        dest = jnp.where(mask, count + pos, cap)              # cap = dropped
        n_new = jnp.sum(mask.astype(jnp.int32))
        overflow = count + n_new > cap
        dest = jnp.minimum(dest, cap)                          # clamp for scatter-drop
        buf = buf.at[dest].set(jnp.where(mask, row_ids, -1), mode="drop")
        return buf, jnp.minimum(count + n_new, cap), overflow

    bufs, counts, over = jax.vmap(one_channel)(
        state.row_ids, state.counts, matches.T)
    return BADIndexState(bufs, counts, state.watermarks,
                         state.overflowed | over)


def new_entries(state: BADIndexState, channel: int,
                max_new: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Window of entries since the watermark for one channel.

    Returns (row_ids (max_new,) int32, valid (max_new,) bool). max_new is a
    static bound (the per-period ingest budget); excess entries beyond it are
    reported via count so callers can iterate.
    """
    wm = state.watermarks[channel]
    count = state.counts[channel]
    idx = wm + jnp.arange(max_new, dtype=jnp.int32)
    valid = idx < count
    rows = jnp.where(valid, state.row_ids[channel][jnp.minimum(idx, state.capacity - 1)], -1)
    return rows, valid


def advance_watermark(state: BADIndexState, channel: int) -> BADIndexState:
    """Mark the channel as executed: future reads see only newer entries."""
    return BADIndexState(
        state.row_ids,
        state.counts,
        state.watermarks.at[channel].set(state.counts[channel]),
        state.overflowed.at[channel].set(False),
    )


def advance_watermarks(state: BADIndexState,
                       channels: jnp.ndarray) -> BADIndexState:
    """Vectorized ``advance_watermark`` for a batch of executed channels."""
    return BADIndexState(
        state.row_ids,
        state.counts,
        state.watermarks.at[channels].set(state.counts[channels]),
        state.overflowed.at[channels].set(False),
    )


def compact(state: BADIndexState) -> BADIndexState:
    """Drop already-delivered entries (host-side maintenance between periods).

    Shifts each channel's live window ``[watermark, count)`` to the front so
    the fixed-capacity buffer behaves like the paper's LSM merge of old
    components. Not jitted (runs in the engine's maintenance slot).
    """
    import numpy as np

    bufs = np.asarray(state.row_ids).copy()
    counts = np.asarray(state.counts).copy()
    wms = np.asarray(state.watermarks).copy()
    for c in range(bufs.shape[0]):
        live = bufs[c, wms[c]:counts[c]].copy()
        bufs[c] = -1
        bufs[c, : live.shape[0]] = live
        counts[c] = live.shape[0]
        wms[c] = 0
    return BADIndexState(jnp.asarray(bufs), jnp.asarray(counts),
                         jnp.asarray(wms), state.overflowed)
