"""Fig. 17: maximum subscriptions supportable within the period deadline.

For each optimization combo, double the subscription count until channel
execution exceeds the (CPU-scaled) deadline; report the largest passing
count. Mirrors the paper's 'max subscriptions within the 10-minute period'.
"""
from __future__ import annotations

import numpy as np

from repro.core.plans import ExecutionFlags
from benchmarks.common import build_drug_engine, emit, exec_time, scale

DEADLINE_S = 0.250   # CPU-scaled period budget
COMBOS = {
    "original": ExecutionFlags(scan_mode="window"),
    "index_only": ExecutionFlags(scan_mode="bad_index"),
    "agg_only": ExecutionFlags(scan_mode="window", aggregation=True),
    "push_only": ExecutionFlags(scan_mode="window", param_pushdown=True),
    "full": ExecutionFlags.fully_optimized(),
}


def max_subs(rng, flags) -> int:
    n = 2048
    best = 0
    while n <= scale(262_144, 8192):
        eng = build_drug_engine(rng, n_subs=n, n_new=scale(8192, 1024),
                                match_rate=0.02,
                                preload=0)
        t, _ = exec_time(eng, "TweetsAboutDrugs", flags, repeats=2)
        if t > DEADLINE_S:
            break
        best = n
        n *= 2
    return best


def run(rng) -> None:
    results = {}
    for name, flags in COMBOS.items():
        m = max_subs(rng, flags)
        results[name] = m
        emit(f"fig17/{name}", DEADLINE_S, f"max_subs={m}")
    emit("fig17/gain", 0.0,
         f"full_vs_original_x{results['full']/max(results['original'],1):.1f}")


if __name__ == "__main__":
    run(np.random.default_rng(0))
