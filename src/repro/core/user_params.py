"""UserParameters dataset (paper §4.2).

"a dataset which will be created by the system when a channel is created ...
includes fields for the channel's parameter(s) and the number of subscriptions
interested in each. These fields facilitate the dynamic addition or removal of
parameters as subscriber interests evolve."

Channel parameters come from small categorical domains (states, countries,
topics), so the TPU-native realization is a dense refcount table over the
domain: membership tests during the early semi-join become O(1) gathers.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class UserParameters:
    """refcount[v] = number of live subscriptions with parameter v."""

    refcount: np.ndarray  # (domain,) int64

    @property
    def domain(self) -> int:
        return int(self.refcount.shape[0])

    @property
    def num_distinct(self) -> int:
        return int((self.refcount > 0).sum())

    @staticmethod
    def create(domain: int) -> "UserParameters":
        return UserParameters(np.zeros((domain,), dtype=np.int64))

    @staticmethod
    def from_params(params: np.ndarray, domain: int) -> "UserParameters":
        up = UserParameters.create(domain)
        np.add.at(up.refcount, np.asarray(params, dtype=np.int64), 1)
        return up

    def add(self, param: int) -> None:
        if not 0 <= param < self.domain:
            raise ValueError(f"param {param} out of [0, {self.domain})")
        self.refcount[param] += 1

    def add_bulk(self, params: np.ndarray) -> None:
        """Vectorized ``add``: one bincount instead of S increments."""
        params = np.asarray(params, dtype=np.int64).ravel()
        if params.size == 0:
            return
        if int(params.min()) < 0 or int(params.max()) >= self.domain:
            raise ValueError(f"params out of [0, {self.domain})")
        self.refcount += np.bincount(params, minlength=self.domain)

    def remove(self, param: int) -> None:
        if self.refcount[param] <= 0:
            raise ValueError(f"no live subscription with param {param}")
        self.refcount[param] -= 1

    def remove_bulk(self, params: np.ndarray) -> None:
        """Vectorized ``remove``: one bincount instead of S decrements.
        Validates the whole batch BEFORE mutating (atomic on failure)."""
        params = np.asarray(params, dtype=np.int64).ravel()
        if params.size == 0:
            return
        if int(params.min()) < 0 or int(params.max()) >= self.domain:
            raise ValueError(f"params out of [0, {self.domain})")
        dec = np.bincount(params, minlength=self.domain)
        if (self.refcount < dec).any():
            raise ValueError("remove_bulk exceeds live refcounts")
        self.refcount -= dec

    def mask(self) -> jnp.ndarray:
        """(domain,) bool device array for the early semi-join."""
        return jnp.asarray(self.refcount > 0)


def semi_join(param_values: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """(N,) record param values x (domain,) membership -> (N,) keep mask.

    The augmented plan's first join (records x UserParameters): prunes every
    record whose parameter value no subscriber asked for, *before* the wide
    join with the subscription dataset.
    """
    clipped = jnp.clip(param_values, 0, mask.shape[0] - 1)
    in_domain = (param_values >= 0) & (param_values < mask.shape[0])
    return mask[clipped] & in_domain
