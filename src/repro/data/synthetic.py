"""Synthetic data: EnrichedTweets streams (paper §5.1/§5.4) + LM token batches.

Tweet field distributions reproduce the paper's stated selectivities:
predicates I-III are 50% each, IV-V are 20% each; states follow a US-census
-like skew so subscription aggregation sees realistic group sizes (§5.2);
the real-world stream (§5.7) is language-skewed (en > pt > rest).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core import records as R

# Rough relative US state populations (50 entries, normalized at use).
STATE_WEIGHTS = np.array([
    39, 30, 22, 21, 13, 12.8, 11.8, 10.8, 10.7, 10.0,
    9.3, 8.9, 7.9, 7.3, 7.2, 6.9, 6.3, 6.2, 6.1, 5.9,
    5.8, 5.1, 4.9, 4.6, 4.5, 4.4, 3.4, 3.2, 3.2, 3.1,
    3.0, 2.9, 2.3, 2.2, 2.1, 2.0, 1.9, 1.9, 1.8, 1.5,
    1.4, 1.3, 1.1, 1.1, 1.0, 0.97, 0.91, 0.78, 0.65, 0.58,
])

LANG_WEIGHTS = np.array([0.62, 0.18, 0.08, 0.06, 0.06])  # en, pt, es, ar, ja


def tweet_batch(rng: np.random.Generator, n: int, t0: int,
                rate_per_s: int = 2000) -> R.RecordBatch:
    """One ingest window of EnrichedTweets with the paper's selectivities."""
    f = np.zeros((n, R.ENRICHED_TWEET_SCHEMA.num_fields), dtype=np.int32)
    f[:, R.STATE] = rng.choice(50, size=n, p=STATE_WEIGHTS / STATE_WEIGHTS.sum())
    f[:, R.ABOUT_COUNTRY] = (rng.random(n) > 0.5).astype(np.int32)         # I: 50%
    f[:, R.RETWEET_COUNT] = np.where(rng.random(n) < 0.5,                   # II: 50%
                                     rng.integers(10001, 200000, n),
                                     rng.integers(0, 10001, n))
    f[:, R.HATE_SPEECH_RATE] = np.where(rng.random(n) < 0.5,                # III: 50%
                                        rng.integers(6, 11, n),
                                        rng.integers(0, 6, n))
    f[:, R.THREATENING_RATE] = np.where(rng.random(n) < 0.2,                # IV: 20%
                                        rng.integers(6, 11, n),
                                        rng.integers(0, 6, n))
    f[:, R.WEAPON_MENTIONED] = (rng.random(n) < 0.2).astype(np.int32)       # V: 20%
    f[:, R.DRUG_ACTIVITY] = rng.integers(0, 5, n)
    f[:, R.LANG] = rng.choice(5, size=n, p=LANG_WEIGHTS)
    f[:, R.COUNTRY] = rng.integers(0, 200, n)
    f[:, R.TIMESTAMP] = t0 + (np.arange(n) // max(1, rate_per_s))
    loc = rng.uniform(-100, 100, size=(n, 2)).astype(np.float32)
    return R.RecordBatch.from_numpy(f, loc)


def drug_tweak(batch_fields: np.ndarray, rng: np.random.Generator,
               match_rate: float = 0.1) -> np.ndarray:
    """Force a fraction of records to match TweetsAboutDrugs' fixed preds."""
    n = batch_fields.shape[0]
    hit = rng.random(n) < match_rate
    batch_fields[hit, R.THREATENING_RATE] = 10
    batch_fields[hit, R.DRUG_ACTIVITY] = 3
    return batch_fields


def subscriptions_by_population(rng: np.random.Generator, n: int,
                                num_brokers: int = 1
                                ) -> Tuple[np.ndarray, np.ndarray]:
    """1M-style subscription set skewed by state population (paper §5.2)."""
    params = rng.choice(50, size=n, p=STATE_WEIGHTS / STATE_WEIGHTS.sum())
    brokers = rng.integers(0, num_brokers, n)
    return params.astype(np.int32), brokers.astype(np.int32)


# ---------------------------------------------------------------------------
# LM token pipeline (sharded-host loading pattern)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TokenStream:
    """Deterministic synthetic next-token stream: each host generates only its
    shard (seeded by (host_id, step)), mirroring per-host data loading."""

    vocab_size: int
    seq_len: int
    global_batch: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0

    def batch(self, step: int) -> dict:
        per_host = self.global_batch // self.num_hosts
        rng = np.random.default_rng(
            (self.seed, self.host_id, step, 0xBADDA7A))
        # Markov-ish structure so the LM has something learnable.
        base = rng.integers(0, self.vocab_size, (per_host, self.seq_len + 1))
        run = rng.random((per_host, self.seq_len + 1)) < 0.5
        toks = base.copy()
        for t in range(1, self.seq_len + 1):
            toks[:, t] = np.where(run[:, t],
                                  (toks[:, t - 1] + 1) % self.vocab_size,
                                  toks[:, t])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
