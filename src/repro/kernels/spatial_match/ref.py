"""Pure-jnp oracle for the spatial_match kernel (TweetsAboutCrime join)."""
from __future__ import annotations

import jax.numpy as jnp


def spatial_match(tweet_locs: jnp.ndarray, user_locs: jnp.ndarray,
                  radius: float) -> jnp.ndarray:
    """(R, 2) x (U, 2) -> (R, U) bool: euclidean distance < radius."""
    d = tweet_locs[:, None, :] - user_locs[None, :, :]
    dist2 = jnp.sum(d * d, axis=-1)
    return dist2 < jnp.asarray(radius, tweet_locs.dtype) ** 2
