"""Fused multi-channel execution + vectorized control plane + bugfix
regressions (drop_channel row remap, plan-cache staleness, broker overflow)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import records as R
from repro.core.broker import fanout_sids, pack_payloads
from repro.core.channel import (ChannelSpec, most_threatening_tweets,
                                trending_tweets_in_country, tweets_about_crime,
                                tweets_about_drugs)
from repro.core.engine import BADEngine, DeliveryStats
from repro.core.plans import ChannelResult, ExecutionFlags
from repro.core.predicates import Predicate

from conftest import make_tweets


def _small_engine(rng, with_spatial=True, with_param=True, use_pallas=False):
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("Broker1", "Broker2"), use_pallas=use_pallas)
    if with_param:
        eng.create_channel(tweets_about_drugs())
        eng.create_channel(most_threatening_tweets())
        eng.create_channel(trending_tweets_in_country(0, "EnglishTrending"))
    if with_spatial:
        eng.create_channel(tweets_about_crime(3))
        eng.set_user_locations(
            (rng.normal(size=(40, 2)) * 30).astype(np.float32),
            rng.integers(0, 2, 40))
    if with_param:
        eng.subscribe_bulk("TweetsAboutDrugs",
                           rng.integers(0, 50, 300), rng.integers(0, 2, 300))
        eng.subscribe_bulk("MostThreateningTweets",
                           rng.integers(0, 50, 200), rng.integers(0, 2, 200))
        eng.subscribe_bulk("EnglishTrending",
                           rng.integers(0, 200, 250), rng.integers(0, 2, 250))
    eng.ingest(make_tweets(rng, 700))
    return eng


ALL_MODE_FLAGS = [
    ExecutionFlags(scan_mode=m, aggregation=a, param_pushdown=a)
    for m in ("full", "window", "trad_index", "bad_index")
    for a in (False, True)
]
MODE_ONLY_FLAGS = [ExecutionFlags(scan_mode=m)
                   for m in ("full", "window", "trad_index", "bad_index")]


def _flag_id(f):
    return f"{f.scan_mode}{'+agg+push' if f.aggregation else ''}"


def _assert_fused_matches_sequential(eng, flags):
    seq = {name: eng.execute_channel(name, flags, advance=False, timed=False)
           for name in eng.channels}
    fused = eng.execute_all(flags, advance=False, timed=False)
    assert set(fused) == set(seq)
    for name in seq:
        assert fused[name].num_results == seq[name].num_results, name
        assert fused[name].num_notified == seq[name].num_notified, name
        assert fused[name].scanned == seq[name].scanned, name
        np.testing.assert_allclose(fused[name].broker_bytes,
                                   seq[name].broker_bytes, err_msg=name)
    return fused


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["oracle", "pallas"])
@pytest.mark.parametrize("flags", ALL_MODE_FLAGS, ids=_flag_id)
def test_execute_all_matches_sequential(rng, flags, use_pallas):
    """execute_all == per-channel execute_channel on every reported count,
    for >= 3 param channels (different domains/payloads) + one spatial —
    with both the jnp oracle and the Pallas kernels behind the fused plan."""
    eng = _small_engine(rng, use_pallas=use_pallas)
    fused = _assert_fused_matches_sequential(eng, flags)
    assert fused["TweetsAboutCrime3"].num_results > 0  # spatial is exercised


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["oracle", "pallas"])
@pytest.mark.parametrize("flags", MODE_ONLY_FLAGS, ids=_flag_id)
def test_execute_all_spatial_only_engine(rng, flags, use_pallas):
    """A spatial-only engine runs entirely through the fused spatial join
    (empty param group) and still matches the per-channel loop."""
    eng = _small_engine(rng, with_param=False, use_pallas=use_pallas)
    fused = _assert_fused_matches_sequential(eng, flags)
    assert set(fused) == {"TweetsAboutCrime3"}
    assert fused["TweetsAboutCrime3"].num_results > 0


def test_execute_all_advances_all_watermarks(rng):
    eng = _small_engine(rng, with_spatial=False)
    flags = ExecutionFlags(scan_mode="bad_index")
    first = eng.execute_all(flags, timed=False)
    assert any(r.num_results > 0 for r in first.values())
    again = eng.execute_all(flags, timed=False)
    assert all(r.num_results == 0 for r in again.values())
    eng.ingest(make_tweets(rng, 300, t0=5000))
    third = eng.execute_all(flags, timed=False)
    rows = np.asarray(third["TweetsAboutDrugs"].result.matched_rows)
    valid = np.asarray(third["TweetsAboutDrugs"].result.matched_valid)
    assert (rows[valid] >= 700).all()        # only post-watermark records


def test_subscribe_bulk_matches_replay(rng):
    """Vectorized bulk load == Algorithm-1 replay: same group structure,
    same refcounts, and incremental ops still work on the rebuilt state."""
    params = rng.integers(0, 50, 500).astype(np.int32)
    brokers = rng.integers(0, 2, 500).astype(np.int32)
    bulk = BADEngine(brokers=("B1", "B2"), group_cap=64)
    bulk.create_channel(tweets_about_drugs())
    sids = bulk.subscribe_bulk("TweetsAboutDrugs", params, brokers)
    assert len(set(sids.tolist())) == 500
    replay = BADEngine(brokers=("B1", "B2"), group_cap=64)
    replay.create_channel(tweets_about_drugs())
    st_r = replay.channels["TweetsAboutDrugs"]
    for p, b in zip(params.tolist(), brokers.tolist()):
        st_r.aggregator.add_subscription(int(p), int(b))
        st_r.user_params.add(int(p))

    def sig(groups):
        return sorted((int(groups.group_params[i]), int(groups.group_brokers[i]),
                       int(groups.group_counts[i]))
                      for i in range(groups.num_groups))

    st_b = bulk.channels["TweetsAboutDrugs"]
    assert sig(st_b.aggregator.build()) == sig(st_r.aggregator.build())
    np.testing.assert_array_equal(st_b.user_params.refcount,
                                  st_r.user_params.refcount)
    # incremental ops on the rebuilt (array-backed) state
    sid = bulk.subscribe("TweetsAboutDrugs", int(params[0]), "B1")
    assert sid == 500
    assert bulk.unsubscribe("TweetsAboutDrugs", int(params[0]), "B1", sid)
    assert st_b.aggregator.build().num_subscriptions == 500


def test_subscribe_bulk_merges_into_existing_groups():
    eng = BADEngine(brokers=("B1",), group_cap=8)
    eng.create_channel(tweets_about_drugs())
    for _ in range(3):
        eng.subscribe("TweetsAboutDrugs", 7, "B1")
    eng.subscribe_bulk("TweetsAboutDrugs", np.full(9, 7, np.int32),
                       np.zeros(9, np.int32))
    g = eng.channels["TweetsAboutDrugs"].aggregator.build()
    # 12 subs with param 7, cap 8 -> ceil(12/8) == 2 groups, like replay
    assert g.num_groups == 2
    assert sorted(g.group_counts.tolist()) == [4, 8]


def test_drop_middle_channel_keeps_index_identity(rng):
    """Dropping a middle channel must not hand its BAD-index rows (or
    watermarks) to the surviving channels."""
    eng = BADEngine(dataset_capacity=1024, index_capacity=512,
                    max_window=512, max_candidates=128)
    specs = [
        ChannelSpec("A", (Predicate.parse(R.THREATENING_RATE, "==", 10),)),
        ChannelSpec("B", (Predicate.parse(R.DRUG_ACTIVITY, "==", 3),)),
        ChannelSpec("C", (Predicate.parse(R.WEAPON_MENTIONED, "==", 1),)),
    ]
    for s in specs:
        eng.create_channel(s)
        eng.subscribe(s.name, 5, "BrokerA")
    fields = np.zeros((30, 10), dtype=np.int32)
    fields[:, R.STATE] = 5
    fields[:, R.TIMESTAMP] = 10
    fields[:10, R.THREATENING_RATE] = 10     # rows 0..9 match A
    fields[10:20, R.DRUG_ACTIVITY] = 3       # rows 10..19 match B
    fields[20:, R.WEAPON_MENTIONED] = 1      # rows 20..29 match C
    eng.ingest(R.RecordBatch.from_numpy(fields))
    eng.drop_channel("B")
    flags = ExecutionFlags(scan_mode="bad_index")
    rep_c = eng.execute_channel("C", flags, advance=False)
    rows = np.asarray(rep_c.result.matched_rows)
    valid = np.asarray(rep_c.result.matched_valid)
    assert sorted(rows[valid].tolist()) == list(range(20, 30))
    rep_a = eng.execute_channel("A", flags, advance=False)
    rows = np.asarray(rep_a.result.matched_rows)
    valid = np.asarray(rep_a.result.matched_valid)
    assert sorted(rows[valid].tolist()) == list(range(0, 10))


def test_recreated_channel_gets_fresh_plan(rng):
    """Re-creating a same-named channel with different predicates must not be
    served the stale compiled plan (old lru_cache keyed on channel name)."""
    eng = BADEngine(dataset_capacity=1024, index_capacity=512,
                    max_window=512, max_candidates=128)
    eng.create_channel(
        ChannelSpec("X", (Predicate.parse(R.THREATENING_RATE, "==", 10),)))
    eng.subscribe("X", 5, "BrokerA")
    fields = np.zeros((8, 10), dtype=np.int32)
    fields[:, R.STATE] = 5
    fields[:, R.TIMESTAMP] = 10
    fields[:, R.THREATENING_RATE] = 10
    eng.ingest(R.RecordBatch.from_numpy(fields))
    flags = ExecutionFlags(scan_mode="window")
    assert eng.execute_channel("X", flags, advance=False).num_results == 8
    eng.drop_channel("X")
    eng.create_channel(
        ChannelSpec("X", (Predicate.parse(R.WEAPON_MENTIONED, "==", 1),)))
    eng.subscribe("X", 5, "BrokerA")
    fields2 = fields.copy()
    fields2[:, R.TIMESTAMP] = 20
    fields2[:4, R.WEAPON_MENTIONED] = 1      # only 4 match the NEW predicate
    eng.ingest(R.RecordBatch.from_numpy(fields2))
    rep = eng.execute_channel("X", flags, advance=False)
    assert rep.num_results == 4


def test_execute_all_fresh_targets_after_recreate(rng):
    """The stacked-targets cache must not survive a drop/re-create of a
    same-named channel (version counters restart at 0)."""
    eng = BADEngine(dataset_capacity=1024, index_capacity=512,
                    max_window=512, max_candidates=128)
    eng.create_channel(tweets_about_drugs())
    eng.subscribe("TweetsAboutDrugs", 5, "BrokerA")
    flags = ExecutionFlags(scan_mode="window")
    eng.execute_all(flags, advance=False, timed=False)   # warm stacked cache
    eng.drop_channel("TweetsAboutDrugs")
    eng.create_channel(tweets_about_drugs())
    eng.subscribe("TweetsAboutDrugs", 7, "BrokerA")      # different param
    fields = np.zeros((8, 10), dtype=np.int32)
    fields[:, R.STATE] = 5                               # old subscriber only
    fields[:, R.THREATENING_RATE] = 10
    fields[:, R.DRUG_ACTIVITY] = 3
    fields[:, R.TIMESTAMP] = 10
    eng.ingest(R.RecordBatch.from_numpy(fields))
    rep = eng.execute_all(flags, advance=False, timed=False)["TweetsAboutDrugs"]
    assert rep.num_results == 0          # nobody subscribes to state 5 anymore
    seq = eng.execute_channel("TweetsAboutDrugs", flags, advance=False)
    assert seq.num_results == 0


def _spatial_spec(name, radius):
    return ChannelSpec(name, (Predicate.parse(R.WEAPON_MENTIONED, "==", 1),),
                       join="spatial", spatial_radius=radius)


def _weapon_batch(n, ts, loc):
    fields = np.zeros((n, 10), dtype=np.int32)
    fields[:, R.WEAPON_MENTIONED] = 1
    fields[:, R.TIMESTAMP] = ts
    locs = np.full((n, 2), loc, dtype=np.float32)
    return R.RecordBatch.from_numpy(fields, locs)


def test_execute_all_fresh_spatial_plan_after_recreate(rng):
    """Drop + re-create a same-named spatial channel with a different radius:
    execute_all must compile a fresh fused plan (radius lives in the spec),
    never serving the stale one."""
    eng = BADEngine(dataset_capacity=1024, index_capacity=512,
                    max_window=512, max_candidates=128)
    eng.create_channel(_spatial_spec("Crime", radius=1000.0))
    eng.set_user_locations(np.zeros((4, 2), dtype=np.float32))
    eng.ingest(_weapon_batch(6, ts=10, loc=5.0))
    flags = ExecutionFlags(scan_mode="window")
    wide = eng.execute_all(flags, timed=False)["Crime"]
    assert wide.num_results == 6 * 4            # radius 1000 covers everyone
    eng.drop_channel("Crime")
    eng.create_channel(_spatial_spec("Crime", radius=0.5))
    eng.ingest(_weapon_batch(6, ts=20, loc=5.0))  # 5.0 away from every user
    narrow = eng.execute_all(flags, advance=False, timed=False)["Crime"]
    assert narrow.num_results == 0              # stale radius would report 24
    seq = eng.execute_channel("Crime", flags, advance=False, timed=False)
    assert seq.num_results == narrow.num_results == 0


def test_execute_all_fresh_user_targets_after_relocation(rng):
    """set_user_locations between fused executions must invalidate the
    stacked user-set cache (version bump), not serve stale coordinates."""
    eng = BADEngine(dataset_capacity=1024, index_capacity=512,
                    max_window=512, max_candidates=128)
    eng.create_channel(_spatial_spec("Crime", radius=1.0))
    eng.set_user_locations(np.full((3, 2), 5.0, dtype=np.float32))
    flags = ExecutionFlags(scan_mode="window")
    eng.ingest(_weapon_batch(4, ts=10, loc=5.0))
    near = eng.execute_all(flags, advance=False, timed=False)["Crime"]
    assert near.num_results == 4 * 3
    eng.set_user_locations(np.full((3, 2), 500.0, dtype=np.float32))
    far = eng.execute_all(flags, advance=False, timed=False)["Crime"]
    assert far.num_results == 0                 # stale users would report 12


def test_execution_report_surfaces_overflow(rng):
    """deliver=True runs broker packing and surfaces drop counts:
    delivered + overflow == produced for both stages, identically between
    the fused and per-channel paths; deliver=False leaves overflow None."""
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("B1", "B2"), group_cap=8,
                    max_deliver_pairs=16, max_notify=32,
                    # retry ring off: repeated fused calls would otherwise
                    # re-present (and re-count) the prior call's overflow,
                    # which is exactly what this per-call parity test is NOT
                    # about (tests/test_retry_ring.py covers the ring)
                    ring_capacity=0)
    eng.create_channel(tweets_about_drugs())
    eng.create_channel(tweets_about_crime(1))
    eng.set_user_locations((rng.normal(size=(30, 2)) * 30).astype(np.float32))
    eng.subscribe_bulk("TweetsAboutDrugs",
                       rng.integers(0, 50, 200), rng.integers(0, 2, 200))
    eng.ingest(make_tweets(rng, 500, match_drugs=0.3))
    for agg in (False, True):
        flags = ExecutionFlags(scan_mode="window", aggregation=agg,
                               param_pushdown=agg)
        fused = eng.execute_all(flags, advance=False, timed=False,
                                deliver=True)
        for name in eng.channels:
            rep = eng.execute_channel(name, flags, advance=False, timed=False,
                                      deliver=True)
            o = rep.overflow
            assert isinstance(o, DeliveryStats)
            assert o.delivered_pairs + o.overflow_pairs == rep.num_results
            assert o.delivered_sids + o.overflow_sids == rep.num_notified
            assert o.overflow > 0               # caps are tiny: drops happen
            assert fused[name].overflow == o
        assert eng.execute_channel("TweetsAboutDrugs", flags, advance=False,
                                   timed=False).overflow is None


def test_broker_buffers_random_invariants(rng):
    """Seeded mini-fuzz of pack_payloads/fanout_sids: the hypothesis suite in
    test_property.py runs the SAME shared checkers (conftest) when hypothesis
    is installed; this keeps the invariants exercised without it."""
    from conftest import (check_fanout_invariants, check_pack_invariants,
                          random_broker_result)
    for trial in range(25):
        res, group_sids, exp_rows, exp_tgts = random_broker_result(
            rng, n_rows=int(rng.integers(1, 30)),
            max_t=int(rng.integers(1, 5)),
            n_groups=int(rng.integers(1, 6)), cap=int(rng.integers(1, 4)))
        check_pack_invariants(res, group_sids, exp_rows, exp_tgts,
                              max_pairs=int(rng.integers(1, 12)))
        check_fanout_invariants(res, group_sids, exp_tgts,
                                max_notify=int(rng.integers(1, 16)))


def test_subscribe_bulk_rejects_out_of_domain_atomically():
    eng = BADEngine()
    eng.create_channel(tweets_about_drugs())             # param_domain == 50
    bad = np.array([3, 60, 4], np.int32)                 # 60 out of domain
    with pytest.raises(ValueError, match="out of"):
        eng.subscribe_bulk("TweetsAboutDrugs", bad, np.zeros(3, np.int32))
    with pytest.raises(ValueError, match="out of"):      # bad broker id too
        eng.subscribe_bulk("TweetsAboutDrugs", np.array([3], np.int32),
                           np.array([9], np.int32))
    for bad_param in (-1, 50):                           # single-sub path
        with pytest.raises(ValueError, match="out of"):
            eng.subscribe("TweetsAboutDrugs", bad_param, "BrokerA")
    st = eng.channels["TweetsAboutDrugs"]
    assert st.aggregator.build().num_subscriptions == 0  # nothing half-applied
    assert int(st.user_params.refcount.sum()) == 0


def _overflow_result(n_pairs):
    """A ChannelResult with ``n_pairs`` valid pairs, distinct rows/targets."""
    rows = jnp.arange(n_pairs, dtype=jnp.int32)[:, None]
    tgts = jnp.arange(n_pairs, dtype=jnp.int32)[:, None] % 4
    valid = jnp.ones((n_pairs, 1), dtype=bool)
    z = jnp.zeros((), jnp.int32)
    return ChannelResult(rows, tgts, valid, rows[:, 0],
                         jnp.ones((n_pairs,), bool), z, z, z,
                         jnp.zeros((1,), jnp.float32),
                         jnp.zeros((1,), jnp.int32))


def test_pack_payloads_overflow_drops_not_overwrites():
    res = _overflow_result(10)
    group_sids = jnp.arange(4, dtype=jnp.int32)[:, None]   # 4 groups of 1
    out, delivered, overflow = pack_payloads(res, group_sids,
                                             payload_words=2, max_pairs=6)
    assert int(delivered) == 6
    assert int(overflow) == 4
    # the buffer holds the FIRST 6 pairs in order — the last slot is pair 5,
    # not the last overflowing pair (the old clamp overwrote it with pair 9)
    assert np.asarray(out[:, 0]).tolist() == [0, 1, 2, 3, 4, 5]


def test_fanout_sids_overflow_drops_not_overwrites():
    res = _overflow_result(10)
    group_sids = (jnp.arange(4, dtype=jnp.int32) * 100)[:, None]
    out, delivered, overflow = fanout_sids(res, group_sids, max_notify=7)
    assert int(delivered) == 7
    assert int(overflow) == 3
    expected = [(i % 4) * 100 for i in range(7)]
    assert np.asarray(out).tolist() == expected


def test_no_overflow_counts_zero(rng):
    res = _overflow_result(5)
    group_sids = jnp.arange(4, dtype=jnp.int32)[:, None]
    _, delivered, overflow = pack_payloads(res, group_sids,
                                           payload_words=2, max_pairs=16)
    assert int(delivered) == 5 and int(overflow) == 0
    _, delivered, overflow = fanout_sids(res, group_sids, max_notify=16)
    assert int(delivered) == 5 and int(overflow) == 0
