"""shard_map collectives: sequence-parallel flash-decode attention.

The KV cache for serving is sharded over the `model` axis on the *sequence*
dimension (works for every GQA geometry — head counts never need to divide
the axis). Each model shard computes flash partials (acc, m, l) over its local
KV slice; the merge is an exact log-sum-exp combine using one pmax + one psum
of (B, H, D)-sized tensors — O(B·H·D) bytes instead of re-reading the cache.

This is the TPU analogue of FlashDecoding split-KV, expressed as a collective
schedule instead of a grid.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map
from repro.distributed.partition import Rules, sanitize_spec
from repro.kernels.flash_decode import ref as fd_ref


def sp_decode_attention(rules: Rules, q: jnp.ndarray, k: jnp.ndarray,
                        v: jnp.ndarray, kv_len: jnp.ndarray,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q (B, H, D); k/v (B, KH, S, D) seq-sharded; kv_len (B,) -> (B, H, D)."""
    mesh = rules.mesh
    m_axis = rules.model_axis
    if m_axis is None:
        return fd_ref.decode_attention(q, k, v, kv_len, scale)
    n_shards = mesh.shape[m_axis]
    b, h, d = q.shape
    s = k.shape[2]
    b_spec = rules.batch_axes if rules.batch_axes else None
    bq = sanitize_spec(P(b_spec, None, None), q.shape, mesh)
    bkv = sanitize_spec(P(b_spec, None, m_axis, None), k.shape, mesh)
    blen = sanitize_spec(P(b_spec), kv_len.shape, mesh)
    shard_size = s // n_shards

    def local(qs, ks, vs, lens):
        # Local slice covers absolute kv positions [idx*shard, (idx+1)*shard).
        idx = jax.lax.axis_index(m_axis)
        local_len = jnp.clip(lens - idx * shard_size, 0, shard_size)
        acc, m, l = fd_ref.decode_attention_partial(qs, ks, vs, local_len, scale)
        m_g = jax.lax.pmax(m, m_axis)
        m_safe = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
        c = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        acc = jax.lax.psum(acc * c[..., None], m_axis)
        l = jax.lax.psum(l * c, m_axis)
        return fd_ref.normalize(acc, l, qs.dtype)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(bq, bkv, bkv, blen),
                   out_specs=bq)
    return fn(q, k, v, kv_len)
