"""Train an LM with the full production loop (checkpoint/restart included).

Default: a reduced xlstm config for a fast CPU demo. ``--full-100m`` trains a
~100M-parameter tinyllama-family config for a few hundred steps (hours on
this CPU; the code path is identical to the TPU deployment).

    PYTHONPATH=src python examples/train_lm.py --steps 30
    PYTHONPATH=src python examples/train_lm.py --full-100m --steps 300
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro import configs
from repro.launch.train import train
from repro.models.model import ModelApi


def hundred_m_config():
    base = configs.get_config("tinyllama-1.1b")
    return dataclasses.replace(
        base, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
        superlayer_repeat=12, n_layers=12, head_dim=64, vocab_size=32000,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        grad_accum=1, remat=False).validate()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    cfg = hundred_m_config() if args.full_100m else configs.get_reduced("xlstm-125m")
    print(f"training {cfg.name} ({ModelApi(cfg).param_count():,} params) "
          f"for {args.steps} steps")
    _, _, losses = train(cfg, args.steps, args.batch, args.seq, args.ckpt_dir,
                         ckpt_every=20, log_every=5)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
