"""Sustained subscription churn: incremental (epoch/delta) vs rebuild.

The pre-churn-engine control plane paid O(S) on every subscription change
(full re-aggregation + full stacked-cache rebuild + usually a retrace per
tick). The churn engine pays O(Δ): the aggregator touches only the affected
(param, broker) keys and the device caches are patched in place. This suite
measures the end-to-end difference — bulk add + bulk remove + fused
``execute_all(deliver=True)`` per tick — at several live-subscription sizes
and add/remove mixes, plus spatial-cohort churn.

Acceptance: incremental sustains >= 5x the rebuild baseline's
subscriptions/sec at 100k+ live subscriptions with ZERO retraces and zero
rebuilds across steady-state ticks (both are quoted in the derived column).
"""
from __future__ import annotations

import numpy as np

from repro.core.channel import tweets_about_crime, tweets_about_drugs
from repro.core.churn import ChurnWorkload, run_ticks
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags
from benchmarks.common import emit

TICKS = 6          # timed ticks (after the untimed warm phase)
WARMUP = 4
ROUNDS = 4         # control-plane batches per executed tick (paper regime:
                   # subscriptions arrive continuously between periods)


def _loaded_engine(seed: int, n_live: int, incremental: bool,
                   with_cohort: bool = False,
                   deliver_pairs: int = 1 << 12):
    rng = np.random.default_rng(seed)
    # buffers sized to the churn workload: small ingest batches, and
    # delivery caps ABOVE the per-tick result/notify volume — spill+drain
    # (host-driven, eagerly compiled per shape bucket) is delivery work,
    # not the maintenance cost this suite isolates. The FLAT suite passes a
    # larger pair cap: flat pairs are per-subscription, so the convert-stage
    # volume equals the send-stage volume
    eng = BADEngine(dataset_capacity=1 << 14, index_capacity=1 << 13,
                    max_window=1 << 11, max_candidates=1 << 10,
                    brokers=("B1", "B2", "B3", "B4"), group_cap=64,
                    max_deliver_pairs=deliver_pairs, max_notify=1 << 15,
                    max_spill=1 << 9, incremental=incremental)
    eng.create_channel(tweets_about_drugs())
    sids = eng.subscribe_bulk("TweetsAboutDrugs",
                              rng.integers(0, 50, n_live),
                              rng.integers(0, 4, n_live))
    if with_cohort:
        eng.create_channel(tweets_about_crime(3))
        n_users = max(256, n_live // 16)
        eng.set_user_locations(
            rng.uniform(-100, 100, size=(n_users, 2)).astype(np.float32),
            rng.integers(0, 4, n_users))
        eng.subscribe_users("TweetsAboutCrime3",
                            rng.choice(n_users, n_users // 2, replace=False))
    return eng, {"TweetsAboutDrugs": sids}, rng


def _run_mode(seed: int, n_live: int, incremental: bool, adds: int,
              removes: int, user_churn: int = 0, flags=None,
              deliver_pairs: int = 1 << 12):
    with_cohort = user_churn > 0
    eng, live, rng = _loaded_engine(seed, n_live, incremental, with_cohort,
                                    deliver_pairs)
    wl = [ChurnWorkload("TweetsAboutDrugs", adds_per_tick=adds,
                        removes_per_tick=removes, num_brokers=4,
                        user_channel="TweetsAboutCrime3" if with_cohort
                        else None,
                        user_churn_per_tick=user_churn)]
    kw = dict(flags=flags or ExecutionFlags.fully_optimized(), deliver=True,
              ingest_per_tick=128, live_sids=live, churn_rounds=ROUNDS)
    # warm phase (untimed): absorbs trace/compile AND the one-time capacity
    # crossing as the slot table settles into its steady padded bucket
    run_ticks(eng, wl, WARMUP, rng, warmup=WARMUP, **kw)
    return run_ticks(eng, wl, TICKS, rng, warmup=0, **kw)


def bench_sustained(rng, n_live: int, label: str) -> None:
    """Balanced add/remove churn (live count hovers) — the steady state the
    delta protocol is built for."""
    churn = max(256, n_live // 400)
    seed = int(rng.integers(0, 2 ** 31))
    reps = {}
    for mode, incremental in (("incremental", True), ("rebuild", False)):
        rep = _run_mode(seed, n_live, incremental, churn, churn)
        reps[mode] = rep
        m = rep.maintenance
        emit(f"churn/sustained/{label}/{mode}", rep.wall_s / rep.ticks,
             f"subs_per_s={rep.subs_per_s:.0f};live={rep.live_subs}"
             f";retraces={m.traces};rebuilds={m.rebuilds}"
             f";patches={m.patches};results={rep.results}")
    # identical seeds -> identical op streams -> identical SUBSCRIBER-level
    # outcomes (group partitions may differ within compact_slack, so the
    # pair/result count is not the invariant — the notified sIDs are)
    assert reps["incremental"].delivered_sids == \
        reps["rebuild"].delivered_sids, \
        (reps["incremental"].delivered_sids, reps["rebuild"].delivered_sids)
    ratio = reps["incremental"].subs_per_s / max(reps["rebuild"].subs_per_s,
                                                 1e-9)
    steady = reps["incremental"].maintenance
    emit(f"churn/sustained/{label}/speedup", 0.0,
         f"x{ratio:.1f} (target >= 5x at 100k+); "
         f"steady retraces={steady.traces} rebuilds={steady.rebuilds}")


def bench_mixed(rng, n_live: int, label: str) -> None:
    """Unbalanced mixes: add-heavy growth (may legitimately cross padded
    capacity -> counted rebuilds) and remove-heavy shrink (exercises slot
    free-lists + key compaction)."""
    churn = max(256, n_live // 400)
    for tag, adds, removes in (("add_heavy", churn, churn // 4),
                               ("remove_heavy", churn // 4, churn)):
        seed = int(rng.integers(0, 2 ** 31))
        out = {}
        for mode, incremental in (("incremental", True), ("rebuild", False)):
            rep = _run_mode(seed, n_live, incremental, adds, removes)
            out[mode] = rep
            m = rep.maintenance
            emit(f"churn/mixed/{label}/{tag}/{mode}", rep.wall_s / rep.ticks,
                 f"subs_per_s={rep.subs_per_s:.0f};live={rep.live_subs}"
                 f";retraces={m.traces};rebuilds={m.rebuilds}")
        ratio = out["incremental"].subs_per_s / max(
            out["rebuild"].subs_per_s, 1e-9)
        emit(f"churn/mixed/{label}/{tag}/speedup", 0.0, f"x{ratio:.1f}")


def bench_flat(rng, n_live: int, label: str) -> None:
    """FLAT layout (no aggregation — per-subscription rows) under balanced
    churn: the stable flat slots + positional join-map cells let the churn
    engine patch the flat stacked cache in place (zero rebuilds at steady
    state) where the rebuild baseline re-flattens and re-uploads O(S) every
    epoch."""
    churn = max(256, n_live // 400)
    seed = int(rng.integers(0, 2 ** 31))
    flags = ExecutionFlags(scan_mode="bad_index")     # aggregation=False
    reps = {}
    for mode, incremental in (("incremental", True), ("rebuild", False)):
        rep = _run_mode(seed, n_live, incremental, churn, churn, flags=flags,
                        deliver_pairs=1 << 15)
        reps[mode] = rep
        m = rep.maintenance
        emit(f"churn/flat/{label}/{mode}", rep.wall_s / rep.ticks,
             f"subs_per_s={rep.subs_per_s:.0f};live={rep.live_subs}"
             f";retraces={m.traces};rebuilds={m.rebuilds}"
             f";patches={m.patches};results={rep.results}")
    # flat layout: one target per subscription, so identical op streams
    # must deliver identical sID totals in both modes
    assert reps["incremental"].delivered_sids == \
        reps["rebuild"].delivered_sids, \
        (reps["incremental"].delivered_sids, reps["rebuild"].delivered_sids)
    ratio = reps["incremental"].subs_per_s / max(reps["rebuild"].subs_per_s,
                                                 1e-9)
    steady = reps["incremental"].maintenance
    emit(f"churn/flat/{label}/speedup", 0.0,
         f"x{ratio:.1f}; steady retraces={steady.traces} "
         f"rebuilds={steady.rebuilds}")


def bench_cohort(rng, n_live: int, label: str) -> None:
    """Spatial-cohort churn riding the same ticks: user subscribe/unsubscribe
    patch the stacked user-target rows in place."""
    churn = max(256, n_live // 400)
    seed = int(rng.integers(0, 2 ** 31))
    out = {}
    for mode, incremental in (("incremental", True), ("rebuild", False)):
        rep = _run_mode(seed, n_live, incremental, churn, churn,
                        user_churn=max(64, churn // 8))
        out[mode] = rep
        m = rep.maintenance
        emit(f"churn/cohort/{label}/{mode}", rep.wall_s / rep.ticks,
             f"subs_per_s={rep.subs_per_s:.0f};user_ops="
             f"{rep.user_adds + rep.user_removes}"
             f";retraces={m.traces};rebuilds={m.rebuilds}")
    ratio = out["incremental"].subs_per_s / max(out["rebuild"].subs_per_s,
                                                1e-9)
    emit(f"churn/cohort/{label}/speedup", 0.0, f"x{ratio:.1f}")


def run(rng) -> None:
    # NOT routed through scale(): the O(Δ) vs O(S) separation is a function
    # of the live-set size, so shrinking it 16x would benchmark the regime
    # below the crossover. These sizes run in seconds; only the large
    # points stay out of smoke mode.
    for n, label in ((10_000, "10k"), (100_000, "100k")):
        bench_sustained(rng, n, label)
    bench_mixed(rng, 100_000, "100k")
    bench_cohort(rng, 100_000, "100k")
    bench_flat(rng, 100_000, "100k")
    from benchmarks.common import SMOKE
    if not SMOKE:
        # the shared fused execute+deliver floor (~constant per tick) bounds
        # the ratio at small S; the target >= 5x emerges from ~1M live
        bench_sustained(rng, 400_000, "400k")
        bench_sustained(rng, 1_000_000, "1M")
        bench_flat(rng, 400_000, "400k")


if __name__ == "__main__":
    run(np.random.default_rng(0))
