"""Recurrent / state-space blocks: Mamba2 (SSD), mLSTM, sLSTM.

All sub-quadratic sequence mixers here share one TPU-native skeleton,
``chunked_gla``: chunked gated linear attention with per-head scalar decay.
Within a chunk the computation is dense matmuls (MXU); across chunks the
(Dk, Dv) states propagate through ``jax.lax.associative_scan`` (log-depth,
fully visible to HLO cost analysis — no sequential while loops on the
training path).

  o_t = q_t . S_t,   S_t = sum_{j<=t} exp(L_t - L_j) * k_j v_j^T,
  L_t = cumsum(log a).

- Mamba2/SSD: q=C_t, k=B_t, v=dt*x_t, log a = -softplus(dt)*exp(A_log).
- mLSTM: q/k/v projections, log a = logsigmoid(f), input gate folded into v;
  normalizer state tracked via an appended all-ones value channel.
  (The xLSTM paper's exponential input gate + max-stabilizer is replaced by
  the bounded sigmoid/log-sigmoid pair in the chunked form — the standard
  GLA-stable parameterization; the sequential sLSTM below keeps the paper's
  exact exponential gating with stabilizer state.)
- sLSTM: strictly sequential (recurrent gate matrices R), implemented with
  lax.scan over time — faithful to the paper; its elementwise recurrence is
  O(T*d) flops (negligible next to the projections, see DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_dense, rms_norm


# ---------------------------------------------------------------------------
# chunked gated linear attention
# ---------------------------------------------------------------------------


def chunked_gla(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                log_a: jnp.ndarray, chunk: int,
                initial_state: jnp.ndarray | None = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q/k (B, H, T, Dk), v (B, H, T, Dv), log_a (B, H, T) <= 0.

    Returns (o (B, H, T, Dv), final_state (B, H, Dk, Dv)).
    """
    b, h, t, dk = q.shape
    dv = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    n = t // chunk
    qc = q.reshape(b, h, n, chunk, dk)
    kc = k.reshape(b, h, n, chunk, dk)
    vc = v.reshape(b, h, n, chunk, dv)
    la = log_a.reshape(b, h, n, chunk)
    L = jnp.cumsum(la, axis=-1)                          # within-chunk cumsum
    Ltot = L[..., -1]                                    # (B, H, N)

    # intra-chunk: A[i, j] = exp(L_i - L_j) (q_i . k_j), j <= i
    qi = qc * jnp.exp(L)[..., None]
    kj = kc * jnp.exp(-L)[..., None]
    att = jnp.einsum("bhnid,bhnjd->bhnij", qi, kj)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    att = jnp.where(mask, att, 0.0)
    o_intra = jnp.einsum("bhnij,bhnjv->bhniv", att, vc)

    # chunk summaries: S_n = sum_j exp(Ltot - L_j) k_j v_j^T
    kw = kc * jnp.exp(Ltot[..., None] - L)[..., None]
    S = jnp.einsum("bhnjd,bhnjv->bhndv", kw, vc)         # (B, H, N, Dk, Dv)
    decay = jnp.exp(Ltot)                                # (B, H, N)

    def combine(a, b_):
        d1, s1 = a
        d2, s2 = b_
        return d1 * d2, s1 * d2[..., None, None] + s2

    d_run, s_run = jax.lax.associative_scan(combine, (decay, S), axis=2)
    if initial_state is not None:
        s_run = s_run + initial_state[:, :, None] * d_run[..., None, None]
    # state entering chunk n = s_run[n-1] (or initial_state for n=0)
    init = initial_state if initial_state is not None else jnp.zeros_like(s_run[:, :, 0])
    s_prev = jnp.concatenate([init[:, :, None], s_run[:, :, :-1]], axis=2)
    o_inter = jnp.einsum("bhnid,bhndv->bhniv", qi, s_prev)
    o = (o_intra + o_inter).reshape(b, h, t, dv)
    return o, s_run[:, :, -1]


def gla_step(q, k, v, log_a, state):
    """Single-token recurrence: state (B, H, Dk, Dv); q/k (B, H, Dk); v (B, H, Dv)."""
    a = jnp.exp(log_a)[..., None, None]
    state = state * a + k[..., :, None] * v[..., None, :]
    o = jnp.einsum("bhd,bhdv->bhv", q, state)
    return o, state


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------


def mamba2_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    head_dim = 64
    n_heads = max(1, d_in // head_dim)
    if d_in % head_dim:
        head_dim = d_in // n_heads
    return d_in, n_heads, head_dim


def mamba2_init(key, cfg: ModelConfig, dtype=None) -> Dict[str, jnp.ndarray]:
    dtype = dtype or cfg.param_dtype
    d = cfg.d_model
    ds = cfg.ssm_state
    d_in, h, hd = mamba2_dims(cfg)
    conv_ch = d_in + 2 * ds
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": init_dense(k1, (d, 2 * d_in + 2 * ds + h), dtype),
        "conv_w": init_dense(k2, (cfg.ssm_conv, conv_ch), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_proj": init_dense(k3, (d_in, d), dtype),
        "norm_w": jnp.ones((d_in,), jnp.float32),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv: x (B, T, C), w (W, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1]] * w[i][None, None] for i in range(width))
    return out + b[None, None]


def mamba2_apply(p, x: jnp.ndarray, cfg: ModelConfig,
                 state: Dict[str, jnp.ndarray] | None = None):
    """x (B, T, D) -> (y (B, T, D), final state dict)."""
    cdtype = cfg.compute_dtype
    b, t, d = x.shape
    ds = cfg.ssm_state
    d_in, h, hd = mamba2_dims(cfg)
    proj = (x.astype(cdtype) @ p["in_proj"].astype(cdtype))
    z, xc, B, C, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + ds, 2 * d_in + 2 * ds], axis=-1)
    conv_in = jnp.concatenate([xc, B, C], axis=-1)
    conv = jax.nn.silu(_causal_conv(conv_in, p["conv_w"].astype(cdtype),
                                    p["conv_b"].astype(cdtype)))
    xc, B, C = jnp.split(conv, [d_in, d_in + ds], axis=-1)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, T, H)
    log_a = (-dtf * jnp.exp(p["a_log"])).transpose(0, 2, 1)        # (B, H, T)
    xh = xc.reshape(b, t, h, hd).transpose(0, 2, 1, 3)             # (B, H, T, hd)
    v = xh * dtf.transpose(0, 2, 1)[..., None].astype(cdtype)
    qk_shape = jnp.broadcast_to(B[:, None], (b, h, t, ds))
    q = jnp.broadcast_to(C[:, None], (b, h, t, ds))
    init = state["ssm"] if state is not None else None
    o, s_fin = chunked_gla(q.astype(jnp.float32), qk_shape.astype(jnp.float32),
                           v.astype(jnp.float32), log_a,
                           min(cfg.ssm_chunk, t), init)
    y = o + xh.astype(jnp.float32) * p["d_skip"][None, :, None, None]
    y = y.transpose(0, 2, 1, 3).reshape(b, t, d_in).astype(cdtype)
    y = rms_norm(y, p["norm_w"], cfg.norm_eps) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(cdtype)
    new_state = {"ssm": s_fin,
                 "conv": conv_in[:, t - (cfg.ssm_conv - 1):].astype(cdtype)}
    return out, new_state


def mamba2_decode(p, x: jnp.ndarray, cfg: ModelConfig,
                  state: Dict[str, jnp.ndarray]):
    """x (B, D) one token; state {'ssm' (B,H,ds,hd), 'conv' (B,W-1,C)}."""
    cdtype = cfg.compute_dtype
    b, d = x.shape
    ds = cfg.ssm_state
    d_in, h, hd = mamba2_dims(cfg)
    proj = x.astype(cdtype) @ p["in_proj"].astype(cdtype)
    z, xc, B, C, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + ds, 2 * d_in + 2 * ds], axis=-1)
    conv_in = jnp.concatenate([xc, B, C], axis=-1)                 # (B, C)
    hist = jnp.concatenate([state["conv"], conv_in[:, None]], axis=1)  # (B, W, C)
    w = p["conv_w"].astype(cdtype)
    conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, w) + p["conv_b"].astype(cdtype))
    xc, B, C = jnp.split(conv, [d_in, d_in + ds], axis=-1)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, H)
    log_a = -dtf * jnp.exp(p["a_log"])
    xh = xc.reshape(b, h, hd)
    v = xh.astype(jnp.float32) * dtf[..., None]
    k = jnp.broadcast_to(B[:, None], (b, h, ds)).astype(jnp.float32)
    q = jnp.broadcast_to(C[:, None], (b, h, ds)).astype(jnp.float32)
    o, s_new = gla_step(q, k, v, log_a, state["ssm"])
    y = o + xh.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(b, d_in).astype(cdtype)
    y = rms_norm(y, p["norm_w"], cfg.norm_eps) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(cdtype)
    return out, {"ssm": s_new, "conv": hist[:, 1:]}


def mamba2_state_shapes(cfg: ModelConfig, batch: int):
    d_in, h, hd = mamba2_dims(cfg)
    conv_ch = d_in + 2 * cfg.ssm_state
    return {
        "ssm": jax.ShapeDtypeStruct((batch, h, cfg.ssm_state, hd), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_ch),
                                     cfg.compute_dtype),
    }


# ---------------------------------------------------------------------------
# mLSTM block (parallel chunked form)
# ---------------------------------------------------------------------------


def mlstm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    hd = d_in // h
    return d_in, h, hd


def mlstm_init(key, cfg: ModelConfig, dtype=None) -> Dict[str, jnp.ndarray]:
    dtype = dtype or cfg.param_dtype
    d = cfg.d_model
    d_in, h, hd = mlstm_dims(cfg)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "up": init_dense(k1, (d, 2 * d_in), dtype),            # x branch + z gate
        "wqkv": init_dense(k2, (d_in, 3 * d_in), dtype),
        "wgates": init_dense(k3, (d_in, 2 * h), dtype),        # i, f per head
        "gate_b": jnp.zeros((2 * h,), jnp.float32),
        "down": init_dense(k4, (d_in, d), dtype),
        "norm_w": jnp.ones((d_in,), jnp.float32),
    }


def _mlstm_qkvg(p, xp, cfg, b, t_or_none):
    d_in, h, hd = mlstm_dims(cfg)
    qkv = xp @ p["wqkv"].astype(xp.dtype)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    gates = xp.astype(jnp.float32) @ p["wgates"].astype(jnp.float32) + p["gate_b"]
    ig, fg = jnp.split(gates, 2, axis=-1)
    return q, k, v, jax.nn.sigmoid(ig), jax.nn.log_sigmoid(fg)


def mlstm_apply(p, x: jnp.ndarray, cfg: ModelConfig,
                state: Dict[str, jnp.ndarray] | None = None):
    cdtype = cfg.compute_dtype
    b, t, d = x.shape
    d_in, h, hd = mlstm_dims(cfg)
    up = x.astype(cdtype) @ p["up"].astype(cdtype)
    xp, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_g, logf = _mlstm_qkvg(p, xp, cfg, b, t)
    to_h = lambda a: a.reshape(b, t, h, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    q, k, v = to_h(q) * hd ** -0.5, to_h(k), to_h(v)
    v = v * i_g.transpose(0, 2, 1)[..., None]                  # input gate
    ones = jnp.ones_like(v[..., :1])
    v_aug = jnp.concatenate([v, ones], axis=-1)                # normalizer channel
    init = state["ssm"] if state is not None else None
    o_aug, s_fin = chunked_gla(q, k, v_aug, logf.transpose(0, 2, 1),
                               min(cfg.ssm_chunk, t), init)
    o, denom = o_aug[..., :hd], o_aug[..., hd:]
    o = o / jnp.maximum(jnp.abs(denom), 1.0)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d_in).astype(cdtype)
    o = rms_norm(o, p["norm_w"], cfg.norm_eps) * jax.nn.silu(z)
    return o @ p["down"].astype(cdtype), {"ssm": s_fin}


def mlstm_decode(p, x: jnp.ndarray, cfg: ModelConfig,
                 state: Dict[str, jnp.ndarray]):
    cdtype = cfg.compute_dtype
    b, d = x.shape
    d_in, h, hd = mlstm_dims(cfg)
    up = x.astype(cdtype) @ p["up"].astype(cdtype)
    xp, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_g, logf = _mlstm_qkvg(p, xp, cfg, b, None)
    to_h = lambda a: a.reshape(b, h, hd).astype(jnp.float32)
    q, k, v = to_h(q) * hd ** -0.5, to_h(k), to_h(v)
    v = v * i_g[..., None]
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    o_aug, s_new = gla_step(q, k, v_aug, logf, state["ssm"])
    o, denom = o_aug[..., :hd], o_aug[..., hd:]
    o = (o / jnp.maximum(jnp.abs(denom), 1.0)).reshape(b, d_in).astype(cdtype)
    o = rms_norm(o, p["norm_w"], cfg.norm_eps) * jax.nn.silu(z)
    return o @ p["down"].astype(cdtype), {"ssm": s_new}


def mlstm_state_shapes(cfg: ModelConfig, batch: int):
    d_in, h, hd = mlstm_dims(cfg)
    return {"ssm": jax.ShapeDtypeStruct((batch, h, hd, hd + 1), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM block (sequential, exponential gating with stabilizer — xLSTM eq. 14-24)
# ---------------------------------------------------------------------------


def slstm_init(key, cfg: ModelConfig, dtype=None) -> Dict[str, jnp.ndarray]:
    dtype = dtype or cfg.param_dtype
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wx": init_dense(k1, (d, 4 * d), dtype),               # i, f, z, o preacts
        "r": init_dense(k2, (h, hd, 4 * hd), dtype, scale=hd ** -0.5),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "out": init_dense(k3, (d, d), dtype),
        "norm_w": jnp.ones((d,), jnp.float32),
    }


def _slstm_cell(gates, c, n, m, hprev_unused=None):
    """gates: (B, H, hd, 4) fp32 preactivations -> new (c, n, m, h)."""
    ig, fg, zg, og = gates[..., 0], gates[..., 1], gates[..., 2], gates[..., 3]
    log_i = ig                                      # exponential input gate
    log_f = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(log_f + m, log_i)           # stabilizer state
    c_new = jnp.exp(log_f + m - m_new) * c + jnp.exp(log_i - m_new) * jnp.tanh(zg)
    n_new = jnp.exp(log_f + m - m_new) * n + jnp.exp(log_i - m_new)
    h = jax.nn.sigmoid(og) * c_new / jnp.maximum(n_new, 1.0)
    return c_new, n_new, m_new, h


def slstm_apply(p, x: jnp.ndarray, cfg: ModelConfig,
                state: Dict[str, jnp.ndarray] | None = None):
    cdtype = cfg.compute_dtype
    b, t, d = x.shape
    h_heads = cfg.n_heads
    hd = d // h_heads
    wx = (x.astype(cdtype) @ p["wx"].astype(cdtype)).astype(jnp.float32) + p["b"]
    wx = wx.reshape(b, t, h_heads, 4, hd).transpose(1, 0, 2, 4, 3)  # (T,B,H,hd,4)
    r = p["r"].astype(jnp.float32)                   # (H, hd, 4hd)

    if state is None:
        zeros = jnp.zeros((b, h_heads, hd), jnp.float32)
        init = (zeros, zeros, zeros - 1e30, zeros)
    else:
        init = (state["c"], state["n"], state["m"], state["h"])

    def step(carry, wx_t):
        c, n, m, h_prev = carry
        rec = jnp.einsum("bhd,hdk->bhk", h_prev, r).reshape(b, h_heads, hd, 4)
        c, n, m, h = _slstm_cell(wx_t + rec, c, n, m)
        return (c, n, m, h), h

    (c, n, m, h_last), hs = jax.lax.scan(step, init, wx)
    hs = hs.transpose(1, 0, 2, 3).reshape(b, t, d).astype(cdtype)
    hs = rms_norm(hs, p["norm_w"], cfg.norm_eps)
    out = hs @ p["out"].astype(cdtype)
    return out, {"c": c, "n": n, "m": m, "h": h_last}


def slstm_decode(p, x: jnp.ndarray, cfg: ModelConfig,
                 state: Dict[str, jnp.ndarray]):
    out, st = slstm_apply(p, x[:, None, :], cfg, state)
    return out[:, 0], st


def slstm_state_shapes(cfg: ModelConfig, batch: int):
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    s = jax.ShapeDtypeStruct((batch, h, hd), jnp.float32)
    return {"c": s, "n": s, "m": s, "h": s}
