"""Sharded-vs-single-device parity harness for the mesh-sharded BAD engine.

``ShardedBADEngine`` partitions the subscription population over N
device-local engines (channels and the data plane replicate; subscriptions
hash-partition by global sID). The contract these tests pin down: sharding
is a PHYSICAL layout choice — the delivered notification content must be
bit-identical to a single-device engine running the same seeded workload.

Parity is asserted on partition-INdependent observables:

  * the delivered sID multiset (end-subscriber notifications) — always;
  * the delivered (row_id, sID) pair multiset expanded from the payload
    wire lines — whenever no churn lands while entries are ring-resident.
    Under churn + sustained overflow, ring entries whose group epoch moved
    go stale and DROP at re-presentation (pairs re-group; sIDs never go
    stale), so there the capped engines' pair multiset is checked as a
    sub-multiset of the oracle's instead.

Aggregate counts that depend on the grouping itself (``num_results`` — the
same content chops into more, smaller groups under partitioning) are
deliberately NOT compared; ``num_notified`` (produced member sIDs) is
partition-independent and is.

Everything multi-device runs under the conftest-forced
``--xla_force_host_platform_device_count`` host device count and skips
cleanly when the flag could not take effect.
"""
import collections

import numpy as np
import pytest

from repro.core import plans
from repro.core.broker import payload_notifications
from repro.core.channel import tweets_about_crime, tweets_about_drugs
from repro.core.churn import ChurnWorkload, run_ticks
from repro.core.engine import BADEngine
from repro.core.plans import ChannelPlan, ExecutionFlags
from repro.core.sharded import ShardedBADEngine
from repro.distributed import collectives, partition

from conftest import check_delivery_conservation, make_tweets

FLAGS = ExecutionFlags(scan_mode="window", aggregation=True,
                       param_pushdown=True)
PW = 8    # engine default deliver_payload_words

# generous delivery caps: the plan-matrix tests run overflow-free so pair
# content parity is exact (nothing rings, nothing can go stale)
MATRIX_CAPS = dict(dataset_capacity=4096, index_capacity=1024,
                   max_window=1024, max_candidates=512,
                   brokers=("B1", "B2"), group_cap=8,
                   max_deliver_pairs=1 << 12, max_notify=1 << 14,
                   ring_capacity=1 << 10)

# tight per-shard caps: the churn fuzz runs in sustained overflow so the
# ring/spill/drain machinery is exercised on every shard
OVERFLOW_CAPS = dict(dataset_capacity=8192, index_capacity=1024,
                     max_window=2048, max_candidates=512,
                     brokers=("B1", "B2"), group_cap=8,
                     max_deliver_pairs=24, max_notify=48, ring_capacity=256,
                     max_spill=2048, spill_capacity=1 << 15)


def _delivered(rep):
    """Per-tick delivered content from the per-shard debug buffers:
    ((row, sid) pair list, sid list)."""
    pair_rows, sids = [], []
    for r in rep.per_shard:
        o = r.overflow
        pair_rows += [tuple(x) for x in payload_notifications(
            r.payload, o.delivered_pairs, PW).tolist()]
        sids += np.asarray(r.notify)[:o.delivered_sids].tolist()
    return pair_rows, sids


def _drain_content(drain_reports, pair_rows, sids, allow_drops=False):
    """Fold DrainReport content (and assert exactly-once: no drops unless
    the caller expects staleness)."""
    for dr in drain_reports:
        if not allow_drops:
            assert dr.stats.dropped_pairs == dr.stats.dropped_sids == 0
        if dr.payload is not None and dr.stats.delivered_pairs:
            pair_rows += [tuple(x) for x in payload_notifications(
                dr.payload, dr.stats.delivered_pairs, PW).tolist()]
        if dr.notify is not None and dr.stats.delivered_sids:
            sids += dr.notify[:dr.stats.delivered_sids].tolist()


def _settle(eng):
    """Flush every ring through the spill queues and drain to empty;
    returns the drained ((row, sid) pairs, sids). Settling happens against
    unchanged tables, so nothing may drop."""
    pair_rows, sids = [], []
    eng.flush_rings()
    rounds = 0
    while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
        rounds += 1
        assert rounds < 500, "drain did not converge"
        _drain_content(eng.drain_spilled().values(), pair_rows, sids)
    assert eng.ring_pending_pairs() + eng.ring_pending_sids() == 0
    return pair_rows, sids


# ---------------------------------------------------------------------------
# plan-matrix parity: 4 scan modes x {aggregated, flat} x {padded, compact}
# ---------------------------------------------------------------------------


def _matrix_run(num_shards, plan):
    """The seeded matrix workload: one param channel under ``plan``, one
    spatial channel riding along, 2 delivered ticks, no overflow."""
    rng = np.random.default_rng(5)
    eng = ShardedBADEngine(num_shards=num_shards, **MATRIX_CAPS)
    eng.debug_delivery_buffers = True
    eng.set_user_locations((rng.normal(size=(40, 2)) * 30).astype(np.float32),
                           rng.integers(0, 2, 40))
    eng.create_channel(tweets_about_drugs())
    eng.create_channel(tweets_about_crime(1))
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, 250),
                       rng.integers(0, 2, 250))
    eng.set_plan("TweetsAboutDrugs", plan)
    # the spatial channel shares the scan mode; compact backends are a
    # param-join layout, so it stays on the padded family
    eng.set_plan("TweetsAboutCrime1", ChannelPlan(
        scan_mode=plan.scan_mode,
        backend=plan.backend if plan.backend in ("oracle", "pallas")
        else "oracle"))
    pair_rows, sids, notified = [], [], 0
    for tick in range(2):
        eng.ingest(make_tweets(rng, 150, t0=100 * (tick + 1),
                               match_drugs=0.25))
        reps = eng.execute_all(None, timed=False, deliver=True)
        for name, rep in reps.items():
            o = rep.overflow
            check_delivery_conservation(o, rep.num_results, rep.num_notified)
            assert (o.spilled_pairs + o.dropped_pairs + o.spilled_sids
                    + o.dropped_sids) == 0, (name, o)
            p, s = _delivered(rep)
            pair_rows += [(name,) + t for t in p]
            sids += [(name, x) for x in s]
            notified += rep.num_notified
    return pair_rows, sids, notified


@pytest.mark.multidevice
@pytest.mark.parametrize("backend", ["oracle", "compact"])
@pytest.mark.parametrize("aggregation", [True, False])
@pytest.mark.parametrize("scan_mode", plans.SCAN_MODES)
def test_plan_matrix_parity(scan_mode, aggregation, backend):
    """2-way sharded == single-device, content-exact, for every scan mode x
    layout x {padded, compact} backend — with a spatial channel in the same
    engine to cover the cohort partitioning path."""
    plan = ChannelPlan(scan_mode=scan_mode, aggregation=aggregation,
                       param_pushdown=True, backend=backend)
    p1, s1, n1 = _matrix_run(1, plan)
    p2, s2, n2 = _matrix_run(2, plan)
    assert sorted(p1) == sorted(p2)
    assert sorted(s1) == sorted(s2)
    assert n1 == n2
    assert len(s1) > 0    # the workload actually delivered something


# ---------------------------------------------------------------------------
# churn + sustained-overflow fuzz: N in {1, 2, 4} vs a generous-cap oracle
# ---------------------------------------------------------------------------


def _fuzz_run(num_shards, cap_overrides, reshard_at=None, reshard_to=None):
    """6 churn ticks under sustained overflow, then settle to empty.
    Returns (pair multiset, sid multiset, engine)."""
    rng = np.random.default_rng(11)
    kw = dict(OVERFLOW_CAPS)
    kw.update(cap_overrides)
    eng = ShardedBADEngine(num_shards=num_shards, **kw)
    eng.debug_delivery_buffers = True
    eng.create_channel(tweets_about_drugs())
    live = list(eng.subscribe_bulk("TweetsAboutDrugs",
                                   rng.integers(0, 50, 200),
                                   rng.integers(0, 2, 200)))
    pair_rows, sids = [], []
    for tick in range(6):
        new = eng.subscribe_bulk("TweetsAboutDrugs",
                                 rng.integers(0, 50, 40),
                                 rng.integers(0, 2, 40))
        live += list(new)
        rm = [live.pop(rng.integers(0, len(live))) for _ in range(20)]
        eng.remove_subscriptions("TweetsAboutDrugs", np.asarray(rm))
        eng.ingest(make_tweets(rng, 120, t0=100 * (tick + 1),
                               match_drugs=0.3))
        rep = eng.execute_all(FLAGS, timed=False,
                              deliver=True)["TweetsAboutDrugs"]
        check_delivery_conservation(rep.overflow, rep.num_results,
                                    rep.num_notified)
        p, s = _delivered(rep)
        pair_rows += p
        sids += s
        if reshard_at == tick:
            # mid-stream migration: rings flush + drain against the OLD
            # engines; the drained content stays part of the delivery stream
            _drain_content(eng.reshard(reshard_to).values(), pair_rows, sids)
    p, s = _settle(eng)
    return pair_rows + p, sids + s, eng


@pytest.fixture(scope="module")
def fuzz_oracle():
    """Single-device generous-cap run of the fuzz workload: nothing ever
    overflows, so its delivered content is the ground-truth multiset."""
    pair_rows, sids, eng = _fuzz_run(1, dict(max_deliver_pairs=1 << 13,
                                             max_notify=1 << 15,
                                             ring_capacity=1 << 12))
    assert len(sids) > 500    # the workload is not degenerate
    return pair_rows, sids


@pytest.mark.multidevice
@pytest.mark.parametrize("num_shards", [1, 2, 4])
def test_churn_overflow_fuzz_vs_oracle(num_shards, fuzz_oracle):
    """Capped N-way sharded engines under churn + sustained overflow
    deliver exactly the oracle's sID multiset (notifications are never
    lost, duplicated, or misrouted), conserve per tick, and drain to empty.
    Pair content: a sub-multiset of the oracle's — churned ring-resident
    PAIRS go stale by design (their grouping moved) while their sIDs are
    re-sent; nothing may appear that the oracle did not produce."""
    oracle_pairs, oracle_sids = fuzz_oracle
    pair_rows, sids, eng = _fuzz_run(num_shards, {})
    assert sorted(sids) == sorted(oracle_sids)
    extra = collections.Counter(pair_rows) - collections.Counter(oracle_pairs)
    assert not extra, f"pairs not produced by the oracle: {extra}"
    # everything drained: global conservation closed out
    assert eng.ring_pending_pairs() + eng.ring_pending_sids() == 0
    assert eng.spill.pending_pairs() + eng.spill.pending_sids() == 0


@pytest.mark.multidevice
def test_reshard_ring_flush_conservation(fuzz_oracle):
    """Resharding 2 -> 4 mid-stream (rings populated) loses nothing: the
    flush-drain-migrate protocol keeps the delivered sID multiset exactly
    equal to the oracle's, and the re-partitioned live population matches
    the host registry shard-by-shard."""
    oracle_pairs, oracle_sids = fuzz_oracle
    pair_rows, sids, eng = _fuzz_run(2, {}, reshard_at=2, reshard_to=4)
    assert eng.num_shards == 4
    assert sorted(sids) == sorted(oracle_sids)
    extra = collections.Counter(pair_rows) - collections.Counter(oracle_pairs)
    assert not extra
    # re-partition dropped no live subscription: the union of the shards'
    # aggregator-held sIDs is the registry population, each on its hash shard
    live = eng.live_sids("TweetsAboutDrugs")
    per_shard = eng.shard_live_sids("TweetsAboutDrugs")
    got = np.sort(np.concatenate(per_shard)) if per_shard else live[:0]
    np.testing.assert_array_equal(got, live)
    owner = partition.shard_for_sids(live, 4)
    for i, shard_sids in enumerate(per_shard):
        np.testing.assert_array_equal(shard_sids, np.sort(live[owner == i]))


# ---------------------------------------------------------------------------
# steady state: zero retraces per shard
# ---------------------------------------------------------------------------


@pytest.mark.multidevice
def test_zero_steady_state_retraces_per_shard():
    """After warmup, steady churned ticks patch device state in place on
    every shard: per-shard traces and rebuilds stay flat while patches
    advance (the epoch/delta protocol survives the sharded control plane)."""
    rng = np.random.default_rng(9)
    eng = ShardedBADEngine(num_shards=4, **MATRIX_CAPS)
    eng.create_channel(tweets_about_drugs())
    live = list(eng.subscribe_bulk("TweetsAboutDrugs",
                                   rng.integers(0, 50, 300),
                                   rng.integers(0, 2, 300)))
    def churn_tick(tick):
        new = eng.subscribe_bulk("TweetsAboutDrugs",
                                 rng.integers(0, 50, 32),
                                 rng.integers(0, 2, 32))
        live.extend(new)
        rm = [live.pop(rng.integers(0, len(live))) for _ in range(32)]
        eng.remove_subscriptions("TweetsAboutDrugs", np.asarray(rm))
        eng.ingest(make_tweets(rng, 100, t0=1000 * (tick + 1),
                               match_drugs=0.25))
        eng.execute_all(FLAGS, timed=False, deliver=True)

    for tick in range(2):    # churned warmup: traces + first capacity sizing
        churn_tick(tick)
    snaps = eng.per_shard_maintenance()
    for tick in range(2, 6):
        churn_tick(tick)
    deltas = [e.maintenance.since(s)
              for e, s in zip(eng.shards, snaps)]
    assert [d.traces for d in deltas] == [0] * 4
    assert [d.rebuilds for d in deltas] == [0] * 4
    assert sum(d.patches for d in deltas) > 0


# ---------------------------------------------------------------------------
# cross-shard notification routing (the collective shuffle)
# ---------------------------------------------------------------------------


@pytest.mark.multidevice
def test_shuffle_notify_matches_ref(multidevice):
    """The shard_map all-gather shuffle is bit-identical to the host
    reference on random -1-padded buffers, and every routed sID lands on
    the shard that owns it."""
    rng = np.random.default_rng(21)
    mesh = collectives.notify_mesh(4)
    assert mesh is not None
    for trial in range(5):
        sids = rng.integers(0, 1000, (4, 24)).astype(np.int32)
        sids[rng.random((4, 24)) < 0.4] = -1
        owners = np.where(sids >= 0,
                          rng.integers(0, 4, (4, 24)), -1).astype(np.int32)
        got = np.asarray(collectives.shuffle_notify(mesh, sids, owners))
        want = collectives.shuffle_notify_ref(sids, owners, 4)
        np.testing.assert_array_equal(got, want)
        by_owner = {o: sids[(owners == o) & (sids >= 0)]
                    for o in range(4)}
        for o in range(4):
            row = got[o][got[o] >= 0]
            assert sorted(row.tolist()) == sorted(by_owner[o].tolist())


@pytest.mark.multidevice
def test_routed_delivery_preserves_sids():
    """With ``route_cross_shard`` on, each tick's routed buffers hold
    exactly the delivered sID multiset, grouped onto broker-owner shards
    (row o only carries sIDs whose broker endpoint shard is o)."""
    rng = np.random.default_rng(13)
    eng = ShardedBADEngine(num_shards=4, route_cross_shard=True,
                           **MATRIX_CAPS)
    eng.create_channel(tweets_about_drugs())
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, 300),
                       rng.integers(0, 2, 300))
    total = 0
    for tick in range(2):
        eng.ingest(make_tweets(rng, 150, t0=100 * (tick + 1),
                               match_drugs=0.3))
        rep = eng.execute_all(FLAGS, timed=False,
                              deliver=True)["TweetsAboutDrugs"]
        assert rep.routed is not None
        assert rep.routed.shape[0] == 4
        _, sids = _delivered(rep)
        routed = rep.routed[rep.routed >= 0]
        assert sorted(routed.tolist()) == sorted(sids)
        brokers = eng._reg["TweetsAboutDrugs"].brokers
        for o in range(4):
            row = rep.routed[o][rep.routed[o] >= 0]
            if row.size:
                owners = partition.broker_owner(brokers[row], 4)
                assert (owners == o).all()
        total += len(sids)
    assert total > 0


# ---------------------------------------------------------------------------
# facade anchors (device-count independent)
# ---------------------------------------------------------------------------


def test_facade_matches_plain_engine():
    """num_shards=1 facade == plain BADEngine, buffer-exact: the sharded
    control plane adds global sID allocation and nothing else."""
    def drive(eng):
        rng = np.random.default_rng(17)
        eng.debug_delivery_buffers = True
        eng.create_channel(tweets_about_drugs())
        eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, 120),
                           rng.integers(0, 2, 120))
        out = []
        for tick in range(2):
            eng.ingest(make_tweets(rng, 100, t0=100 * (tick + 1),
                                   match_drugs=0.25))
            out.append(eng.execute_all(FLAGS, timed=False,
                                       deliver=True)["TweetsAboutDrugs"])
        return out
    plain = drive(BADEngine(**MATRIX_CAPS))
    facade = drive(ShardedBADEngine(num_shards=1, **MATRIX_CAPS))
    for p, f in zip(plain, facade):
        s = f.per_shard[0]
        assert f.num_results == p.num_results
        assert f.num_notified == p.num_notified
        assert f.overflow == p.overflow
        np.testing.assert_array_equal(np.asarray(s.payload),
                                      np.asarray(p.payload))
        np.testing.assert_array_equal(np.asarray(s.notify),
                                      np.asarray(p.notify))


@pytest.mark.multidevice
def test_drop_channel_leaves_other_partitions_intact():
    """Dropping one channel leaves the other channel's partitioned
    population untouched (registry == union of shard aggregators, each on
    its hash shard), and the dropped name can be re-created and
    re-subscribed."""
    rng = np.random.default_rng(23)
    eng = ShardedBADEngine(num_shards=4, **MATRIX_CAPS)
    eng.create_channel(tweets_about_drugs())
    eng.create_channel(tweets_about_crime(1))
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, 200),
                       rng.integers(0, 2, 200))
    crime = eng.subscribe_bulk("TweetsAboutCrime1",
                               rng.integers(0, 50, 100),
                               rng.integers(0, 2, 100))
    eng.remove_subscriptions("TweetsAboutCrime1", crime[:40])
    before = eng.live_sids("TweetsAboutCrime1")
    eng.drop_channel("TweetsAboutDrugs")
    np.testing.assert_array_equal(eng.live_sids("TweetsAboutCrime1"), before)
    per_shard = eng.shard_live_sids("TweetsAboutCrime1")
    np.testing.assert_array_equal(np.sort(np.concatenate(per_shard)), before)
    owner = partition.shard_for_sids(before, 4)
    for i, shard_sids in enumerate(per_shard):
        np.testing.assert_array_equal(shard_sids, np.sort(before[owner == i]))
    # the dropped name is reusable; execution still runs on the survivor
    eng.create_channel(tweets_about_drugs())
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, 50),
                       rng.integers(0, 2, 50))
    eng.ingest(make_tweets(rng, 80, t0=500, match_drugs=0.3))
    reps = eng.execute_all(FLAGS, timed=False, deliver=True)
    assert set(reps) == {"TweetsAboutDrugs", "TweetsAboutCrime1"}


@pytest.mark.multidevice
def test_churn_driver_through_facade():
    """The sustained-churn driver runs unmodified against the sharded
    facade (capped, so the ring/spill path is live) and loses nothing."""
    rng = np.random.default_rng(3)
    eng = ShardedBADEngine(num_shards=4, **OVERFLOW_CAPS)
    eng.create_channel(tweets_about_drugs())
    wl = [ChurnWorkload("TweetsAboutDrugs", adds_per_tick=64,
                        removes_per_tick=32)]
    rep = run_ticks(
        eng, wl, 5, rng, flags=FLAGS, deliver=True, ingest_per_tick=64,
        make_batch=lambda rr, n, t0: make_tweets(rr, n, t0=t0,
                                                 match_drugs=0.3),
        warmup=2)
    assert rep.adds > 0 and rep.removes > 0
    assert rep.delivered_sids > 0
    assert rep.subs_per_s > 0
