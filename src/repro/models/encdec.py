"""Encoder-decoder transformer (seamless-m4t backbone).

Encoder: bidirectional dense superlayers over precomputed frame embeddings
(the audio frontend is a stub per the task spec). Decoder: causal self-attn +
cross-attn + SwiGLU MLP, scanned, with self KV caches and precomputed
per-layer cross K/V for serving.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.partition import shard
from repro.models import attention
from repro.models.config import ModelConfig
from repro.models.kvcache import kv_cache_shapes
from repro.models.layers import init_dense, mlp_apply, mlp_init, rms_norm, rope_frequencies


def _enc_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": attention.attn_init(k1, cfg),
            "norm2": jnp.ones((cfg.d_model,), jnp.float32),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.param_dtype)}


def _dec_layer_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
            "self_attn": attention.attn_init(k1, cfg),
            "norm_c": jnp.ones((cfg.d_model,), jnp.float32),
            "cross_attn": attention.attn_init(k2, cfg),
            "norm2": jnp.ones((cfg.d_model,), jnp.float32),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.param_dtype)}


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.superlayer_repeat)
    return {
        "embed": init_dense(ks[2], (cfg.padded_vocab, cfg.d_model),
                            cfg.param_dtype, scale=1.0),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "head": init_dense(ks[3], (cfg.d_model, cfg.padded_vocab), cfg.param_dtype),
    }


def encode(params, cfg: ModelConfig, embeds: jnp.ndarray) -> jnp.ndarray:
    x = shard(embeds.astype(cfg.compute_dtype), "act_btd")
    cos, sin = rope_frequencies(cfg.resolved_head_dim, x.shape[1], cfg.rope_theta)

    def body(h, p):
        a = attention.attn_apply(p["attn"], rms_norm(h, p["norm1"], cfg.norm_eps),
                                 cfg, cos, sin, causal=False)
        h = shard(h + a, "act_btd")
        m = mlp_apply(p["mlp"], rms_norm(h, p["norm2"], cfg.norm_eps),
                      cfg.compute_dtype)
        return shard(h + m, "act_btd"), ()

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(p, enc_out, cfg: ModelConfig):
    """Project encoder memory to this layer's cross K/V (B, KH, Se, hd)."""
    cdtype = cfg.compute_dtype
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ p["wk"].astype(cdtype)).reshape(b, s, cfg.n_kv_heads, hd)
    v = (enc_out @ p["wv"].astype(cdtype)).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(cdtype).reshape(cfg.n_kv_heads, hd)
        v = v + p["bv"].astype(cdtype).reshape(cfg.n_kv_heads, hd)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def _dec_layer(p, x, cfg, cos, sin, enc_out):
    a = attention.attn_apply(p["self_attn"], rms_norm(x, p["norm1"], cfg.norm_eps),
                             cfg, cos, sin, causal=True)
    x = shard(x + a, "act_btd")
    kv = _cross_kv(p["cross_attn"], enc_out, cfg)
    c = attention.attn_apply(p["cross_attn"], rms_norm(x, p["norm_c"], cfg.norm_eps),
                             cfg, cos, sin, causal=False, kv_override=kv)
    x = shard(x + c, "act_btd")
    m = mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg.compute_dtype)
    return shard(x + m, "act_btd")


def forward(params, cfg: ModelConfig, src_embeds: jnp.ndarray,
            tgt_tokens: jnp.ndarray) -> jnp.ndarray:
    enc_out = encode(params, cfg, src_embeds)
    x = shard(params["embed"][tgt_tokens].astype(cfg.compute_dtype), "act_btd")
    cos, sin = rope_frequencies(cfg.resolved_head_dim, x.shape[1], cfg.rope_theta)

    def body(h, p):
        return _dec_layer(p, h, cfg, cos, sin, enc_out), ()

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return shard(x @ params["head"].astype(cfg.compute_dtype), "act_btv")


def loss_fn(params, cfg: ModelConfig, batch):
    logits = forward(params, cfg, batch["embeds"], batch["tokens"]).astype(jnp.float32)
    labels = batch["labels"]
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - tgt)
    return loss, {"loss": loss, "aux": jnp.zeros((), jnp.float32),
                  "ntokens": jnp.asarray(labels.size, jnp.float32)}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, src_embeds: jnp.ndarray,
            tgt_tokens: jnp.ndarray, max_len: int):
    """Encode + decoder prefill. Returns (logits (B,V), caches, pos)."""
    enc_out = encode(params, cfg, src_embeds)
    x = shard(params["embed"][tgt_tokens].astype(cfg.compute_dtype), "act_btd")
    b, s, _ = x.shape
    cos, sin = rope_frequencies(cfg.resolved_head_dim, s, cfg.rope_theta)

    def body(h, p):
        a, self_kv = attention.attn_prefill(
            p["self_attn"], rms_norm(h, p["norm1"], cfg.norm_eps), cfg, cos, sin)
        h = shard(h + a, "act_btd")
        ck, cv = _cross_kv(p["cross_attn"], enc_out, cfg)
        c = attention.attn_apply(p["cross_attn"],
                                 rms_norm(h, p["norm_c"], cfg.norm_eps),
                                 cfg, cos, sin, causal=False, kv_override=(ck, cv))
        h = shard(h + c, "act_btd")
        m = mlp_apply(p["mlp"], rms_norm(h, p["norm2"], cfg.norm_eps),
                      cfg.compute_dtype)
        pad = max_len - s
        cache = {
            "k": jnp.pad(self_kv["k"], ((0, 0), (0, 0), (0, pad), (0, 0))),
            "v": jnp.pad(self_kv["v"], ((0, 0), (0, 0), (0, pad), (0, 0))),
            "ck": ck, "cv": cv,
        }
        return shard(h + m, "act_btd"), cache

    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = (x @ params["head"].astype(cfg.compute_dtype))[:, 0, :cfg.vocab_size]
    return logits, caches, jnp.asarray(s, jnp.int32)


def decode_step(params, cfg: ModelConfig, caches, pos, token):
    from repro.kernels.flash_decode import ref as fd_ref

    x = shard(params["embed"][token].astype(cfg.compute_dtype), "act_bd")
    b = x.shape[0]
    max_pos = caches["k"].shape[3] if isinstance(caches, dict) else None
    # caches is a stacked dict from prefill: {'k','v','ck','cv'} each (R, ...)
    max_pos = caches["k"].shape[3]
    cos, sin = rope_frequencies(cfg.resolved_head_dim, max_pos, cfg.rope_theta)
    kv_len = jnp.full((b,), pos + 1, jnp.int32)
    enc_len = jnp.full((b,), caches["ck"].shape[3], jnp.int32)

    def body(h, xs):
        p, cache = xs
        a, new_kv = attention.attn_decode(
            p["self_attn"], rms_norm(h, p["norm1"], cfg.norm_eps), cfg, cos, sin,
            {"k": cache["k"], "v": cache["v"]}, pos, kv_len)
        h = h + a
        # cross attention against fixed encoder memory
        hq = rms_norm(h, p["norm_c"], cfg.norm_eps)
        q = (hq @ p["cross_attn"]["wq"].astype(cfg.compute_dtype))
        if cfg.qkv_bias:
            q = q + p["cross_attn"]["bq"].astype(cfg.compute_dtype)
        q = q.reshape(b, cfg.n_heads, cfg.resolved_head_dim)
        c = fd_ref.decode_attention(q, cache["ck"], cache["cv"], enc_len)
        c = c.reshape(b, -1) @ p["cross_attn"]["wo"].astype(cfg.compute_dtype)
        h = h + c
        m = mlp_apply(p["mlp"], rms_norm(h, p["norm2"], cfg.norm_eps),
                      cfg.compute_dtype)
        return h + m, {"k": new_kv["k"], "v": new_kv["v"],
                       "ck": cache["ck"], "cv": cache["cv"]}

    x, new_caches = jax.lax.scan(body, x, (params["dec_layers"], caches))
    x = rms_norm(x[:, None], params["final_norm"], cfg.norm_eps)
    logits = (x @ params["head"].astype(cfg.compute_dtype))[:, 0, :cfg.vocab_size]
    return logits, new_caches


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    self_kv = kv_cache_shapes(batch, cfg.n_kv_heads, max_len,
                              cfg.resolved_head_dim, cfg.compute_dtype)
    cross = kv_cache_shapes(batch, cfg.n_kv_heads, enc_len,
                            cfg.resolved_head_dim, cfg.compute_dtype)
    shapes = {"k": self_kv["k"], "v": self_kv["v"],
              "ck": cross["k"], "cv": cross["v"]}
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.superlayer_repeat,) + s.shape, s.dtype),
        shapes)
