"""Distribution layer: sharding rules, sanitization, pipeline, mesh, dryrun
machinery on a tiny host mesh (1 CPU device -> (1,1) mesh; the 512-device
production mesh is exercised by launch/dryrun.py in a subprocess)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.partition import make_rules, sanitize_spec, use_rules
from repro.distributed.pipeline import bubble_fraction, pipeline_forward
from repro.launch.mesh import make_host_mesh, make_mesh


def test_sanitize_spec_divisibility():
    mesh = make_mesh((1, 1), ("data", "model"))
    # axis missing from mesh is dropped
    s = sanitize_spec(P(("pod", "data"), "model"), (8, 8), mesh)
    assert s == P("data", "model")
    # non-divisible dim drops the axis (simulated by size-1 mesh w/ dim 7 ok)
    s = sanitize_spec(P("data", None), (7, 3), mesh)
    assert s == P("data", None)   # 7 % 1 == 0
    # spec longer than rank truncates
    s = sanitize_spec(P("data", None, "model"), (4, 4), mesh)
    assert s == P("data", None)


def test_sanitize_spec_nondivisible_real():
    import os
    # verified against a >1-way mesh in the dryrun subprocess test below;
    # here check the arithmetic path directly with a fake mesh mapping
    class FakeMesh:
        shape = {"data": 4, "model": 2}
    s = sanitize_spec(P("data", "model"), (6, 6), FakeMesh)
    assert s == P(None, "model")    # 6 % 4 != 0 -> drop; 6 % 2 == 0 -> keep
    s = sanitize_spec(P(("data", "model"), None), (8, 8), FakeMesh)
    assert s == P(("data", "model"), None)


def test_rules_seq_shard_alias():
    mesh = make_host_mesh()
    r = make_rules(mesh, seq_shard=True)
    assert r.table["act_btd"] == r.table["act_btd_sp"]
    r2 = make_rules(mesh, seq_shard=False)
    assert r2.table["act_btd"] != r2.table["act_btd_sp"]


def test_shard_noop_without_rules():
    from repro.distributed.partition import shard
    x = jnp.ones((4, 4))
    assert shard(x, "act_btd") is x


def test_pipeline_forward_matches_sequential(rng):
    """GPipe shard_map pipeline == sequential stage application ((1,) axis)."""
    mesh = make_mesh((1,), ("pod",))
    w = jnp.asarray(rng.normal(size=(1, 8, 8)), jnp.float32)  # 1 stage

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    run = pipeline_forward(mesh, "pod", lambda p, x: stage_fn(p, x), 4)
    xs = jnp.asarray(rng.normal(size=(4, 2, 8)), jnp.float32)
    got = run({"w": w}, xs)
    want = jnp.stack([stage_fn({"w": w[0]}, xs[i]) for i in range(4)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(2, 2) - 1 / 3) < 1e-9


def test_sp_decode_attention_host_mesh(rng):
    """Sequence-parallel flash-decode on the host mesh == reference."""
    from repro.distributed.collectives import sp_decode_attention
    from repro.kernels.flash_decode import ref as fd_ref
    mesh = make_host_mesh(model_parallel=jax.device_count())
    rules = make_rules(mesh)
    b, h, kh, s, d = 2, 4, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, kh, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, kh, s, d)), jnp.float32)
    kv_len = jnp.asarray([50, 9], jnp.int32)
    want = fd_ref.decode_attention(q, k, v, kv_len)
    got = sp_decode_attention(rules, q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.slow
def test_dryrun_smallest_cell_subprocess():
    """The production-mesh dry-run itself (512 fake devices) in a subprocess."""
    import os
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-125m",
         "--shape", "decode_32k", "--out", "/tmp/dryrun_test",
         "--skip-probes"],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
