from repro.optim.adafactor import Adafactor, AdafactorState
from repro.optim.adamw import AdamW, AdamWState
from repro.optim.schedule import constant, warmup_cosine

__all__ = ["Adafactor", "AdafactorState", "AdamW", "AdamWState",
           "constant", "warmup_cosine", "make_optimizer"]


def make_optimizer(name: str, lr=None, **kw):
    lr = lr or constant(3e-4)
    if name == "adamw":
        return AdamW(lr=lr, **kw)
    if name == "adafactor":
        return Adafactor(lr=lr, **kw)
    raise ValueError(name)
