"""GQA attention: train/prefill (causal full-seq) and cached decode paths."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partition import active_rules, shard


def _shard_attn(q, k, v, cfg: ModelConfig):
    """Head-parallel attention when heads divide the model axis; otherwise
    context-parallel (q seq dim over `model`, GQA KV broadcast) — archs like
    qwen2 (12/28 heads vs a 16-way axis) would silently replicate every head
    per device under plain head sharding."""
    rules = active_rules()
    if rules is None or rules.model_axis is None:
        return q, k, v
    m = rules.mesh.shape[rules.model_axis]
    if cfg.n_heads % m == 0:
        return (shard(q, "act_bhtd"), shard(k, "act_bhtd"),
                shard(v, "act_bhtd"))
    # KV stays batch-sharded; only the model axis is replicated (GQA KV is
    # small). "kv_prefill" = P(batch, None, None, None).
    return (shard(q, "act_bhtd_cp"), shard(k, "kv_prefill"),
            shard(v, "kv_prefill"))
from repro.models import kvcache
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, init_dense


def attn_init(key, cfg: ModelConfig, dtype=None) -> Dict[str, jnp.ndarray]:
    dtype = dtype or cfg.param_dtype
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": init_dense(kq, (d, cfg.n_heads * hd), dtype),
        "wk": init_dense(kk, (d, cfg.n_kv_heads * hd), dtype),
        "wv": init_dense(kv, (d, cfg.n_kv_heads * hd), dtype),
        "wo": init_dense(ko, (cfg.n_heads * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig, cdtype):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"].astype(cdtype)
    k = x @ p["wk"].astype(cdtype)
    v = x @ p["wv"].astype(cdtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdtype)
        k = k + p["bk"].astype(cdtype)
        v = v + p["bv"].astype(cdtype)
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    return q, k, v


CHUNKED_ATTN_THRESHOLD = 8192   # S >= this uses the no-S^2-buffer path
CHUNK_KV = 1024


def _chunked_sdpa(q, k, v, causal: bool) -> jnp.ndarray:
    """Online-softmax attention over unrolled KV chunks (XLA 'flash').

    Long-context prefill cannot materialize the (S, S) logits tensor
    (32k x 32k fp32 is ~4 GiB per head-batch slice); this computes the same
    result with only a (B, H, S, CHUNK) tile live at a time. The chunk loop
    is unrolled (static) so HLO cost analysis counts every chunk — required
    by the dry-run accounting. Forward-only paths (prefill) use this.
    """
    b, h, s, d = q.shape
    kh = k.shape[1]
    g = h // kh
    scale = d ** -0.5
    qf = q * jnp.asarray(scale, q.dtype)   # bf16 operands, f32 accumulation
    n_chunks = -(-s // CHUNK_KV)
    m = jnp.full((b, h, s, 1), -jnp.inf, jnp.float32)
    l = jnp.zeros((b, h, s, 1), jnp.float32)
    acc = jnp.zeros((b, h, s, d), jnp.float32)
    qpos = jnp.arange(s)[:, None]
    for c in range(n_chunks):
        # Chain chunk INPUTS through the barrier: otherwise every chunk's
        # (B,H,S,CHUNK) logits dot is independent and the scheduler keeps
        # all of them alive at once (S^2-equivalent peak memory).
        m, l, acc, k, v = jax.lax.optimization_barrier((m, l, acc, k, v))
        lo = c * CHUNK_KV
        hi = min(s, lo + CHUNK_KV)
        kc = jnp.repeat(k[:, :, lo:hi], g, axis=1)
        vc = jnp.repeat(v[:, :, lo:hi], g, axis=1)
        sc = jnp.einsum("bhqd,bhld->bhql", qf, kc,
                        preferred_element_type=jnp.float32)
        if causal:
            kpos = jnp.arange(lo, hi)[None, :]
            sc = jnp.where(kpos <= qpos, sc, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(sc - m_safe)
        p = jnp.where(jnp.isfinite(sc), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum("bhql,bhld->bhqd",
                                      p.astype(vc.dtype), vc,
                                      preferred_element_type=jnp.float32)
        m = m_new
    out = acc / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype)


def _sdpa(q, k, v, cfg: ModelConfig, causal: bool) -> jnp.ndarray:
    """Dispatch on cfg.attn_impl: einsum reference or Pallas flash kernel."""
    if cfg.attn_impl == "flash":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal)
    # "ref_full" pins the S^2-materializing einsum path (baseline A/B).
    if cfg.attn_impl != "ref_full" and q.shape[2] >= CHUNKED_ATTN_THRESHOLD:
        return _chunked_sdpa(q, k, v, causal)
    from repro.kernels.flash_attention import ref as fa_ref
    return fa_ref.flash_attention(q, k, v, causal=causal)


def attn_apply(p, x: jnp.ndarray, cfg: ModelConfig, cos, sin,
               causal: bool = True,
               kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None
               ) -> jnp.ndarray:
    """Full-sequence attention (training / prefill / encoder / cross)."""
    cdtype = cfg.compute_dtype
    x = x.astype(cdtype)
    q, k, v = _project_qkv(p, x, cfg, cdtype)
    if kv_override is not None:
        k, v = kv_override                       # cross-attention
    else:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q, k, v = _shard_attn(q, k, v, cfg)
    out = _sdpa(q, k, v, cfg, causal)
    b, s = x.shape[:2]
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return out @ p["wo"].astype(cdtype)


def attn_prefill(p, x: jnp.ndarray, cfg: ModelConfig, cos, sin
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Causal attention that also returns the K/V for the cache."""
    cdtype = cfg.compute_dtype
    x = x.astype(cdtype)
    q, k, v = _project_qkv(p, x, cfg, cdtype)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q, k, v = _shard_attn(q, k, v, cfg)
    out = _sdpa(q, k, v, cfg, causal=True)
    b, s = x.shape[:2]
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return out @ p["wo"].astype(cdtype), {"k": k, "v": v}


def attn_decode(p, x: jnp.ndarray, cfg: ModelConfig, cos, sin,
                cache: Dict[str, jnp.ndarray], pos: jnp.ndarray,
                kv_len: jnp.ndarray
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode with cache update.

    x (B, D); pos () int32 write position; kv_len (B,) live lengths (after
    this token). Uses the sequence-parallel flash-decode collective when a
    mesh is active.
    """
    cdtype = cfg.compute_dtype
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    x1 = x[:, None, :].astype(cdtype)            # (B, 1, D)
    q, k, v = _project_qkv(p, x1, cfg, cdtype)
    positions = jnp.broadcast_to(pos, (b, 1))
    q = apply_rope(q, cos, sin, positions[:, None].repeat(cfg.n_heads, 1))
    k = apply_rope(k, cos, sin, positions[:, None].repeat(cfg.n_kv_heads, 1))
    cache = kvcache.update_kv(cache, k, v, pos)
    cache = {"k": shard(cache["k"], "kv_cache"), "v": shard(cache["v"], "kv_cache")}
    q1 = q[:, :, 0]                               # (B, H, hd)
    rules = active_rules()
    if rules is not None and rules.model_axis is not None \
            and cache["k"].shape[2] % rules.mesh.shape[rules.model_axis] == 0:
        from repro.distributed.collectives import sp_decode_attention
        out = sp_decode_attention(rules, q1, cache["k"], cache["v"], kv_len)
    else:
        from repro.kernels.flash_decode import ops as fd_ops
        from repro.kernels.flash_decode import ref as fd_ref
        if cfg.attn_impl == "flash":
            out = fd_ops.decode_attention(q1, cache["k"], cache["v"], kv_len)
        else:
            out = fd_ref.decode_attention(q1, cache["k"], cache["v"], kv_len)
    out = out.reshape(b, -1)
    return out @ p["wo"].astype(cdtype), cache
