"""Multi-channel scaling: vectorized control plane + fused execution.

Measurements the single-channel figures cannot show:

  control plane -- 100k-subscription bulk load through the vectorized
      ``aggregate`` path vs replaying Algorithm 1 one Python call per
      subscription (the paper's broker-side ingest bottleneck).
  data plane    -- one fused ``execute_all`` jitted call driving every
      channel vs the per-channel host loop, at several channel counts;
      since PR 2 the fused call covers spatial channels too (mixed
      param+spatial engine, TweetsAboutCrime in the same plan).
  kernels       -- the fused plan with Pallas ``predicate_filter`` /
      ``spatial_match`` kernels vs the jnp oracle (compiled Pallas is the
      TPU path; in interpret mode off-TPU this records the overhead).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.channel import (most_threatening_tweets, tweets_about_crime,
                                trending_tweets_in_country, tweets_about_drugs)
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags
from repro.data.synthetic import tweet_batch
from benchmarks.common import emit, scale, timeit

LANGS = ["En", "Pt", "Es", "Ar", "Ja"]


def _replay_load(eng: BADEngine, channel: str, params: np.ndarray,
                 brokers: np.ndarray) -> None:
    """The pre-vectorization path: one Algorithm-1 call per subscription."""
    st = eng.channels[channel]
    for p, b in zip(params.tolist(), brokers.tolist()):
        st.aggregator.add_subscription(p, b)
        st.user_params.add(p)
    st.invalidate_targets()


def _fresh_drug_engine() -> BADEngine:
    eng = BADEngine(dataset_capacity=1 << 16, index_capacity=1 << 14,
                    max_window=1 << 14, max_candidates=1 << 12,
                    brokers=("B1", "B2", "B3", "B4"))
    eng.create_channel(tweets_about_drugs())
    return eng


def bench_bulk_load(rng, repeats: int = 3) -> None:
    n_bulk = scale(100_000, 4096)
    params = rng.integers(0, 50, n_bulk).astype(np.int32)
    brokers = rng.integers(0, 4, n_bulk).astype(np.int32)
    t_replay = t_bulk = float("inf")
    for _ in range(repeats):
        eng = _fresh_drug_engine()
        t0 = time.perf_counter()
        _replay_load(eng, "TweetsAboutDrugs", params, brokers)
        t_replay = min(t_replay, time.perf_counter() - t0)
        g_replay = eng.channels["TweetsAboutDrugs"].aggregator.build()

        eng = _fresh_drug_engine()
        t0 = time.perf_counter()
        eng.subscribe_bulk("TweetsAboutDrugs", params, brokers)
        t_bulk = min(t_bulk, time.perf_counter() - t0)
        g_bulk = eng.channels["TweetsAboutDrugs"].aggregator.build()
    assert g_bulk.num_subscriptions == g_replay.num_subscriptions == n_bulk
    assert g_bulk.num_groups == g_replay.num_groups
    emit("multi_channel/bulk_load/replay", t_replay, f"subs={n_bulk}")
    emit("multi_channel/bulk_load/vectorized", t_bulk,
         f"subs={n_bulk};groups={g_bulk.num_groups}")
    emit("multi_channel/bulk_load/speedup", 0.0,
         f"x{t_replay / t_bulk:.1f} (target >= 10x)")


def _channel_set(n: int, with_spatial: bool = False):
    specs = [tweets_about_drugs(), most_threatening_tweets()]
    if with_spatial:
        specs.append(tweets_about_crime(3))
    specs += [trending_tweets_in_country(i, f"{LANGS[i]}Trending")
              for i in range(len(LANGS))]
    return specs[:n]


def _loaded_engine(rng, specs, n_subs: int, n_tweets: int, n_users: int,
                   use_pallas: bool = False, group_cap=None) -> BADEngine:
    eng = BADEngine(dataset_capacity=1 << 16, index_capacity=1 << 14,
                    max_window=1 << 14, max_candidates=1 << 12,
                    brokers=("B1", "B2", "B3", "B4"), use_pallas=use_pallas,
                    group_cap=group_cap)
    for spec in specs:
        eng.create_channel(spec)
        if spec.join == "param":
            eng.subscribe_bulk(spec.name,
                               rng.integers(0, spec.param_domain, n_subs),
                               rng.integers(0, 4, n_subs))
    if any(s.join == "spatial" for s in specs):
        eng.set_user_locations(
            rng.uniform(-100, 100, size=(n_users, 2)).astype(np.float32),
            rng.integers(0, 4, n_users))
    eng.ingest(tweet_batch(rng, n_tweets, t0=1))
    return eng


def bench_fused_execution(rng, n_channels: int, n_subs: int = None,
                          n_tweets: int = None, with_spatial: bool = False,
                          n_users: int = None, tag: str = "",
                          deliver: bool = False) -> None:
    n_subs = scale(20_000, 1024) if n_subs is None else n_subs
    n_tweets = scale(16_384, 1024) if n_tweets is None else n_tweets
    n_users = scale(2048, 256) if n_users is None else n_users
    specs = _channel_set(n_channels, with_spatial)
    # delivery wire lines carry the sID list per group: bound the group cap
    # to the realistic per-parameter population, not the 40KB frame default
    eng = _loaded_engine(rng, specs, n_subs, n_tweets, n_users,
                         group_cap=64 if deliver else None)
    flags = ExecutionFlags.fully_optimized()

    def sequential():
        return [eng.execute_channel(s.name, flags, advance=False, timed=False,
                                    deliver=deliver)
                for s in specs]

    def fused():
        return eng.execute_all(flags, advance=False, timed=False,
                               deliver=deliver)

    seq_reports = sequential()          # warm every per-channel trace
    fused_reports = fused()             # warm the fused trace
    for s in specs:                     # counts must agree exactly
        r = next(r for r in seq_reports if r.channel == s.name)
        assert fused_reports[s.name].num_results == r.num_results
        assert fused_reports[s.name].num_notified == r.num_notified
        if deliver:                     # ... and so must delivery accounting
            assert fused_reports[s.name].overflow == r.overflow
    t_seq = timeit(sequential)
    t_fused = timeit(fused)
    eng.spill.clear()                   # timing loops re-spill the same tick
    total = sum(r.num_results for r in seq_reports)
    name = f"multi_channel/exec/c{n_channels}{tag}"
    emit(f"{name}/sequential", t_seq, f"results={total}")
    emit(f"{name}/fused", t_fused, f"results={total}")
    emit(f"{name}/speedup", 0.0, f"x{t_seq / t_fused:.2f}")


def bench_fused_pallas_vs_oracle(rng, n_channels: int = 4,
                                 n_subs: int = None,
                                 n_tweets: int = None,
                                 n_users: int = None) -> None:
    """Same mixed param+spatial fused plan, Pallas kernels vs jnp oracle."""
    n_subs = scale(20_000, 1024) if n_subs is None else n_subs
    n_tweets = scale(16_384, 1024) if n_tweets is None else n_tweets
    n_users = scale(2048, 256) if n_users is None else n_users
    specs = _channel_set(n_channels, with_spatial=True)
    seed = rng.integers(0, 2 ** 31)
    times = {}
    results = {}
    for backend, use_pallas in (("oracle", False), ("pallas", True)):
        r = np.random.default_rng(seed)
        eng = _loaded_engine(r, specs, n_subs, n_tweets, n_users,
                             use_pallas=use_pallas)
        flags = ExecutionFlags.fully_optimized()
        reports = eng.execute_all(flags, advance=False, timed=False)  # warm
        results[backend] = {n: rep.num_results for n, rep in reports.items()}
        times[backend] = timeit(
            lambda: eng.execute_all(flags, advance=False, timed=False))
    # Predicate evaluation is integer-exact between kernel and oracle; the
    # spatial join may flip O(1-in-millions) pairs sitting exactly on the
    # radius boundary (the kernel's MXU form t2+u2-2t.u rounds differently
    # than the oracle's (t-u)^2), so compare with a boundary tolerance.
    for n, want in results["oracle"].items():
        got = results["pallas"][n]
        assert abs(got - want) <= max(2, want // 10_000), (n, want, got)
    total = sum(results["oracle"].values())
    emit(f"multi_channel/exec/mixed{n_channels}/fused_oracle",
         times["oracle"], f"results={total}")
    emit(f"multi_channel/exec/mixed{n_channels}/fused_pallas",
         times["pallas"], f"results={total}")
    emit(f"multi_channel/exec/mixed{n_channels}/pallas_vs_oracle", 0.0,
         f"x{times['oracle'] / times['pallas']:.2f} "
         "(>1 means pallas faster; expect <1 in interpret mode off-TPU)")


def run(rng) -> None:
    bench_bulk_load(rng)
    for n in (2, 4, 7):
        bench_fused_execution(rng, n)
    # mixed param+spatial engine: the spatial channel rides the same fused
    # call (acceptance: >= 4 channels, fused-vs-sequential + speedup)
    for n in (4, 8):
        bench_fused_execution(rng, n, with_spatial=True, tag="mixed")
    # end-to-end WITH broker delivery: the convert+send stages ride the same
    # jitted call in the fused path vs one jitted delivery per channel in the
    # sequential loop (acceptance: fused delivery wins at >= 4 channels)
    for n in (4, 7):
        bench_fused_execution(rng, n, tag="deliver", deliver=True)
    bench_fused_pallas_vs_oracle(rng)


if __name__ == "__main__":
    run(np.random.default_rng(0))
