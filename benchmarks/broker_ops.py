"""Table 2: broker receive / convert-to-wire / send-out timings,
original vs aggregated result layout — plus the fused-delivery extensions:

  fused_delivery -- the convert+send stages for C channels as ONE jitted
      ``deliver_all`` call (vmapped pack/fanout, one-hot per-broker
      accounting, flat spill capture) vs the per-channel host loop calling
      ``pack_payloads``/``fanout_sids`` C times. Acceptance target: fused
      wins at >= 4 channels.
  spill_drain    -- forced overflow through tiny delivery buffers, then
      ``drain_spilled()`` rounds until the queue is empty: the cost of making
      overflow survivable instead of silently dropping it.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.broker import (broker_traffic_summary, deliver_all,
                               fanout_sids, pack_payloads)
from repro.core.engine import BADEngine
from repro.core.channel import tweets_about_drugs, trending_tweets_in_country
from repro.core.plans import ExecutionFlags
from benchmarks.common import build_drug_engine, emit, scale, timeit

LANGS = ["En", "Pt", "Es", "Ar", "Ja", "De", "Fr"]


def bench_table2(rng) -> None:
    # group_cap ~ per-parameter population: the wire format holds the
    # actual sID lists (the paper's variable-length records), not a
    # frame-sized pad
    eng = build_drug_engine(rng, n_subs=scale(8000), n_new=scale(8192),
                            match_rate=0.05, states=10, preload=0,
                            group_cap=512)
    rows = {}
    for name, agg in (("original", False), ("optimized", True)):
        flags = ExecutionFlags(scan_mode="bad_index", aggregation=agg)
        rep = eng.execute_channel("TweetsAboutDrugs", flags, advance=False,
                                  deliver=True)
        sids = eng.group_sids_array("TweetsAboutDrugs", agg)

        # receive: platform -> broker transfer (device->host of the payloads)
        payload, count, _ = pack_payloads(rep.result, sids, payload_words=16,
                                          max_pairs=1 << 13)
        t_recv = timeit(lambda: np.asarray(payload))
        # convert: materialize the wire payload rows
        t_conv = timeit(lambda: pack_payloads(rep.result, sids,
                                              payload_words=16,
                                              max_pairs=1 << 13)[0])
        # send: per-subscriber dispatch list (identical volume both layouts)
        t_send = timeit(lambda: fanout_sids(rep.result, sids,
                                            max_notify=1 << 15)[0])
        rows[name] = (t_recv, t_conv, t_send)
        # delivery accounting folded into the traffic summary: drops (and
        # spill-recoverable drops) are first-class, not just byte counts
        summ = broker_traffic_summary(rep.result, rep.overflow)
        emit(f"table2/{name}/receive", t_recv,
             f"rows={int(count)};bytes={summ['total_bytes']:.0f}")
        emit(f"table2/{name}/convert", t_conv,
             f"rows={int(count)};delivered={summ['delivered_pairs']};"
             f"spilled={summ['spilled_pairs']};dropped={summ['dropped_pairs']}")
        emit(f"table2/{name}/send", t_send,
             f"notified={rep.num_notified};delivered={summ['delivered_sids']};"
             f"spilled={summ['spilled_sids']};dropped={summ['dropped_sids']}")
        eng.spill.clear()
    o, p = rows["original"], rows["optimized"]
    emit("table2/ratio", 0.0,
         f"recv_x{o[0]/max(p[0],1e-9):.2f};conv_x{o[1]/max(p[1],1e-9):.2f};"
         f"send_x{o[2]/max(p[2],1e-9):.2f} (paper: 5.1/1.9/1.0)")


def _delivery_engine(rng, n_channels: int, n_subs: int) -> BADEngine:
    eng = BADEngine(dataset_capacity=1 << 16, index_capacity=1 << 14,
                    max_window=1 << 14, max_candidates=1 << 11,
                    brokers=("B1", "B2", "B3", "B4"), group_cap=64,
                    max_deliver_pairs=1 << 11, max_notify=1 << 13)
    specs = [tweets_about_drugs()] + [
        trending_tweets_in_country(i, f"{LANGS[i]}Trending")
        for i in range(n_channels - 1)]
    for spec in specs:
        eng.create_channel(spec)
        eng.subscribe_bulk(spec.name,
                           rng.integers(0, spec.param_domain, n_subs),
                           rng.integers(0, 4, n_subs))
    from repro.data.synthetic import tweet_batch
    eng.ingest(tweet_batch(rng, scale(16_384), t0=1))
    return eng


def bench_fused_delivery(rng, n_channels: int, n_subs: int = None) -> None:
    """Convert+send for C channels: one fused jitted ``deliver_all`` vs the
    per-channel host loop (C x pack_payloads + C x fanout_sids)."""
    n_subs = scale(20_000, 1024) if n_subs is None else n_subs
    eng = _delivery_engine(rng, n_channels, n_subs)
    flags = ExecutionFlags(scan_mode="bad_index", aggregation=True)
    reps = eng.execute_all(flags, advance=False, timed=False)
    chs = sorted(eng.channels.values(), key=lambda s: s.index)
    # stacked inputs exactly as execute_all(deliver=True) binds them
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[reps[st.spec.name].result for st in chs])
    stacked = jax.tree.map(jnp.asarray, stacked)
    sids_all = eng._stacked_sids(chs, aggregated=True)
    tb = eng._stacked_inputs(chs, True)[0].brokers
    pw, mp, mn, sc = (eng.deliver_payload_words, eng.max_deliver_pairs,
                      eng.max_notify, eng.max_spill)
    nb = eng.brokers.num_brokers
    fused_fn = jax.jit(lambda res, sids, tb: deliver_all(
        res, sids, pw, mp, mn, sc, target_brokers=tb, num_brokers=nb))

    per_sids = [eng.group_sids_array(st.spec.name, True) for st in chs]

    def host_loop():
        out = []
        for st, sids in zip(chs, per_sids):
            res = reps[st.spec.name].result
            out.append(pack_payloads(res, sids, pw, mp)[0])
            out.append(fanout_sids(res, sids, mn)[0])
        return out

    def fused():
        return fused_fn(stacked, sids_all, tb)

    d = fused()   # warm + parity: fused delivered == per-channel delivered
    for i, (st, sids) in enumerate(zip(chs, per_sids)):
        _, dlv, _ = pack_payloads(reps[st.spec.name].result, sids, pw, mp)
        assert int(d.pack.delivered[i]) == int(dlv), st.spec.name
    t_loop = timeit(host_loop)
    t_fused = timeit(fused)
    total = int(np.asarray(d.pack.produced).sum())
    name = f"table2/fused_delivery/c{n_channels}"
    emit(f"{name}/per_channel_loop", t_loop, f"pairs={total}")
    emit(f"{name}/fused", t_fused, f"pairs={total}")
    emit(f"{name}/speedup", 0.0,
         f"x{t_loop / max(t_fused, 1e-9):.2f} (target >1 at >= 4 channels)")


def bench_spill_drain(rng) -> None:
    """Forced overflow -> SpillQueue -> drain_spilled() rounds to empty."""
    eng = build_drug_engine(rng, n_subs=scale(8000), n_new=scale(8192),
                            match_rate=0.05, states=10, preload=0,
                            group_cap=64)
    # tiny delivery buffers force most of the tick into the spill queue
    eng.max_deliver_pairs, eng.max_notify = 16, 64
    eng._deliver_jit = None
    flags = ExecutionFlags(scan_mode="bad_index", aggregation=True)
    rep = eng.execute_channel("TweetsAboutDrugs", flags, advance=False,
                              timed=False, deliver=True)
    o = rep.overflow
    t0 = time.perf_counter()
    rounds = redelivered = 0
    while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
        rounds += 1
        for dr in eng.drain_spilled().values():
            redelivered += dr.stats.delivered_pairs + dr.stats.delivered_sids
    t_drain = time.perf_counter() - t0
    emit("table2/spill_drain/tick", 0.0,
         f"delivered={o.delivered_pairs + o.delivered_sids};"
         f"spilled={o.spilled_pairs + o.spilled_sids};"
         f"dropped={o.dropped_pairs + o.dropped_sids}")
    emit("table2/spill_drain/drain_to_empty", t_drain,
         f"rounds={rounds};redelivered={redelivered}")


def bench_ring_drain(rng) -> None:
    """Sustained overflow, ring vs host drain: the device retry ring
    re-packs overflow inside the next execute_all call (ZERO drain_spilled
    host calls), vs the ring-disabled baseline that round-trips every
    spilled pair/sID through the host SpillQueue each tick."""
    from repro.core.churn import ChurnWorkload, run_ticks
    from repro.data.synthetic import drug_tweak, tweet_batch
    from repro.core import records as R

    def make_batch(r, n, t0):
        f = tweet_batch(r, n, t0=t0)
        fields = drug_tweak(np.asarray(f.fields).copy(), r, 0.2)
        return R.RecordBatch.from_numpy(fields, np.asarray(f.location))

    n_subs = scale(8000, 512)
    ticks, warm = 6, 2
    out = {}
    # the ring window is sized to hold the run's whole backlog (so the ring
    # mode truly never touches the host queue); the host mode gets capture
    # windows/queue large enough that nothing drops either — both modes
    # deliver the same capped volume per tick, the difference is WHERE the
    # backlog lives and what it costs to keep it moving
    for tag, ring in (("ring", scale(1 << 19, 1 << 13)), ("host", 0)):
        r = np.random.default_rng(7)
        eng = BADEngine(dataset_capacity=1 << 15, index_capacity=1 << 13,
                        max_window=1 << 12, max_candidates=1 << 11,
                        brokers=("B1", "B2", "B3", "B4"), group_cap=64,
                        max_deliver_pairs=64, max_notify=256,
                        max_spill=1 << 16, spill_capacity=1 << 19,
                        ring_capacity=ring)
        eng.create_channel(tweets_about_drugs())
        eng.subscribe_bulk("TweetsAboutDrugs",
                           r.integers(0, 50, n_subs), r.integers(0, 4, n_subs))
        wl = [ChurnWorkload("TweetsAboutDrugs", adds_per_tick=0,
                            removes_per_tick=0)]
        rep = run_ticks(eng, wl, ticks + warm, r,
                        flags=ExecutionFlags(scan_mode="bad_index",
                                             aggregation=True,
                                             param_pushdown=True),
                        deliver=True, ingest_per_tick=scale(2048, 256),
                        make_batch=make_batch, warmup=warm)
        out[tag] = rep
        emit(f"table2/ring_drain/{tag}", rep.wall_s / rep.ticks,
             f"delivered={rep.delivered_pairs + rep.delivered_sids};"
             f"drain_calls={rep.drain_calls};ring={rep.ring_pending};"
             f"queue={rep.queue_pending};dropped={rep.dropped}")
    assert out["ring"].drain_calls == 0, out["ring"]
    assert out["ring"].dropped == 0, out["ring"]
    ratio = ((out["host"].wall_s / out["host"].ticks)
             / max(out["ring"].wall_s / out["ring"].ticks, 1e-9))
    emit("table2/ring_drain/speedup", 0.0,
         f"x{ratio:.2f} per tick (host drain_calls="
         f"{out['host'].drain_calls} -> 0)")


def run(rng) -> None:
    bench_table2(rng)
    for n in (2, 4, 7):
        bench_fused_delivery(rng, n)
    bench_spill_drain(rng)
    bench_ring_drain(rng)


if __name__ == "__main__":
    run(np.random.default_rng(0))
