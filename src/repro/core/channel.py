"""ChannelSpec: a continuous parameterized query (paper §3.3).

A channel has (i) *fixed* predicates over the active dataset — known at
channel-creation time, candidates for the BAD index; (ii) a *parameterized*
predicate binding a record field to the subscriber's parameter (the join with
the subscription dataset); (iii) optionally a *spatial* join against the
UserLocations dataset (TweetsAboutCrime); (iv) a period.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.core import records as R
from repro.core.predicates import Predicate


@dataclasses.dataclass(frozen=True)
class ChannelSpec:
    name: str
    fixed_preds: tuple                  # Tuple[Predicate, ...]
    # "param": record[param_field] == subscription.param (TweetsAboutDrugs /
    #          MostThreateningTweets / TrendingTweetsInACountry)
    # "spatial": subscription.param = user id; match via
    #            spatial_distance(user.location, record.location) < radius
    join: str = "param"
    param_field: int = R.STATE
    param_domain: int = 50
    spatial_radius: float = 10.0
    period_s: float = 600.0             # PERIOD PT10M
    payload_bytes: int = 30 * 1024      # ~30 KB per EnrichedTweet (paper §5.1)

    def __post_init__(self):
        if self.join not in ("param", "spatial"):
            raise ValueError(f"unknown join type {self.join}")
        object.__setattr__(self, "fixed_preds", tuple(self.fixed_preds))


def tweets_about_drugs() -> ChannelSpec:
    """Fig. 6: state=MyState AND threatening_rate=10 AND drug_activity='Manufacturing Drugs'."""
    return ChannelSpec(
        name="TweetsAboutDrugs",
        fixed_preds=(
            Predicate.parse(R.THREATENING_RATE, "==", 10),
            Predicate.parse(R.DRUG_ACTIVITY, "==", 3),
        ),
        join="param",
        param_field=R.STATE,
        param_domain=50,
    )


def most_threatening_tweets() -> ChannelSpec:
    """Fig. 8: state=MyState AND threatening_rate=10."""
    return ChannelSpec(
        name="MostThreateningTweets",
        fixed_preds=(Predicate.parse(R.THREATENING_RATE, "==", 10),),
        join="param",
        param_field=R.STATE,
        param_domain=50,
    )


def tweets_about_crime(num_conditions: int = 3) -> ChannelSpec:
    """Figs. 3/15: spatial channel with 1..5 fixed predicates (I..V)."""
    preds: List[Predicate] = [
        Predicate.parse(R.ABOUT_COUNTRY, "==", 0),        # (I)   selectivity 50%
        Predicate.parse(R.RETWEET_COUNT, ">", 10000),     # (II)  selectivity 50%
        Predicate.parse(R.HATE_SPEECH_RATE, ">", 5),      # (III) selectivity 50%
        Predicate.parse(R.THREATENING_RATE, ">", 5),      # (IV)  selectivity 20%
        Predicate.parse(R.WEAPON_MENTIONED, "==", 1),     # (V)   selectivity 20%
    ]
    if not 1 <= num_conditions <= 5:
        raise ValueError("num_conditions in [1, 5]")
    return ChannelSpec(
        name=f"TweetsAboutCrime{num_conditions}",
        fixed_preds=tuple(preds[:num_conditions]),
        join="spatial",
        param_field=R.STATE,   # unused for spatial join
        spatial_radius=10.0,
    )


def trending_tweets_in_country(lang_code: int, name: str) -> ChannelSpec:
    """Fig. 20 real-world channels: lang=X AND retweet_count>100000, by country."""
    return ChannelSpec(
        name=name,
        fixed_preds=(
            Predicate.parse(R.LANG, "==", lang_code),
            Predicate.parse(R.RETWEET_COUNT, ">", 100000),
        ),
        join="param",
        param_field=R.COUNTRY,
        param_domain=200,
        payload_bytes=3584,   # ~3.5 KB real tweets (paper §5.7)
    )
