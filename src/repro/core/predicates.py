"""Predicate algebra + the per-dataset conditionsList (paper §4.3.1).

A channel's *fixed* predicates form a conjunction over int32 record fields.
All channels registered on a dataset are compiled together into a dense,
padded ``CompiledConditions`` table so that ingestion-time evaluation is one
vectorized pass (the Pallas ``predicate_filter`` kernel consumes exactly this
layout; ``evaluate_conditions`` below is the pure-jnp oracle).

Padding uses an always-true predicate (op=GE, value=INT32_MIN on field 0).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

# Comparison ops.
EQ, NE, LT, LE, GT, GE = range(6)
_OP_NAMES = {"==": EQ, "!=": NE, "<": LT, "<=": LE, ">": GT, ">=": GE}

_INT32_MIN = np.int32(-(2 ** 31))


@dataclasses.dataclass(frozen=True)
class Predicate:
    """``field <op> value`` over an int32 column."""

    field: int
    op: int
    value: int

    @staticmethod
    def parse(field: int, op: str, value: int) -> "Predicate":
        return Predicate(field, _OP_NAMES[op], int(value))


@dataclasses.dataclass(frozen=True)
class CompiledConditions:
    """conditionsList for one dataset: (num_channels, max_preds) padded.

    field_idx, op, value: (C, P) int32; npreds: (C,) int32.
    """

    field_idx: np.ndarray
    op: np.ndarray
    value: np.ndarray
    npreds: np.ndarray

    @property
    def num_channels(self) -> int:
        return self.field_idx.shape[0]

    @property
    def max_preds(self) -> int:
        return self.field_idx.shape[1]


def compile_conditions(channels: Sequence[Sequence[Predicate]],
                       min_preds: int = 1) -> CompiledConditions:
    """Stack per-channel fixed-predicate conjunctions into one padded table."""
    num_c = len(channels)
    max_p = max(min_preds, max((len(c) for c in channels), default=1), 1)
    field_idx = np.zeros((num_c, max_p), dtype=np.int32)
    op = np.full((num_c, max_p), GE, dtype=np.int32)
    value = np.full((num_c, max_p), _INT32_MIN, dtype=np.int32)
    npreds = np.zeros((num_c,), dtype=np.int32)
    for ci, preds in enumerate(channels):
        npreds[ci] = len(preds)
        for pi, p in enumerate(preds):
            field_idx[ci, pi] = p.field
            op[ci, pi] = p.op
            value[ci, pi] = p.value
    return CompiledConditions(field_idx, op, value, npreds)


def apply_op(lhs: jnp.ndarray, op: jnp.ndarray, rhs: jnp.ndarray) -> jnp.ndarray:
    """Vectorized comparator dispatch; shapes broadcast together."""
    return jnp.select(
        [op == EQ, op == NE, op == LT, op == LE, op == GT, op == GE],
        [lhs == rhs, lhs != rhs, lhs < rhs, lhs <= rhs, lhs > rhs, lhs >= rhs],
        default=True,
    )


def evaluate_conditions(fields: jnp.ndarray, conds: CompiledConditions) -> jnp.ndarray:
    """Pure-jnp oracle: (N, F) records x conditionsList -> (N, C) bool matches.

    A record matches channel c iff it satisfies *all* of the channel's fixed
    predicates (paper Algorithm 2).
    """
    field_idx = jnp.asarray(conds.field_idx)      # (C, P)
    op = jnp.asarray(conds.op)                    # (C, P)
    value = jnp.asarray(conds.value)              # (C, P)
    vals = fields[:, field_idx]                   # (N, C, P)
    ok = apply_op(vals, op[None], value[None])    # (N, C, P)
    return jnp.all(ok, axis=-1)                   # (N, C)


def evaluate_single(fields: jnp.ndarray, preds: Sequence[Predicate]) -> jnp.ndarray:
    """(N, F) x conjunction -> (N,) bool. Convenience for one channel."""
    conds = compile_conditions([list(preds)])
    return evaluate_conditions(fields, conds)[:, 0]


def selectivity(fields: np.ndarray, preds: Sequence[Predicate]) -> float:
    mask = np.asarray(evaluate_single(jnp.asarray(fields), preds))
    return float(mask.mean()) if mask.size else 0.0
