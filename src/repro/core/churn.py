"""Sustained-churn driver: O(Δ) subscription maintenance under load.

The paper's strategic aggregation (§4.1) assumes subscriptions arrive
continuously; this module drives that regime end to end. ``run_ticks``
interleaves bulk subscription adds/removals (and optional spatial-cohort
churn) with fused ``execute_all(deliver=True)`` ticks, and reports the
sustained control-plane throughput together with the engine's maintenance
counters — at steady state the epoch/delta protocol should show *patches*
advancing while *traces* and *rebuilds* stay flat (every device cache is
patched in place; nothing recompiles).

The driver owns the live-sID bookkeeping (which subscriptions exist and can
be removed) so the engine under test is exercised purely through its public
control-plane API.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.engine import MaintenanceStats
from repro.core.plans import ExecutionFlags
from repro.core.runtime import EngineProtocol
from repro.data.synthetic import tweet_batch


@dataclasses.dataclass
class ChurnReport:
    """One ``run_ticks`` run. ``wall_s`` covers the TIMED ticks only
    (``warmup`` ticks are excluded so trace/compile time is not billed to
    steady-state throughput); ``maintenance`` is the engine counter delta
    over the timed ticks."""

    ticks: int
    adds: int
    removes: int
    user_adds: int
    user_removes: int
    wall_s: float
    maintenance: MaintenanceStats
    live_subs: int
    results: int
    delivered_pairs: int
    delivered_sids: int
    spilled: int
    dropped: int
    # host round-trips: ``drain_spilled()`` invocations over the timed
    # ticks — zero when the device retry ring absorbs sustained overflow —
    # and what is still ring-resident / host-queued when the run ends
    drain_calls: int = 0
    ring_pending: int = 0
    queue_pending: int = 0
    # measured maximum number of ticks simultaneously in flight (1 on the
    # synchronous path; == requested depth once a pipelined run warms up)
    pipeline_depth: int = 1

    @property
    def subs_per_s(self) -> float:
        """Sustained control-plane throughput: subscription mutations
        (adds + removes + cohort churn) per wall second, execution and
        delivery included."""
        ops = self.adds + self.removes + self.user_adds + self.user_removes
        return ops / max(self.wall_s, 1e-9)

    @property
    def ticks_per_s(self) -> float:
        return self.ticks / max(self.wall_s, 1e-9)


class _LivePool:
    """Amortized append + O(k) swap-remove sample over the live sIDs —
    driver bookkeeping must stay o(live) per batch or it would be billed to
    the engine under test."""

    def __init__(self, init: np.ndarray):
        self.n = len(init)
        self.buf = np.empty((max(1024, 2 * self.n),), np.int32)
        self.buf[:self.n] = init

    def add(self, new: np.ndarray) -> None:
        need = self.n + len(new)
        if need > len(self.buf):
            nb = np.empty((max(need, 2 * len(self.buf)),), np.int32)
            nb[:self.n] = self.buf[:self.n]
            self.buf = nb
        self.buf[self.n:need] = new
        self.n = need

    def sample_remove(self, rng: np.random.Generator,
                      n_rm: int) -> np.ndarray:
        """Remove ~n_rm random live sIDs (unique positions; duplicates in
        the draw collapse) and return them."""
        pick = np.unique(rng.integers(0, self.n, n_rm))
        out = self.buf[pick].copy()
        k = len(pick)
        n0 = self.n - k
        mark = np.zeros((k,), bool)
        mark[pick[pick >= n0] - n0] = True
        self.buf[pick[pick < n0]] = self.buf[n0:self.n][~mark]
        self.n = n0
        return out

    def view(self) -> np.ndarray:
        return self.buf[:self.n]


@dataclasses.dataclass
class ChurnWorkload:
    """Per-tick churn mix for one param channel."""

    channel: str
    adds_per_tick: int = 512
    removes_per_tick: int = 512
    param_domain: int = 50
    num_brokers: int = 1
    # spatial cohort churn (requires the engine to hold a spatial channel
    # with an explicit cohort); 0 disables
    user_channel: Optional[str] = None
    user_churn_per_tick: int = 0


def run_ticks(engine: "EngineProtocol",
              workloads: List[ChurnWorkload],
              ticks: int,
              rng: np.random.Generator,
              flags: ExecutionFlags = None,
              deliver: bool = True,
              ingest_per_tick: int = 256,
              make_batch: Callable = None,
              warmup: int = 2,
              live_sids: Optional[Dict[str, np.ndarray]] = None,
              churn_rounds: int = 1,
              use_channel_plans: bool = False,
              on_tick: Callable = None,
              on_drain: Callable = None,
              pipeline_depth: int = 1,
              drain_every: Optional[int] = None) -> ChurnReport:
    """Drive ``ticks`` churn ticks: per workload, bulk-add then bulk-remove
    subscriptions, optionally churn a spatial cohort, ingest a record batch,
    run the fused ``execute_all`` (optionally with fused delivery), and
    drain any spilled notifications.

    ``engine`` is anything satisfying ``runtime.EngineProtocol`` — the
    typed extraction of the shared control/data-plane surface
    (subscribe_bulk / remove_subscriptions / ingest / execute_all /
    drain_spilled / spill / maintenance / ring_pending_*) — the
    single-device ``BADEngine`` or the mesh-sharded
    ``core.sharded.ShardedBADEngine``; the driver never reaches into
    engine internals.

    ``live_sids`` (channel -> sID array) seeds the removable population —
    pass the sIDs of a preloaded engine; it is updated in place. The first
    ``warmup`` ticks are untimed (they absorb trace/compile and the first
    capacity rebuild); the returned report covers the rest.

    ``churn_rounds`` control-plane batches land per executed tick — the
    paper's regime, where subscriptions arrive continuously between channel
    periods. Every batch pays the maintenance cost (the rebuild baseline
    re-aggregates per BATCH, exactly as the pre-churn-engine control plane
    did on every ``subscribe_bulk``).

    ``use_channel_plans`` executes under each channel's assigned
    ``ChannelPlan`` (``execute_all(None)`` — the planner-driven plan-group
    partitioning) instead of homogeneous ``flags``. ``on_tick(tick,
    reports)`` fires after every executed tick — hook a
    ``RuntimePlanner.step`` here to re-plan mid-run. ``on_drain(reports)``
    fires after every ``drain_spilled`` round (testing/parity hook).

    ``pipeline_depth >= 2`` drives the ticks through the asynchronous
    ``TickPipeline`` (core/runtime.py): each tick's fused calls are
    dispatched while up to ``depth - 1`` previous ticks are still executing
    on device, the next tick's churn/ingest numpy work overlaps them, and
    ``drain_spilled`` batches every ``drain_every`` ticks (default: ==
    depth). Reports are accounted by their DISPATCH tick number, spill
    capture runs through the SpillQueue's epoch-free resolved lane, and the
    run flushes + drains to empty before returning — the delivered
    notification multiset is identical to the synchronous path's.
    """
    if use_channel_plans:
        flags = None
    else:
        flags = flags or ExecutionFlags.fully_optimized()
    make_batch = make_batch or (lambda r, n, t0: tweet_batch(r, n, t0=t0))
    if pipeline_depth > 1:
        return _run_ticks_pipelined(
            engine, workloads, ticks, rng, flags, deliver, ingest_per_tick,
            make_batch, warmup, live_sids, churn_rounds, on_tick, on_drain,
            pipeline_depth, drain_every)
    live: Dict[str, _LivePool] = {
        w.channel: _LivePool(np.zeros((0,), np.int32)) for w in workloads}
    if live_sids:
        live.update({k: _LivePool(np.asarray(v, np.int32))
                     for k, v in live_sids.items()})
    adds = removes = user_adds = user_removes = 0
    results = dp = ds = sp = dr = drains = 0
    t0_clock = 0.0
    snap = engine.maintenance.snapshot()
    now = engine.now
    for tick in range(ticks):
        if tick == warmup:
            snap = engine.maintenance.snapshot()
            t0_clock = time.perf_counter()
        timed = tick >= warmup
        for _ in range(max(1, churn_rounds)):
            for w in workloads:
                if w.adds_per_tick:
                    params = rng.integers(0, w.param_domain,
                                          w.adds_per_tick).astype(np.int32)
                    brokers = rng.integers(0, w.num_brokers,
                                           w.adds_per_tick).astype(np.int32)
                    new = engine.subscribe_bulk(w.channel, params, brokers)
                    live[w.channel].add(new)
                    if timed:
                        adds += len(new)
                n_rm = min(w.removes_per_tick, live[w.channel].n)
                if n_rm:
                    rm = live[w.channel].sample_remove(rng, n_rm)
                    gone = engine.remove_subscriptions(w.channel, rm)
                    if timed:
                        removes += gone
                if w.user_channel and w.user_churn_per_tick:
                    nu = engine.user_locations.shape[0]
                    k = w.user_churn_per_tick
                    out = engine.unsubscribe_users(
                        w.user_channel, rng.integers(0, nu, k))
                    inn = engine.subscribe_users(
                        w.user_channel, rng.integers(0, nu, k))
                    if timed:
                        user_removes += out
                        user_adds += inn
        if ingest_per_tick:
            now += 100
            engine.ingest(make_batch(rng, ingest_per_tick, now))
        reports = engine.execute_all(flags, timed=False, deliver=deliver)
        if on_tick is not None:
            on_tick(tick, reports)
        if timed:
            for rep in reports.values():
                results += rep.num_results
                if rep.overflow is not None:
                    dp += rep.overflow.delivered_pairs
                    ds += rep.overflow.delivered_sids
                    sp += rep.overflow.spilled_pairs + rep.overflow.spilled_sids
                    dr += rep.overflow.dropped_pairs + rep.overflow.dropped_sids
        while engine.spill.pending_pairs() + engine.spill.pending_sids() > 0:
            if timed:
                drains += 1
            drained = engine.drain_spilled()
            if on_drain is not None:
                on_drain(drained)
            for drr in drained.values():
                if timed:
                    dp += drr.stats.delivered_pairs
                    ds += drr.stats.delivered_sids
                    dr += drr.stats.dropped_pairs + drr.stats.dropped_sids
    wall = time.perf_counter() - t0_clock if ticks > warmup else 0.0
    if live_sids is not None:    # hand the surviving population back
        for k, pool in live.items():
            live_sids[k] = pool.view().copy()
    return ChurnReport(
        ticks=max(0, ticks - warmup), adds=adds, removes=removes,
        user_adds=user_adds, user_removes=user_removes, wall_s=wall,
        maintenance=engine.maintenance.since(snap),
        live_subs=sum(pool.n for pool in live.values()),
        results=results, delivered_pairs=dp, delivered_sids=ds,
        spilled=sp, dropped=dr, drain_calls=drains,
        ring_pending=(engine.ring_pending_pairs()
                      + engine.ring_pending_sids()),
        queue_pending=(engine.spill.pending_pairs()
                       + engine.spill.pending_sids()))


def _run_ticks_pipelined(engine, workloads, ticks, rng, flags, deliver,
                         ingest_per_tick, make_batch, warmup, live_sids,
                         churn_rounds, on_tick, on_drain,
                         pipeline_depth, drain_every) -> ChurnReport:
    """The ``pipeline_depth >= 2`` body of ``run_ticks``: same workload
    schedule, ticks driven through ``TickPipeline``. Reports surface up to
    ``depth - 1`` ticks after dispatch and are accounted by DISPATCH tick
    number (so the timed window covers exactly the same work as the
    synchronous path); the pipeline is flushed at the warmup boundary so
    trace/compile latency is never billed to the timed window."""
    from repro.core.runtime import TickPipeline

    live: Dict[str, _LivePool] = {
        w.channel: _LivePool(np.zeros((0,), np.int32)) for w in workloads}
    if live_sids:
        live.update({k: _LivePool(np.asarray(v, np.int32))
                     for k, v in live_sids.items()})
    adds = removes = user_adds = user_removes = 0
    results = dp = ds = sp = dr = drains = 0
    t0_clock = 0.0
    snap = engine.maintenance.snapshot()
    now = engine.now
    pipe = TickPipeline(engine, depth=pipeline_depth,
                        drain_every=drain_every)

    def account(tick_no: int, reports: Dict) -> None:
        nonlocal results, dp, ds, sp, dr
        if on_tick is not None:
            on_tick(tick_no, reports)
        if tick_no < warmup:
            return
        for rep in reports.values():
            results += rep.num_results
            if rep.overflow is not None:
                dp += rep.overflow.delivered_pairs
                ds += rep.overflow.delivered_sids
                sp += (rep.overflow.spilled_pairs
                       + rep.overflow.spilled_sids)
                dr += (rep.overflow.dropped_pairs
                       + rep.overflow.dropped_sids)

    def drain_to_empty(timed: bool) -> None:
        nonlocal dp, ds, dr, drains
        while engine.spill.pending_pairs() + engine.spill.pending_sids() > 0:
            if timed:
                drains += 1
            drained = engine.drain_spilled()
            if on_drain is not None:
                on_drain(drained)
            for drr in drained.values():
                if timed:
                    dp += drr.stats.delivered_pairs
                    ds += drr.stats.delivered_sids
                    dr += drr.stats.dropped_pairs + drr.stats.dropped_sids

    for tick in range(ticks):
        if tick == warmup:
            # quiesce before the timed window: in-flight warmup ticks sync
            # (their trace/compile and spills stay unbilled), the queue
            # empties, and the clock starts on a clean pipeline
            for t, reps in pipe.flush():
                account(t, reps)
            drain_to_empty(False)
            snap = engine.maintenance.snapshot()
            t0_clock = time.perf_counter()
        timed = tick >= warmup
        for _ in range(max(1, churn_rounds)):
            for w in workloads:
                if w.adds_per_tick:
                    params = rng.integers(0, w.param_domain,
                                          w.adds_per_tick).astype(np.int32)
                    brokers = rng.integers(0, w.num_brokers,
                                           w.adds_per_tick).astype(np.int32)
                    new = engine.subscribe_bulk(w.channel, params, brokers)
                    live[w.channel].add(new)
                    if timed:
                        adds += len(new)
                n_rm = min(w.removes_per_tick, live[w.channel].n)
                if n_rm:
                    rm = live[w.channel].sample_remove(rng, n_rm)
                    gone = engine.remove_subscriptions(w.channel, rm)
                    if timed:
                        removes += gone
                if w.user_channel and w.user_churn_per_tick:
                    nu = engine.user_locations.shape[0]
                    k = w.user_churn_per_tick
                    out = engine.unsubscribe_users(
                        w.user_channel, rng.integers(0, nu, k))
                    inn = engine.subscribe_users(
                        w.user_channel, rng.integers(0, nu, k))
                    if timed:
                        user_removes += out
                        user_adds += inn
        if ingest_per_tick:
            now += 100
            engine.ingest(make_batch(rng, ingest_per_tick, now))
        for t, reps in pipe.step(flags, deliver=deliver):
            account(t, reps)
        if pipe.drain_due():
            drain_to_empty(timed)
    for t, reps in pipe.flush():
        account(t, reps)
    drain_to_empty(ticks > warmup)
    wall = time.perf_counter() - t0_clock if ticks > warmup else 0.0
    if live_sids is not None:    # hand the surviving population back
        for k, pool in live.items():
            live_sids[k] = pool.view().copy()
    return ChurnReport(
        ticks=max(0, ticks - warmup), adds=adds, removes=removes,
        user_adds=user_adds, user_removes=user_removes, wall_s=wall,
        maintenance=engine.maintenance.since(snap),
        live_subs=sum(pool.n for pool in live.values()),
        results=results, delivered_pairs=dp, delivered_sids=ds,
        spilled=sp, dropped=dr, drain_calls=drains,
        ring_pending=(engine.ring_pending_pairs()
                      + engine.ring_pending_sids()),
        queue_pending=(engine.spill.pending_pairs()
                       + engine.spill.pending_sids()),
        pipeline_depth=max(pipe.max_in_flight, 1))
