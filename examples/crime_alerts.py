"""TweetsAboutCrime: the paper's spatial channel end to end.

Users register a location; the channel pushes nearby threatening tweets
(fixed predicates I-III + spatial_distance < 10). Shows the BAD index and
the MXU-friendly spatial join, and periodic execution with watermarks.

    PYTHONPATH=src python examples/crime_alerts.py
"""
import numpy as np

from repro.core import records as R
from repro.core.channel import tweets_about_crime
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags
from repro.data.synthetic import tweet_batch


def main():
    rng = np.random.default_rng(7)
    eng = BADEngine(dataset_capacity=1 << 15, index_capacity=1 << 14,
                    max_window=1 << 14, max_candidates=1 << 11,
                    use_pallas=True)          # Pallas kernels on the hot paths
    eng.create_channel(tweets_about_crime(3))

    n_users = 1500
    eng.set_user_locations((rng.normal(size=(n_users, 2)) * 40)
                           .astype(np.float32))
    print(f"{n_users} users registered locations")

    for period in range(3):
        batch = tweet_batch(rng, 8192, t0=1 + period * 600)
        eng.ingest(batch)
        rep = eng.execute_channel("TweetsAboutCrime3",
                                  ExecutionFlags(scan_mode="bad_index"))
        print(f"period {period}: indexed-candidates={rep.scanned} "
              f"alerts={rep.num_results} wall={rep.wall_time_s*1e3:.1f}ms")


if __name__ == "__main__":
    main()
