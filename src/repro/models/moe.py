"""Top-k MoE layer with capacity-based scatter dispatch (GShard semantics,
scatter/gather realization — no (T, E, C) one-hot tensors).

Experts are sharded over the `model` mesh axis (EP): both assigned MoE archs
have 16 experts == the 16-way model axis, so each chip owns one expert's
weights. Token->expert routing produces a position-in-expert via a cumsum
over the (T*k, E) assignment one-hot (T*k x E int32 — small), tokens are
scattered into the (E, C, D) expert buffer (XLA emits the all-to-all), the
expert GEMM runs as a grouped einsum, and results gather back with combine
weights. Tokens beyond capacity C are dropped (standard capacity-factor
semantics); the router uses softmax-after-top-k normalization (Mixtral/DBRX
convention).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partition import shard
from repro.models.config import ModelConfig
from repro.models.layers import init_dense


def moe_init(key, cfg: ModelConfig, dtype=None) -> Dict[str, jnp.ndarray]:
    dtype = dtype or cfg.param_dtype
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": init_dense(kr, (d, e), jnp.float32),
        "gate": init_dense(kg, (e, d, f), dtype),
        "up": init_dense(ku, (e, d, f), dtype),
        "down": init_dense(kd, (e, f, d), dtype),
    }


def capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.moe_top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_apply(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (out (B, S, D), aux_loss ())."""
    cdtype = cfg.compute_dtype
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.moe_top_k
    cap = capacity(t, cfg)
    xt = x.reshape(t, d).astype(cdtype)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E) fp32 router
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                   # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalize

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    # position of each (token, slot) within its expert
    flat_e = top_e.reshape(t * k)                            # (Tk,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # (Tk, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                # exclusive cumsum
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < cap
    dest = jnp.where(keep, flat_e * cap + pos_in_e, e * cap)  # drop slot

    # dispatch: (E*C, D) buffer (+1 dump row), scatter token copies
    src = jnp.repeat(xt, k, axis=0) if k > 1 else xt         # (Tk, D)
    buf = jnp.zeros((e * cap + 1, d), dtype=cdtype)
    buf = buf.at[dest].set(src, mode="drop")
    hidden = buf[: e * cap].reshape(e, cap, d)
    hidden = shard(hidden, "act_moe")

    # grouped expert GEMMs (SwiGLU)
    g = jnp.einsum("ecd,edf->ecf", hidden, p["gate"].astype(cdtype))
    u = jnp.einsum("ecd,edf->ecf", hidden, p["up"].astype(cdtype))
    h = jax.nn.silu(g) * u
    out_e = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(cdtype))
    out_e = shard(out_e, "act_moe")

    # combine: gather each slot's expert output, weight, sum over k
    flat = out_e.reshape(e * cap, d)
    flat = jnp.concatenate([flat, jnp.zeros((1, d), cdtype)], axis=0)
    gathered = flat[jnp.where(keep, dest, e * cap)]          # (Tk, D)
    w = (top_p.reshape(t * k) * keep).astype(cdtype)
    out = (gathered * w[:, None]).reshape(t, k, d).sum(axis=1)
    return out.reshape(b, s, d), aux.astype(jnp.float32)
