"""Device-resident retry ring: overflow re-delivers inside the next
``execute_all`` call (no host round-trip), epoch staleness masks churned
entries, ring overflow cascades to the host SpillQueue as last resort, and
multi-tick DeliveryStats conservation — ring-resident pairs included —
holds against a no-cap oracle engine (delivered sID/pair multiset
equality), ring wraparound included."""
import numpy as np
import pytest

from repro.core.channel import tweets_about_crime, tweets_about_drugs
from repro.core.churn import ChurnWorkload, run_ticks
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags

from conftest import check_delivery_conservation, make_tweets

FLAGS = ExecutionFlags(scan_mode="window", aggregation=True,
                       param_pushdown=True)


def _ring_engine(rng, ring_capacity=64, max_deliver_pairs=16, max_notify=32,
                 n_subs=200, spatial=False, **kw):
    eng = BADEngine(dataset_capacity=4096, index_capacity=1024,
                    max_window=2048, max_candidates=512,
                    brokers=("B1", "B2"), group_cap=8,
                    max_deliver_pairs=max_deliver_pairs,
                    max_notify=max_notify, ring_capacity=ring_capacity, **kw)
    eng.create_channel(tweets_about_drugs())
    if spatial:
        eng.create_channel(tweets_about_crime(1))
        eng.set_user_locations(
            (rng.normal(size=(30, 2)) * 30).astype(np.float32),
            rng.integers(0, 2, 30))
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, n_subs),
                       rng.integers(0, 2, n_subs))
    return eng


def test_ring_redelivers_without_host_drain(rng):
    """Overflow lands in the ring, NOT the host queue, and the next
    execute_all call re-delivers it on device: retried is counted, the ring
    shrinks by what was delivered, and drain_spilled never has work."""
    eng = _ring_engine(rng, ring_capacity=1 << 12)
    eng.ingest(make_tweets(rng, 400, match_drugs=0.3))
    rep = eng.execute_all(FLAGS, timed=False, deliver=True)["TweetsAboutDrugs"]
    o = rep.overflow
    check_delivery_conservation(o, rep.num_results, rep.num_notified)
    assert o.spilled_pairs > 0 and o.retried_pairs == 0
    assert eng.spill.pending_pairs() + eng.spill.pending_sids() == 0
    assert eng.ring_pending_pairs() == o.spilled_pairs
    assert eng.ring_pending_sids() == o.spilled_sids
    assert not eng.drain_spilled()
    # next tick: NO new records — everything delivered is a ring retry
    total_p, total_s = o.spilled_pairs, o.spilled_sids
    got_p = got_s = 0
    for _ in range(200):
        if eng.ring_pending_pairs() + eng.ring_pending_sids() == 0:
            break
        rep = eng.execute_all(FLAGS, timed=False,
                              deliver=True)["TweetsAboutDrugs"]
        o = rep.overflow
        assert rep.num_results == 0
        check_delivery_conservation(o, 0, 0)
        assert o.retried_pairs > 0 or o.retried_sids > 0
        assert o.dropped_pairs == o.dropped_sids == 0
        got_p += o.delivered_pairs
        got_s += o.delivered_sids
    assert (got_p, got_s) == (total_p, total_s)
    assert eng.spill.pending_pairs() + eng.spill.pending_sids() == 0


def test_ring_epoch_staleness_drops(rng):
    """Churn between ticks bumps the epoch: ring-resident PAIRS go stale and
    drop (counted) at the next presentation instead of indexing a moved
    table; ring sIDs never go stale and still deliver."""
    eng = _ring_engine(rng, ring_capacity=1 << 12)
    eng.ingest(make_tweets(rng, 400, match_drugs=0.3))
    rep = eng.execute_all(FLAGS, timed=False, deliver=True)["TweetsAboutDrugs"]
    spilled_p, spilled_s = rep.overflow.spilled_pairs, rep.overflow.spilled_sids
    assert spilled_p > 0
    eng.subscribe("TweetsAboutDrugs", 3, "B1")          # epoch bump
    dropped = delivered_s = 0
    for _ in range(200):
        if eng.ring_pending_pairs() + eng.ring_pending_sids() == 0:
            break
        rep = eng.execute_all(FLAGS, timed=False,
                              deliver=True)["TweetsAboutDrugs"]
        o = rep.overflow
        check_delivery_conservation(o, rep.num_results, rep.num_notified)
        assert o.delivered_pairs == 0                  # no stale re-pack
        dropped += o.dropped_pairs
        delivered_s += o.delivered_sids
    assert dropped == spilled_p
    assert delivered_s == spilled_s


def test_ring_overflow_cascades_to_host_queue(rng):
    """Overflow past the ring window lands in the host SpillQueue (the
    bounded last resort) — conservation still holds and the two stores
    together hold exactly the overflow."""
    eng = _ring_engine(rng, ring_capacity=8)
    eng.ingest(make_tweets(rng, 400, match_drugs=0.3))
    rep = eng.execute_all(FLAGS, timed=False, deliver=True)["TweetsAboutDrugs"]
    o = rep.overflow
    check_delivery_conservation(o, rep.num_results, rep.num_notified)
    assert o.spilled_pairs > 8                          # ring + queue
    assert eng.ring_pending_pairs() == 8
    assert eng.spill.pending_pairs() == o.spilled_pairs - 8
    assert eng.spill.pending_sids() == o.spilled_sids - 8


def test_flush_rings_hands_entries_to_queue(rng):
    """flush_rings moves ring-resident entries into the host queue (drain
    then re-delivers them); channel drops flush implicitly and drain counts
    the unroutable entries as dropped."""
    eng = _ring_engine(rng, ring_capacity=1 << 12)
    eng.ingest(make_tweets(rng, 400, match_drugs=0.3))
    o = eng.execute_all(FLAGS, timed=False,
                        deliver=True)["TweetsAboutDrugs"].overflow
    eng.flush_rings()
    assert eng.ring_pending_pairs() == 0
    assert eng.spill.pending_pairs() == o.spilled_pairs
    assert eng.spill.pending_sids() == o.spilled_sids
    delivered = 0
    while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
        for dr in eng.drain_spilled().values():
            assert dr.stats.dropped_pairs == dr.stats.dropped_sids == 0
            delivered += dr.stats.delivered_pairs + dr.stats.delivered_sids
    assert delivered == o.spilled_pairs + o.spilled_sids


def test_run_ticks_sustained_overflow_zero_drain_calls(rng):
    """Under sustained overflow the ring engine performs ZERO drain_spilled
    host calls across ticks while the host-drain baseline needs them every
    tick; dropped stays zero on both."""
    reports = {}
    for tag, ring in (("ring", 1 << 12), ("host", 0)):
        r = np.random.default_rng(7)
        eng = _ring_engine(r, ring_capacity=ring, n_subs=300)
        wl = [ChurnWorkload("TweetsAboutDrugs", adds_per_tick=0,
                            removes_per_tick=0)]
        rep = run_ticks(eng, wl, 6, r, flags=FLAGS, deliver=True,
                        ingest_per_tick=128,
                        make_batch=lambda rr, n, t0: make_tweets(
                            rr, n, t0=t0, match_drugs=0.3),
                        warmup=2)
        reports[tag] = rep
        assert rep.dropped == 0, tag
    assert reports["ring"].drain_calls == 0
    assert reports["ring"].ring_pending > 0
    assert reports["ring"].queue_pending == 0
    assert reports["host"].drain_calls > 0
    assert reports["host"].ring_pending == 0


def _delivered_content(rep):
    """(pair lines, sids) actually delivered by one fused tick."""
    o = rep.overflow
    pairs = [tuple(line) for line in
             rep.payload[:o.delivered_pairs, :2].tolist()]
    sids = rep.notify[:o.delivered_sids].tolist()
    return pairs, sids


@pytest.mark.parametrize("trial", range(4))
def test_multi_tick_conservation_fuzz_vs_oracle(trial):
    """Seeded fuzz: sustained overflow through capped engines (ring +
    queue cascade, wraparound included) delivers — across ticks plus a
    final flush+drain — exactly the pair/sID multisets a no-cap oracle
    engine delivers per tick. DeliveryStats conservation (ring included)
    holds at every tick."""
    r = np.random.default_rng(100 + trial)
    caps = dict(max_deliver_pairs=int(r.integers(8, 40)),
                max_notify=int(r.integers(16, 80)),
                ring_capacity=int(r.integers(4, 48)))
    engines = {}
    for tag, kw in (("capped", caps),
                    ("oracle", dict(max_deliver_pairs=1 << 14,
                                    max_notify=1 << 16,
                                    ring_capacity=1 << 12))):
        rr = np.random.default_rng(1000 + trial)
        eng = _ring_engine(rr, n_subs=150 + 25 * trial, **kw)
        eng.debug_delivery_buffers = True
        engines[tag] = eng
    want_pairs, want_sids = [], []
    got_pairs, got_sids = [], []
    retried_total = 0
    rng_data = np.random.default_rng(2000 + trial)
    for tick in range(int(r.integers(4, 8))):
        batch = make_tweets(rng_data, int(r.integers(30, 120)),
                            t0=100 * (tick + 1), match_drugs=0.3)
        for tag, eng in engines.items():
            eng.ingest(batch)
            rep = eng.execute_all(FLAGS, timed=False,
                                  deliver=True)["TweetsAboutDrugs"]
            o = rep.overflow
            check_delivery_conservation(o, rep.num_results, rep.num_notified)
            p, s = _delivered_content(rep)
            if tag == "oracle":
                assert o.overflow == 0 and o.retried_pairs == 0
                want_pairs += p
                want_sids += s
            else:
                retried_total += o.retried_pairs + o.retried_sids
                got_pairs += p
                got_sids += s
    # wraparound exercised: ring entries were re-presented at least once
    assert retried_total > 0
    # drain the capped engine completely: ring -> queue -> DrainReports
    eng = engines["capped"]
    eng.flush_rings()
    rounds = 0
    while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
        rounds += 1
        assert rounds < 500
        for dr in eng.drain_spilled().values():
            assert dr.stats.dropped_pairs == dr.stats.dropped_sids == 0
            if dr.payload is not None and dr.stats.delivered_pairs:
                got_pairs += [tuple(x) for x in
                              dr.payload[:dr.stats.delivered_pairs,
                                         :2].tolist()]
            if dr.notify is not None and dr.stats.delivered_sids:
                got_sids += dr.notify[:dr.stats.delivered_sids].tolist()
    assert sorted(got_pairs) == sorted(want_pairs)
    assert sorted(got_sids) == sorted(want_sids)


def test_spatial_ring_redelivers_and_goes_stale_on_cohort_change(rng):
    """The spatial join group owns its own ring: identity-fanout overflow
    re-delivers on device; converting the channel to a cohort (epoch bump +
    target-space remap) stales the resident pairs instead of misrouting."""
    eng = _ring_engine(rng, ring_capacity=1 << 12, spatial=True)
    eng.ingest(make_tweets(rng, 400, match_drugs=0.3))
    flags = ExecutionFlags(scan_mode="window")
    rep = eng.execute_all(flags, timed=False, deliver=True)["TweetsAboutCrime1"]
    o = rep.overflow
    check_delivery_conservation(o, rep.num_results, rep.num_notified)
    assert o.spilled_pairs > 0
    assert eng.spill.pending_pairs("TweetsAboutCrime1") == 0
    # second call with no new data: ring retries deliver
    rep = eng.execute_all(flags, timed=False, deliver=True)["TweetsAboutCrime1"]
    assert rep.overflow.retried_pairs == o.spilled_pairs
    assert rep.overflow.delivered_pairs > 0
    # cohort creation remaps the spatial target space -> resident stale
    left_p = rep.overflow.spilled_pairs
    assert left_p > 0
    eng.subscribe_users("TweetsAboutCrime1", np.arange(5))
    rep = eng.execute_all(flags, timed=False, deliver=True)["TweetsAboutCrime1"]
    o = rep.overflow
    check_delivery_conservation(o, rep.num_results, rep.num_notified)
    assert o.dropped_pairs >= left_p     # stale pairs dropped, not misrouted


def test_ring_donation_reuses_buffers_and_preserves_conservation(rng):
    """The fused delivery call donates the presented retry ring: steady
    state reuses the ring allocation in place (pointer-set overlap), and
    the donated path's multi-tick conservation is unchanged — delivered +
    spilled + dropped == produced at every tick, drain included."""
    eng = _ring_engine(rng, ring_capacity=32)
    eng.ingest(make_tweets(rng, 400, match_drugs=0.3))
    rep = eng.execute_all(FLAGS, timed=False, deliver=True)["TweetsAboutDrugs"]
    check_delivery_conservation(rep.overflow, rep.num_results,
                                rep.num_notified)
    [(_, _, ring)] = list(eng._rings.values())
    if not hasattr(ring.pair_rows, "unsafe_buffer_pointer"):
        pytest.skip("jax.Array.unsafe_buffer_pointer unavailable")
    before = {x.unsafe_buffer_pointer() for x in ring}
    for tick in range(4):
        eng.ingest(make_tweets(rng, 60, t0=100 * (tick + 2),
                               match_drugs=0.3))
        rep = eng.execute_all(FLAGS, timed=False,
                              deliver=True)["TweetsAboutDrugs"]
        check_delivery_conservation(rep.overflow, rep.num_results,
                                    rep.num_notified)
        assert rep.overflow.dropped_pairs == 0
        [(_, _, ring)] = list(eng._rings.values())
        after = {x.unsafe_buffer_pointer() for x in ring}
        assert before & after, f"tick {tick}: ring reallocated from scratch"
        before = after
    while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
        for dr in eng.drain_spilled().values():
            assert dr.stats.dropped_pairs == dr.stats.dropped_sids == 0


def test_ring_counts_pass_matches_table_derivation(rng):
    """Threading TargetArrays.counts into deliver_all is a pure
    optimization: stats and buffers are identical to deriving the member
    counts from the sID table."""
    import jax.numpy as jnp
    from repro.core.broker import pack_payloads_all, fanout_sids_all
    from conftest import random_stacked_broker_result
    stacked, group_sids, _, _ = random_stacked_broker_result(rng, 3, 16, 3,
                                                             4, 3)
    counts = jnp.sum(jnp.asarray(group_sids) >= 0, axis=-1).astype(jnp.int32)
    a = pack_payloads_all(stacked, jnp.asarray(group_sids), 2, 16)
    b = pack_payloads_all(stacked, jnp.asarray(group_sids), 2, 16,
                          counts=counts)
    np.testing.assert_array_equal(np.asarray(a.payload), np.asarray(b.payload))
    np.testing.assert_array_equal(np.asarray(a.delivered),
                                  np.asarray(b.delivered))
    fa = fanout_sids_all(stacked, jnp.asarray(group_sids), 32)
    fb = fanout_sids_all(stacked, jnp.asarray(group_sids), 32, counts=counts)
    np.testing.assert_array_equal(np.asarray(fa.notify), np.asarray(fb.notify))
    np.testing.assert_array_equal(np.asarray(fa.produced),
                                  np.asarray(fb.produced))
