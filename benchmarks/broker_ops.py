"""Table 2: broker receive / convert-to-wire / send-out timings,
original vs aggregated result layout."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.broker import fanout_sids, pack_payloads
from repro.core.plans import ExecutionFlags
from benchmarks.common import build_drug_engine, emit, timeit


def run(rng) -> None:
    # group_cap ~ per-parameter population: the wire format holds the
    # actual sID lists (the paper's variable-length records), not a
    # frame-sized pad
    eng = build_drug_engine(rng, n_subs=8000, n_new=8192,
                            match_rate=0.05, states=10, preload=0,
                            group_cap=512)
    rows = {}
    for name, agg in (("original", False), ("optimized", True)):
        flags = ExecutionFlags(scan_mode="bad_index", aggregation=agg)
        rep = eng.execute_channel("TweetsAboutDrugs", flags, advance=False)
        sids = eng.group_sids_array("TweetsAboutDrugs", agg)

        # receive: platform -> broker transfer (device->host of the payloads)
        payload, count, _ = pack_payloads(rep.result, sids, payload_words=16,
                                          max_pairs=1 << 13)
        t_recv = timeit(lambda: np.asarray(payload))
        # convert: materialize the wire payload rows
        t_conv = timeit(lambda: pack_payloads(rep.result, sids,
                                              payload_words=16,
                                              max_pairs=1 << 13)[0])
        # send: per-subscriber dispatch list (identical volume both layouts)
        t_send = timeit(lambda: fanout_sids(rep.result, sids,
                                            max_notify=1 << 15)[0])
        rows[name] = (t_recv, t_conv, t_send)
        emit(f"table2/{name}/receive", t_recv,
             f"rows={int(count)};bytes={rep.broker_bytes.sum():.0f}")
        emit(f"table2/{name}/convert", t_conv, f"rows={int(count)}")
        emit(f"table2/{name}/send", t_send, f"notified={rep.num_notified}")
    o, p = rows["original"], rows["optimized"]
    emit("table2/ratio", 0.0,
         f"recv_x{o[0]/max(p[0],1e-9):.2f};conv_x{o[1]/max(p[1],1e-9):.2f};"
         f"send_x{o[2]/max(p[2],1e-9):.2f} (paper: 5.1/1.9/1.0)")


if __name__ == "__main__":
    run(np.random.default_rng(0))
