"""END-TO-END DRIVER: serve a model inside the Big Active Data loop.

The paper's EnrichedTweets are produced by an upstream enrichment job (its
ref [32]); here the enrichment IS the engine's post-join stage: raw tweet
records flow through ingestion-time BAD indexing and channel execution,
then a (reduced) qwen2-family LM scores every candidate INSIDE the fused
tick call (``core/enrich.LMScorer`` -> ``launch/serve.prefill_scores``)
and the per-channel delivery budget keeps only the top-scoring pairs —
the full Fig. 1 pipeline with a model in the delivery loop, no host
round-trip between join, scoring, and broker fan-out.

    PYTHONPATH=src python examples/enriched_pipeline.py [--periods 3]

``--heuristic`` swaps the LM for the pure-jnp urgency scorer (fast path,
what the smoke test runs); ``--budget 0`` detaches ranking entirely.
"""
import argparse
import time

import numpy as np

from repro.core import enrich
from repro.core.channel import most_threatening_tweets, tweets_about_drugs
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionRequest
from repro.data.synthetic import tweet_batch


def build_stage(budget, heuristic=False, prompt_len=16):
    """The enrichment stage: a reduced-LM scorer (one batched prefill per
    tick over the candidate stream) or the heuristic payload scorer."""
    if heuristic:
        return enrich.HeuristicScorer(budget=budget)
    from repro.models.model import ModelApi
    stage = enrich.LMScorer(budget=budget)
    n = ModelApi(stage.cfg).param_count()
    print(f"enrichment model {stage.cfg.name}-reduced ({n:,} params)")
    return stage


def run(periods=3, batch=2048, budget=64, heuristic=False,
        n_subs=2000, capacity=1 << 15):
    """Drive ``periods`` enriched ticks; returns the per-period reports."""
    rng = np.random.default_rng(0)
    eng = BADEngine(dataset_capacity=capacity, index_capacity=capacity // 2,
                    max_window=capacity // 2,
                    max_candidates=max(256, capacity >> 4),
                    brokers=("BrokerA", "BrokerB"))
    eng.create_channel(tweets_about_drugs())
    eng.create_channel(most_threatening_tweets())
    params, brokers = (rng.integers(0, 50, n_subs).astype(np.int32),
                       rng.integers(0, 2, n_subs).astype(np.int32))
    eng.subscribe_bulk("TweetsAboutDrugs", params, brokers)
    eng.subscribe_bulk("MostThreateningTweets", params, brokers)
    if budget:
        eng.set_enrichment(build_stage(budget, heuristic))
    print(f"2 channels, {2 * n_subs} subscriptions, "
          f"budget={budget or 'off'} "
          f"scorer={'heuristic' if heuristic or not budget else 'lm'}")

    out = []
    for period in range(periods):
        # 1. raw feed -> 2. ingestion: conditionsList eval + BAD indexing
        eng.ingest(tweet_batch(rng, batch, t0=1 + period * 600))
        # 3. one fused tick: discovery, join, model scoring + budget rank,
        #    broker fan-out — a single ExecutionRequest, a single jit call
        t0 = time.perf_counter()
        reports = eng.execute(ExecutionRequest(deliver=True, timed=True))
        wall = time.perf_counter() - t0
        for chan, rep in reports.items():
            o = rep.overflow
            print(f"period {period} {chan}: matched={rep.scanned} "
                  f"groups={rep.num_results} notified={rep.num_notified} "
                  f"delivered={o.delivered_pairs} ranked_out={o.ranked_pairs} "
                  f"tick={wall * 1e3:.1f}ms")
        out.append(reports)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--periods", type=int, default=3)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--budget", type=int, default=64,
                    help="per-channel delivered-pair budget (0 = no ranking)")
    ap.add_argument("--heuristic", action="store_true",
                    help="use the pure-jnp urgency scorer instead of the LM")
    args = ap.parse_args()
    run(args.periods, args.batch, args.budget, args.heuristic)


if __name__ == "__main__":
    main()
