"""Step builders: train (with gradient-accumulation scan), prefill, decode."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model import ModelApi
from repro.optim import make_optimizer


def default_optimizer(cfg):
    if cfg.optimizer == "adafactor":
        return make_optimizer("adafactor", b1=cfg.adafactor_beta1)
    return make_optimizer(cfg.optimizer)


def build_train_step(api: ModelApi, optimizer=None,
                     accum: Optional[int] = None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_accum > 1 scans over microbatches (batch dim folded to
    (A, B/A, ...)); gradients accumulate in the parameter dtype (bf16 for the
    large-model memory plans — documented in DESIGN.md). ``accum`` overrides
    cfg.grad_accum (the launcher clamps it so each microbatch still covers
    every data-parallel replica).
    """
    cfg = api.cfg
    optimizer = optimizer or default_optimizer(cfg)
    accum = max(1, accum if accum is not None else cfg.grad_accum)

    def train_step(params, opt_state, batch):
        if accum > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, p.dtype), params)

            def body(carry, mb):
                gacc, lacc = carry
                (loss, _), g = jax.value_and_grad(api.loss, has_aux=True)(params, mb)
                gacc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gacc, g)
                return (gacc, lacc + loss), ()

            (gsum, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: (g / accum), gsum)
            loss = lsum / accum
        else:
            (loss, _), grads = jax.value_and_grad(api.loss, has_aux=True)(params, batch)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return train_step


def build_prefill_step(api: ModelApi) -> Callable:
    def prefill_step(params, batch):
        return api.prefill(params, batch)

    return prefill_step


def build_decode_step(api: ModelApi) -> Callable:
    def decode_step(params, caches, pos, batch):
        return api.decode(params, caches, pos, batch)

    return decode_step
