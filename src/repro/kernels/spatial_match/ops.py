"""Jit'd public wrapper for spatial_match: padding + backend dispatch.

Padding uses +inf sentinel coordinates so padded rows/cols never match.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.spatial_match.kernel import (DEFAULT_TR, DEFAULT_TU,
                                                spatial_match_kernel)

# Far sentinel for padded rows/users: coordinates so distant that dist^2
# overflows float32 to +inf, which is never < radius^2. The engine's stacked
# user sets reuse the same value for their shape-bucket padding.
FAR = 1e30
_FAR = FAR


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def spatial_match(tweet_locs: jnp.ndarray, user_locs: jnp.ndarray,
                  radius) -> jnp.ndarray:
    """(R, 2) x (U, 2) -> (R, U) bool; drop-in for ref.spatial_match.

    Also accepts stacked (C, R, 2) x (C, U, 2) inputs with per-channel radii
    (C,), vmapping the kernel over the channel axis (the fused executor's
    layout — pallas_call lowers the batch onto a leading grid dimension).
    """
    if tweet_locs.ndim == 3:
        radii = jnp.broadcast_to(jnp.asarray(radius, jnp.float32),
                                 (tweet_locs.shape[0],))
        return jax.vmap(spatial_match)(tweet_locs, user_locs, radii)
    return _padded(tweet_locs, user_locs,
                   jnp.asarray(radius, jnp.float32) ** 2,
                   interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("tr", "tu", "interpret"))
def _padded(tweet_locs, user_locs, radius2, tr: int = DEFAULT_TR,
            tu: int = DEFAULT_TU, interpret: bool = True):
    r, u = tweet_locs.shape[0], user_locs.shape[0]
    rp, up = -r % tr, -u % tu
    if rp:
        tweet_locs = jnp.pad(tweet_locs, ((0, rp), (0, 0)), constant_values=_FAR)
    if up:
        user_locs = jnp.pad(user_locs, ((0, up), (0, 0)), constant_values=-_FAR)
    out = spatial_match_kernel(tweet_locs, user_locs, radius2, tr=tr, tu=tu,
                               interpret=interpret)
    return out[:r, :u].astype(jnp.bool_)
