"""Reference (pure-jnp) pair expansion over a compacted candidate stream.

The compacted execution join ("compact"/"compact_pallas" backends,
``core/plans.py join_param_stream``) gathers, per stream entry, the owning
channel's join-map row and its member/broker tables; this module expands
those per-entry gathers into the (S, maxT) pair grids — validity, member
counts, wire bytes, broker ids. It is the oracle the Pallas kernel
(``kernel.py``/``ops.py``) must match bit-for-bit: everything is integer
arithmetic, so the two backends are exactly identical.
"""
from __future__ import annotations

import jax.numpy as jnp


def join_pairs(tgt: jnp.ndarray, tgt_n: jnp.ndarray, members: jnp.ndarray,
               brokers: jnp.ndarray, valid: jnp.ndarray,
               payload: jnp.ndarray, num_brokers: int,
               aggregated: bool):
    """Per-entry pair expansion.

    tgt (S, maxT) int32 target slots (-1 padded), tgt_n (S,) live targets per
    entry, members/brokers (S, maxT) int32 per-target gathers, valid (S,)
    entry mask (post semi-join), payload (S,) int32 bytes per pair.

    Returns (pair_valid (S, maxT) bool, members (S, maxT) int32,
    pair_bytes (S, maxT) int32, bids (S, maxT) int32 with the sentinel
    ``num_brokers`` on invalid pairs). Aggregated pairs carry their member
    sID list on the wire (4 B each) — paper §4.1.2; byte totals stay int32
    end-to-end (float32 would round past 2^24).
    """
    maxT = tgt.shape[1]
    cols = jnp.arange(maxT, dtype=jnp.int32)[None, :]
    pair_valid = valid[:, None] & (cols < tgt_n[:, None]) & (tgt >= 0)
    mem = jnp.where(pair_valid, members, 0).astype(jnp.int32)
    per = payload[:, None].astype(jnp.int32) + (4 * mem if aggregated else 0)
    pair_bytes = jnp.where(pair_valid, per, 0)
    bids = jnp.where(pair_valid, brokers, num_brokers).astype(jnp.int32)
    return pair_valid, mem, pair_bytes, bids
