"""Per-channel plans + adaptive runtime planner.

Covers: plan-group partitioning parity (a heterogeneous assignment delivers
exactly what per-plan homogeneous engines deliver, all 4 scan modes x
{agg, flat} x {oracle, pallas}), ring migration across a layout switch (a
flat-slot ring must drain against the FLAT table, never the aggregated slot
table), delivered+dropped == produced telescoped across mid-stream plan
switches, planner hysteresis (patience + cooldown), zero retraces at a
stable assignment, and the offline search / plan-file roundtrip."""
import dataclasses

import numpy as np
import pytest

from repro.core import planner as qp
from repro.core.channel import tweets_about_drugs
from repro.core.engine import BADEngine
from repro.core.planner import PlannerConfig, RuntimePlanner
from repro.core.plans import BACKENDS, ChannelPlan, enumerate_plans

from conftest import check_delivery_conservation, make_tweets

# The mixed-plan fuzz pins the two PADDED backends: the compact family has
# its own dedicated parity suite (test_compact_join.py), and doubling this
# heavy cross-product test would re-prove the same thing.
PADDED = ("oracle", "pallas")
ALL_PLANS = enumerate_plans(backends=PADDED, param_pushdown=True)


def _multi_engine(rng, names, **kw):
    """One param channel per name (identical spec modulo name), identical
    subscriptions per channel — engines built from equal generator states
    are data-identical."""
    args = dict(dataset_capacity=4096, index_capacity=1024, max_window=1024,
                max_candidates=256, brokers=("B1", "B2"), group_cap=8,
                max_deliver_pairs=512, max_notify=1024, ring_capacity=256)
    args.update(kw)
    eng = BADEngine(**args)
    eng.debug_delivery_buffers = True
    base = tweets_about_drugs()
    for name in names:
        eng.create_channel(dataclasses.replace(base, name=name))
        eng.subscribe_bulk(name, rng.integers(0, 50, 40),
                           rng.integers(0, 2, 40))
    return eng


def _content(rep):
    """Delivered wire content of one report: pair (row, target) list + sID
    list (delivered prefixes of the debug buffers)."""
    o = rep.overflow
    pairs = [tuple(p) for p in
             np.asarray(rep.payload)[:o.delivered_pairs, :2].tolist()]
    sids = np.asarray(rep.notify)[:o.delivered_sids].tolist()
    return pairs, sids


# ---------------------------------------------------------------------------
# mixed-plan execute_all parity (satellite: heterogeneous fuzz)
# ---------------------------------------------------------------------------


def test_mixed_plan_parity_all_modes():
    """One engine running SIXTEEN distinct plans — every scan mode x layout
    x backend — delivers, per channel and per tick, the exact pair/sID
    multisets of homogeneous engines running that channel's plan alone."""
    names = [f"Drugs{i}" for i in range(len(ALL_PLANS))]
    hetero = _multi_engine(np.random.default_rng(7), names)
    refs = {b: _multi_engine(np.random.default_rng(7), names,
                             use_pallas=(b == "pallas")) for b in PADDED}
    for name, plan in zip(names, ALL_PLANS):
        hetero.set_plan(name, plan)
    data_rng = np.random.default_rng(99)
    for tick in range(2):
        batch = make_tweets(data_rng, 150, t0=1 + 100 * tick,
                            match_drugs=0.3)
        hetero.ingest(batch)
        for ref in refs.values():
            ref.ingest(batch)
        got = hetero.execute_all(None, timed=False, deliver=True)
        assert len(got) == len(names)
        want = {}
        for flags_plan in enumerate_plans(param_pushdown=True):
            for backend in PADDED:
                ref = refs[backend]
                reps = ref.execute_all(flags_plan.flags, advance=False,
                                       timed=False, deliver=True)
                plan = dataclasses.replace(flags_plan, backend=backend)
                for name, assigned in zip(names, ALL_PLANS):
                    if assigned == plan:
                        want[name] = reps[name]
        for ref in refs.values():   # one watermark advance per tick, like
            ref.execute_all(ALL_PLANS[0].flags, timed=False)  # hetero's call
        for name in names:
            g, w = got[name], want[name]
            assert g.plan == dict(zip(names, ALL_PLANS))[name]
            assert (g.num_results, g.num_notified) == \
                (w.num_results, w.num_notified), name
            o = g.overflow
            check_delivery_conservation(o, g.num_results, g.num_notified)
            assert o.spilled_pairs == o.dropped_pairs == 0, name
            assert o.spilled_sids == o.dropped_sids == 0, name
            gp, gs = _content(g)
            wp, ws = _content(w)
            assert sorted(gp) == sorted(wp), name
            assert sorted(gs) == sorted(ws), name


def test_legacy_flags_ignore_assignments(rng):
    """Explicit flags force ONE homogeneous plan-group regardless of
    per-channel assignments (and do not overwrite them)."""
    eng = _multi_engine(rng, ["A", "B"])
    eng.set_plan("A", ChannelPlan("bad_index", True, True))
    eng.ingest(make_tweets(rng, 100, match_drugs=0.3))
    flags = ChannelPlan("window", True, True).flags
    reps = eng.execute_all(flags, timed=False, deliver=True)
    assert all(r.plan == ChannelPlan.from_flags(flags)
               for r in reps.values())
    assert eng.channel_plan("A") == ChannelPlan("bad_index", True, True)


# ---------------------------------------------------------------------------
# ring migration across a plan switch (satellite: full-plan ring keys)
# ---------------------------------------------------------------------------


def _switch_build(seed, **kw):
    rng = np.random.default_rng(seed)
    eng = _multi_engine(rng, ["D"], **kw)
    eng.ingest(make_tweets(np.random.default_rng(seed + 1), 300,
                           match_drugs=0.4))
    return eng


def _drain_content(eng):
    pairs, sids = [], []
    while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
        for drr in eng.drain_spilled().values():
            if drr.payload is not None:
                pairs += [tuple(p) for p in np.asarray(
                    drr.payload)[:drr.stats.delivered_pairs, :2].tolist()]
            if drr.notify is not None:
                sids += np.asarray(
                    drr.notify)[:drr.stats.delivered_sids].tolist()
            assert drr.stats.dropped_pairs == drr.stats.dropped_sids == 0
    return pairs, sids


def test_layout_switch_drains_flat_ring_against_flat_table():
    """Regression (rings keyed by full plan identity): pairs resident in a
    FLAT-slot ring when the channel switches to the aggregated layout must
    migrate through the SpillQueue and re-pack against the FLAT slot table
    — byte-identical to an engine that never switched — not be presented to
    the aggregated plan's fused call (whose slot table they would silently
    mis-index) or dropped."""
    flat = ChannelPlan("window", False, True)
    agg = ChannelPlan("window", True, True)
    caps = dict(max_deliver_pairs=4, max_notify=8)
    switched = _switch_build(3, **caps)
    stayed = _switch_build(3, **caps)
    for e in (switched, stayed):
        e.set_plan("D", flat)
        rep = e.execute_all(None, timed=False, deliver=True)["D"]
        check_delivery_conservation(rep.overflow, rep.num_results,
                                    rep.num_notified)
    assert switched.ring_pending_pairs() > 0
    key = ("param", flat, ("D",))
    assert key in switched._rings
    assert switched._rings[key][1] == "flat_slot"
    # reference: never switches — flush the flat ring and drain it
    stayed.flush_rings()
    want = _drain_content(stayed)
    # switched: layout flips, next call must NOT feed the flat ring into the
    # aggregated group; its entries surface via the queue instead
    switched.set_plan("D", agg)
    rep2 = switched.execute_all(None, timed=False, deliver=True)["D"]
    assert rep2.num_results == 0                 # no new data this tick
    assert rep2.overflow.retried_pairs == 0      # flat ring NOT re-presented
    assert key not in switched._rings
    assert ("param", agg, ("D",)) in switched._rings
    got = _drain_content(switched)
    assert sorted(got[0]) == sorted(want[0])
    assert sorted(got[1]) == sorted(want[1])


def test_conservation_telescopes_across_plan_switches(rng):
    """delivered + dropped == produced over a run whose plan switches
    mid-stream (flat -> aggregated -> bad_index), rings flushed and the
    queue drained to empty at the end; a no-cap engine following the same
    switch schedule delivers the identical multisets."""
    schedule = {0: ChannelPlan("window", False, True),
                2: ChannelPlan("window", True, True),
                4: ChannelPlan("bad_index", True, True)}
    capped = _switch_build(11, max_deliver_pairs=8, max_notify=16)
    oracle = _switch_build(11, max_deliver_pairs=2048, max_notify=4096,
                           ring_capacity=4096)
    tot = dict(prod_p=0, prod_s=0)
    acc = {id(capped): ([], []), id(oracle): ([], [])}
    data_rng = np.random.default_rng(12)
    for tick in range(6):
        batch = make_tweets(data_rng, 60, t0=200 + 100 * tick,
                            match_drugs=0.4)
        for eng in (capped, oracle):
            if tick in schedule:
                eng.set_plan("D", schedule[tick])
            eng.ingest(batch)
            rep = eng.execute_all(None, timed=False, deliver=True)["D"]
            o = rep.overflow
            check_delivery_conservation(o, rep.num_results, rep.num_notified)
            p, s = _content(rep)
            acc[id(eng)][0].extend(p)
            acc[id(eng)][1].extend(s)
            if eng is capped:
                tot["prod_p"] += rep.num_results
                tot["prod_s"] += rep.num_notified
    for eng in (capped, oracle):
        eng.flush_rings()
        assert eng.ring_flush_drops == 0
        p, s = _drain_content(eng)
        acc[id(eng)][0].extend(p)
        acc[id(eng)][1].extend(s)
    got_p, got_s = acc[id(capped)]
    want_p, want_s = acc[id(oracle)]
    assert len(got_p) == tot["prod_p"] and len(got_s) == tot["prod_s"]
    assert sorted(got_p) == sorted(want_p)
    assert sorted(got_s) == sorted(want_s)


# ---------------------------------------------------------------------------
# zero-retrace steady state under a stable (heterogeneous) assignment
# ---------------------------------------------------------------------------


def test_stable_assignment_is_zero_retrace(rng):
    eng = _multi_engine(rng, ["A", "B"])
    eng.set_plan("A", ChannelPlan("bad_index", True, True))
    eng.set_plan("B", ChannelPlan("window", False, True))
    data_rng = np.random.default_rng(5)
    for tick in range(2):  # warm both plan-groups' traces
        eng.ingest(make_tweets(data_rng, 64, t0=1 + 100 * tick,
                               match_drugs=0.3))
        eng.execute_all(None, timed=False, deliver=True)
    snap = eng.maintenance.snapshot()
    for tick in range(3):
        eng.ingest(make_tweets(data_rng, 64, t0=500 + 100 * tick,
                               match_drugs=0.3))
        eng.execute_all(None, timed=False, deliver=True)
    d = eng.maintenance.since(snap)
    assert d.traces == 0 and d.rebuilds == 0


# ---------------------------------------------------------------------------
# planner decision logic (hysteresis, proposals)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Rep:
    channel: str
    num_results: int
    num_notified: int
    scanned: int
    overflow: object = None


def _planner_engine():
    eng = BADEngine(dataset_capacity=1024, index_capacity=256,
                    max_window=256, max_candidates=64)
    eng.create_channel(tweets_about_drugs())
    return eng


def test_planner_patience_and_cooldown():
    eng = _planner_engine()
    planner = RuntimePlanner(eng, PlannerConfig(patience=2, cooldown=4))
    name = "TweetsAboutDrugs"
    sparse = {name: _Rep(name, 5, 50, 1000)}     # fanout 10, sel 0.005
    start = eng.channel_plan(name)
    assert planner.step(sparse) == []            # streak 1 < patience
    assert eng.channel_plan(name) == start
    [sw] = planner.step(sparse)                  # streak 2 -> switch
    assert sw.new == ChannelPlan("bad_index", True, True)
    assert eng.channel_plan(name) == sw.new
    # fanout collapses -> proposal drops aggregation, but the 0.5-EMA only
    # crosses the 2.0 threshold at tick 6 (10 -> 5.5 -> 3.25 -> 2.125 ->
    # 1.56) and cooldown covers ticks 3..5 anyway; patience then demands a
    # second identical proposal, so the switch lands at tick 7
    lone = {name: _Rep(name, 5, 5, 1000)}        # fanout 1
    for _ in range(4):                           # ticks 3..6: no switch
        assert planner.step(lone) == []
        assert eng.channel_plan(name).aggregation
    [sw2] = planner.step(lone)                   # tick 7
    assert sw2.new == ChannelPlan("bad_index", False, True)
    assert len(planner.switches) == 2
    assert planner.stable_since() == 7


def test_planner_never_proposes_full_and_ratchets_index():
    eng = _planner_engine()
    planner = RuntimePlanner(eng)
    name = "TweetsAboutDrugs"
    # dense observations: selectivity 0.9 -> a non-indexed channel would
    # stay on window...
    planner.observe({name: _Rep(name, 900, 900, 1000)})
    assert planner.propose(name).scan_mode == "window"
    # ...but once ON the index, a high observed selectivity (the index
    # pre-filters what it scans) must not evict it
    eng.set_plan(name, ChannelPlan("bad_index", True, True))
    assert planner.propose(name).scan_mode == "bad_index"
    assert "full" not in {planner.propose(name).scan_mode}


def test_overflow_pressure_forces_aggregation():
    eng = _planner_engine()
    planner = RuntimePlanner(eng)
    name = "TweetsAboutDrugs"

    class _Ov:
        delivered_pairs, spilled_pairs, dropped_pairs = 10, 40, 0
        delivered_sids, spilled_sids, dropped_sids = 10, 0, 0

    planner.observe({name: _Rep(name, 50, 50, 1000, _Ov())})  # fanout 1
    prop = planner.propose(name)
    assert prop.aggregation                      # pressure 0.57 >= 0.25


def test_ring_absorbed_overflow_is_not_pressure():
    """Regression: ring-resident entries are counted as spilled on EVERY
    call that re-presents them (the conservation identity requires it), so
    a retry ring steadily absorbing a small overflow used to read as
    permanent pressure and flip the channel to the aggregated layout. The
    retried volume must be subtracted before the pressure ratio."""
    eng = _planner_engine()
    planner = RuntimePlanner(eng)
    name = "TweetsAboutDrugs"

    class _RingAbsorbed:
        delivered_pairs, spilled_pairs, dropped_pairs = 10, 40, 0
        delivered_sids, spilled_sids, dropped_sids = 10, 0, 0
        retried_pairs, retried_sids = 38, 0      # ring recycling, not loss

    planner.observe({name: _Rep(name, 50, 50, 1000, _RingAbsorbed())})
    # (40 - 38) / 60 = 0.03 << 0.25: the ring is doing its job
    assert not planner.propose(name).aggregation
    # control: the SAME counts without the retried attribution (a fresh
    # overflow of identical size) must still force aggregation
    eng2 = _planner_engine()
    planner2 = RuntimePlanner(eng2)

    class _FreshOverflow:
        delivered_pairs, spilled_pairs, dropped_pairs = 10, 40, 0
        delivered_sids, spilled_sids, dropped_sids = 10, 0, 0
        retried_pairs, retried_sids = 0, 0

    planner2.observe({name: _Rep(name, 50, 50, 1000, _FreshOverflow())})
    assert planner2.propose(name).aggregation


def test_compact_proposed_for_sparse_predless_window_channel():
    """A channel pinned to the window scan (no fixed predicates) whose live
    candidates are sparse gets the compact backend of its family; a dense
    one proposes the padded fused join; channels with fixed predicates take
    the BAD index instead of compaction."""
    eng = _planner_engine()
    spec = dataclasses.replace(tweets_about_drugs(), name="NoPreds",
                               fixed_preds=())
    eng.create_channel(spec)
    planner = RuntimePlanner(eng)
    planner.observe({"NoPreds": _Rep("NoPreds", 20, 20, 1000)})  # sel 0.02
    prop = planner.propose("NoPreds")
    assert prop.scan_mode == "window" and prop.backend == "compact"
    dense = RuntimePlanner(eng)
    dense.observe({"NoPreds": _Rep("NoPreds", 900, 900, 1000)})
    assert dense.propose("NoPreds").backend == "oracle"
    # fixed-pred channel at the same sparsity: BAD index, padded backend
    planner.observe({"TweetsAboutDrugs":
                     _Rep("TweetsAboutDrugs", 20, 20, 1000)})
    prop = planner.propose("TweetsAboutDrugs")
    assert prop.scan_mode == "bad_index" and prop.backend == "oracle"
    # a forced backend disables the compact heuristic entirely
    forced = RuntimePlanner(eng, PlannerConfig(backend="pallas"))
    forced.observe({"NoPreds": _Rep("NoPreds", 20, 20, 1000)})
    assert forced.propose("NoPreds").backend == "pallas"


# ---------------------------------------------------------------------------
# plan spec + offline search / persistence
# ---------------------------------------------------------------------------


def test_channel_plan_validation_and_roundtrip():
    p = ChannelPlan("bad_index", True, True, "pallas")
    assert ChannelPlan.from_dict(p.to_dict()) == p
    assert p.flags.scan_mode == "bad_index"
    assert ChannelPlan.from_flags(p.flags, "pallas") == p
    with pytest.raises(ValueError):
        ChannelPlan("btree")
    with pytest.raises(ValueError):
        ChannelPlan(backend="cuda")
    assert len(enumerate_plans()) == 8
    assert len(enumerate_plans(backends=BACKENDS)) == 32
    with pytest.raises(ValueError):
        ChannelPlan(backend="compact_oracle")


def test_set_plan_validates_and_reports_change(rng):
    eng = _multi_engine(rng, ["A"])
    plan = ChannelPlan("bad_index", True, True)
    assert eng.set_plan("A", plan) is True
    assert eng.set_plan("A", plan) is False      # unchanged
    with pytest.raises(TypeError):
        eng.set_plan("A", plan.flags)
    with pytest.raises(KeyError):
        eng.set_plan("nope", plan)
    assert eng.plan_assignment() == {"A": plan}


def test_search_plans_and_plan_file_roundtrip(rng, tmp_path):
    eng = _multi_engine(rng, ["A"])
    eng.ingest(make_tweets(rng, 120, match_drugs=0.3))
    cands = (ChannelPlan("window", False, True),
             ChannelPlan("bad_index", True, True))
    res = qp.search_plans(eng, candidates=cands, repeats=1)
    assert set(res) == {"A"}
    assert ChannelPlan.from_dict(res["A"]["best"]) in cands
    walls = [r["wall_s"] for r in res["A"]["candidates"]]
    assert walls == sorted(walls) and all(w > 0 for w in walls)
    best = {n: ChannelPlan.from_dict(r["best"]) for n, r in res.items()}
    path = tmp_path / "plans.json"
    qp.save_plans(str(path), best, meta={"k": 1})
    loaded = qp.load_plans(str(path))
    assert loaded == best
    fresh = _multi_engine(np.random.default_rng(0), ["A"])
    assert qp.apply_plans(fresh, loaded) == int(
        loaded["A"] != fresh.default_plan())
    assert fresh.channel_plan("A") == loaded["A"]
    assert qp.apply_plans(fresh, {"missing": cands[0]}) == 0
