"""Fig. 16: BAD index vs traditional index across channel selectivities.

TweetsAboutCrime with 2..5 fixed predicates (I+II ~17%, +III ~10%, +IV ~4.2%,
+V ~0.07% per the paper; our synthetic stream reproduces these rates). The
traditional index serves candidates matching the single most selective
predicate; the BAD index serves exactly the full-conjunction matches.
"""
from __future__ import annotations

import numpy as np

from repro.core.channel import tweets_about_crime
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags
from repro.data.synthetic import tweet_batch
from benchmarks.common import emit, exec_time, scale


def run(rng) -> None:
    for n_conds in (2, 3, 4, 5):
        eng = BADEngine(dataset_capacity=1 << 16, index_capacity=1 << 15,
                        max_window=1 << 15, max_candidates=1 << 14)
        eng.create_channel(tweets_about_crime(n_conds))
        users = (rng.normal(size=(scale(2000), 2)) * 60).astype(np.float32)
        eng.set_user_locations(users)
        n_tweets = scale(16_384, 1024)
        eng.ingest(tweet_batch(rng, n_tweets, t0=100))
        name = f"TweetsAboutCrime{n_conds}"
        t_trad, i_t = exec_time(eng, name, ExecutionFlags(scan_mode="trad_index"))
        t_bad, i_b = exec_time(eng, name, ExecutionFlags(scan_mode="bad_index"))
        assert i_t["results"] == i_b["results"]
        sel = i_b["scanned"] / n_tweets
        emit(f"fig16/conds{n_conds}/trad_index", t_trad,
             f"candidates={i_t['scanned']}")
        emit(f"fig16/conds{n_conds}/bad_index", t_bad,
             f"selectivity={sel:.4f};x{t_trad/max(t_bad,1e-9):.2f}")


if __name__ == "__main__":
    run(np.random.default_rng(0))
