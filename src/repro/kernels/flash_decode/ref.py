"""Pure-jnp oracle for single-token GQA decode attention with a masked cache."""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     kv_len: jnp.ndarray,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """q (B, H, D), k/v (B, KH, S, D), kv_len (B,) -> (B, H, D)."""
    out, m, l = decode_attention_partial(q, k, v, kv_len, scale)
    return (out / jnp.where(l == 0.0, 1.0, l)[..., None]).astype(q.dtype)


def decode_attention_partial(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                             kv_len: jnp.ndarray,
                             scale: Optional[float] = None
                             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Unnormalized flash-decode partials for cross-shard merging.

    Returns (acc (B, H, D) f32 = sum_j e^{s_j - m} v_j, m (B, H) f32 running
    max, l (B, H) f32 = sum_j e^{s_j - m}). Shards holding disjoint kv slices
    can be merged exactly with ``merge_partials``.
    """
    b, h, d = q.shape
    kh, s = k.shape[1], k.shape[2]
    g = h // kh
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32).reshape(b, kh, g, d)
    logits = jnp.einsum("bkgd,bkld->bkgl", qf, k.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, :] < kv_len[:, None]            # (B, S)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)                               # (B, KH, G)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(logits - m_safe[..., None])
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgl,bkld->bkgd", p, v.astype(jnp.float32))
    m_out = jnp.where(jnp.isfinite(m), m, -jnp.inf)
    return (acc.reshape(b, h, d), m_out.reshape(b, h), l.reshape(b, h))


def merge_partials(acc_a, m_a, l_a, acc_b, m_b, l_b):
    """Exact merge of two disjoint-kv flash partials (log-sum-exp algebra)."""
    m = jnp.maximum(m_a, m_b)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    ca = jnp.where(jnp.isfinite(m_a), jnp.exp(m_a - m_safe), 0.0)
    cb = jnp.where(jnp.isfinite(m_b), jnp.exp(m_b - m_safe), 0.0)
    return (acc_a * ca[..., None] + acc_b * cb[..., None],
            m, l_a * ca + l_b * cb)


def normalize(acc, l, dtype):
    return (acc / jnp.where(l == 0.0, 1.0, l)[..., None]).astype(dtype)
