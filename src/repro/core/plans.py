"""Executable channel plans: original vs the three optimizations (paper §4).

All plan functions are pure and jit-compatible (static shapes, masked
windows). The engine binds them with static ``ExecutionFlags``:

scan_mode (how candidate records are found)          -- paper Fig. 11
  "full"       full dataset scan + is_new timestamp filter   (original, no index)
  "window"     delta scan of records since last execution    (ts-ordered storage)
  "trad_index" traditional secondary index on the single most selective fixed
               predicate: candidates = that predicate's matches, remaining
               predicates evaluated at query time
  "bad_index"  the BAD index: precomputed full-conjunction matches + watermark
aggregation     join against subscription-groups instead of raw subscriptions
param_pushdown  early semi-join with UserParameters           -- paper Fig. 9(b)
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import bad_index as bidx
from repro.core import records as R
from repro.core.predicates import CompiledConditions, apply_op, evaluate_conditions
from repro.core.user_params import semi_join

SCAN_MODES = ("full", "window", "trad_index", "bad_index")
# Kernel backends come in two families (oracle = pure jnp, pallas = the
# Pallas kernels) x two join formulations: padded ("oracle"/"pallas" — the
# stacked C x shape-bucket x member-cap pair grid) and compacted
# ("compact"/"compact_pallas" — the flat CSR candidate stream below, where
# join cost scales with LIVE candidates instead of padding).
BACKENDS = ("oracle", "pallas", "compact", "compact_pallas")


def backend_family(backend: str) -> str:
    """The kernel family ("oracle" | "pallas") of any backend name."""
    return "pallas" if backend in ("pallas", "compact_pallas") else "oracle"


def is_compact(backend: str) -> bool:
    """True for the compacted-stream join formulation."""
    return backend in ("compact", "compact_pallas")


def compact_variant(backend: str) -> str:
    """The compacted-stream backend of the given backend's family."""
    return "compact_pallas" if backend_family(backend) == "pallas" \
        else "compact"


@dataclasses.dataclass(frozen=True)
class ExecutionFlags:
    scan_mode: str = "window"
    aggregation: bool = False
    param_pushdown: bool = False

    def __post_init__(self):
        if self.scan_mode not in SCAN_MODES:
            raise ValueError(f"scan_mode must be one of {SCAN_MODES}")

    @staticmethod
    def original() -> "ExecutionFlags":
        return ExecutionFlags(scan_mode="full")

    @staticmethod
    def fully_optimized() -> "ExecutionFlags":
        return ExecutionFlags(scan_mode="bad_index", aggregation=True,
                              param_pushdown=True)


@dataclasses.dataclass(frozen=True)
class ChannelPlan:
    """A channel's full physical plan: scan mode x target layout x kernel
    backend. ``ExecutionFlags`` names the paper's three optimizations;
    ``ChannelPlan`` extends it with the backend axis and is the unit the
    engine partitions ``execute_all`` by — channels sharing a plan run in
    ONE fused jitted call, distinct plans run as separate plan-groups
    (each with its own stacked caches and retry ring, keyed by the plan).
    """

    scan_mode: str = "window"
    aggregation: bool = False
    param_pushdown: bool = False
    backend: str = "oracle"
    # dispatch-time enrichment tag: the attached EnrichmentStage's hashable
    # ``identity`` (core/enrich.py), stamped by the engine when a stage is
    # active so every plan-keyed cache (compiled executables, stream
    # buckets, retry rings, warm signatures) keys on the scorer too — a
    # scorer attach/detach/swap retraces and re-rings exactly like a plan
    # switch. Never assigned to ``ChannelState.plan`` and never persisted
    # (``to_dict`` omits it).
    scorer: Optional[tuple] = None

    def __post_init__(self):
        if self.scan_mode not in SCAN_MODES:
            raise ValueError(f"scan_mode must be one of {SCAN_MODES}")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")

    @property
    def flags(self) -> "ExecutionFlags":
        """The ExecutionFlags view (everything but the backend axis)."""
        return ExecutionFlags(self.scan_mode, self.aggregation,
                              self.param_pushdown)

    @staticmethod
    def from_flags(flags: "ExecutionFlags",
                   backend: str = "oracle") -> "ChannelPlan":
        return ChannelPlan(flags.scan_mode, flags.aggregation,
                           flags.param_pushdown, backend)

    def to_dict(self) -> dict:
        return {"scan_mode": self.scan_mode, "aggregation": self.aggregation,
                "param_pushdown": self.param_pushdown, "backend": self.backend}

    @staticmethod
    def from_dict(d: dict) -> "ChannelPlan":
        return ChannelPlan(d["scan_mode"], bool(d["aggregation"]),
                           bool(d["param_pushdown"]), d.get("backend", "oracle"))


def enumerate_plans(backends=("oracle",), param_pushdown: bool = True):
    """Every static (scan mode x layout x backend) combination — the search
    space of the offline plan seeder and the planner-vs-static benchmark."""
    return tuple(ChannelPlan(scan, agg, param_pushdown, b)
                 for b in backends for scan in SCAN_MODES
                 for agg in (False, True))


@dataclasses.dataclass(frozen=True)
class ExecutionRequest:
    """The single execution spec behind ``BADEngine.execute``/``dispatch``.

    One request subsumes what used to be three overlapping entry points:

      * ``flags`` — the legacy homogeneous mode: every requested channel
        runs ``ChannelPlan.from_flags(flags, backend)``; routed through the
        SAME plan-group machinery as everything else (one synthetic group).
      * ``plan`` — an explicit homogeneous ``ChannelPlan`` (full physical
        plan, backend included). Mutually exclusive with ``flags``.
      * neither — the planner-driven mode: channels run their assigned
        ``ChannelPlan`` (``set_plan``) or the engine default, partitioned
        into plan-groups.

    ``backend`` overrides the kernel backend on whatever plan the above
    resolves to (the old ``execute_channel(backend=...)`` knob, now
    available on the fused path). ``channels`` restricts execution to a
    subset (None = all); restricted dispatches leave the other groups'
    retry rings resident. The remaining fields carry the per-call execution
    options previously spread across keyword arguments."""

    flags: Optional[ExecutionFlags] = None
    plan: Optional[ChannelPlan] = None
    backend: Optional[str] = None
    channels: Optional[tuple] = None
    advance: bool = True
    timed: bool = False
    deliver: bool = False
    resolve_spills: bool = False

    def __post_init__(self):
        if self.flags is not None and self.plan is not None:
            raise ValueError("pass flags or plan, not both")
        if self.backend is not None and self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}")
        if self.channels is not None:
            object.__setattr__(self, "channels", tuple(self.channels))

    def forced_plan(self, default_backend: str) -> Optional[ChannelPlan]:
        """The homogeneous plan this request forces on every requested
        channel, or None for the per-channel-assignment mode (where a
        ``backend`` override, if any, is applied per channel)."""
        if self.plan is not None:
            return (self.plan if self.backend is None
                    else dataclasses.replace(self.plan, backend=self.backend))
        if self.flags is not None:
            return ChannelPlan.from_flags(self.flags,
                                          self.backend or default_backend)
        return None


class TargetArrays(NamedTuple):
    """Device-side join targets: either raw subscriptions or groups."""

    params: jnp.ndarray        # (T,) int32
    brokers: jnp.ndarray       # (T,) int32
    counts: jnp.ndarray        # (T,) int32  (1 for raw subscriptions)
    by_param: jnp.ndarray      # (domain, maxT) int32, -1 padded
    by_param_count: jnp.ndarray  # (domain,) int32


class CandidateSet(NamedTuple):
    rows: jnp.ndarray      # (Rmax,) int32 row ids
    valid: jnp.ndarray     # (Rmax,) bool
    scanned: jnp.ndarray   # () int32 -- records examined (cost accounting)


class ChannelResult(NamedTuple):
    pair_rows: jnp.ndarray     # (Rmax, maxT) int32 record row of each result pair
    pair_targets: jnp.ndarray  # (Rmax, maxT) int32 target (sub or group) index
    pair_valid: jnp.ndarray    # (Rmax, maxT) bool
    matched_rows: jnp.ndarray  # (Rmax,) int32 candidate rows that matched preds
    matched_valid: jnp.ndarray  # (Rmax,) bool
    num_results: jnp.ndarray   # () int32 -- result records produced (pairs)
    num_notified: jnp.ndarray  # () int32 -- end subscribers covered
    scanned: jnp.ndarray       # () int32
    broker_bytes: jnp.ndarray  # (B,) i32 platform->broker traffic (bytes)
    broker_results: jnp.ndarray  # (B,) int32 results per broker


# ---------------------------------------------------------------------------
# Step 1: candidate discovery
# ---------------------------------------------------------------------------


def candidates_full_scan(ds: R.ActiveDataset, conds_one: CompiledConditions,
                         last_ts: jnp.ndarray, max_rows: int) -> CandidateSet:
    """Original plan: scan the whole dataset, is_new() via timestamp compare,
    then evaluate every fixed predicate at query time."""
    cap = ds.capacity
    slots = jnp.arange(cap, dtype=jnp.int32)
    row_ids = _slot_row_ids(ds, slots)
    live = (row_ids >= 0) & (row_ids < ds.size)
    ts = ds.fields[:, R.TIMESTAMP]
    is_new = ts > last_ts
    match = evaluate_conditions(ds.fields, conds_one)[:, 0]
    keep = live & is_new & match
    rows, valid = _compact(row_ids, keep, max_rows)
    return CandidateSet(rows, valid, jnp.asarray(cap, jnp.int32))


def candidates_window(ds: R.ActiveDataset, conds_one: CompiledConditions,
                      last_size: jnp.ndarray, max_rows: int) -> CandidateSet:
    """Delta scan: only records ingested since last execution (ts-ordered)."""
    row_ids = last_size + jnp.arange(max_rows, dtype=jnp.int32)
    in_range = row_ids < ds.size
    slots = row_ids % ds.capacity
    fields = ds.fields[slots]
    match = evaluate_conditions(fields, conds_one)[:, 0]
    keep = in_range & match
    return CandidateSet(jnp.where(keep, row_ids, -1), keep,
                        jnp.minimum(ds.size - last_size, max_rows).astype(jnp.int32))


def candidates_trad_index(ds: R.ActiveDataset, conds_one: CompiledConditions,
                          best_pred: int, last_size: jnp.ndarray,
                          max_rows: int, max_candidates: int) -> CandidateSet:
    """Traditional secondary index on the most selective fixed predicate:
    the index returns rows matching that ONE predicate (compacted — this is
    the index read), remaining predicates are evaluated on the candidates."""
    row_ids = last_size + jnp.arange(max_rows, dtype=jnp.int32)
    in_range = row_ids < ds.size
    slots = row_ids % ds.capacity
    fields = ds.fields[slots]
    fi = conds_one.field_idx[0, best_pred]
    op = conds_one.op[0, best_pred]
    val = conds_one.value[0, best_pred]
    idx_hit = apply_op(fields[:, fi], jnp.asarray(op), jnp.asarray(val)) & in_range
    cand_rows, cand_valid = _compact(row_ids, idx_hit, max_candidates)
    # Evaluate the remaining predicates only on index candidates.
    cfields = ds.fields[jnp.maximum(cand_rows, 0) % ds.capacity]
    match = evaluate_conditions(cfields, conds_one)[:, 0]
    keep = cand_valid & match
    return CandidateSet(jnp.where(keep, cand_rows, -1), keep,
                        jnp.sum(idx_hit.astype(jnp.int32)))


def candidates_bad_index(ds: R.ActiveDataset, index: bidx.BADIndexState,
                         channel: int, max_rows: int) -> CandidateSet:
    """BAD-index plan: fixed predicates were already evaluated at ingestion;
    read only entries newer than the watermark. No re-evaluation."""
    rows, valid = bidx.new_entries(index, channel, max_rows)
    return CandidateSet(rows, valid, jnp.sum(valid.astype(jnp.int32)))


# ---------------------------------------------------------------------------
# Step 2+3: (optional) UserParameters semi-join, then the target join
# ---------------------------------------------------------------------------


def join_param_targets(ds: R.ActiveDataset, cand: CandidateSet,
                       targets: TargetArrays, param_field: int,
                       payload_bytes: int, num_brokers: int,
                       up_mask: Optional[jnp.ndarray],
                       aggregated: bool,
                       domain: Optional[jnp.ndarray] = None,
                       fused: bool = False) -> ChannelResult:
    """record[param_field] == target.param join via the dense by_param map.

    ``domain`` overrides the clip bound when ``targets`` is padded to a
    shared shape bucket (fused multi-channel execution): the channel's *real*
    parameter domain must bound the clip so padded rows never join.
    ``fused`` switches broker accounting to a one-hot contraction — under
    vmap, segment_sum lowers to serialized scatter-adds; unvmapped, the
    scatter is fine and the dense (Rm, maxT, B) one-hot would cost memory.
    """
    slots = jnp.maximum(cand.rows, 0) % ds.capacity
    pvals = ds.fields[slots, param_field]                   # (Rm,)
    valid = cand.valid
    if up_mask is not None:
        valid = valid & semi_join(pvals, up_mask)           # Fig. 9(b) early join
    if domain is None:
        domain = targets.by_param.shape[0]
    pv = jnp.clip(pvals, 0, domain - 1)
    tgt = targets.by_param[pv]                              # (Rm, maxT)
    tgt_n = targets.by_param_count[pv]                      # (Rm,)
    maxT = tgt.shape[1]
    pair_valid = valid[:, None] & (jnp.arange(maxT)[None, :] < tgt_n[:, None]) & (tgt >= 0)
    tgt_safe = jnp.maximum(tgt, 0)
    pair_rows = jnp.where(pair_valid, cand.rows[:, None], -1)
    pair_targets = jnp.where(pair_valid, tgt, -1)
    members = jnp.where(pair_valid, targets.counts[tgt_safe], 0)  # subscribers per pair
    num_results = jnp.sum(pair_valid.astype(jnp.int32))
    num_notified = jnp.sum(members.astype(jnp.int32))
    # Platform->broker traffic: one payload per result pair; aggregated pairs
    # additionally carry the member sID list (4 B each) -- paper §4.1.2.
    # Byte totals accumulate in int32 end-to-end (exact to 2^31 bytes per
    # (channel, broker) per tick; float32 would silently round past 2^24).
    per_pair_bytes = payload_bytes + (4 * members if aggregated else jnp.zeros_like(members))
    pair_bytes = jnp.where(pair_valid, per_pair_bytes, 0).astype(jnp.int32)
    bids = jnp.where(pair_valid, targets.brokers[tgt_safe], num_brokers)
    if fused:
        # Per-broker masked reductions: each is an (Rm, maxT) elementwise
        # select + sum that XLA fuses without materializing a dense
        # (Rm, maxT, B) one-hot. Invalid pairs carry the sentinel id
        # == num_brokers and match no broker; counts stay integer end-to-end
        # (float32 accumulation would silently round past 2^24 pairs).
        broker_bytes = jnp.stack(
            [jnp.sum(jnp.where(bids == b, pair_bytes, 0))
             for b in range(num_brokers)])
        broker_results = jnp.stack(
            [jnp.sum((bids == b).astype(jnp.int32))
             for b in range(num_brokers)])
    else:
        broker_bytes = jax.ops.segment_sum(pair_bytes.ravel(), bids.ravel(),
                                           num_segments=num_brokers + 1)[:-1]
        broker_results = jax.ops.segment_sum(
            pair_valid.astype(jnp.int32).ravel(), bids.ravel(),
            num_segments=num_brokers + 1)[:-1]
    return ChannelResult(pair_rows, pair_targets, pair_valid,
                         jnp.where(valid, cand.rows, -1), valid,
                         num_results, num_notified, cand.scanned,
                         broker_bytes, broker_results)


def join_spatial(ds: R.ActiveDataset, cand: CandidateSet,
                 user_locations: jnp.ndarray, user_brokers: jnp.ndarray,
                 radius, payload_bytes, num_brokers: int,
                 spatial_fn=None, fused: bool = False) -> ChannelResult:
    """spatial_distance(user.location, record.location) < radius join
    (TweetsAboutCrime). ``spatial_fn`` lets the engine swap in the Pallas
    kernel; default is the pure-jnp oracle. ``fused`` switches broker
    accounting to masked per-broker reductions (segment_sum serializes under
    vmap), exactly as in ``join_param_targets``."""
    slots = jnp.maximum(cand.rows, 0) % ds.capacity
    locs = ds.location[slots]                              # (Rm, 2)
    if spatial_fn is None:
        from repro.kernels.spatial_match import ref as spatial_ref
        hits = spatial_ref.spatial_match(locs, user_locations, radius)
    else:
        hits = spatial_fn(locs, user_locations, radius)    # (Rm, U) bool
    pair_valid = hits & cand.valid[:, None]
    U = user_locations.shape[0]
    pair_rows = jnp.where(pair_valid, cand.rows[:, None], -1)
    pair_targets = jnp.where(pair_valid, jnp.arange(U, dtype=jnp.int32)[None, :], -1)
    num_results = jnp.sum(pair_valid.astype(jnp.int32))
    bids = jnp.where(pair_valid, user_brokers[None, :], num_brokers)
    pair_bytes = jnp.where(pair_valid, payload_bytes, 0).astype(jnp.int32)
    if fused:
        broker_bytes = jnp.stack(
            [jnp.sum(jnp.where(bids == b, pair_bytes, 0))
             for b in range(num_brokers)])
        broker_results = jnp.stack(
            [jnp.sum((bids == b).astype(jnp.int32))
             for b in range(num_brokers)])
    else:
        broker_bytes = jax.ops.segment_sum(pair_bytes.ravel(), bids.ravel(),
                                           num_segments=num_brokers + 1)[:-1]
        broker_results = jax.ops.segment_sum(pair_valid.astype(jnp.int32).ravel(),
                                             bids.ravel(),
                                             num_segments=num_brokers + 1)[:-1]
    return ChannelResult(pair_rows, pair_targets, pair_valid,
                         jnp.where(cand.valid, cand.rows, -1), cand.valid,
                         num_results, num_results, cand.scanned,
                         broker_bytes, broker_results)


# ---------------------------------------------------------------------------
# Fused multi-channel execution: every stacked function returns pytrees with a
# leading channel axis, so one jitted call drives all channels (paper scale
# goal: many channels x many subscribers with no per-channel host round-trip).
# ---------------------------------------------------------------------------


def _eval_channel_row(fields: jnp.ndarray, field_idx: jnp.ndarray,
                      op: jnp.ndarray, value: jnp.ndarray) -> jnp.ndarray:
    """(N, F) records x ONE channel's padded predicate row (P,) -> (N,) bool."""
    vals = fields[:, field_idx]                    # (N, P)
    return jnp.all(apply_op(vals, op[None], value[None]), axis=-1)


def candidates_full_scan_all(ds: R.ActiveDataset, conds: CompiledConditions,
                             last_ts: jnp.ndarray, max_rows: int,
                             match_fn=None) -> CandidateSet:
    """Stacked 'full' scan: ONE conditionsList pass covers every channel
    (the per-channel variant re-evaluates its own conjunction per call).
    ``match_fn``: optional (N, F) -> (N, C) evaluator (the Pallas
    ``predicate_filter`` kernel); default is the jnp oracle."""
    cap = ds.capacity
    slots = jnp.arange(cap, dtype=jnp.int32)
    row_ids = _slot_row_ids(ds, slots)
    live = (row_ids >= 0) & (row_ids < ds.size)
    ts = ds.fields[:, R.TIMESTAMP]
    if match_fn is None:
        match = evaluate_conditions(ds.fields, conds)      # (cap, C)
    else:
        match = match_fn(ds.fields)

    def one(last_ts_c, match_c):
        keep = live & (ts > last_ts_c) & match_c
        rows, valid = _compact(row_ids, keep, max_rows)
        return CandidateSet(rows, valid, jnp.asarray(cap, jnp.int32))

    return jax.vmap(one)(last_ts, match.T)


def candidates_window_all(ds: R.ActiveDataset, conds: CompiledConditions,
                          last_size: jnp.ndarray, max_rows: int,
                          match_fn=None) -> CandidateSet:
    """Stacked delta scan: each channel reads its own [last_size, size) window.
    ``match_fn``: optional (C, W, F) -> (C, W) evaluator of channel c's
    conjunction on its own gathered row block (``predicate_filter_rows``);
    default is the vmapped jnp oracle."""
    row_ids = last_size[:, None] + jnp.arange(max_rows, dtype=jnp.int32)[None, :]
    in_range = row_ids < ds.size                           # (C, W)
    fields = ds.fields[row_ids % ds.capacity]              # (C, W, F)
    match = _match_rows(fields, conds, match_fn)
    keep = in_range & match
    scanned = jnp.minimum(ds.size - last_size, max_rows).astype(jnp.int32)
    return CandidateSet(jnp.where(keep, row_ids, -1), keep, scanned)


def candidates_trad_index_all(ds: R.ActiveDataset, conds: CompiledConditions,
                              best_pred: jnp.ndarray, last_size: jnp.ndarray,
                              max_rows: int, max_candidates: int,
                              match_fn=None) -> CandidateSet:
    """Stacked traditional-index scan: per channel, the index read is its most
    selective fixed predicate; the rest evaluate on the candidates (via
    ``match_fn`` with the same (C, N, F) -> (C, N) contract as
    ``candidates_window_all``)."""
    field_idx = jnp.asarray(conds.field_idx)
    op = jnp.asarray(conds.op)
    value = jnp.asarray(conds.value)

    def index_read(best_c, last_size_c, fi_row, op_row, val_row):
        row_ids = last_size_c + jnp.arange(max_rows, dtype=jnp.int32)
        in_range = row_ids < ds.size
        fields = ds.fields[row_ids % ds.capacity]
        idx_hit = apply_op(fields[:, fi_row[best_c]], op_row[best_c],
                           val_row[best_c]) & in_range
        cand_rows, cand_valid = _compact(row_ids, idx_hit, max_candidates)
        return cand_rows, cand_valid, jnp.sum(idx_hit.astype(jnp.int32))

    cand_rows, cand_valid, scanned = jax.vmap(index_read)(
        best_pred, last_size, field_idx, op, value)
    cfields = ds.fields[jnp.maximum(cand_rows, 0) % ds.capacity]  # (C, Rc, F)
    keep = cand_valid & _match_rows(cfields, conds, match_fn)
    return CandidateSet(jnp.where(keep, cand_rows, -1), keep, scanned)


def _match_rows(fields: jnp.ndarray, conds: CompiledConditions,
                match_fn) -> jnp.ndarray:
    """(C, N, F) stacked row blocks -> (C, N): channel c's conjunction on its
    own block, via ``match_fn`` (Pallas) or the vmapped jnp oracle."""
    if match_fn is not None:
        return match_fn(fields)
    return jax.vmap(_eval_channel_row)(fields, jnp.asarray(conds.field_idx),
                                       jnp.asarray(conds.op),
                                       jnp.asarray(conds.value))


def candidates_bad_index_all(index: bidx.BADIndexState, channels: jnp.ndarray,
                             max_rows: int) -> CandidateSet:
    """Stacked BAD-index read: every channel's watermark window at once."""

    def one(c):
        rows, valid = bidx.new_entries(index, c, max_rows)
        return CandidateSet(rows, valid, jnp.sum(valid.astype(jnp.int32)))

    return jax.vmap(one)(channels)


def join_param_targets_all(ds: R.ActiveDataset, cand: CandidateSet,
                           targets: TargetArrays, param_field: jnp.ndarray,
                           payload_bytes: jnp.ndarray, num_brokers: int,
                           up_mask: Optional[jnp.ndarray], aggregated: bool,
                           domain: jnp.ndarray) -> ChannelResult:
    """vmapped ``join_param_targets`` over the channel axis.

    ``cand``/``targets``/``up_mask``/scalars carry a leading C axis; targets
    are shape-bucketed (padded to the max T / domain / fan-out across
    channels) with -1 / 0 padding that can never produce a valid pair.
    """

    def one(cand_c, targets_c, up_mask_c, pf_c, pb_c, dom_c):
        return join_param_targets(
            ds, cand_c, targets_c, pf_c, pb_c, num_brokers,
            up_mask_c if up_mask is not None else None, aggregated, dom_c,
            fused=True)

    um = up_mask if up_mask is not None else jnp.zeros(
        (cand.rows.shape[0], 1), dtype=bool)
    return jax.vmap(one)(cand, targets, um, param_field, payload_bytes, domain)


def join_spatial_all(ds: R.ActiveDataset, cand: CandidateSet,
                     user_locations: jnp.ndarray, user_brokers: jnp.ndarray,
                     radius: jnp.ndarray, payload_bytes: jnp.ndarray,
                     num_brokers: int, spatial_fn=None) -> ChannelResult:
    """vmapped ``join_spatial`` over the channel axis (TweetsAboutCrime at
    fused scale).

    ``cand`` carries a leading C axis; ``user_locations`` (C, U, 2) /
    ``user_brokers`` (C, U) are the stacked per-channel user sets,
    shape-bucketed by the engine with far-sentinel padding (padded users can
    never fall inside any radius); ``radius`` / ``payload_bytes`` are
    per-channel (C,) scalars. ``spatial_fn`` (e.g. the Pallas ``spatial_match``
    wrapper) is batched by vmap — pallas_call lowers the channel axis onto a
    leading grid dimension, so the whole join stays one fused device call.
    """

    def one(cand_c, locs_c, brokers_c, radius_c, payload_c):
        return join_spatial(ds, cand_c, locs_c, brokers_c, radius_c,
                            payload_c, num_brokers, spatial_fn, fused=True)

    return jax.vmap(one)(cand, user_locations, user_brokers, radius,
                         payload_bytes)


# ---------------------------------------------------------------------------
# Flat pair streams: the stacked (C, ...) pair axes as ONE channel-major
# stream proportional to total pending work instead of C x max-pending.
# PairStream/ValueStream are the wire types of the broker's fused spill
# capture (dropped pairs/sIDs keep their channel identity; the broker fills
# them by per-channel-window gathers). The flatten_* builders below are the
# standalone scatter-compaction API over arbitrary masks — exercised by the
# property suites. The compacted execution join (CandStream and the
# join_*_stream functions further down) routes the fused join itself through
# the same formulation.
# ---------------------------------------------------------------------------


class PairStream(NamedTuple):
    """Flat channel-major (row, channel, target) pair stream.

    ``valid`` marks the live slots; ``total`` is the pre-truncation count
    across ALL channels. ``flatten_pairs_all`` emits a compacted in-order
    prefix (``sum(valid) == min(total, max_total)``); the broker's spill
    capture emits per-channel windows (each channel's in-order overflow
    prefix, up to its window size). Invalid slots hold -1.
    """

    rows: jnp.ndarray       # (P,) int32
    channels: jnp.ndarray   # (P,) int32
    targets: jnp.ndarray    # (P,) int32
    valid: jnp.ndarray      # (P,) bool
    total: jnp.ndarray      # () int32


class ValueStream(NamedTuple):
    """Flat channel-major (value, channel) stream (e.g. overflowed sIDs);
    same ``valid``/``total`` semantics as ``PairStream``."""

    values: jnp.ndarray     # (P,) int32
    channels: jnp.ndarray   # (P,) int32
    valid: jnp.ndarray      # (P,) bool
    total: jnp.ndarray      # () int32


def _compact_flat_indices(mask: jnp.ndarray, out_size: int):
    """Indices of set mask positions, compacted in order into ``out_size``
    slots. Returns (idx, valid, total); positions past the buffer are dropped
    (never aliased onto the last slot), exactly like ``_compact``."""
    n = mask.shape[0]
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    dest = jnp.where(mask, pos, out_size)
    idx = jnp.zeros((out_size + 1,), dtype=jnp.int32)
    idx = idx.at[dest].set(jnp.arange(n, dtype=jnp.int32), mode="drop")
    total = jnp.sum(mask.astype(jnp.int32))
    valid = jnp.arange(out_size, dtype=jnp.int32) < total
    return idx[:out_size], valid, total


def flatten_pairs_all(pair_rows: jnp.ndarray, pair_targets: jnp.ndarray,
                      mask: jnp.ndarray, max_total: int) -> PairStream:
    """Compact a stacked (C, ...) masked pair set into one flat channel-major
    (row, channel, target) stream of at most ``max_total`` entries.

    Work downstream of this stream is proportional to the TOTAL pending pairs
    across channels, not ``C x max-pending`` — the shape-bucketed stacked
    layout's padding never survives the compaction.
    """
    C = pair_rows.shape[0]
    rows = pair_rows.reshape(C, -1)
    tgts = pair_targets.reshape(C, -1)
    per = rows.shape[1]
    idx, valid, total = _compact_flat_indices(mask.reshape(-1), max_total)
    neg = jnp.full_like(idx, -1)
    return PairStream(
        jnp.where(valid, rows.reshape(-1)[idx], neg),
        jnp.where(valid, (idx // per).astype(jnp.int32), neg),
        jnp.where(valid, tgts.reshape(-1)[idx], neg),
        valid, total)


def flatten_result_pairs(result: ChannelResult, max_total: int) -> PairStream:
    """The stacked fused-join output as a compacted flat pair stream: every
    valid (record row, channel, target) pair across all channels, in
    channel-major delivery order."""
    return flatten_pairs_all(result.pair_rows, result.pair_targets,
                             result.pair_valid, max_total)


def flatten_values_all(values: jnp.ndarray, mask: jnp.ndarray,
                       max_total: int) -> ValueStream:
    """Compact stacked (C, M) masked values into one flat channel-major
    (value, channel) stream of at most ``max_total`` entries."""
    C = values.shape[0]
    vals = values.reshape(C, -1)
    per = vals.shape[1]
    idx, valid, total = _compact_flat_indices(mask.reshape(-1), max_total)
    neg = jnp.full_like(idx, -1)
    return ValueStream(
        jnp.where(valid, vals.reshape(-1)[idx], neg),
        jnp.where(valid, (idx // per).astype(jnp.int32), neg),
        valid, total)


# ---------------------------------------------------------------------------
# Compacted execution join: the "compact"/"compact_pallas" backends. After
# stacked discovery, live candidates across ALL channels compact into one flat
# channel-major CandStream (the same CSR prefix-sum/scatter formulation as
# flatten_pairs_all); the param/spatial join, member-count gather, and broker
# accounting then run over that stream, so execution cost scales with live
# candidates instead of the padded C x shape-bucket grid. stream_to_stacked
# re-presents the stream join as a standard stacked ChannelResult (contiguous
# per-channel segments), so deliver_all — ring semantics, per-channel caps,
# conservation — runs verbatim; because the compaction is stable and
# channel-major, each channel's valid pairs appear in EXACTLY the padded
# path's ravel order, making delivery pair-for-pair identical under caps.
# ---------------------------------------------------------------------------


class CandStream(NamedTuple):
    """Flat channel-major compacted candidate stream.

    ``counts`` / ``total`` are PRE-truncation (sum over the discovery masks):
    ``total > rows.shape[0]`` means the stream overflowed its static capacity
    and the caller must re-run with a larger one (the engine's grow-on-
    overflow protocol — a truncated stream's results are never used).
    ``channels`` is 0 on invalid slots (safe as a gather index)."""

    rows: jnp.ndarray      # (S,) int32 record row ids, -1 on invalid slots
    channels: jnp.ndarray  # (S,) int32 owning channel, 0 on invalid slots
    valid: jnp.ndarray     # (S,) bool
    counts: jnp.ndarray    # (C,) int32 per-channel live counts
    total: jnp.ndarray     # () int32


class StreamJoin(NamedTuple):
    """Per-entry join output over a CandStream: (S, maxT) pair grids plus
    per-channel (C,) accounting, ready for ``stream_to_stacked``."""

    pair_rows: jnp.ndarray       # (S, maxT) int32
    pair_targets: jnp.ndarray    # (S, maxT) int32
    pair_valid: jnp.ndarray      # (S, maxT) bool
    matched_rows: jnp.ndarray    # (S,) int32
    matched_valid: jnp.ndarray   # (S,) bool
    num_results: jnp.ndarray     # (C,) int32
    num_notified: jnp.ndarray    # (C,) int32
    broker_bytes: jnp.ndarray    # (C, B) int32
    broker_results: jnp.ndarray  # (C, B) int32


def compact_candidates(cand: CandidateSet, max_total: int) -> CandStream:
    """Compact a stacked (C, Rm) CandidateSet into one flat channel-major
    stream of at most ``max_total`` live candidates. Stable: within a
    channel, candidates keep their discovery order."""
    C, Rm = cand.rows.shape
    idx, valid, total = _compact_flat_indices(cand.valid.reshape(-1),
                                              max_total)
    rows = jnp.where(valid, cand.rows.reshape(-1)[idx], -1)
    channels = jnp.where(valid, (idx // Rm).astype(jnp.int32), 0)
    counts = jnp.sum(cand.valid.astype(jnp.int32), axis=1)
    return CandStream(rows, channels, valid, counts, total)


def join_param_stream(ds: R.ActiveDataset, stream: CandStream,
                      targets: TargetArrays, param_field: jnp.ndarray,
                      payload_bytes: jnp.ndarray, num_brokers: int,
                      up_mask: Optional[jnp.ndarray], aggregated: bool,
                      domain: jnp.ndarray, join_fn=None) -> StreamJoin:
    """``join_param_targets_all`` over a compacted stream: every gather is
    per stream ENTRY (channel id -> that channel's stacked tables), so work
    is O(S x maxT) instead of O(C x Rm x maxT). ``targets`` and the
    (C,)-shaped scalars are the same stacked inputs the padded path uses.
    ``join_fn`` is the pair-expansion hook (``kernels/join_compact``): the
    jnp ref by default, the Pallas kernel under "compact_pallas"."""
    if join_fn is None:
        from repro.kernels.join_compact import ref as jc_ref
        join_fn = jc_ref.join_pairs
    ch = stream.channels
    slots = jnp.maximum(stream.rows, 0) % ds.capacity
    pvals = ds.fields[slots, param_field[ch]]               # (S,)
    valid = stream.valid
    if up_mask is not None:
        # per-entry semi_join (Fig. 9(b)): same clip/in-domain semantics
        dom_max = up_mask.shape[1]
        clipped = jnp.clip(pvals, 0, dom_max - 1)
        in_dom = (pvals >= 0) & (pvals < dom_max)
        valid = valid & up_mask[ch, clipped] & in_dom
    pv = jnp.clip(pvals, 0, domain[ch] - 1)
    tgt = targets.by_param[ch, pv]                          # (S, maxT)
    tgt_n = targets.by_param_count[ch, pv]                  # (S,)
    tgt_safe = jnp.maximum(tgt, 0)
    members_tbl = targets.counts[ch[:, None], tgt_safe]     # (S, maxT)
    bids_tbl = targets.brokers[ch[:, None], tgt_safe]       # (S, maxT)
    pair_valid, members, pair_bytes, bids = join_fn(
        tgt, tgt_n, members_tbl, bids_tbl, valid, payload_bytes[ch],
        num_brokers, aggregated)
    pair_rows = jnp.where(pair_valid, stream.rows[:, None], -1)
    pair_targets = jnp.where(pair_valid, tgt, -1)
    return StreamJoin(
        pair_rows, pair_targets, pair_valid,
        jnp.where(valid, stream.rows, -1), valid,
        *_stream_accounting(ch, pair_valid, members, pair_bytes, bids,
                            param_field.shape[0], num_brokers))


def join_spatial_stream(ds: R.ActiveDataset, stream: CandStream,
                        user_locations: jnp.ndarray, user_brokers: jnp.ndarray,
                        radius: jnp.ndarray, payload_bytes: jnp.ndarray,
                        num_brokers: int) -> StreamJoin:
    """``join_spatial_all`` over a compacted stream: each entry gathers its
    channel's user set and evaluates the euclidean oracle formula (the MXU
    spatial kernel's |t|^2+|u|^2-2t.u form is tied to the per-channel dense
    layout and rounds differently at boundaries — the compact family keeps
    the oracle formula for both backends, so compacted spatial results are
    bitwise identical to the padded oracle path)."""
    ch = stream.channels
    slots = jnp.maximum(stream.rows, 0) % ds.capacity
    locs = ds.location[slots]                               # (S, 2)
    ulocs = user_locations[ch]                              # (S, U, 2)
    d = locs[:, None, :] - ulocs
    hits = jnp.sum(d * d, axis=-1) < radius[ch][:, None] ** 2
    pair_valid = hits & stream.valid[:, None]               # (S, U)
    U = user_locations.shape[1]
    pair_rows = jnp.where(pair_valid, stream.rows[:, None], -1)
    pair_targets = jnp.where(
        pair_valid, jnp.arange(U, dtype=jnp.int32)[None, :], -1)
    members = pair_valid.astype(jnp.int32)
    pair_bytes = jnp.where(pair_valid, payload_bytes[ch][:, None],
                           0).astype(jnp.int32)
    bids = jnp.where(pair_valid, user_brokers[ch], num_brokers)
    num_results, num_notified, broker_bytes, broker_results = \
        _stream_accounting(ch, pair_valid, members, pair_bytes, bids,
                           user_locations.shape[0], num_brokers)
    return StreamJoin(pair_rows, pair_targets, pair_valid,
                      jnp.where(stream.valid, stream.rows, -1), stream.valid,
                      num_results, num_results, broker_bytes, broker_results)


def _stream_accounting(ch: jnp.ndarray, pair_valid: jnp.ndarray,
                       members: jnp.ndarray, pair_bytes: jnp.ndarray,
                       bids: jnp.ndarray, num_channels: int,
                       num_brokers: int):
    """Per-channel result/notify/broker accounting over a flat stream: ONE
    segment_sum per quantity with segment = channel x (broker + sentinel)
    (unvmapped, so the scatter-add lowering is fine; invalid pairs carry the
    sentinel broker id == num_brokers, dropped by the slice)."""
    nb1 = num_brokers + 1
    seg = ch[:, None] * nb1 + bids                          # (S, maxT)
    broker_bytes = jax.ops.segment_sum(
        pair_bytes.ravel(), seg.ravel(),
        num_segments=num_channels * nb1).reshape(
            num_channels, nb1)[:, :-1]
    pvc = pair_valid.astype(jnp.int32)
    broker_results = jax.ops.segment_sum(
        pvc.ravel(), seg.ravel(),
        num_segments=num_channels * nb1).reshape(
            num_channels, nb1)[:, :-1]
    num_results = jax.ops.segment_sum(jnp.sum(pvc, axis=1), ch,
                                      num_segments=num_channels)
    num_notified = jax.ops.segment_sum(jnp.sum(members, axis=1), ch,
                                       num_segments=num_channels)
    return num_results, num_notified, broker_bytes, broker_results


def stream_to_stacked(sj: StreamJoin, stream: CandStream,
                      scanned: jnp.ndarray, width: int) -> ChannelResult:
    """Re-present a stream join as a stacked (C, width, maxT) ChannelResult.

    The stream is channel-major, so channel c's entries are the contiguous
    segment [off_c, off_c + counts_c) — a plain offset gather rebuilds the
    per-channel view, preserving within-channel pair order exactly.
    ``width`` need only bound the largest per-channel live count (<= the
    discovery buffer width), NOT the stream size, so the stacked view never
    exceeds the padded grid's footprint. Only meaningful when the stream did
    not truncate (``total <= S``) — the engine discards overflowed runs."""
    S = stream.rows.shape[0]
    off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                           jnp.cumsum(stream.counts)[:-1].astype(jnp.int32)])
    k = jnp.arange(width, dtype=jnp.int32)
    src = off[:, None] + k[None, :]                         # (C, width)
    ok = (k[None, :] < stream.counts[:, None]) & (src < S)
    srcc = jnp.minimum(src, S - 1)
    pair_valid = sj.pair_valid[srcc] & ok[..., None]
    return ChannelResult(
        jnp.where(pair_valid, sj.pair_rows[srcc], -1),
        jnp.where(pair_valid, sj.pair_targets[srcc], -1),
        pair_valid,
        jnp.where(ok, sj.matched_rows[srcc], -1),
        sj.matched_valid[srcc] & ok,
        sj.num_results, sj.num_notified, scanned,
        sj.broker_bytes, sj.broker_results)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _slot_row_ids(ds: R.ActiveDataset, slots: jnp.ndarray) -> jnp.ndarray:
    """Stable row id currently stored in each ring slot (-1 if never used)."""
    size = ds.size
    cap = ds.capacity
    base = (size - 1 - slots) // cap * cap + slots   # largest id == slot (mod cap) and < size
    return jnp.where(size > slots % cap, base, -1).astype(jnp.int32)


def _compact(row_ids: jnp.ndarray, mask: jnp.ndarray,
             out_size: int):
    """Stable masked compaction into a fixed-size buffer."""
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    dest = jnp.where(mask, pos, out_size)
    out = jnp.full((out_size,), -1, dtype=jnp.int32)
    out = out.at[jnp.minimum(dest, out_size)].set(
        jnp.where(mask, row_ids, -1), mode="drop")
    valid = jnp.arange(out_size, dtype=jnp.int32) < jnp.sum(mask.astype(jnp.int32))
    return out, valid
