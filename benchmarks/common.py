"""Shared benchmark scaffolding: CPU-scaled BAD workloads + timing.

Smoke mode (``benchmarks.run --smoke`` / ``set_smoke()``) shrinks every
suite's sizes through ``scale()`` so the whole driver finishes in CI minutes;
``emit`` records each measurement into ``RESULTS`` so the driver can dump a
machine-readable ``BENCH_*.json`` artifact alongside the CSV stream.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import numpy as np

from repro.core import records as R
from repro.core.channel import tweets_about_drugs
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags
from repro.data.synthetic import drug_tweak, subscriptions_by_population, tweet_batch

# CPU-scale factors vs the paper (§5.1): 1M subs -> 50k, 1.2M tweets/period ->
# 32k. Structure (selectivities, skew, group caps) is unchanged.
N_SUBS = 50_000
N_TWEETS_PERIOD = 32_768
DATASET_CAP = 1 << 17
PRELOAD = 60_000

# smoke mode: CI-sized runs (same structure, ~16x smaller counts)
SMOKE = False
# every emit() lands here: [{"name", "us_per_call", "derived"}, ...]
RESULTS: List[Dict[str, object]] = []

# THE benchmark seed: every suite-local generator derives from this one
# value through ``fresh_rng(tag)``, so planner-vs-static (and any other
# A/B) comparisons see bit-identical data run to run AND engine to engine
SEED = 4242


def fresh_rng(tag: object = "") -> np.random.Generator:
    """A deterministic generator for one named stream. Same (SEED, tag) ->
    same stream, across processes (crc32, not the salted builtin ``hash``):
    engines built repeatedly inside a sweep — or once per candidate config —
    must see IDENTICAL subscriptions and tweets or the comparison measures
    data, not plans."""
    import zlib
    return np.random.default_rng((SEED, zlib.crc32(str(tag).encode())))


def set_smoke() -> None:
    """Shrink the shared workload constants for CI smoke runs. Suites route
    their own hardcoded sizes through ``scale()``."""
    global SMOKE, N_SUBS, N_TWEETS_PERIOD, PRELOAD
    SMOKE = True
    N_SUBS, N_TWEETS_PERIOD, PRELOAD = 3_000, 2_048, 4_096


def scale(n: int, floor: int = 256) -> int:
    """A suite-declared size, shrunk ~16x in smoke mode (never below floor)."""
    return n if not SMOKE else max(floor, n // 16)


def timeit(fn: Callable, *args, repeats: int = 3) -> float:
    fn(*args)                                    # warm (trace+compile)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out) if out is not None else None
        best = min(best, time.perf_counter() - t0)
    return best


def build_drug_engine(rng, n_subs: int = None, n_new: int = None,
                      match_rate: float = 0.02, group_cap=None,
                      states: int = 50, preload: int = None) -> BADEngine:
    # size defaults resolve at CALL time so set_smoke() applies to them
    n_subs = N_SUBS if n_subs is None else n_subs
    n_new = N_TWEETS_PERIOD if n_new is None else n_new
    preload = PRELOAD if preload is None else preload
    # ignore the caller's generator state on purpose: engines built
    # repeatedly inside a sweep must see IDENTICAL data (see fresh_rng)
    rng = fresh_rng("drug_engine")
    eng = BADEngine(dataset_capacity=DATASET_CAP, index_capacity=1 << 15,
                    max_window=1 << 15, max_candidates=1 << 12,
                    brokers=("Broker1", "Broker2", "Broker3", "Broker4"),
                    group_cap=group_cap)
    eng.create_channel(tweets_about_drugs())
    params, brokers = subscriptions_by_population(rng, n_subs, 4)
    params = params % states
    eng.subscribe_bulk("TweetsAboutDrugs", params, brokers)
    if preload:
        b = tweet_batch(rng, preload, t0=0)
        eng.ingest(b)
        eng.execute_channel("TweetsAboutDrugs",
                            ExecutionFlags(scan_mode="bad_index"))  # advance
    f = tweet_batch(rng, n_new, t0=10_000)
    fields = drug_tweak(np.asarray(f.fields).copy(), rng, match_rate)
    eng.ingest(R.RecordBatch.from_numpy(fields, np.asarray(f.location)))
    return eng


def exec_time(eng: BADEngine, channel: str, flags: ExecutionFlags,
              repeats: int = 3) -> Tuple[float, Dict]:
    rep = eng.execute_channel(channel, flags, advance=False)   # warm + counts
    best = float("inf")
    for _ in range(repeats):
        r = eng.execute_channel(channel, flags, advance=False, timed=True)
        best = min(best, r.wall_time_s)
    return best, {"results": rep.num_results, "notified": rep.num_notified,
                  "scanned": rep.scanned,
                  "bytes": float(rep.broker_bytes.sum())}


def emit(name: str, seconds: float, derived: str) -> None:
    RESULTS.append({"name": name, "us_per_call": round(seconds * 1e6, 1),
                    "derived": derived})
    print(f"{name},{seconds*1e6:.1f},{derived}", flush=True)
