"""Incremental churn engine: delta-maintained state ≡ from-scratch rebuild.

Covers the epoch/delta protocol end to end: aggregator slot maintenance
(incremental add/remove/compaction vs fresh aggregation), engine-level
removal with UserParameters refcounts, seeded-fuzz interleavings of
add/remove/drop_channel/re-create asserting ``execute_all(deliver=True)``
on the delta-maintained engine matches a from-scratch engine at every
checkpoint, spill-drain staleness across epoch bumps, spatial-cohort
parity, capacity-exceeded fallback, and zero-retrace steady state.
"""
import collections

import numpy as np
import pytest

from repro.core import subscriptions as subs
from repro.core.channel import (ChannelSpec, most_threatening_tweets,
                                tweets_about_crime, tweets_about_drugs)
from repro.core.churn import ChurnWorkload, run_ticks
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags
from repro.core import records as R
from repro.core.predicates import Predicate

from conftest import make_tweets


# ---------------------------------------------------------------------------
# aggregator: incremental slot maintenance vs fresh aggregation
# ---------------------------------------------------------------------------


def _group_sig(g: subs.SubscriptionGroups):
    return sorted((int(g.group_params[i]), int(g.group_brokers[i]),
                   tuple(sorted(g.group_sids[i][:g.group_counts[i]].tolist())))
                  for i in range(g.num_groups))


def test_aggregator_interleaved_ops_match_fresh_aggregate(rng):
    """Random interleavings of add_bulk/remove_bulk/add/remove keep the live
    partition exactly equal to the live subscription set, with every group
    within cap and key-consistent."""
    for trial in range(20):
        r = np.random.default_rng(trial)
        cap = int(r.integers(1, 9))
        agg = subs.Aggregator(cap=cap)
        live = {}
        for step in range(10):
            op = int(r.integers(0, 3))
            if op == 0 or not live:
                n = int(r.integers(1, 50))
                p = r.integers(0, 6, n).astype(np.int32)
                b = r.integers(0, 3, n).astype(np.int32)
                s = agg.add_bulk(p, b)
                live.update({int(x): (int(pp), int(bb))
                             for x, pp, bb in zip(s, p, b)})
            elif op == 1:
                pick = r.choice(list(live.keys()),
                                int(r.integers(1, len(live) + 1)),
                                replace=False)
                removed = agg.remove_bulk(pick.astype(np.int32))
                want = collections.Counter(
                    live[int(x)][0] for x in pick)
                assert collections.Counter(removed.tolist()) == want
                for x in pick:
                    live.pop(int(x))
            else:
                x = int(r.choice(list(live.keys())))
                pp, bb = live.pop(x)
                assert agg.remove_subscription(pp, bb, x)
            flat = subs.flatten_groups(agg.build())
            assert sorted(flat.sids.tolist()) == sorted(live.keys())
            assert agg.num_subscriptions == len(live)
            for sid, pp, bb in zip(flat.sids.tolist(), flat.params.tolist(),
                                   flat.brokers.tolist()):
                assert live[sid] == (pp, bb)
            g = agg.build()
            assert (g.group_counts >= 1).all()
            assert (g.group_counts <= cap).all()


def test_add_bulk_from_empty_matches_aggregate(rng):
    params = rng.integers(0, 5, 400).astype(np.int32)
    brokers = rng.integers(0, 2, 400).astype(np.int32)
    agg = subs.Aggregator(cap=7)
    agg.add_bulk(params, brokers)
    ref = subs.aggregate(subs.SubscriptionTable.build(params, brokers), 7)
    # identical groups INCLUDING membership (not just the count multiset):
    # from empty, the incremental chop equals the vectorized sort+chop
    assert _group_sig(agg.build()) == _group_sig(ref)


def test_compaction_bounds_slots_and_fixes_fragmentation(rng):
    """Long add/remove cycling neither leaks slot rows (free-list reuse) nor
    accumulates fragmented groups past the compaction slack."""
    agg = subs.Aggregator(cap=8, compact_slack=2)
    sids = agg.add_bulk(rng.integers(0, 4, 400), np.zeros(400, np.int32))
    peak = agg.num_slots
    live = set(sids.tolist())
    for cycle in range(30):
        pick = rng.choice(np.asarray(sorted(live), np.int32), 120,
                          replace=False)
        agg.remove_bulk(pick)
        live -= set(int(x) for x in pick)
        new = agg.add_bulk(rng.integers(0, 4, 120), np.zeros(120, np.int32))
        live |= set(new.tolist())
    # capacity stays bounded near the peak: dead slots were reused
    assert agg.num_slots <= peak + 8
    # every key is within compact_slack of its minimal group count
    for (p, b), lst in agg._by_key.items():
        total = agg._key_subs[(p, b)]
        minimal = -(-total // agg.cap)
        assert len(lst) - minimal < agg.compact_slack
    assert agg.build().num_subscriptions == len(live)


def test_delta_slots_cover_all_mutations(rng):
    """Every mutated/opened/freed slot appears in the taken delta; patching
    ONLY those slots reproduces the full slot table."""
    agg = subs.Aggregator(cap=4)
    sids = agg.add_bulk(rng.integers(0, 5, 100), rng.integers(0, 2, 100))
    agg.take_delta()
    shadow = agg.slot_arrays()
    # interleave: removals + adds
    agg.remove_bulk(sids[10:60])
    agg.add_bulk(rng.integers(0, 5, 30), rng.integers(0, 2, 30))
    d = agg.take_delta()
    sl = sorted(d.slots)
    p, b, c, s = agg.slot_rows(sl)
    sp, sb, sc, ss = shadow
    grow = agg.num_slots - sp.shape[0]
    if grow > 0:
        sp = np.concatenate([sp, np.zeros(grow, np.int32)])
        sb = np.concatenate([sb, np.zeros(grow, np.int32)])
        sc = np.concatenate([sc, np.zeros(grow, np.int32)])
        ss = np.concatenate([ss, np.full((grow, agg.cap), -1, np.int32)])
    sp[sl], sb[sl], sc[sl], ss[sl] = p, b, c, s
    np.testing.assert_array_equal(sp, agg.slot_arrays()[0])
    np.testing.assert_array_equal(sb, agg.slot_arrays()[1])
    np.testing.assert_array_equal(sc, agg.slot_arrays()[2])
    np.testing.assert_array_equal(ss, agg.slot_arrays()[3])


# ---------------------------------------------------------------------------
# engine: removal API + refcounts
# ---------------------------------------------------------------------------


def test_remove_subscriptions_decrements_refcounts(rng):
    eng = BADEngine(brokers=("B1", "B2"), group_cap=8)
    eng.create_channel(tweets_about_drugs())
    params = rng.integers(0, 50, 300).astype(np.int32)
    sids = eng.subscribe_bulk("TweetsAboutDrugs", params,
                              rng.integers(0, 2, 300))
    st = eng.channels["TweetsAboutDrugs"]
    assert int(st.user_params.refcount.sum()) == 300
    e0 = st.epoch
    n = eng.remove_subscriptions("TweetsAboutDrugs", sids[:200])
    assert n == 200
    assert st.epoch == e0 + 1
    np.testing.assert_array_equal(
        st.user_params.refcount,
        np.bincount(params[200:].astype(np.int64), minlength=50))
    # the early semi-join mask SHRINKS when a param's last subscriber leaves
    gone = set(params[:200].tolist()) - set(params[200:].tolist())
    if gone:
        mask = np.asarray(st.user_params.mask())
        assert not mask[sorted(gone)].any()
    # unknown sIDs are ignored, nothing double-decremented
    assert eng.remove_subscriptions("TweetsAboutDrugs", sids[:200]) == 0
    assert int(st.user_params.refcount.sum()) == 100


# ---------------------------------------------------------------------------
# fuzz: delta-maintained execute_all ≡ from-scratch engine
# ---------------------------------------------------------------------------


FUZZ_FLAGS = [
    ExecutionFlags(scan_mode="window", aggregation=True, param_pushdown=True),
    ExecutionFlags(scan_mode="window"),
    ExecutionFlags(scan_mode="bad_index", aggregation=True,
                   param_pushdown=True),
]


def _fresh_replay(live, timeline, users=None, user_brokers=None,
                  cohorts=None):
    """A from-scratch engine: replays the create/drop/ingest TIMELINE (a
    channel's record visibility starts at its creation — window start and
    BAD-index rows alike), then loads exactly the live subscription set
    with the ORIGINAL sIDs so delivered-sID multisets are comparable.
    Subscription load order does not affect candidate sets."""
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("B1", "B2"), group_cap=8)
    for kind, payload in timeline:
        if kind == "create":
            eng.create_channel(payload)
        elif kind == "drop":
            eng.drop_channel(payload)
        else:
            eng.ingest(payload)
    if users is not None:
        eng.set_user_locations(users, user_brokers)
    for name, subs_live in live.items():
        if subs_live:
            arr = sorted(subs_live.items())
            sids = np.asarray([s for s, _ in arr], np.int32)
            packed = np.asarray([v for _, v in arr], np.int64)
            st = eng.channels[name]
            st.aggregator.add_bulk(packed & 0xFFFF, packed >> 16, sids=sids)
            st.user_params.add_bulk(packed & 0xFFFF)
            st.note_change()
    for name, uids in (cohorts or {}).items():
        eng.subscribe_users(name, np.asarray(sorted(uids), np.int32))
    return eng


def _delivered_sets(eng, flags):
    """Semantic outcome of one tick: per channel (num_results, num_notified,
    broker_bytes, broker_results, delivered sid multiset, delivered (row,
    member-count) multiset) with caps large enough that nothing overflows."""
    from repro.core.broker import fanout_sids, pack_payloads
    out = {}
    reps = eng.execute_all(flags, advance=False, timed=False, deliver=True)
    for name, rep in reps.items():
        # the table matching the fused path's target space (slot tables on
        # an incremental engine — compacted build rows would misroute when
        # the slot table has holes)
        sids_tbl = eng.fused_sids_table(name, flags.aggregation)
        buf, dlv, ov = pack_payloads(rep.result, sids_tbl, 2, 1 << 14)
        assert int(ov) == 0
        rows = np.asarray(buf)[:int(dlv)]
        nbuf, ndlv, nov = fanout_sids(rep.result, sids_tbl, 1 << 15)
        assert int(nov) == 0
        out[name] = (
            rep.num_results, rep.num_notified,
            tuple(np.asarray(rep.result.broker_bytes).tolist()),
            tuple(np.asarray(rep.result.broker_results).tolist()),
            sorted(np.asarray(nbuf)[:int(ndlv)].tolist()),
            sorted(map(tuple, rows[:, [0, 2]].tolist())),
        )
    eng.flush_rings()
    eng.spill.clear()
    return out


@pytest.mark.parametrize("flags", FUZZ_FLAGS,
                         ids=lambda f: f"{f.scan_mode}"
                         f"{'+agg' if f.aggregation else ''}")
def test_fuzz_delta_engine_equals_fresh_engine(rng, flags):
    """Seeded interleavings of subscribe_bulk / subscribe /
    remove_subscriptions / unsubscribe / drop_channel+re-create / ingest:
    at every checkpoint the delta-maintained engine's
    ``execute_all(deliver=True)`` outcome (counts, per-broker accounting,
    delivered sID multiset, delivered row/member lines) equals a
    from-scratch engine built from the live set."""
    specs = [tweets_about_drugs(), most_threatening_tweets()]
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("B1", "B2"), group_cap=8)
    timeline = []
    for s in specs:
        eng.create_channel(s)
        timeline.append(("create", s))
    live = {s.name: {} for s in specs}   # sid -> param | (broker << 16)

    def add_bulk(name, n):
        params = rng.integers(0, 50, n).astype(np.int32)
        brokers = rng.integers(0, 2, n).astype(np.int32)
        sids = eng.subscribe_bulk(name, params, brokers)
        live[name].update({int(s): int(p) | (int(b) << 16)
                           for s, p, b in zip(sids, params, brokers)})

    add_bulk("TweetsAboutDrugs", 150)
    add_bulk("MostThreateningTweets", 100)
    for step in range(12):
        op = int(rng.integers(0, 6))
        name = ("TweetsAboutDrugs", "MostThreateningTweets")[
            int(rng.integers(0, 2))]
        if op == 0:
            add_bulk(name, int(rng.integers(1, 60)))
        elif op == 1 and live[name]:
            p = int(rng.integers(0, 50))
            bi = int(rng.integers(2))
            sid = eng.subscribe(name, p, ("B1", "B2")[bi])
            live[name][sid] = p | (bi << 16)
        elif op == 2 and live[name]:
            pick = rng.choice(list(live[name].keys()),
                              min(len(live[name]),
                                  int(rng.integers(1, 80))), replace=False)
            n = eng.remove_subscriptions(name, pick.astype(np.int32))
            assert n == len(set(pick.tolist()))
            for x in pick:
                live[name].pop(int(x))
        elif op == 3 and live[name]:
            sid = int(rng.choice(list(live[name].keys())))
            v = live[name].pop(sid)
            assert eng.unsubscribe(name, v & 0xFFFF,
                                   ("B1", "B2")[v >> 16], sid)
        elif op == 4 and name == "MostThreateningTweets":
            # drop + re-create: epoch state restarts, caches must not
            # serve the dead channel's arrays; record visibility restarts
            # at re-creation (the timeline replay mirrors that)
            eng.drop_channel(name)
            spec2 = most_threatening_tweets()
            eng.create_channel(spec2)
            timeline.append(("drop", name))
            timeline.append(("create", spec2))
            live[name] = {}
            add_bulk(name, int(rng.integers(1, 50)))
        else:
            b = make_tweets(rng, int(rng.integers(20, 80)),
                            t0=1000 + 100 * step, match_drugs=0.3)
            eng.ingest(b)
            timeline.append(("ingest", b))
        if step % 3 == 2:    # checkpoint
            fresh = _fresh_replay(live, timeline)
            got = _delivered_sets(eng, flags)
            want = _delivered_sets(fresh, flags)
            assert got == want, f"step {step}"
    fresh = _fresh_replay(live, timeline)
    assert _delivered_sets(eng, flags) == _delivered_sets(fresh, flags)


# ---------------------------------------------------------------------------
# spill staleness across epochs
# ---------------------------------------------------------------------------


def test_spill_drain_staleness_across_epoch_bumps(rng):
    """Pair spills recorded at epoch e are unroutable after ANY further
    epoch bump — including one produced by the new bulk-removal API — and
    drain as counted drops; sid spills survive (raw ids never go stale)."""
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("B1", "B2"), group_cap=8,
                    max_deliver_pairs=16, max_notify=32)
    eng.create_channel(tweets_about_drugs())
    sids = eng.subscribe_bulk("TweetsAboutDrugs",
                              rng.integers(0, 50, 200),
                              rng.integers(0, 2, 200))
    eng.ingest(make_tweets(rng, 500, match_drugs=0.3))
    flags = ExecutionFlags(scan_mode="window")
    rep = eng.execute_channel("TweetsAboutDrugs", flags, advance=False,
                              timed=False, deliver=True)
    assert rep.overflow.spilled_pairs > 0
    eng.remove_subscriptions("TweetsAboutDrugs", sids[:5])   # epoch bump
    dropped = delivered_sids = 0
    while eng.spill.pending_pairs("TweetsAboutDrugs") \
            + eng.spill.pending_sids("TweetsAboutDrugs") > 0:
        dr = eng.drain_spilled().get("TweetsAboutDrugs")
        if dr is None:
            break
        assert dr.stats.delivered_pairs == 0
        dropped += dr.stats.dropped_pairs
        delivered_sids += dr.stats.delivered_sids
    assert dropped == rep.overflow.spilled_pairs
    assert delivered_sids == rep.overflow.spilled_sids


# ---------------------------------------------------------------------------
# steady state: zero retraces, capacity fallback
# ---------------------------------------------------------------------------


def test_steady_churn_zero_retraces_and_correct(rng):
    """After warmup, steady balanced churn patches in place: no retraces,
    no rebuilds — and the delta-maintained engine still matches a fresh
    engine at the end."""
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("B1", "B2"), group_cap=8)
    spec = tweets_about_drugs()
    eng.create_channel(spec)
    sids = eng.subscribe_bulk("TweetsAboutDrugs",
                              rng.integers(0, 50, 600),
                              rng.integers(0, 2, 600))
    wl = [ChurnWorkload("TweetsAboutDrugs", adds_per_tick=64,
                        removes_per_tick=64, num_brokers=2)]
    flags = ExecutionFlags.fully_optimized()
    kw = dict(flags=flags, deliver=True, ingest_per_tick=64,
              make_batch=lambda r, n, t0: make_tweets(r, n, t0=t0,
                                                      match_drugs=0.2),
              live_sids={"TweetsAboutDrugs": sids})
    run_ticks(eng, wl, 4, rng, warmup=4, **kw)          # warm (untimed)
    rep = run_ticks(eng, wl, 5, rng, warmup=0, **kw)
    assert rep.maintenance.traces == 0, rep.maintenance
    assert rep.maintenance.rebuilds == 0, rep.maintenance
    assert rep.maintenance.patches >= 5
    # end-state equivalence vs a fresh engine over one more tick
    st = eng.channels["TweetsAboutDrugs"]
    flat = eng._flat_table(st)
    fresh = BADEngine(dataset_capacity=2048, index_capacity=1024,
                      max_window=1024, max_candidates=256,
                      brokers=("B1", "B2"), group_cap=8)
    fresh.create_channel(spec)
    fresh.subscribe_bulk("TweetsAboutDrugs", flat.params, flat.brokers)
    b = make_tweets(rng, 200, t0=10 ** 6, match_drugs=0.3)
    eng.ingest(b)
    fresh.ingest(b)
    # flat layout: one target per subscription -> EXACT equality (counts
    # and bytes); aggregated layout: the churned group partition may differ
    # from fresh aggregation within compact_slack, but the subscriber-level
    # outcome (num_notified) must match
    f_flat = ExecutionFlags(scan_mode="window")
    g = eng.execute_all(f_flat, advance=False, timed=False)["TweetsAboutDrugs"]
    w = fresh.execute_all(f_flat, advance=False,
                          timed=False)["TweetsAboutDrugs"]
    assert (g.num_results, g.num_notified) == (w.num_results, w.num_notified)
    np.testing.assert_allclose(g.broker_bytes, w.broker_bytes)
    f_agg = ExecutionFlags(scan_mode="window", aggregation=True,
                           param_pushdown=True)
    g = eng.execute_all(f_agg, advance=False, timed=False)["TweetsAboutDrugs"]
    w = fresh.execute_all(f_agg, advance=False,
                          timed=False)["TweetsAboutDrugs"]
    assert g.num_notified == w.num_notified


def test_flat_steady_churn_zero_rebuilds_and_retraces(rng):
    """FLAT layout (per-subscription rows): steady balanced churn patches
    the stacked cache in place — zero rebuilds, zero retraces after warmup —
    and the delta-maintained flat state still matches the per-channel
    from-scratch reference."""
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("B1", "B2"), group_cap=8)
    eng.create_channel(tweets_about_drugs())
    sids = eng.subscribe_bulk("TweetsAboutDrugs",
                              rng.integers(0, 50, 600),
                              rng.integers(0, 2, 600))
    wl = [ChurnWorkload("TweetsAboutDrugs", adds_per_tick=64,
                        removes_per_tick=64, num_brokers=2)]
    flags = ExecutionFlags(scan_mode="window")     # flat, no aggregation
    kw = dict(flags=flags, deliver=True, ingest_per_tick=64,
              make_batch=lambda r, n, t0: make_tweets(r, n, t0=t0,
                                                      match_drugs=0.2),
              live_sids={"TweetsAboutDrugs": sids})
    run_ticks(eng, wl, 4, rng, warmup=4, **kw)          # warm (untimed)
    rep = run_ticks(eng, wl, 5, rng, warmup=0, **kw)
    assert rep.maintenance.traces == 0, rep.maintenance
    assert rep.maintenance.rebuilds == 0, rep.maintenance
    assert rep.maintenance.patches >= 5
    # end-state parity vs the per-channel from-scratch path
    b = make_tweets(rng, 200, t0=10 ** 7, match_drugs=0.3)
    eng.ingest(b)
    got = eng.execute_all(flags, advance=False, timed=False)["TweetsAboutDrugs"]
    seq = eng.execute_channel("TweetsAboutDrugs", flags, advance=False,
                              timed=False)
    assert (got.num_results, got.num_notified) == (seq.num_results,
                                                   seq.num_notified)
    np.testing.assert_allclose(got.broker_bytes, seq.broker_bytes)


def test_flat_slot_spills_drain_against_flat_table(rng):
    """Fused FLAT spills on an incremental engine carry FLAT-slot targets;
    with holes in the flat slot table (removals) the drain must re-pack
    against the flat slot table — the compacted flatten_groups table would
    notify the wrong subscribers."""
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("B1",), group_cap=4,
                    max_deliver_pairs=4, max_notify=1 << 12,
                    ring_capacity=0)   # force overflow through the host queue
    eng.create_channel(tweets_about_drugs())
    params = np.asarray(list(range(10)) * 4, np.int32)
    sids = eng.subscribe_bulk("TweetsAboutDrugs", params,
                              np.zeros(len(params), np.int32))
    # free a scattered set of flat slots -> holes below live slots
    gone = sids[params == 2]
    assert eng.remove_subscriptions("TweetsAboutDrugs", gone) == len(gone)
    agg = eng.channels["TweetsAboutDrugs"].aggregator
    assert agg.num_flat_slots > agg.num_subscriptions   # holes exist
    fields = np.zeros((30, 10), dtype=np.int32)
    fields[:, R.STATE] = np.arange(30) % 10
    fields[:, R.THREATENING_RATE] = 10
    fields[:, R.DRUG_ACTIVITY] = 3
    fields[:, R.TIMESTAMP] = 50
    eng.ingest(R.RecordBatch.from_numpy(fields))
    flags = ExecutionFlags(scan_mode="window")          # flat layout
    rep = eng.execute_all(flags, advance=False, timed=False,
                          deliver=True)["TweetsAboutDrugs"]
    assert rep.overflow.spilled_pairs > 0
    sid_param = {int(s): int(p) for s, p in zip(sids, params)
                 if int(s) not in set(gone.tolist())}
    checked = 0
    while eng.spill.pending_pairs() > 0:
        for dr in eng.drain_spilled().values():
            if dr.payload is None:
                continue
            for line in dr.payload[:dr.stats.delivered_pairs]:
                row, members = int(line[0]), int(line[2])
                assert members == 1                     # flat: one sub/row
                got = int(line[4])
                want_param = int(fields[row, R.STATE])
                assert sid_param[got] == want_param, (row, got)
                checked += 1
    assert checked > 0
    eng.spill.clear()


def test_capacity_exceeded_falls_back_to_rebuild(rng):
    """Growing past the padded slot capacity triggers a (counted) full
    rebuild with a bigger bucket — results stay correct throughout."""
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("B1",), group_cap=4)
    eng.create_channel(tweets_about_drugs())
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, 40),
                       np.zeros(40, np.int32))
    flags = ExecutionFlags(scan_mode="window", aggregation=True,
                           param_pushdown=True)
    eng.ingest(make_tweets(rng, 300, match_drugs=0.3))
    eng.execute_all(flags, advance=False, timed=False)    # warm cache
    m0 = eng.maintenance.snapshot()
    # quadruple the subscription set: slots blow past the padded bucket
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, 400),
                       np.zeros(400, np.int32))
    got = eng.execute_all(flags, advance=False, timed=False)
    d = eng.maintenance.since(m0)
    assert d.rebuilds >= 1
    seq = eng.execute_channel("TweetsAboutDrugs", flags, advance=False,
                              timed=False)
    assert got["TweetsAboutDrugs"].num_results == seq.num_results
    assert got["TweetsAboutDrugs"].num_notified == seq.num_notified


def test_out_of_band_mutation_forces_rebuild(rng):
    """Mutating the aggregator directly + invalidate_targets (the legacy
    hatch, used by the replay benchmark) leaves no delta — the cache must
    detect the gap and rebuild, not serve stale arrays."""
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("B1",), group_cap=8)
    eng.create_channel(tweets_about_drugs())
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, 100),
                       np.zeros(100, np.int32))
    flags = ExecutionFlags(scan_mode="window", aggregation=True,
                           param_pushdown=True)
    eng.ingest(make_tweets(rng, 200, match_drugs=0.3))
    eng.execute_all(flags, advance=False, timed=False)
    st = eng.channels["TweetsAboutDrugs"]
    st.aggregator.add_subscription(7, 0)     # out-of-band
    st.user_params.add(7)
    st.invalidate_targets()
    got = eng.execute_all(flags, advance=False, timed=False)
    seq = eng.execute_channel("TweetsAboutDrugs", flags, advance=False,
                              timed=False)
    assert got["TweetsAboutDrugs"].num_results == seq.num_results
    assert got["TweetsAboutDrugs"].num_notified == seq.num_notified


# ---------------------------------------------------------------------------
# spatial cohorts
# ---------------------------------------------------------------------------


def _cohort_engine(rng, n_users=40):
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("B1", "B2"), group_cap=8)
    eng.create_channel(tweets_about_crime(1))
    eng.set_user_locations(
        (rng.normal(size=(n_users, 2)) * 30).astype(np.float32),
        rng.integers(0, 2, n_users))
    eng.ingest(make_tweets(rng, 400))
    return eng


def test_cohort_restricts_spatial_matches(rng):
    """An explicit cohort serves ONLY its members; delivered sIDs are global
    user ids; fused and per-channel paths agree."""
    eng = _cohort_engine(rng)
    flags = ExecutionFlags(scan_mode="window")
    all_users = eng.execute_all(flags, advance=False,
                                timed=False)["TweetsAboutCrime1"]
    cohort = np.arange(0, 40, 2)
    eng.subscribe_users("TweetsAboutCrime1", cohort)
    got = eng.execute_all(flags, advance=False, timed=False,
                          deliver=True)["TweetsAboutCrime1"]
    seq = eng.execute_channel("TweetsAboutCrime1", flags, advance=False,
                              timed=False, deliver=True)
    assert got.num_results == seq.num_results
    assert got.overflow == seq.overflow
    assert got.num_results < all_users.num_results
    # delivered sids are GLOBAL uids drawn from the cohort
    from repro.core.broker import fanout_sids
    tbl = eng._spatial_sids_table(eng.channels["TweetsAboutCrime1"])
    buf, dlv, ov = fanout_sids(seq.result, tbl, 1 << 14)
    assert int(ov) == 0
    delivered = set(np.asarray(buf)[:int(dlv)].tolist())
    assert delivered and delivered <= set(cohort.tolist())
    eng.spill.clear()


def test_cohort_churn_patches_match_rebuild(rng):
    """Cohort add/remove maintained by deltas == a fresh engine given the
    final cohort, with zero rebuilds across steady cohort churn."""
    eng = _cohort_engine(rng)
    eng.subscribe_users("TweetsAboutCrime1", np.arange(20))
    flags = ExecutionFlags(scan_mode="window")
    eng.execute_all(flags, advance=False, timed=False)      # warm
    m0 = eng.maintenance.snapshot()
    cohort = set(range(20))
    for step in range(6):
        out = rng.choice(sorted(cohort), 3, replace=False)
        eng.unsubscribe_users("TweetsAboutCrime1", out)
        cohort -= set(int(x) for x in out)
        inn = rng.integers(0, 40, 3)
        eng.subscribe_users("TweetsAboutCrime1", inn)
        cohort |= set(int(x) for x in inn)
        got = eng.execute_all(flags, advance=False, timed=False)
        seq = eng.execute_channel("TweetsAboutCrime1", flags, advance=False,
                                  timed=False)
        assert got["TweetsAboutCrime1"].num_results == seq.num_results
    assert eng.maintenance.since(m0).rebuilds == 0
    # equivalence vs fresh engine holding the final cohort
    fresh = _cohort_engine(np.random.default_rng(0))
    # rebuild identical world: same users/records as eng
    fresh.set_user_locations(np.asarray(eng.user_locations),
                             np.asarray(eng.user_brokers))
    fresh.subscribe_users("TweetsAboutCrime1",
                          np.asarray(sorted(cohort), np.int32))
    got = eng.execute_all(flags, advance=False, timed=False)
    want = fresh.execute_all(flags, advance=False, timed=False)
    assert got["TweetsAboutCrime1"].num_results == \
        want["TweetsAboutCrime1"].num_results


def test_remove_bulk_ignores_wild_sids(rng):
    """Unknown sIDs — including negative and past-the-map values — are
    ignored per contract, never an IndexError."""
    agg = subs.Aggregator(cap=4)
    sids = agg.add_bulk(np.zeros(6, np.int32), np.zeros(6, np.int32))
    out = agg.remove_bulk(np.asarray([-5000, -1, 10 ** 7, int(sids[0])],
                                     np.int64))
    assert out.tolist() == [0]
    assert agg.num_subscriptions == 5


def test_slot_space_spills_drain_against_slot_table(rng):
    """Fused aggregated spills on an incremental engine carry SLOT-space
    targets; with free slots in the table (a group emptied by removals) the
    drain must re-pack against the slot table — the compacted build() table
    would notify the wrong subscribers."""
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("B1",), group_cap=4,
                    max_deliver_pairs=4, max_notify=1 << 12,
                    ring_capacity=0)   # force overflow through the host queue
    eng.create_channel(tweets_about_drugs())
    # params 0..9, one group each (plus param 3 twice to survive removal)
    params = np.asarray(list(range(10)) * 4, np.int32)
    sids = eng.subscribe_bulk("TweetsAboutDrugs", params,
                              np.zeros(len(params), np.int32))
    # empty param 2's group entirely -> its slot goes on the free list,
    # shifting build()'s compacted rows relative to slot indices
    agg = eng.channels["TweetsAboutDrugs"].aggregator
    gone = sids[params == 2]
    assert eng.remove_subscriptions("TweetsAboutDrugs", gone) == len(gone)
    assert agg.num_live_groups < agg.num_slots   # a hole exists
    fields = np.zeros((30, 10), dtype=np.int32)
    fields[:, R.STATE] = np.arange(30) % 10
    fields[:, R.THREATENING_RATE] = 10
    fields[:, R.DRUG_ACTIVITY] = 3
    fields[:, R.TIMESTAMP] = 50
    eng.ingest(R.RecordBatch.from_numpy(fields))
    flags = ExecutionFlags(scan_mode="window", aggregation=True,
                           param_pushdown=True)
    rep = eng.execute_all(flags, advance=False, timed=False,
                          deliver=True)["TweetsAboutDrugs"]
    assert rep.overflow.spilled_pairs > 0
    # oracle: every drained payload line's sID list must hold sIDs whose
    # live param equals the record's STATE field
    sid_param = {int(s): int(p) for s, p in zip(sids, params)
                 if int(s) not in set(gone.tolist())}
    checked = 0
    while eng.spill.pending_pairs() > 0:
        for dr in eng.drain_spilled().values():
            if dr.payload is None:
                continue
            for line in dr.payload[:dr.stats.delivered_pairs]:
                row, members = int(line[0]), int(line[2])
                assert members > 0
                got = [int(x) for x in line[4:4 + members]]
                want_param = int(fields[row, R.STATE])
                for s in got:
                    assert sid_param[s] == want_param, (row, got)
                checked += 1
    assert checked > 0
    eng.spill.clear()


def test_empty_cohort_creation_bumps_epoch(rng):
    """subscribe_users([]) flips a channel from all-users to an EMPTY
    cohort: pending spatial spills must go stale (target space remapped)
    and execution must now serve nobody."""
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256,
                    brokers=("B1",), group_cap=8,
                    max_deliver_pairs=4, max_notify=8)
    eng.create_channel(tweets_about_crime(1))
    eng.set_user_locations(np.zeros((8, 2), np.float32))
    fields = np.zeros((20, 10), dtype=np.int32)
    fields[:, R.ABOUT_COUNTRY] = 0
    fields[:, R.TIMESTAMP] = 5
    eng.ingest(R.RecordBatch.from_numpy(fields,
                                        np.zeros((20, 2), np.float32)))
    flags = ExecutionFlags(scan_mode="window")
    rep = eng.execute_channel("TweetsAboutCrime1", flags, advance=False,
                              timed=False, deliver=True)
    assert rep.overflow.spilled_pairs > 0
    e0 = eng.channels["TweetsAboutCrime1"].epoch
    eng.subscribe_users("TweetsAboutCrime1", np.zeros((0,), np.int32))
    assert eng.channels["TweetsAboutCrime1"].epoch == e0 + 1
    dropped = 0
    while eng.spill.pending_pairs("TweetsAboutCrime1") > 0:
        dr = eng.drain_spilled().get("TweetsAboutCrime1")
        if dr is None:
            break
        assert dr.stats.delivered_pairs == 0   # stale, not misrouted
        dropped += dr.stats.dropped_pairs
    assert dropped == rep.overflow.spilled_pairs
    got = eng.execute_all(flags, advance=False, timed=False)
    assert got["TweetsAboutCrime1"].num_results == 0
    eng.spill.clear()


def test_cohort_validation_and_empty(rng):
    eng = _cohort_engine(rng)
    with pytest.raises(ValueError, match="not a spatial"):
        eng2 = BADEngine()
        eng2.create_channel(tweets_about_drugs())
        eng2.subscribe_users("TweetsAboutDrugs", [0])
    with pytest.raises(ValueError, match="out of"):
        eng.subscribe_users("TweetsAboutCrime1", [99])
    assert eng.unsubscribe_users("TweetsAboutCrime1", [3]) == 0  # no cohort
    eng.subscribe_users("TweetsAboutCrime1", [1, 2, 3])
    assert eng.unsubscribe_users("TweetsAboutCrime1", [1, 2, 3]) == 3
    flags = ExecutionFlags(scan_mode="window")
    # empty cohort: nobody is served
    got = eng.execute_all(flags, advance=False, timed=False)
    assert got["TweetsAboutCrime1"].num_results == 0
