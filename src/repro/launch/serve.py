"""Batched serving loop: prefill + decode with KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.steps import build_decode_step
from repro.models.model import ModelApi


def prefill_scores(params, cfg, tokens: jnp.ndarray,
                   lanes: int = 64) -> jnp.ndarray:
    """One batched prefill as a relevance scorer: (B, S) int32 prompts ->
    (B,) float32 scores, the mean of the first ``lanes`` final-position
    logits. This is the serving path's prefill (``lm.forward`` over the
    full prompt, no KV cache kept) reshaped for the engine's enrichment
    hook (``core/enrich.LMScorer``): pure in ``params``/``tokens``, so it
    traces INTO the engine's fused tick call and batches over the whole
    candidate stream in one forward."""
    from repro.models import lm
    logits, _ = lm.forward(params, cfg, tokens=tokens)
    return jnp.mean(logits[:, -1, :lanes], axis=-1).astype(jnp.float32)


def serve(cfg, batch: int, prompt_len: int, gen: int, greedy: bool = True):
    api = ModelApi(cfg)
    params = api.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    max_len = prompt_len + gen
    if cfg.is_encdec:
        pf_batch = {"embeds": jnp.asarray(
            rng.normal(size=(batch, prompt_len, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                               (batch, 4)), jnp.int32)}
        max_len = 4 + gen
    elif cfg.frontend == "embed":
        pf_batch = {"embeds": jnp.asarray(
            rng.normal(size=(batch, prompt_len, cfg.d_model)), jnp.float32)}
    else:
        pf_batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}

    decode = jax.jit(build_decode_step(api), donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, caches, pos = api.prefill(params, pf_batch, max_len=max_len)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tokens = [np.asarray(jnp.argmax(logits, -1))]
    t0 = time.perf_counter()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(gen - 1):
        logits, caches = decode(params, caches, pos + i, {"token": tok})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    return np.stack(tokens, 1), t_prefill, t_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    toks, tp, td = serve(cfg, args.batch, args.prompt_len, args.gen)
    per_tok = td / max(1, args.gen - 1) * 1e3
    print(f"prefill {tp*1e3:.1f} ms; decode {per_tok:.2f} ms/token; "
          f"sample row: {toks[0][:8].tolist()}")


if __name__ == "__main__":
    main()
