"""Jit'd public wrapper for flash_attention: padding, scale, dispatch."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (DEFAULT_TK, DEFAULT_TQ,
                                                  flash_attention_kernel)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: Optional[float] = None,
                    tq: Optional[int] = None, tk: Optional[int] = None) -> jnp.ndarray:
    """q (B, H, S, D), k/v (B, KH, S, D) -> (B, H, S, D).

    Pads S to the tile size (padded kv is masked out by causality for the
    padded q rows; for non-causal use, padded kv keys are masked via a huge
    negative bias on padded rows — handled by padding k with zeros and
    relying on causal=True for trainining paths; non-causal callers must pass
    tile-aligned S).
    """
    b, h, s, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    tq = tq or min(DEFAULT_TQ, s)
    tk = tk or min(DEFAULT_TK, s)
    pad = -s % max(tq, tk)
    if pad:
        if not causal:
            raise ValueError("non-causal flash_attention requires tile-aligned S")
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = flash_attention_kernel(q, k, v, causal=causal, scale=scale,
                                 tq=tq, tk=tk, interpret=not _on_tpu())
    return out[:, :, :s]
