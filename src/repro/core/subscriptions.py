"""Subscriptions + Algorithm 1 subscription aggregation (paper §4.1).

Control plane (this module) is host-side numpy — subscriptions arrive one at a
time between channel executions, exactly as in the paper ("all grouping is
completed before the execution of the next channel begins"). The data plane
consumes the dense, padded arrays produced here.

TPU adaptation of the frame-size rule: AsterixDB frames hold whole records, so
the paper caps a subscription-group record at the frame size ``f``. Our frames
are tensor tiles; the analogous rule is a per-group sID capacity ``cap``
rounded to the 128-lane register width so one group occupies whole vector
registers. ``cap_from_frame_bytes`` reproduces the paper's rule (group record
size ~ frame size), ``lane_align`` applies the TPU rounding.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

SID_BYTES = 4          # sIDs are int32
LANE = 128             # TPU vector lane count


def cap_from_frame_bytes(frame_bytes: int, align: bool = True) -> int:
    """Paper rule: optimal subgroup record size == frame size (Figs. 12-13)."""
    cap = max(1, frame_bytes // SID_BYTES)
    return lane_align(cap) if align else cap


def lane_align(cap: int) -> int:
    if cap <= LANE:
        return cap
    return (cap // LANE) * LANE


@dataclasses.dataclass
class SubscriptionTable:
    """Flat (un-aggregated) subscriptions — the *original* BAD layout."""

    sids: np.ndarray      # (S,) int32
    params: np.ndarray    # (S,) int32 -- encoded channel parameter
    brokers: np.ndarray   # (S,) int32 -- broker id

    @property
    def num_subscriptions(self) -> int:
        return int(self.sids.shape[0])

    @staticmethod
    def empty() -> "SubscriptionTable":
        z = np.zeros((0,), dtype=np.int32)
        return SubscriptionTable(z.copy(), z.copy(), z.copy())

    @staticmethod
    def build(params: np.ndarray, brokers: np.ndarray) -> "SubscriptionTable":
        params = np.asarray(params, dtype=np.int32)
        brokers = np.asarray(brokers, dtype=np.int32)
        sids = np.arange(params.shape[0], dtype=np.int32)
        return SubscriptionTable(sids, params, brokers)


@dataclasses.dataclass
class SubscriptionGroups:
    """Aggregated subscription-group records (paper Fig. 7b).

    group_params: (G,) int32     -- the shared parameter
    group_brokers: (G,) int32
    group_sids:   (G, cap) int32 -- member sIDs, padded with -1
    group_counts: (G,) int32
    """

    group_params: np.ndarray
    group_brokers: np.ndarray
    group_sids: np.ndarray
    group_counts: np.ndarray
    cap: int

    @property
    def num_groups(self) -> int:
        return int(self.group_params.shape[0])

    @property
    def num_subscriptions(self) -> int:
        return int(self.group_counts.sum())


class Aggregator:
    """Incremental Algorithm 1: place each arriving subscription in an open
    group with matching (params, broker), else open a new group."""

    def __init__(self, cap: int):
        if cap < 1:
            raise ValueError("group capacity must be >= 1")
        self.cap = cap
        # (param, broker) -> list of group indices. Group members are python
        # lists when touched incrementally, numpy arrays after a bulk load
        # (_mutable_members converts on demand) — bulk never pays a
        # per-subscription list conversion.
        self._by_key: Dict[Tuple[int, int], List[int]] = {}
        self._params: List[int] = []
        self._brokers: List[int] = []
        self._members: List = []
        self._next_sid = 0

    def _mutable_members(self, gi: int) -> List[int]:
        m = self._members[gi]
        if isinstance(m, np.ndarray):
            m = self._members[gi] = m.tolist()
        return m

    def add_subscription(self, param: int, broker: int,
                         sid: Optional[int] = None) -> int:
        """Paper Algorithm 1. Returns the sID assigned."""
        if sid is None:
            sid = self._next_sid
        self._next_sid = max(self._next_sid, sid + 1)
        key = (int(param), int(broker))
        for gi in self._by_key.get(key, ()):           # AddToExistingGroup
            if len(self._members[gi]) < self.cap:
                self._mutable_members(gi).append(sid)
                return sid
        gi = len(self._params)                          # open a new group
        self._params.append(int(param))
        self._brokers.append(int(broker))
        self._members.append([sid])
        self._by_key.setdefault(key, []).append(gi)
        return sid

    def add_bulk(self, params: np.ndarray, brokers: np.ndarray,
                 sids: Optional[np.ndarray] = None) -> np.ndarray:
        """Vectorized bulk load: Algorithm-1 semantics without per-subscription
        Python calls.

        Existing members and the new batch are re-aggregated together through
        ``aggregate`` (sort + chop), touching Python only per *group*. Per
        (param, broker) key this yields the minimal ``ceil(n_key / cap)``
        groups — identical to replaying Algorithm 1 from scratch. When
        removals have left a key's groups fragmented, the rebuild *compacts*
        them (fewer groups than continuing the incremental state), so group
        indices/membership are not stable across a bulk load; subscriber
        notification semantics are unchanged and the engine invalidates every
        group-derived cache on any subscription change. Returns the sIDs
        assigned to the new batch.
        """
        params = np.asarray(params, dtype=np.int32).ravel()
        brokers = np.asarray(brokers, dtype=np.int32).ravel()
        if params.shape != brokers.shape:
            raise ValueError("params and brokers must have the same length")
        n = params.shape[0]
        if sids is None:
            sids = self._next_sid + np.arange(n, dtype=np.int32)
        else:
            sids = np.asarray(sids, dtype=np.int32).ravel()
            if sids.shape[0] != n:   # before _next_sid moves: fail unmutated
                raise ValueError("sids must have the same length as params")
        if n == 0:
            return sids
        self._next_sid = max(self._next_sid, int(sids.max()) + 1)
        old = flatten_groups(self.build())
        table = SubscriptionTable(
            np.concatenate([old.sids, sids]),
            np.concatenate([old.params, params]),
            np.concatenate([old.brokers, brokers]))
        g = aggregate(table, self.cap)
        counts = g.group_counts
        self._params = g.group_params.tolist()
        self._brokers = g.group_brokers.tolist()
        self._members = [g.group_sids[i, :counts[i]]
                         for i in range(g.num_groups)]
        self._by_key = {}
        for gi, key in enumerate(zip(self._params, self._brokers)):
            self._by_key.setdefault(key, []).append(gi)
        return sids

    def remove_subscription(self, param: int, broker: int, sid: int) -> bool:
        key = (int(param), int(broker))
        for gi in self._by_key.get(key, ()):
            m = self._members[gi]
            # probe without degrading array-backed groups to lists; convert
            # only the one group actually being mutated
            found = bool((m == sid).any()) if isinstance(m, np.ndarray) \
                else sid in m
            if found:
                self._mutable_members(gi).remove(sid)
                return True
        return False

    def build(self) -> SubscriptionGroups:
        live = [i for i, m in enumerate(self._members) if len(m)]
        g = len(live)
        group_params = np.zeros((g,), dtype=np.int32)
        group_brokers = np.zeros((g,), dtype=np.int32)
        group_sids = np.full((g, self.cap), -1, dtype=np.int32)
        group_counts = np.zeros((g,), dtype=np.int32)
        for out, gi in enumerate(live):
            m = self._members[gi]
            group_params[out] = self._params[gi]
            group_brokers[out] = self._brokers[gi]
            group_sids[out, : len(m)] = m
            group_counts[out] = len(m)
        return SubscriptionGroups(group_params, group_brokers, group_sids,
                                  group_counts, self.cap)


def _sort_key(params: np.ndarray, brokers: np.ndarray) -> np.ndarray:
    """Fused (param, broker) sort key in the narrowest dtype that holds it —
    numpy's stable sort is radix for narrow integers, comparison otherwise."""
    if params.size and (int(params.min()) < 0 or int(brokers.min()) < 0):
        return (params.astype(np.int64) << 32) | (
            brokers.astype(np.int64) & 0xFFFFFFFF)
    span = int(brokers.max()) + 1 if brokers.size else 1
    key_range = (int(params.max()) + 1) * span if params.size else 1
    if key_range <= (1 << 15):
        return (params * span + brokers).astype(np.int16)
    if key_range <= (1 << 31):
        return (params.astype(np.int64) * span + brokers).astype(np.int32)
    return (params.astype(np.int64) << 32) | brokers.astype(np.int64)


def aggregate(table: SubscriptionTable, cap: int) -> SubscriptionGroups:
    """Bulk aggregation (vectorized equivalent of replaying Algorithm 1).

    Sort by (param, broker) — one stable argsort of a fused 64-bit key — then
    chop each run into cap-sized subgroups. Per-key group counts equal the
    incremental replay's ``ceil(n_key / cap)``; no per-subscription Python.
    """
    n = table.num_subscriptions
    if n == 0:
        return SubscriptionGroups(*(np.zeros((0,), np.int32),) * 2,
                                  np.zeros((0, cap), np.int32),
                                  np.zeros((0,), np.int32), cap)
    key = _sort_key(table.params, table.brokers)
    order = np.argsort(key, kind="stable")   # radix for narrow integer keys
    k = key[order]
    s = table.sids[order]
    new_run = np.empty(n, dtype=bool)
    new_run[0] = True
    new_run[1:] = k[1:] != k[:-1]
    run_starts = np.flatnonzero(new_run)
    run_id = np.cumsum(new_run, dtype=np.int32) - 1
    pos_in_run = np.arange(n, dtype=np.int64) - run_starts[run_id]
    sub_id = pos_in_run // cap
    # a group starts at every run start and every cap boundary within a run
    new_group = new_run.copy()
    new_group[1:] |= sub_id[1:] != sub_id[:-1]
    group_starts = np.flatnonzero(new_group)
    g = group_starts.shape[0]
    gid = np.cumsum(new_group, dtype=np.int32) - 1
    group_sids = np.full((g, cap), -1, dtype=np.int32)
    group_sids[gid, pos_in_run % cap] = s
    group_counts = np.diff(np.append(group_starts, n)).astype(np.int32)
    return SubscriptionGroups(table.params[order[group_starts]],
                              table.brokers[order[group_starts]],
                              group_sids, group_counts, cap)


def flatten_groups(groups: SubscriptionGroups) -> SubscriptionTable:
    """Vectorized inverse of ``aggregate``: groups -> flat member table.

    Rows come out group-by-group in member order — the same order the old
    per-group Python loop produced — with no per-subscription work.
    """
    counts = groups.group_counts.astype(np.int64)
    member_mask = np.arange(groups.cap)[None, :] < counts[:, None]
    return SubscriptionTable(
        groups.group_sids[member_mask].astype(np.int32),
        np.repeat(groups.group_params, counts).astype(np.int32),
        np.repeat(groups.group_brokers, counts).astype(np.int32))


def param_to_targets(params: np.ndarray, domain: int,
                     pad: int = -1) -> Tuple[np.ndarray, np.ndarray]:
    """Dense join map: param value -> row indices of targets holding it.

    Returns (map (domain, maxd) int32 padded, counts (domain,) int32). This is
    the TPU realization of the index nested-loop join in the augmented plan —
    the join against a small categorical domain becomes a gather. Pure numpy:
    a stable argsort ranks each target within its param run, so the scatter
    preserves the ascending-row order the incremental fill produced.
    """
    params = np.asarray(params, dtype=np.int32)
    counts = np.bincount(params, minlength=domain).astype(np.int32)
    maxd = max(1, int(counts.max()) if counts.size else 1)
    out = np.full((domain, maxd), pad, dtype=np.int32)
    if params.size:
        order = np.argsort(params, kind="stable")
        sorted_p = params[order]
        run_start = np.cumsum(counts) - counts          # (domain,)
        pos = np.arange(params.size, dtype=np.int64) - run_start[sorted_p]
        out[sorted_p, pos] = order.astype(np.int32)
    return out, counts
