"""Jit'd public wrapper for the predicate_filter kernel.

Handles: conditionsList canonicalization (cached per table), N-padding to the
tile size, int8->bool conversion, and backend dispatch (Pallas compiled on
TPU, interpret mode elsewhere).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.predicates import CompiledConditions
from repro.kernels.predicate_filter import ref
from repro.kernels.predicate_filter.kernel import DEFAULT_TN, predicate_filter_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


_CANON_CACHE: Dict[Tuple, Tuple] = {}


def canonical_arrays(conds: CompiledConditions, num_fields: int):
    """Cached interval canonicalization. Values are HOST numpy arrays so the
    cache is trace-safe: ``predicate_filter`` is called inside the engine's
    jitted plans, and caching device arrays created under a trace would leak
    tracers into later traces. numpy operands become per-trace constants at
    the jit boundary."""
    key = (conds.field_idx.tobytes(), conds.op.tobytes(), conds.value.tobytes(),
           conds.npreds.tobytes(), conds.field_idx.shape, num_fields)
    if key not in _CANON_CACHE:
        ic = ref.canonicalize(conds, num_fields)
        _CANON_CACHE[key] = (ic.lo, ic.hi, ic.neq)
    return _CANON_CACHE[key]


def predicate_filter(fields: jnp.ndarray, conds: CompiledConditions,
                     tn: int = DEFAULT_TN) -> jnp.ndarray:
    """(N, F) int32 records x conditionsList -> (N, C) bool match bitmap."""
    lo, hi, neq = canonical_arrays(conds, int(fields.shape[1]))
    return predicate_filter_padded(fields, lo, hi, neq, tn=tn,
                                   interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def predicate_filter_padded(fields: jnp.ndarray, lo: jnp.ndarray,
                            hi: jnp.ndarray, neq: jnp.ndarray,
                            tn: int = DEFAULT_TN,
                            interpret: bool = True) -> jnp.ndarray:
    n = fields.shape[0]
    n_pad = -n % tn
    if n_pad:
        fields = jnp.pad(fields, ((0, n_pad), (0, 0)))
    out = predicate_filter_kernel(fields, lo, hi, neq, tn=tn, interpret=interpret)
    return out[:n].astype(jnp.bool_)


def predicate_filter_rows(fields: jnp.ndarray, conds: CompiledConditions,
                          tn: int = DEFAULT_TN) -> jnp.ndarray:
    """(C, N, F) stacked row blocks -> (C, N) bool: channel c's conjunction
    evaluated on its own block only.

    This is the fused executor's window / candidate-recheck shape, where each
    channel gathers a different row window. The kernel runs with a single-row
    bounds table per channel and is batched by vmap — pallas_call lowers the
    channel axis onto a leading grid dimension, one device call total.
    """
    lo, hi, neq = canonical_arrays(conds, int(fields.shape[-1]))
    interpret = not _on_tpu()

    def one(f, l, h, q):
        return predicate_filter_padded(f, l[None], h[None], q[None], tn=tn,
                                       interpret=interpret)[:, 0]

    return jax.vmap(one)(fields, lo, hi, neq)


def predicate_filter_ref(fields: jnp.ndarray, conds: CompiledConditions) -> jnp.ndarray:
    """Oracle path with identical canonicalization (for allclose tests)."""
    lo, hi, neq = canonical_arrays(conds, int(fields.shape[1]))
    return ref.predicate_filter(fields, lo, hi, neq)
