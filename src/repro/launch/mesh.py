"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state. Single pod: (16, 16) = 256 v5e chips, axes (data, model). Multi-pod:
(2, 16, 16) = 512 chips, axes (pod, data, model); `pod` composes with `data`
for batch sharding (DP across pods) or carries pipeline stages in PP mode.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across JAX versions.

    ``jax.sharding.AxisType`` (and ``make_mesh``'s ``axis_types`` kwarg) only
    exist in newer JAX releases; older ones default every axis to Auto anyway,
    so omitting the kwarg is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Smoke-scale mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))
