"""Sharded checkpoint manager: atomic, async, keep-N, elastic restore.

Layout: <dir>/step_<N>/ holds one .npy per pytree leaf (host-local shards in
multi-host deployments; full arrays on a single host) plus a manifest. Writes
go to a temp dir + atomic rename, so a failure mid-save never corrupts the
latest checkpoint. ``restore`` accepts a *different* mesh/sharding than the
save used (elastic scaling): leaves are loaded as host arrays and re-placed
with ``jax.device_put`` under the new shardings.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "_".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path) or "root"
        out.append((name.replace("/", "_"), leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: Optional[bool] = None) -> str:
        """Snapshot to host memory synchronously, write to disk (async by
        default), atomic-rename, prune old steps."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        blocking = not self.async_save if blocking is None else blocking
        self.wait()
        if blocking:
            return self._write(step, host_tree)
        self._thread = threading.Thread(target=self._write,
                                        args=(step, host_tree), daemon=True)
        self._thread.start()
        return self._final_path(step)

    def _final_path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write(self, step: int, host_tree) -> str:
        final = self._final_path(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = _leaf_paths(host_tree)
        manifest = {"step": step, "time": time.time(), "leaves": []}
        for name, leaf in leaves:
            np.save(os.path.join(tmp, name + ".npy"), leaf)
            manifest["leaves"].append(
                {"name": name, "shape": list(np.shape(leaf)),
                 "dtype": str(np.asarray(leaf).dtype)})
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic publish
        self._prune()
        return final

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._final_path(s), ignore_errors=True)

    # ------------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                shardings: Any = None) -> Any:
        """Rebuild ``like``-structured tree; optionally place on new shardings
        (elastic restore onto a different mesh)."""
        self.wait()
        path = self._final_path(step)
        leaves = _leaf_paths(like)
        arrays = []
        for name, ref in leaves:
            arr = np.load(os.path.join(path, name + ".npy"))
            if list(arr.shape) != list(np.shape(ref)):
                raise ValueError(f"shape mismatch for {name}: "
                                 f"{arr.shape} vs {np.shape(ref)}")
            arrays.append(arr)
        treedef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree.map(
                lambda x, r: jax.device_put(
                    x.astype(str(np.dtype(_np_dtype(r))))
                    if hasattr(r, "dtype") else x),
                tree, like)
        return tree


def _np_dtype(leaf):
    return leaf.dtype
