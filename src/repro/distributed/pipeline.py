"""GPipe-style pipeline parallelism over a mesh axis via shard_map.

The `pod` axis can carry pipeline stages instead of data parallelism: each
stage owns a contiguous block of superlayers; microbatches stream through
with ``jax.lax.ppermute`` moving activations stage-to-stage. The schedule is
the classic GPipe fill-drain loop (num_microbatches + num_stages - 1 ticks);
bubble fraction = (S-1)/(M+S-1).

This module implements the *forward* pipeline (serving / evaluation) and a
loss pipeline whose backward is derived by jax.grad through the ppermute
(reverse collective permute) — the standard JAX treatment.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import pcast_varying, shard_map


def pipeline_forward(mesh: Mesh, axis: str, stage_fn: Callable,
                     num_microbatches: int):
    """Build a pipelined forward over ``axis``.

    stage_fn(stage_params, x) -> x, applied by every stage to whatever
    microbatch currently resides on it. Inputs enter at stage 0, outputs
    leave from the last stage.

    Returns fn(stage_params_stacked, x_microbatched) where
      stage_params_stacked: leaves (S, ...) sharded over `axis`,
      x_microbatched: (M, B_micro, ...) replicated over `axis`.
    """
    n_stages = mesh.shape[axis]

    def pipelined(stage_params, xs):
        m = xs.shape[0]
        ticks = m + n_stages - 1
        stage = jax.lax.axis_index(axis)
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            state, outputs = carry
            # Stage 0 injects microbatch t (if any remain).
            inject = jnp.where(t < m, t, m - 1)
            x_in = xs[inject]
            state = jnp.where(stage == 0, x_in, state)
            live = (t - stage >= 0) & (t - stage < m)
            y = stage_fn(stage_params, state)
            y = jnp.where(live, y, state)
            # Last stage emits microbatch t - (S-1).
            emit_idx = t - (n_stages - 1)
            is_emit = (stage == n_stages - 1) & (emit_idx >= 0)
            slot = jnp.maximum(emit_idx, 0)
            outputs = outputs.at[slot].set(
                jnp.where(is_emit, y, outputs[slot]))
            # Shift activations downstream.
            state = jax.lax.ppermute(y, axis, fwd_perm)
            return (state, outputs), ()

        # carriers must be device-varying from the start (shard_map vma rules)
        state0 = pcast_varying(jnp.zeros_like(xs[0]), axis)
        outputs0 = pcast_varying(jnp.zeros_like(xs), axis)
        (_, outputs), _ = jax.lax.scan(tick, (state0, outputs0),
                                       jnp.arange(ticks))
        # Outputs live on the last stage; broadcast to all for the caller.
        outputs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outputs, jnp.zeros_like(outputs)),
            axis)
        return outputs

    def run(stage_params_stacked, x_microbatched):
        p_specs = jax.tree.map(lambda _: P(axis), stage_params_stacked)
        fn = shard_map(
            lambda sp, xx: pipelined(
                jax.tree.map(lambda a: a[0], sp), xx),
            mesh=mesh,
            in_specs=(p_specs, P()),
            out_specs=P())
        return fn(stage_params_stacked, x_microbatched)

    return run


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
