"""Jit'd public wrapper for the join_compact kernel.

Handles: S-padding to the tile size, dtype canonicalization, int8->bool
conversion, and backend dispatch (Pallas compiled on TPU, interpret mode
elsewhere). Drop-in for ``ref.join_pairs`` — the ``join_fn`` hook of
``core/plans.py join_param_stream``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.join_compact.kernel import DEFAULT_TS, join_pairs_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def join_pairs(tgt: jnp.ndarray, tgt_n: jnp.ndarray, members: jnp.ndarray,
               brokers: jnp.ndarray, valid: jnp.ndarray,
               payload: jnp.ndarray, num_brokers: int, aggregated: bool,
               ts: int = DEFAULT_TS):
    """Same contract as ``ref.join_pairs`` (bit-identical: all-integer)."""
    s = tgt.shape[0]
    s_pad = -s % ts
    if s_pad:
        pad2 = ((0, s_pad), (0, 0))
        tgt = jnp.pad(tgt, pad2, constant_values=-1)
        members = jnp.pad(members, pad2)
        brokers = jnp.pad(brokers, pad2)
        tgt_n = jnp.pad(tgt_n, (0, s_pad))
        valid = jnp.pad(valid, (0, s_pad))
        payload = jnp.pad(payload, (0, s_pad))
    i32 = lambda a: a.astype(jnp.int32)
    pv, mem, by, bids = join_pairs_kernel(
        i32(tgt), i32(tgt_n), i32(members), i32(brokers), i32(valid),
        i32(payload), num_brokers, aggregated, ts=ts,
        interpret=not _on_tpu())
    return pv[:s].astype(jnp.bool_), mem[:s], by[:s], bids[:s]
