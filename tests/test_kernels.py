"""Per-kernel shape/dtype sweeps against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predicates import Predicate, compile_conditions, evaluate_conditions
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_decode import ops as fd_ops
from repro.kernels.flash_decode import ref as fd_ref
from repro.kernels.predicate_filter import ops as pf_ops
from repro.kernels.spatial_match import ops as sm_ops
from repro.kernels.spatial_match import ref as sm_ref


# ---------------------------------------------------------------------------
# predicate_filter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 256, 513])
@pytest.mark.parametrize("nchan", [1, 3, 9])
def test_predicate_filter_sweep(rng, n, nchan):
    fields = jnp.asarray(rng.integers(-50, 50, (n, 10)).astype(np.int32))
    chans = []
    ops = ["==", "!=", "<", "<=", ">", ">="]
    for c in range(nchan):
        preds = [Predicate.parse(int(rng.integers(0, 10)),
                                 ops[int(rng.integers(0, 6))],
                                 int(rng.integers(-40, 40)))
                 for _ in range(int(rng.integers(1, 4)))]
        # keep at most one != per (channel, field)
        seen = {}
        preds = [p for p in preds
                 if not (p.op == 1 and seen.setdefault(p.field, p.value) != p.value)]
        chans.append(preds)
    conds = compile_conditions(chans)
    want = np.asarray(evaluate_conditions(fields, conds))
    got = np.asarray(pf_ops.predicate_filter(fields, conds))
    assert np.array_equal(want, got)


def test_predicate_filter_interval_edges():
    # boundary values at int32 extremes
    fields = jnp.asarray(np.array([[-2**31, 2**31 - 1, 0, 5, 0, 0, 0, 0, 0, 0]],
                                  dtype=np.int32))
    chans = [[Predicate.parse(0, "<=", -2**31 + 1)],
             [Predicate.parse(1, ">=", 2**31 - 1)],
             [Predicate.parse(3, "==", 5), Predicate.parse(3, "!=", 4)]]
    conds = compile_conditions(chans)
    want = np.asarray(evaluate_conditions(fields, conds))
    got = np.asarray(pf_ops.predicate_filter(fields, conds))
    assert np.array_equal(want, got)


# ---------------------------------------------------------------------------
# spatial_match
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,u", [(1, 1), (10, 33), (300, 700)])
def test_spatial_match_sweep(rng, r, u):
    t = (rng.normal(size=(r, 2)) * 25).astype(np.float32)
    us = (rng.normal(size=(u, 2)) * 25).astype(np.float32)
    want = np.asarray(sm_ref.spatial_match(jnp.asarray(t), jnp.asarray(us), 10.0))
    got = np.asarray(sm_ops.spatial_match(jnp.asarray(t), jnp.asarray(us), 10.0))
    assert np.array_equal(want, got)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("b,h,kh,s,d", [
    (1, 2, 1, 128, 32), (2, 4, 2, 256, 64), (1, 8, 8, 128, 128),
    (1, 6, 2, 384, 64),
])
def test_flash_attention_sweep(rng, b, h, kh, s, d, dtype, atol):
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, kh, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, kh, s, d)), dtype)
    want = fa_ref.flash_attention(q, k, v, causal=True)
    got = fa_ops.flash_attention(q, k, v, causal=True, tq=128, tk=128)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_attention_noncausal(rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    want = fa_ref.flash_attention(q, k, v, causal=False)
    got = fa_ops.flash_attention(q, k, v, causal=False, tq=128, tk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_flash_attention_padding(rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 200, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 200, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 200, 64)), jnp.float32)
    want = fa_ref.flash_attention(q, k, v, causal=True)
    got = fa_ops.flash_attention(q, k, v, causal=True, tq=128, tk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kh,s,d", [
    (1, 2, 1, 128, 32), (2, 4, 2, 384, 64), (3, 8, 8, 256, 128),
])
def test_flash_decode_sweep(rng, b, h, kh, s, d):
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, kh, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, kh, s, d)), jnp.float32)
    kv_len = jnp.asarray(rng.integers(1, s + 1, (b,)), jnp.int32)
    want = fd_ref.decode_attention(q, k, v, kv_len)
    got = fd_ops.decode_attention(q, k, v, kv_len, tk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_flash_decode_merge_matches_monolithic(rng):
    b, h, kh, s, d = 2, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, kh, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, kh, s, d)), jnp.float32)
    kv_len = jnp.asarray([500, 70], jnp.int32)
    want = fd_ref.decode_attention(q, k, v, kv_len)
    # 4-way split-KV with partial merge (the sequence-parallel schedule)
    parts = []
    for i in range(4):
        sl = slice(i * 128, (i + 1) * 128)
        local_len = jnp.clip(kv_len - i * 128, 0, 128)
        parts.append(fd_ref.decode_attention_partial(q, k[:, :, sl], v[:, :, sl],
                                                     local_len))
    acc, m, l = parts[0]
    for p in parts[1:]:
        acc, m, l = fd_ref.merge_partials(acc, m, l, *p)
    got = fd_ref.normalize(acc, l, q.dtype)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_flash_decode_empty_shard(rng):
    """A shard whose kv slice is entirely dead must not poison the merge."""
    b, h, kh, d = 1, 2, 1, 32
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, kh, 128, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, kh, 128, d)), jnp.float32)
    a1 = fd_ref.decode_attention_partial(q, k, v, jnp.asarray([64], jnp.int32))
    a2 = fd_ref.decode_attention_partial(q, k, v, jnp.asarray([0], jnp.int32))
    acc, m, l = fd_ref.merge_partials(*a1, *a2)
    got = fd_ref.normalize(acc, l, q.dtype)
    want = fd_ref.decode_attention(q, k, v, jnp.asarray([64], jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)
    assert np.isfinite(np.asarray(got)).all()
