"""Logical-axis sharding rules and the `shard` constraint hook.

Model code tags activations with *logical* spec names; this module maps them
to mesh `PartitionSpec`s via the active rule set. Without an active mesh the
hook is a no-op, so the identical model code serves smoke tests (1 CPU
device) and production-mesh lowering (256/512 devices).

Default logical rules (Megatron-style TP + (pod,data) DP):
  batch   -> ("pod", "data")        activations, inputs
  heads   -> "model"                attention q heads / ffn hidden / experts
  vocab   -> "model"                embedding + lm head vocab dim
  kv_seq  -> "model"                KV cache sequence dim (flash-decode SP)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_STATE = threading.local()

# spec name -> PartitionSpec factory given axis rules
def _specs(batch_axes, model_axis) -> Dict[str, P]:
    b = batch_axes
    m = model_axis
    return {
        # activations
        "act_btd": P(b, None, None),          # (batch, seq, d_model)
        "act_btd_sp": P(b, m, None),          # sequence-parallel variant
        "act_ff": P(b, None, m),              # (batch, seq, d_ff)
        "act_heads": P(b, None, m, None),     # (batch, seq, heads, head_dim)
        "act_bhtd": P(b, m, None, None),      # (batch, heads, seq, head_dim)
        "act_bhtd_cp": P(b, None, m, None),   # context-parallel q: seq over
                                              # model (head count need not
                                              # divide the axis)
        "act_btv": P(b, None, m),             # logits (batch, seq, vocab)
        "act_bd": P(b, None),                 # (batch, d_model)
        "act_bhd": P(b, m, None),             # decode q (batch, heads, head_dim)
        "act_moe": P(m, None, None),          # (experts, capacity, d_model)
        # params
        "p_embed": P(m, None),                # (vocab, d_model)
        "p_out": P(None, m),                  # (d_model, vocab|ff|heads*hd)
        "p_in": P(m, None),                   # (ff|heads*hd, d_model)
        "p_norm": P(None),
        "p_bias_m": P(m),
        "p_expert_out": P(m, None, None),     # (E, d_model, d_ff)
        "p_expert_in": P(m, None, None),      # (E, d_ff, d_model) - dim1 sharded? no: experts
        "p_router": P(None, m),
        # kv cache: (batch, kv_heads, seq, head_dim), sequence-sharded on model
        "kv_cache": P(b, None, m, None),
        "kv_prefill": P(b, None, None, None),
        "replicated": P(),
    }


class Rules:
    def __init__(self, mesh: Mesh, batch_axes, model_axis,
                 seq_shard: bool = False, ws_decode: bool = False):
        self.mesh = mesh
        self.table = _specs(batch_axes, model_axis)
        if seq_shard:   # Megatron-SP: residual stream seq dim over `model`
            self.table["act_btd"] = self.table["act_btd_sp"]
        if ws_decode:   # weight-stationary serving: d_model over FSDP axis
            self.table["act_bd"] = P(None, batch_axes)
            # MoE dispatch buffers follow: (experts, capacity, d_model) with
            # d_model on the FSDP axis so expert GEMMs contract against
            # resident weight shards (no per-token expert-weight gathers).
            self.table["act_moe"] = P(model_axis, None, batch_axes)
        self.batch_axes = batch_axes
        self.model_axis = model_axis
        self.seq_shard = seq_shard
        self.ws_decode = ws_decode

    def spec(self, name: str) -> P:
        return self.table[name]

    def sharding(self, name: str) -> NamedSharding:
        return NamedSharding(self.mesh, self.table[name])


def active_rules() -> Optional[Rules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def make_rules(mesh: Mesh, seq_shard: bool = False,
               ws_decode: bool = False) -> Rules:
    axes = mesh.axis_names
    model_axis = "model" if "model" in axes else None
    batch = tuple(a for a in ("pod", "data") if a in axes)
    batch_axes = batch if batch else None
    return Rules(mesh, batch_axes, model_axis, seq_shard=seq_shard,
                 ws_decode=ws_decode)


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes that do not evenly divide the corresponding dim.

    Keeps specs legal for every architecture uniformly (e.g. 28 attention
    heads or batch=1 on a 16-way axis fall back to replication on that dim
    instead of relying on GSPMD padding).
    """
    out = []
    for i, entry in enumerate(spec):
        if i >= len(shape):
            break                      # spec longer than rank: truncate
        if entry is None:
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        kept = []
        for a in axes:
            if a not in mesh.shape:
                continue
            n = mesh.shape[a]
            if shape[i] % (size * n) == 0:
                kept.append(a)
                size *= n
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def shard(x: jnp.ndarray, spec_name: str) -> jnp.ndarray:
    """with_sharding_constraint under active rules; no-op otherwise."""
    rules = active_rules()
    if rules is None:
        return x
    spec = sanitize_spec(rules.spec(spec_name), x.shape, rules.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# BAD-engine entity partitioning (the sharded engine, core/sharded.py)
#
# Subscriptions and spatial cohort users are assigned to shards by a STABLE
# hash of their global id: the owner of an entity is a pure function of
# (id, num_shards), never of load order or of what else is live — so churn
# deltas route without any directory lookup, and re-partitioning after a
# channel drop or a reshard recomputes the same assignment for every
# surviving id. Knuth's multiplicative hash decorrelates the assignment from
# the sequential id allocation (consecutive sIDs spread across shards
# instead of landing in contiguous runs); users get a different odd
# multiplier so a uid and an equal-valued sID do not co-locate.
# ---------------------------------------------------------------------------

_SID_MULT = np.uint64(2654435761)    # Knuth 2^32 / phi
_UID_MULT = np.uint64(2246822519)    # xxhash PRIME32_2


def _multiplicative_shard(ids: np.ndarray, num_shards: int,
                          mult: np.uint64) -> np.ndarray:
    ids = np.asarray(ids)
    if ids.size and int(ids.min()) < 0:
        raise ValueError("entity ids must be non-negative")
    if num_shards <= 1:
        return np.zeros(ids.shape, np.int32)
    h = (ids.astype(np.uint64) * mult) & np.uint64(0xFFFFFFFF)
    return (h % np.uint64(num_shards)).astype(np.int32)


def shard_for_sids(sids: np.ndarray, num_shards: int) -> np.ndarray:
    """Owning shard for each subscription id (vectorized, stable)."""
    return _multiplicative_shard(sids, num_shards, _SID_MULT)


def shard_for_users(uids: np.ndarray, num_shards: int) -> np.ndarray:
    """Owning shard for each spatial-cohort user id."""
    return _multiplicative_shard(uids, num_shards, _UID_MULT)


def broker_owner(broker_ids: np.ndarray, num_shards: int) -> np.ndarray:
    """The shard hosting each broker endpoint. Brokers are few and
    enumerated densely, so round-robin placement is balanced by
    construction; notifications whose subscription lives elsewhere are
    routed here by the collective shuffle (collectives.shuffle_notify)."""
    if num_shards <= 1:
        return np.zeros(np.asarray(broker_ids).shape, np.int32)
    return (np.asarray(broker_ids).astype(np.int64)
            % num_shards).astype(np.int32)
