"""Per-architecture smoke tests: reduced config, one train step + one decode
step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.launch.steps import build_train_step, default_optimizer
from repro.models.model import SHAPES, ModelApi


def _batch(cfg, rng, b=2, s=32):
    if cfg.is_encdec:
        return {"embeds": jnp.asarray(rng.normal(size=(b, s, cfg.d_model)),
                                      jnp.float32),
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 8)),
                                      jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 8)),
                                      jnp.int32)}
    if cfg.frontend == "embed":
        return {"embeds": jnp.asarray(rng.normal(size=(b, s, cfg.d_model)),
                                      jnp.float32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                      jnp.int32)}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                  jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full config encodes the assigned architecture exactly."""
    spec = {
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "seamless-m4t-medium": (24, 1024, 16, 16, 4096, 256206),
    }[arch]
    cfg = get_config(arch)
    layers = cfg.superlayer_repeat * len(cfg.block_pattern)
    if arch == "zamba2-2.7b":
        # 54 mamba layers + 9 shared-attn applications; n_layers counts mamba
        layers = cfg.superlayer_repeat * (len(cfg.block_pattern) - 1)
    if cfg.is_encdec:
        layers = cfg.superlayer_repeat + cfg.n_enc_layers
    assert (layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
            cfg.vocab_size) == spec


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch, rng):
    cfg = get_reduced(arch)
    api = ModelApi(cfg)
    params = api.init(jax.random.key(0))
    optimizer = default_optimizer(cfg)
    opt_state = optimizer.init(params)
    step = jax.jit(build_train_step(api, optimizer, accum=2))
    batch = _batch(cfg, rng, b=4, s=32)
    p2, o2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch, rng):
    cfg = get_reduced(arch)
    api = ModelApi(cfg)
    params = api.init(jax.random.key(0))
    batch = _batch(cfg, rng, b=2, s=16)
    batch.pop("labels", None)
    logits, caches, pos = api.prefill(params, batch, max_len=24)
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, caches2 = api.decode(params, caches, pos, {"token": tok})
    assert logits2.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "zamba2-2.7b", "xlstm-125m",
                                  "phi3.5-moe-42b-a6.6b", "pixtral-12b"])
def test_decode_matches_forward(arch, rng):
    """Cached decode == teacher-forced forward, token by token.

    MoE needs a no-drop capacity factor: with drops, token routing depends on
    the rest of the batch (GShard capacity semantics), so teacher-forced and
    single-token paths legitimately diverge.
    """
    from repro.models import lm
    cfg = get_reduced(arch)
    if cfg.n_experts:
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    api = ModelApi(cfg)
    params = api.init(jax.random.key(1))
    B, S = 2, 16
    if cfg.frontend == "embed":
        embeds = jnp.asarray(rng.normal(size=(B, S + 2, cfg.d_model)), jnp.float32)
        full, _ = lm.forward(params, cfg, embeds=embeds)
        lg, caches, pos = api.prefill(params, {"embeds": embeds[:, :S]},
                                      max_len=S + 4)
        err = [float(jnp.abs(lg - full[:, S - 1, :cfg.vocab_size]).max())]
        lg, caches = api.decode(params, caches, pos,
                                {"embed": embeds[:, S]})
        err.append(float(jnp.abs(lg - full[:, S, :cfg.vocab_size]).max()))
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 3)), jnp.int32)
        full, _ = lm.forward(params, cfg, tokens=toks)
        lg, caches, pos = api.prefill(params, {"tokens": toks[:, :S]},
                                      max_len=S + 4)
        err = [float(jnp.abs(lg - full[:, S - 1, :cfg.vocab_size]).max())]
        for i in range(3):
            lg, caches = api.decode(params, caches, pos + i,
                                    {"token": toks[:, S + i]})
            err.append(float(jnp.abs(lg - full[:, S + i, :cfg.vocab_size]).max()))
    assert max(err) < 5e-3, err


def test_long_500k_support_flags():
    from repro.models.model import ModelApi
    runs = {a: ModelApi(get_config(a)).supports("long_500k") for a in ARCH_IDS}
    assert runs["xlstm-125m"] and runs["zamba2-2.7b"]
    assert not runs["qwen2-1.5b"] and not runs["llama3-405b"]
    assert sum(runs.values()) == 2


@pytest.mark.parametrize("arch", ["qwen2-7b", "seamless-m4t-medium"])
def test_chunked_attention_path_consistency(arch, rng):
    """The chunked (>=8k) attention path agrees with the full-S^2 path."""
    import dataclasses
    import repro.models.attention as A
    from repro.models import lm, encdec
    cfg = get_reduced(arch)
    api = ModelApi(cfg)
    params = api.init(jax.random.key(0))
    batch = _batch(cfg, rng, b=2, s=64)
    old = A.CHUNKED_ATTN_THRESHOLD, A.CHUNK_KV
    try:
        A.CHUNKED_ATTN_THRESHOLD, A.CHUNK_KV = 32, 16   # force chunked
        l1, m1 = api.loss(params, batch)
        A.CHUNKED_ATTN_THRESHOLD = 1 << 30              # force full path
        l2, m2 = api.loss(params, batch)
    finally:
        A.CHUNKED_ATTN_THRESHOLD, A.CHUNK_KV = old
    assert abs(float(l1) - float(l2)) < 1e-4
