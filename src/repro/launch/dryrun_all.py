"""Run the full dry-run matrix: 10 archs x 4 shapes x {single-pod, multi-pod}.

Each cell runs in a fresh subprocess (jax pins the 512-device host platform at
first init; isolation also bounds compile-cache memory). Resumable: existing
JSON results are skipped unless --force.

  PYTHONPATH=src python -m repro.launch.dryrun_all [--mesh both|pod|multipod]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

ARCHS = [
    "xlstm-125m", "tinyllama-1.1b", "qwen2-1.5b", "zamba2-2.7b",
    "seamless-m4t-medium", "qwen2-7b", "pixtral-12b",
    "phi3.5-moe-42b-a6.6b", "dbrx-132b", "llama3-405b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default="both", choices=["both", "pod", "multipod"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--archs", default=",".join(ARCHS))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"both": [False, True], "pod": [False], "multipod": [True]}[args.mesh]
    cells = [(a, s, m) for a in args.archs.split(",")
             for s in args.shapes.split(",") for m in meshes]
    t_start = time.time()
    failures = []
    for i, (arch, shape, multi) in enumerate(cells):
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
        if os.path.exists(path) and not args.force:
            print(f"[{i+1}/{len(cells)}] skip (exists): {arch} {shape} {mesh_name}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", args.out]
        if multi:
            cmd.append("--multi-pod")
        print(f"[{i+1}/{len(cells)}] {arch} {shape} {mesh_name} ...", flush=True)
        t0 = time.time()
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={**os.environ, "PYTHONPATH": "src"})
            sys.stdout.write(r.stdout)
            if r.returncode != 0:
                failures.append((arch, shape, mesh_name))
                print(f"  FAILED rc={r.returncode}\n{r.stderr[-3000:]}")
        except subprocess.TimeoutExpired:
            failures.append((arch, shape, mesh_name))
            print("  TIMEOUT")
        print(f"  cell wall: {time.time()-t0:.0f}s "
              f"(total {time.time()-t_start:.0f}s)", flush=True)
    print(f"done; {len(failures)} failures: {failures}")


if __name__ == "__main__":
    main()
