"""Model-enriched notification pipeline (core/enrich.py) + the consolidated
execution surface (plans.ExecutionRequest, runtime.EngineProtocol).

The enrichment hook's contract, pinned here:

  * no-op parity — a NoopScorer (budget=None or under-budget) engine is
    delivery-BIT-identical to a scorer-less one: same delivered (row, sID)
    multisets, same DeliveryStats, across padded/compact x agg/flat and on
    the sharded engine;
  * ranked drops — over-budget channels keep the top-``budget`` pairs by
    (score desc, ravel asc), count the remainder in ``ranked_pairs`` /
    ``ranked_sids``, and conservation (delivered + spilled + dropped ==
    produced + retried) still telescopes per stage;
  * tie determinism — equal scores keep ravel (delivery) order, so a
    constant scorer with budget B delivers exactly the scorer-less prefix,
    identically on every run;
  * zero steady-state retraces — a fixed attached stage keys the compiled
    plans once; repeated ticks replay cached traces.

The execution-surface contract: ``execute_all``/``dispatch_all`` are thin
wrappers over one ``ExecutionRequest`` path, and both engines satisfy the
typed ``EngineProtocol``.
"""
import importlib.util
import pathlib

import numpy as np
import pytest

from repro.core import enrich
from repro.core import records as R
from repro.core.broker import payload_notifications
from repro.core.channel import most_threatening_tweets, tweets_about_drugs
from repro.core.engine import BADEngine
from repro.core.plans import ChannelPlan, ExecutionFlags, ExecutionRequest
from repro.core.runtime import EngineProtocol, TickPipeline
from repro.core.sharded import ShardedBADEngine

from conftest import check_delivery_conservation, make_tweets

PW = 8    # engine default deliver_payload_words

FLAGS_AGG = ExecutionFlags(scan_mode="window", aggregation=True,
                           param_pushdown=True)
FLAGS_FLAT = ExecutionFlags(scan_mode="window", aggregation=False,
                            param_pushdown=False)


def _engine(seed=0, stage=None, **kw):
    rng = np.random.default_rng(seed)
    kw.setdefault("max_deliver_pairs", 256)
    kw.setdefault("max_notify", 512)
    kw.setdefault("ring_capacity", 0)
    eng = BADEngine(dataset_capacity=4096, index_capacity=1024,
                    max_window=2048, max_candidates=512,
                    brokers=("B1", "B2"), group_cap=8, **kw)
    eng.debug_delivery_buffers = True
    eng.create_channel(tweets_about_drugs())
    eng.create_channel(most_threatening_tweets())
    for name in ("TweetsAboutDrugs", "MostThreateningTweets"):
        eng.subscribe_bulk(name, rng.integers(0, 50, 200),
                           rng.integers(0, 2, 200))
    if stage is not None:
        eng.set_enrichment(stage)
    eng.ingest(make_tweets(rng, 192, match_drugs=0.3))
    return eng


def _delivered(reports):
    """Per-channel delivered content + stats: ((row, sID) multiset, sID
    multiset, DeliveryStats) keyed by channel."""
    out = {}
    for name, rep in reports.items():
        o = rep.overflow
        pairs = sorted(map(tuple, payload_notifications(
            np.asarray(rep.payload), o.delivered_pairs, PW).tolist()))
        sids = sorted(np.asarray(rep.notify)[:o.delivered_sids].tolist())
        out[name] = (pairs, sids, o)
    return out


@pytest.mark.parametrize("backend", ["oracle", "compact"],
                         ids=["padded", "compact"])
@pytest.mark.parametrize("flags", [FLAGS_AGG, FLAGS_FLAT],
                         ids=["agg", "flat"])
@pytest.mark.parametrize("stage", [enrich.NoopScorer(),
                                   enrich.NoopScorer(budget=100_000),
                                   enrich.HeuristicScorer(budget=100_000)],
                         ids=["noop-untagged", "noop-budget", "heur-budget"])
def test_noop_scorer_bit_parity(backend, flags, stage):
    """Under-budget (or budget-less) stages leave delivery bit-identical to
    the scorer-less engine: multisets AND full DeliveryStats."""
    plan = ChannelPlan.from_flags(flags, backend)
    base = _engine()
    enriched = _engine(stage=stage)
    for eng in (base, enriched):
        for name in eng.channels:
            eng.set_plan(name, plan)
    want = _delivered(base.execute_all(None, deliver=True))
    got = _delivered(enriched.execute_all(None, deliver=True))
    assert set(want) == set(got)
    for name in want:
        assert got[name][0] == want[name][0]
        assert got[name][1] == want[name][1]
        assert got[name][2] == want[name][2]


def test_budget_rank_drops_lowest():
    """Over-budget channels deliver exactly the top-``budget`` highest-
    scored pairs: with RETWEET_COUNT as the only differentiating field, the
    survivors are the records with the largest counts."""
    rng = np.random.default_rng(3)
    eng = BADEngine(dataset_capacity=4096, index_capacity=1024,
                    max_window=2048, max_candidates=512,
                    brokers=("B1",), group_cap=8,
                    max_deliver_pairs=256, max_notify=512, ring_capacity=0)
    eng.debug_delivery_buffers = True
    eng.create_channel(most_threatening_tweets())
    eng.subscribe_bulk("MostThreateningTweets",
                       np.zeros(1, np.int32), np.zeros(1, np.int32))
    n = 24
    batch = make_tweets(rng, n)
    fields = np.asarray(batch.fields).copy()
    fields[:, R.STATE] = 0                      # all match the subscription
    fields[:, R.THREATENING_RATE] = 10          # all pass the predicate
    fields[:, R.HATE_SPEECH_RATE] = 0
    fields[:, R.WEAPON_MENTIONED] = 0
    fields[:, R.DRUG_ACTIVITY] = 0
    fields[:, R.RETWEET_COUNT] = np.arange(n) * 100  # score ~ ingest order
    rows = eng.ingest(
        R.RecordBatch.from_numpy(fields, np.asarray(batch.location)))
    budget = 5
    eng.set_enrichment(enrich.HeuristicScorer(budget=budget))
    rep = eng.execute_all(FLAGS_FLAT, deliver=True)["MostThreateningTweets"]
    o = rep.overflow
    assert rep.num_results == n and o.delivered_pairs == budget
    assert o.ranked_pairs == n - budget
    got_rows = sorted(payload_notifications(
        np.asarray(rep.payload), o.delivered_pairs, PW)[:, 0].tolist())
    # the delivered record rows are exactly the ``budget`` records with the
    # largest retweet counts — the last ``budget`` ingested rows
    assert got_rows == sorted(np.asarray(rows)[-budget:].tolist())
    check_delivery_conservation(o, rep.num_results, rep.num_notified)


def _delivered_ordered(reports):
    """Like ``_delivered`` but keeps delivery order (prefix comparisons)."""
    out = {}
    for name, rep in reports.items():
        o = rep.overflow
        out[name] = list(map(tuple, payload_notifications(
            np.asarray(rep.payload), o.delivered_pairs, PW).tolist()))
    return out


def test_budget_rank_tie_determinism():
    """Constant scores + budget B: the kept set is the first B pairs in
    ravel (delivery) order — exactly the scorer-less delivered PREFIX (flat
    mode: one sID per pair) — and the outcome is identical run to run."""
    runs = []
    for _ in range(2):
        base = _engine(seed=7)
        want = _delivered_ordered(base.execute_all(FLAGS_FLAT, deliver=True))
        eng = _engine(seed=7, stage=enrich.NoopScorer(budget=9))
        reports = eng.execute_all(FLAGS_FLAT, deliver=True)
        got = _delivered_ordered(reports)
        for name in got:
            o = reports[name].overflow
            assert o.delivered_pairs <= 9
            assert got[name] == want[name][:len(got[name])]
            if reports[name].num_results > 9:
                assert o.ranked_pairs == reports[name].num_results - 9
        runs.append(got)
    assert runs[0] == runs[1]


def test_conservation_with_ranked_drops_and_overflow():
    """Ranked drops compose with capacity overflow (tight caps + ring):
    conservation still telescopes per stage and ranked_* is a subset of
    dropped_*."""
    stage = enrich.HeuristicScorer(budget=6)
    eng = _engine(seed=5, stage=stage, max_deliver_pairs=4, max_notify=8,
                  ring_capacity=16)
    for _ in range(3):
        rng = np.random.default_rng(eng.now + 1)
        eng.ingest(make_tweets(rng, 96, t0=eng.now + 1, match_drugs=0.3))
        reports = eng.execute_all(FLAGS_AGG, deliver=True)
        for rep in reports.values():
            o = rep.overflow
            check_delivery_conservation(o, rep.num_results, rep.num_notified)
            assert o.ranked_pairs <= o.dropped_pairs
            assert o.ranked_sids <= o.dropped_sids
            assert o.delivered_pairs <= min(6, 4)


def test_detach_and_swap_stage():
    """set_enrichment(None) restores scorer-less delivery; a swapped stage
    re-keys the dispatched plans (different identity) without error."""
    base = _engine(seed=2)
    want = _delivered(base.execute_all(FLAGS_AGG, deliver=True))
    eng = _engine(seed=2, stage=enrich.HeuristicScorer(budget=3))
    eng.execute_all(FLAGS_AGG, deliver=True)
    assert eng.set_enrichment(None)
    assert not eng.set_enrichment(None)
    rng = np.random.default_rng(99)
    base.ingest(make_tweets(rng, 64, t0=base.now + 1))
    rng = np.random.default_rng(99)
    eng.ingest(make_tweets(rng, 64, t0=eng.now + 1))
    w2 = _delivered(base.execute_all(FLAGS_AGG, deliver=True))
    g2 = _delivered(eng.execute_all(FLAGS_AGG, deliver=True))
    for name in w2:
        assert g2[name][0] == w2[name][0]
        assert g2[name][2] == w2[name][2]
    with pytest.raises(TypeError):
        eng.set_enrichment(object())


def test_zero_steady_state_retraces_with_scorer():
    """A fixed attached stage traces once per plan-group shape; subsequent
    ticks replay cached executables (traces counter flat)."""
    eng = _engine(seed=4, stage=enrich.HeuristicScorer(budget=8))
    for tick in range(4):
        rng = np.random.default_rng(100 + tick)
        eng.ingest(make_tweets(rng, 96, t0=eng.now + 1, match_drugs=0.3))
        eng.execute_all(FLAGS_AGG, timed=False, deliver=True)
        if tick == 1:
            snap = eng.maintenance.snapshot()
    assert eng.maintenance.since(snap).traces == 0


def test_pipelined_dispatch_with_scorer():
    """The stage rides the asynchronous pipeline: dispatch_all defers the
    sync, rank stats land lazily, conservation holds."""
    eng = _engine(seed=6, stage=enrich.HeuristicScorer(budget=8))
    pipe = TickPipeline(eng, depth=3)
    seen = []
    for tick in range(5):
        rng = np.random.default_rng(200 + tick)
        eng.ingest(make_tweets(rng, 96, t0=eng.now + 1, match_drugs=0.3))
        seen.extend(pipe.step(FLAGS_AGG, deliver=True))
    seen.extend(pipe.flush())
    assert len(seen) == 5 and pipe.max_in_flight == 3
    ranked = 0
    for _, reports in seen:
        for rep in reports.values():
            o = rep.overflow
            check_delivery_conservation(o, rep.num_results, rep.num_notified)
            ranked += o.ranked_pairs
    assert ranked > 0


# ---------------------------------------------------------------------------
# sharded engine
# ---------------------------------------------------------------------------

def _sharded(num_shards, stage=None, seed=0):
    rng = np.random.default_rng(seed)
    eng = ShardedBADEngine(num_shards=num_shards,
                           dataset_capacity=4096, index_capacity=1024,
                           max_window=2048, max_candidates=512,
                           brokers=("B1", "B2"), group_cap=8,
                           max_deliver_pairs=256, max_notify=512,
                           ring_capacity=0)
    eng.debug_delivery_buffers = True
    eng.create_channel(tweets_about_drugs())
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, 200),
                       rng.integers(0, 2, 200))
    if stage is not None:
        eng.set_enrichment(stage)
    eng.ingest(make_tweets(rng, 192, match_drugs=0.3))
    return eng


def _sharded_delivered(reports):
    out = {}
    for name, rep in reports.items():
        pairs, sids = [], []
        for shard_rep in rep.per_shard:
            o = shard_rep.overflow
            pairs.extend(map(tuple, payload_notifications(
                np.asarray(shard_rep.payload), o.delivered_pairs,
                PW).tolist()))
            sids.extend(np.asarray(shard_rep.notify)[:o.delivered_sids]
                        .tolist())
        out[name] = (sorted(pairs), sorted(sids), rep.overflow)
    return out


@pytest.mark.multidevice
def test_sharded_noop_parity(multidevice):
    """NoopScorer on the mesh: per-shard budgets never bind, so delivered
    content and merged stats equal the scorer-less mesh exactly."""
    base = _sharded(3)
    enriched = _sharded(3, stage=enrich.NoopScorer(budget=100_000))
    want = _sharded_delivered(base.execute_all(FLAGS_AGG, deliver=True))
    got = _sharded_delivered(enriched.execute_all(FLAGS_AGG, deliver=True))
    for name in want:
        assert got[name] == want[name]


@pytest.mark.multidevice
def test_sharded_ranked_budget_per_shard(multidevice):
    """The budget binds PER SHARD (a per-device delivery capacity): merged
    delivered pairs <= shards * budget, merged ranked_* sums shard-wise,
    and global conservation telescopes."""
    budget = 4
    eng = _sharded(3, stage=enrich.HeuristicScorer(budget=budget))
    rep = eng.execute_all(FLAGS_AGG, deliver=True)["TweetsAboutDrugs"]
    o = rep.overflow
    assert o.delivered_pairs <= 3 * budget
    assert o.ranked_pairs > 0
    check_delivery_conservation(o, rep.num_results, rep.num_notified)
    assert o.ranked_pairs == sum(
        r.overflow.ranked_pairs for r in rep.per_shard)


@pytest.mark.multidevice
def test_sharded_enrichment_survives_reshard(multidevice):
    """reshard rebuilds shards with the stage attached (identity preserved),
    so post-reshard ticks still rank."""
    eng = _sharded(2, stage=enrich.HeuristicScorer(budget=4))
    eng.execute_all(FLAGS_AGG, deliver=True)
    eng.reshard(3)
    assert all(e.enrichment is eng._enrichment for e in eng.shards)
    rng = np.random.default_rng(42)
    eng.ingest(make_tweets(rng, 96, t0=eng.now + 1, match_drugs=0.3))
    rep = eng.execute_all(FLAGS_AGG, deliver=True)["TweetsAboutDrugs"]
    assert rep.overflow.ranked_pairs > 0


# ---------------------------------------------------------------------------
# execution-surface consolidation
# ---------------------------------------------------------------------------

def test_engine_protocol_satisfied():
    """Both engines structurally satisfy the typed driver surface."""
    eng = _engine()
    sh = ShardedBADEngine(num_shards=1, dataset_capacity=1024,
                          index_capacity=256, max_window=512,
                          max_candidates=128)
    assert isinstance(eng, EngineProtocol)
    assert isinstance(sh, EngineProtocol)
    assert not isinstance(object(), EngineProtocol)


def test_execution_request_validation():
    with pytest.raises(ValueError):
        ExecutionRequest(flags=FLAGS_AGG,
                         plan=ChannelPlan.from_flags(FLAGS_AGG))
    with pytest.raises(ValueError):
        ExecutionRequest(backend="not-a-backend")
    req = ExecutionRequest(channels=["a", "b"])
    assert req.channels == ("a", "b")


def test_execution_request_equivalence():
    """The legacy facades and the explicit request produce identical
    reports; plan and flags+backend spellings of the same plan agree."""
    a = _engine(seed=8)
    b = _engine(seed=8)
    c = _engine(seed=8)
    want = _delivered(a.execute_all(FLAGS_AGG, deliver=True))
    via_req = _delivered(b.execute(
        ExecutionRequest(flags=FLAGS_AGG, deliver=True)))
    plan = ChannelPlan.from_flags(FLAGS_AGG, "oracle")
    via_plan = _delivered(c.execute(
        ExecutionRequest(plan=plan, deliver=True)))
    for name in want:
        assert via_req[name] == want[name]
        assert via_plan[name] == want[name]


def test_execution_request_channel_subset():
    """channels= restricts execution; unknown channels raise; the other
    channel's watermark does not advance."""
    eng = _engine(seed=9)
    reports = eng.execute(ExecutionRequest(
        channels=("TweetsAboutDrugs",), deliver=True))
    assert set(reports) == {"TweetsAboutDrugs"}
    assert eng.channels["TweetsAboutDrugs"].executions == 1
    assert eng.channels["MostThreateningTweets"].executions == 0
    with pytest.raises(KeyError):
        eng.execute(ExecutionRequest(channels=("NoSuchChannel",)))
    empty = eng.execute(ExecutionRequest(channels=()))
    assert empty == {}


def test_execution_request_backend_override():
    """backend= overrides the kernel backend on assigned plans — the old
    execute_channel(backend=...) knob on the fused path."""
    eng = _engine(seed=10)
    for name in eng.channels:
        eng.set_plan(name, ChannelPlan.from_flags(FLAGS_AGG, "oracle"))
    want = _delivered(eng.execute_all(None, deliver=True))
    eng2 = _engine(seed=10)
    for name in eng2.channels:
        eng2.set_plan(name, ChannelPlan.from_flags(FLAGS_AGG, "oracle"))
    got = _delivered(eng2.execute(ExecutionRequest(
        backend="compact", deliver=True)))
    for name in want:   # compact join is content-identical to padded
        assert got[name][0] == want[name][0]
        assert got[name][2] == want[name][2]
    assert all(r.plan.backend == "compact"
               for r in eng2.execute(ExecutionRequest(
                   backend="compact")).values())


# ---------------------------------------------------------------------------
# examples smoke (reduced size, slow job)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_enriched_pipeline_example_smoke():
    """The example runs end to end at reduced size on the heuristic path
    and ranks against the budget."""
    path = (pathlib.Path(__file__).resolve().parents[1] / "examples"
            / "enriched_pipeline.py")
    spec = importlib.util.spec_from_file_location("enriched_pipeline", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    out = mod.run(periods=2, batch=128, budget=8, heuristic=True,
                  n_subs=100, capacity=1 << 12)
    assert len(out) == 2
    ranked = sum(rep.overflow.ranked_pairs
                 for reports in out for rep in reports.values())
    assert ranked > 0
    for reports in out:
        for rep in reports.values():
            assert rep.overflow.delivered_pairs <= 8
