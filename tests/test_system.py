"""End-to-end behaviour tests for the BAD system (paper semantics)."""
import numpy as np
import pytest

from repro.core import records as R
from repro.core.channel import (most_threatening_tweets, tweets_about_crime,
                                tweets_about_drugs)
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags

from conftest import make_tweets


@pytest.fixture
def engine(rng):
    eng = BADEngine(dataset_capacity=4096, index_capacity=2048,
                    max_window=2048, max_candidates=512,
                    brokers=("Broker1", "Broker2"))
    eng.create_channel(tweets_about_drugs())
    eng.create_channel(most_threatening_tweets())
    eng.subscribe_bulk("TweetsAboutDrugs",
                       rng.integers(0, 50, 300), rng.integers(0, 2, 300))
    eng.subscribe_bulk("MostThreateningTweets",
                       rng.integers(0, 50, 300), rng.integers(0, 2, 300))
    eng.ingest(make_tweets(rng, 1024))
    return eng


ALL_PLANS = [
    ExecutionFlags.original(),
    ExecutionFlags(scan_mode="window"),
    ExecutionFlags(scan_mode="trad_index"),
    ExecutionFlags(scan_mode="bad_index"),
    ExecutionFlags(scan_mode="bad_index", aggregation=True),
    ExecutionFlags(scan_mode="bad_index", aggregation=True, param_pushdown=True),
    ExecutionFlags(scan_mode="window", aggregation=True, param_pushdown=True),
]


@pytest.mark.parametrize("flags", ALL_PLANS, ids=lambda f: f"{f.scan_mode}"
                         f"{'+agg' if f.aggregation else ''}"
                         f"{'+push' if f.param_pushdown else ''}")
def test_plan_equivalence_notified(engine, flags):
    """Every plan must notify exactly the same set of end subscribers."""
    base = engine.execute_channel("TweetsAboutDrugs",
                                  ExecutionFlags.original(), advance=False)
    rep = engine.execute_channel("TweetsAboutDrugs", flags, advance=False)
    assert rep.num_notified == base.num_notified
    # matched records are identical too
    a = set(np.asarray(base.result.matched_rows)[np.asarray(base.result.matched_valid)].tolist())
    b = set(np.asarray(rep.result.matched_rows)[np.asarray(rep.result.matched_valid)].tolist())
    assert a == b


def test_aggregation_reduces_results_and_bytes(engine):
    orig = engine.execute_channel("TweetsAboutDrugs",
                                  ExecutionFlags(scan_mode="window"), advance=False)
    agg = engine.execute_channel("TweetsAboutDrugs",
                                 ExecutionFlags(scan_mode="window", aggregation=True),
                                 advance=False)
    assert agg.num_results < orig.num_results
    assert agg.broker_bytes.sum() < orig.broker_bytes.sum()
    assert agg.num_notified == orig.num_notified


def test_bad_index_scans_less(engine):
    orig = engine.execute_channel("TweetsAboutDrugs",
                                  ExecutionFlags.original(), advance=False)
    bad = engine.execute_channel("TweetsAboutDrugs",
                                 ExecutionFlags(scan_mode="bad_index"), advance=False)
    assert bad.scanned < orig.scanned
    assert bad.num_results == orig.num_results


def test_watermark_no_duplicate_delivery(rng):
    """is_new semantics: a record is delivered once, even across executions."""
    eng = BADEngine(dataset_capacity=4096, index_capacity=2048,
                    max_window=2048, max_candidates=512)
    eng.create_channel(tweets_about_drugs())
    eng.subscribe("TweetsAboutDrugs", 5, "BrokerA")
    b1 = make_tweets(rng, 256, t0=10)
    eng.ingest(b1)
    eng.execute_channel("TweetsAboutDrugs", ExecutionFlags(scan_mode="bad_index"))
    r_again = eng.execute_channel("TweetsAboutDrugs",
                                  ExecutionFlags(scan_mode="bad_index"))
    assert r_again.num_results == 0          # nothing new since watermark
    b2 = make_tweets(rng, 256, t0=2000)
    eng.ingest(b2)
    r2 = eng.execute_channel("TweetsAboutDrugs",
                             ExecutionFlags(scan_mode="bad_index"))
    # every delivered record in r2 is from the second batch
    rows = np.asarray(r2.result.matched_rows)[np.asarray(r2.result.matched_valid)]
    assert (rows >= 256).all()


def test_spatial_channel_matches_bruteforce(rng):
    eng = BADEngine(dataset_capacity=1024, index_capacity=1024,
                    max_window=1024, max_candidates=256)
    eng.create_channel(tweets_about_crime(3))
    users = (rng.normal(size=(100, 2)) * 30).astype(np.float32)
    eng.set_user_locations(users)
    batch = make_tweets(rng, 512)
    eng.ingest(batch)
    rep = eng.execute_channel("TweetsAboutCrime3",
                              ExecutionFlags(scan_mode="bad_index"), advance=False)
    from repro.core.predicates import evaluate_single
    loc = np.asarray(batch.location)
    mask = np.asarray(evaluate_single(batch.fields,
                                      tweets_about_crime(3).fixed_preds))
    d2 = ((loc[:, None, :] - users[None]) ** 2).sum(-1)
    expected = int((mask[:, None] & (d2 < 100.0)).sum())
    assert rep.num_results == expected


def test_dynamic_subscribe_unsubscribe(rng):
    eng = BADEngine(dataset_capacity=1024, index_capacity=1024,
                    max_window=1024, max_candidates=256)
    eng.create_channel(tweets_about_drugs())
    sid = eng.subscribe("TweetsAboutDrugs", 7, "BrokerA")
    eng.subscribe("TweetsAboutDrugs", 7, "BrokerA")
    st = eng.channels["TweetsAboutDrugs"]
    assert st.user_params.refcount[7] == 2
    assert eng.unsubscribe("TweetsAboutDrugs", 7, "BrokerA", sid)
    assert st.user_params.refcount[7] == 1
    fields = np.zeros((4, 10), dtype=np.int32)
    fields[:, R.STATE] = 7
    fields[:, R.THREATENING_RATE] = 10
    fields[:, R.DRUG_ACTIVITY] = 3
    fields[:, R.TIMESTAMP] = 5
    eng.ingest(R.RecordBatch.from_numpy(fields))
    rep = eng.execute_channel("TweetsAboutDrugs",
                              ExecutionFlags.fully_optimized(), advance=False)
    assert rep.num_notified == 4              # 4 records x 1 remaining sub
