"""qwen2-1.5b [dense] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
GQA with QKV bias, tied embeddings. [arXiv:2407.10671; hf]"""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
        vocab_size=151936, head_dim=128, qkv_bias=True, rope_theta=1e6,
        tie_embeddings=True,
        block_pattern=("dense",), superlayer_repeat=28,
        param_dtype=jnp.bfloat16, grad_accum=16, optimizer="adamw",
        sub_quadratic=False,
    ).validate()
