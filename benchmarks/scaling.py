"""Figs. 18-19: speed-up and scale-up of the optimized pipeline.

One CPU core cannot time real multi-node execution, so the cluster dimension
is modeled the way the paper's experiments scale *work per node*:

- speed-up (Fig 18): total load fixed; per-node work = load / nodes. We time
  the optimized channel on load/nodes records for nodes in {2,4,8} and report
  T(2)/T(n) (ideal: n/2).
- scale-up (Fig 19): per-node work fixed; we time a fixed-size per-node slice
  for each cluster size and rate (ideal: flat).
"""
from __future__ import annotations

import numpy as np

from repro.core.plans import ExecutionFlags
from benchmarks.common import build_drug_engine, emit, exec_time, scale

FLAGS = ExecutionFlags.fully_optimized()


def run(rng) -> None:
    total = scale(32_768, 4096)
    times = {}
    for nodes in (2, 4, 8):
        eng = build_drug_engine(rng, n_subs=scale(20_000, 1024),
                                n_new=total // nodes,
                                match_rate=0.03, preload=0)
        t, _ = exec_time(eng, "TweetsAboutDrugs", FLAGS)
        times[nodes] = t
        emit(f"fig18/speedup/nodes{nodes}", t,
             f"speedup_x{times[2]/max(t,1e-9):.2f} (ideal x{nodes/2:.0f})")
    for rate in (1000, 2000):
        per_node = scale(rate * 8, 512)  # 8s of CPU-scaled ingest per node
        base = None
        for nodes in (2, 4, 8):
            eng = build_drug_engine(rng, n_subs=scale(20_000, 1024),
                                    n_new=per_node,
                                    match_rate=0.03, preload=0)
            t, _ = exec_time(eng, "TweetsAboutDrugs", FLAGS)
            base = base or t
            emit(f"fig19/scaleup/rate{rate}/nodes{nodes}", t,
                 f"vs_base_x{t/max(base,1e-9):.2f} (ideal x1.0)")


if __name__ == "__main__":
    run(np.random.default_rng(0))
