"""Logical-axis sharding rules and the `shard` constraint hook.

Model code tags activations with *logical* spec names; this module maps them
to mesh `PartitionSpec`s via the active rule set. Without an active mesh the
hook is a no-op, so the identical model code serves smoke tests (1 CPU
device) and production-mesh lowering (256/512 devices).

Default logical rules (Megatron-style TP + (pod,data) DP):
  batch   -> ("pod", "data")        activations, inputs
  heads   -> "model"                attention q heads / ffn hidden / experts
  vocab   -> "model"                embedding + lm head vocab dim
  kv_seq  -> "model"                KV cache sequence dim (flash-decode SP)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_STATE = threading.local()

# spec name -> PartitionSpec factory given axis rules
def _specs(batch_axes, model_axis) -> Dict[str, P]:
    b = batch_axes
    m = model_axis
    return {
        # activations
        "act_btd": P(b, None, None),          # (batch, seq, d_model)
        "act_btd_sp": P(b, m, None),          # sequence-parallel variant
        "act_ff": P(b, None, m),              # (batch, seq, d_ff)
        "act_heads": P(b, None, m, None),     # (batch, seq, heads, head_dim)
        "act_bhtd": P(b, m, None, None),      # (batch, heads, seq, head_dim)
        "act_bhtd_cp": P(b, None, m, None),   # context-parallel q: seq over
                                              # model (head count need not
                                              # divide the axis)
        "act_btv": P(b, None, m),             # logits (batch, seq, vocab)
        "act_bd": P(b, None),                 # (batch, d_model)
        "act_bhd": P(b, m, None),             # decode q (batch, heads, head_dim)
        "act_moe": P(m, None, None),          # (experts, capacity, d_model)
        # params
        "p_embed": P(m, None),                # (vocab, d_model)
        "p_out": P(None, m),                  # (d_model, vocab|ff|heads*hd)
        "p_in": P(m, None),                   # (ff|heads*hd, d_model)
        "p_norm": P(None),
        "p_bias_m": P(m),
        "p_expert_out": P(m, None, None),     # (E, d_model, d_ff)
        "p_expert_in": P(m, None, None),      # (E, d_ff, d_model) - dim1 sharded? no: experts
        "p_router": P(None, m),
        # kv cache: (batch, kv_heads, seq, head_dim), sequence-sharded on model
        "kv_cache": P(b, None, m, None),
        "kv_prefill": P(b, None, None, None),
        "replicated": P(),
    }


class Rules:
    def __init__(self, mesh: Mesh, batch_axes, model_axis,
                 seq_shard: bool = False, ws_decode: bool = False):
        self.mesh = mesh
        self.table = _specs(batch_axes, model_axis)
        if seq_shard:   # Megatron-SP: residual stream seq dim over `model`
            self.table["act_btd"] = self.table["act_btd_sp"]
        if ws_decode:   # weight-stationary serving: d_model over FSDP axis
            self.table["act_bd"] = P(None, batch_axes)
            # MoE dispatch buffers follow: (experts, capacity, d_model) with
            # d_model on the FSDP axis so expert GEMMs contract against
            # resident weight shards (no per-token expert-weight gathers).
            self.table["act_moe"] = P(model_axis, None, batch_axes)
        self.batch_axes = batch_axes
        self.model_axis = model_axis
        self.seq_shard = seq_shard
        self.ws_decode = ws_decode

    def spec(self, name: str) -> P:
        return self.table[name]

    def sharding(self, name: str) -> NamedSharding:
        return NamedSharding(self.mesh, self.table[name])


def active_rules() -> Optional[Rules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def make_rules(mesh: Mesh, seq_shard: bool = False,
               ws_decode: bool = False) -> Rules:
    axes = mesh.axis_names
    model_axis = "model" if "model" in axes else None
    batch = tuple(a for a in ("pod", "data") if a in axes)
    batch_axes = batch if batch else None
    return Rules(mesh, batch_axes, model_axis, seq_shard=seq_shard,
                 ws_decode=ws_decode)


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes that do not evenly divide the corresponding dim.

    Keeps specs legal for every architecture uniformly (e.g. 28 attention
    heads or batch=1 on a 16-way axis fall back to replication on that dim
    instead of relying on GSPMD padding).
    """
    out = []
    for i, entry in enumerate(spec):
        if i >= len(shape):
            break                      # spec longer than rank: truncate
        if entry is None:
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        kept = []
        for a in axes:
            if a not in mesh.shape:
                continue
            n = mesh.shape[a]
            if shape[i] % (size * n) == 0:
                kept.append(a)
                size *= n
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def shard(x: jnp.ndarray, spec_name: str) -> jnp.ndarray:
    """with_sharding_constraint under active rules; no-op otherwise."""
    rules = active_rules()
    if rules is None:
        return x
    spec = sanitize_spec(rules.spec(spec_name), x.shape, rules.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
