"""KV cache + recurrent-state containers for serving."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def create_kv_cache(batch: int, kv_heads: int, max_len: int, head_dim: int,
                    dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    return {
        "k": jnp.zeros((batch, kv_heads, max_len, head_dim), dtype=dtype),
        "v": jnp.zeros((batch, kv_heads, max_len, head_dim), dtype=dtype),
    }


def kv_cache_shapes(batch: int, kv_heads: int, max_len: int, head_dim: int,
                    dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    shape = (batch, kv_heads, max_len, head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def update_kv(cache: Dict[str, jnp.ndarray], k_new: jnp.ndarray,
              v_new: jnp.ndarray, pos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Write one new token's K/V at position ``pos`` (same for all batch rows).

    k_new/v_new: (B, KH, 1, D); pos: () int32. A scatter on the (possibly
    sequence-sharded) cache dim — GSPMD turns this into a masked local update.
    """
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, 0, pos, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, 0, pos, 0))
    return {"k": k, "v": v}
