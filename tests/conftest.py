import os
import sys

import numpy as np
import pytest

# --- multi-device plumbing (tests marked ``multidevice``) -------------------
# XLA fixes the host device count at backend initialization, so the flag must
# be in the environment BEFORE anything imports jax. conftest import is the
# earliest hook pytest gives us; if jax is already in (a re-entrant run, a
# plugin that imported it first), leave the environment alone and let the
# marker hook below skip the marked tests instead of asserting on a count
# that can no longer change.
MULTIDEVICE_COUNT = 4
if "jax" not in sys.modules \
        and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={MULTIDEVICE_COUNT}"
    ).strip()


def pytest_runtest_setup(item):
    if item.get_closest_marker("multidevice") is None:
        return
    import jax
    if jax.device_count() < MULTIDEVICE_COUNT:
        pytest.skip(
            f"needs {MULTIDEVICE_COUNT} XLA host devices; have "
            f"{jax.device_count()} (JAX initialized before the forced host "
            f"device count could take effect)")


@pytest.fixture(scope="session")
def multidevice():
    """Device list for marked tests: asserts the forced host device count
    took effect (or skips the requester) and hands back the devices."""
    import jax
    if jax.device_count() < MULTIDEVICE_COUNT:
        pytest.skip(f"needs {MULTIDEVICE_COUNT} XLA host devices")
    return jax.devices()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_tweets(rng, n, t0=1, match_drugs=0.1):
    from repro.core import records as R
    from repro.data.synthetic import drug_tweak, tweet_batch
    batch = tweet_batch(rng, n, t0)
    fields = np.asarray(batch.fields).copy()
    fields = drug_tweak(fields, rng, match_drugs)
    return R.RecordBatch.from_numpy(fields, np.asarray(batch.location))


# --- shared broker-buffer fuzz helpers (test_property + test_multi_channel;
# --- they cannot import each other: test_property importorskips hypothesis)


def random_broker_result(rng, n_rows, max_t, n_groups, cap):
    """Random ChannelResult + group-sID table: arbitrary validity mask,
    arbitrary targets, groups with 1..cap members (-1 padded). Also returns
    the expected delivery order (valid pairs in ravel order)."""
    import jax.numpy as jnp
    from repro.core.plans import ChannelResult
    valid = rng.random((n_rows, max_t)) < 0.5
    tgts = rng.integers(0, n_groups, (n_rows, max_t)).astype(np.int32)
    rows = rng.integers(0, 1000, (n_rows, max_t)).astype(np.int32)
    counts = rng.integers(1, cap + 1, n_groups)
    group_sids = np.full((n_groups, cap), -1, np.int32)
    for g in range(n_groups):
        group_sids[g, :counts[g]] = rng.integers(0, 10000, counts[g])
    z = jnp.zeros((), jnp.int32)
    res = ChannelResult(jnp.asarray(rows), jnp.asarray(tgts),
                        jnp.asarray(valid), jnp.asarray(rows[:, 0]),
                        jnp.asarray(valid[:, 0]), z, z, z,
                        jnp.zeros((1,), jnp.int32),
                        jnp.zeros((1,), jnp.int32))
    flat = valid.ravel()
    return res, group_sids, rows.ravel()[flat], tgts.ravel()[flat]


def check_pack_invariants(res, group_sids, exp_rows, exp_tgts, max_pairs):
    """Conservation (delivered + overflow == valid pairs), exact in-order
    prefix, header member counts, and no overflow pair scattered over the
    last slot (the pre-PR-1 clamping bug aliased overflow onto the tail)."""
    import jax.numpy as jnp
    from repro.core.broker import pack_payloads
    out, delivered, overflow = pack_payloads(res, jnp.asarray(group_sids),
                                             payload_words=2,
                                             max_pairs=max_pairs)
    total = exp_rows.size
    d = int(delivered)
    assert d + int(overflow) == total
    assert d == min(total, max_pairs)
    got = np.asarray(out)
    assert got.shape[0] == max_pairs
    np.testing.assert_array_equal(got[:d, 0], exp_rows[:d])
    np.testing.assert_array_equal(got[:d, 1], exp_tgts[:d])
    members = (group_sids[exp_tgts[:d]] >= 0).sum(axis=1) if d else []
    np.testing.assert_array_equal(got[:d, 2], members)
    assert (got[d:] == 0).all()


def random_stacked_broker_result(rng, n_channels, n_rows, max_t, n_groups,
                                 cap):
    """C independent random ChannelResults stacked on a leading channel axis
    (the fused join's output layout) + stacked (C, T, cap) group-sID tables.
    Also returns the per-channel expected delivery orders."""
    import jax
    singles = [random_broker_result(rng, n_rows, max_t, n_groups, cap)
               for _ in range(n_channels)]
    stacked = jax.tree.map(lambda *xs: jax.numpy.stack(xs),
                           *[s[0] for s in singles])
    group_sids = np.stack([s[1] for s in singles])
    return stacked, group_sids, [s[2] for s in singles], [s[3] for s in singles]


def check_deliver_all_invariants(stacked, group_sids, exp_rows, exp_tgts,
                                 max_pairs, max_notify, spill_cap,
                                 num_brokers=2):
    """The fused-delivery contract, per channel: conservation per stage
    (delivered + captured-spill + uncaptured == produced), delivered prefix
    identical to the single-channel kernels, spill streams channel-major and
    exact, per-broker one-hot accounting sums to delivered."""
    import jax
    import jax.numpy as jnp
    from repro.core.broker import deliver_all, fanout_sids, pack_payloads
    C = group_sids.shape[0]
    tb = np.arange(group_sids.shape[1], dtype=np.int32)[None, :] % num_brokers
    tb = np.broadcast_to(tb, (C, group_sids.shape[1]))
    d = deliver_all(stacked, jnp.asarray(group_sids), 2, max_pairs,
                    max_notify, spill_cap, target_brokers=jnp.asarray(tb),
                    num_brokers=num_brokers)
    pair_ch = np.asarray(d.pair_spill.channels)[np.asarray(d.pair_spill.valid)]
    sid_ch = np.asarray(d.sid_spill.channels)[np.asarray(d.sid_spill.valid)]
    assert (np.diff(pair_ch) >= 0).all() and (np.diff(sid_ch) >= 0).all()
    spill_rows = np.asarray(d.pair_spill.rows)[np.asarray(d.pair_spill.valid)]
    spill_tgts = np.asarray(d.pair_spill.targets)[np.asarray(d.pair_spill.valid)]
    spill_sids = np.asarray(d.sid_spill.values)[np.asarray(d.sid_spill.valid)]
    pair_total = sid_total = 0
    for c in range(C):
        one = jax.tree.map(lambda a, c=c: a[c], stacked)
        sids_c = jnp.asarray(group_sids[c])
        buf, dlv, ov = pack_payloads(one, sids_c, 2, max_pairs)
        assert int(d.pack.delivered[c]) == int(dlv)
        assert int(d.pack.produced[c]) == int(dlv) + int(ov)
        np.testing.assert_array_equal(np.asarray(d.pack.payload[c]),
                                      np.asarray(buf))
        nbuf, ndlv, nov = fanout_sids(one, sids_c, max_notify)
        assert int(d.fan.delivered[c]) == int(ndlv)
        assert int(d.fan.produced[c]) == int(ndlv) + int(nov)
        np.testing.assert_array_equal(np.asarray(d.fan.notify[c]),
                                      np.asarray(nbuf))
        assert int(np.asarray(d.pack.per_broker[c]).sum()) == int(dlv)
        # spill streams: exactly the overflow tail of this channel's expected
        # in-order delivery, truncated by the PER-CHANNEL spill window (one
        # channel's overflow can never crowd out another's)
        dl = int(dlv)
        want_rows, want_tgts = exp_rows[c][dl:], exp_tgts[c][dl:]
        sel = pair_ch == c
        take = min(len(want_rows), spill_cap)
        np.testing.assert_array_equal(spill_rows[sel], want_rows[:take])
        np.testing.assert_array_equal(spill_tgts[sel], want_tgts[:take])
        pair_total += len(want_rows)
        full_sids = group_sids[c][exp_tgts[c]]
        full_sids = full_sids[full_sids >= 0]
        want_sids = full_sids[int(ndlv):]
        take = min(len(want_sids), spill_cap)
        np.testing.assert_array_equal(spill_sids[sid_ch == c],
                                      want_sids[:take])
        sid_total += len(want_sids)
    assert int(d.pair_spill.total) == pair_total
    assert int(d.sid_spill.total) == sid_total


def check_delivery_conservation(stats, num_results, num_notified):
    """delivered + spilled + dropped == produced, per stage. Ring-aware
    deliveries additionally count re-presented ring entries (``retried_*``)
    in produced: fresh == produced - retried."""
    assert (stats.delivered_pairs + stats.spilled_pairs + stats.dropped_pairs
            == num_results + stats.retried_pairs)
    assert (stats.delivered_sids + stats.spilled_sids + stats.dropped_sids
            == num_notified + stats.retried_sids)
    assert stats.delivered_pairs + stats.overflow_pairs \
        == num_results + stats.retried_pairs
    assert stats.delivered_sids + stats.overflow_sids \
        == num_notified + stats.retried_sids


def check_fanout_invariants(res, group_sids, exp_tgts, max_notify):
    """Conservation over member sIDs, exact in-order prefix, every delivered
    sID exists in the group table (none invented from -1 padding), tail
    stays -1 (no last-slot aliasing)."""
    import jax.numpy as jnp
    from repro.core.broker import fanout_sids
    exp_sids = group_sids[exp_tgts]
    exp_sids = exp_sids[exp_sids >= 0]
    out, delivered, overflow = fanout_sids(res, jnp.asarray(group_sids),
                                           max_notify=max_notify)
    d = int(delivered)
    assert d + int(overflow) == exp_sids.size
    assert d == min(exp_sids.size, max_notify)
    got = np.asarray(out)
    assert got.shape[0] == max_notify
    np.testing.assert_array_equal(got[:d], exp_sids[:d])
    assert (got[d:] == -1).all()
    assert set(got[:d].tolist()) <= set(group_sids[group_sids >= 0].tolist())
