"""Pallas TPU kernel: fused causal GQA flash attention (train / prefill).

Grid (B, H, nQ, nK); the innermost kv dimension is sequential on TPU so the
(1, 1, TQ, D) output block is revisited with running softmax state carried in
VMEM scratch (FlashAttention-2 schedule adapted to the MXU: TQ/TK tiles are
128-multiples so both matmuls hit the systolic array; fully-masked kv tiles
are skipped via pl.when on the causal diagonal).

VMEM per step (TQ=TK=256, D=128): q/k/v tiles 3*256*128*4 = 384 KB,
s/p (256,256) f32 = 256 KB, acc (256,128) f32 = 128 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TQ = 256
DEFAULT_TK = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, tq: int, tk: int, n_k: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Causal: a kv tile strictly above the diagonal contributes nothing.
    live = (ik * tk <= iq * tq + tq - 1) if causal else (ik >= 0)

    @pl.when(live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)       # (TQ, D)
        k = k_ref[0, 0].astype(jnp.float32)       # (TK, D)
        v = v_ref[0, 0].astype(jnp.float32)       # (TK, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
            kpos = ik * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]                        # (TQ, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                     # (TQ, TK)
        corr = jnp.exp(m_prev - m_new)             # (TQ, 1)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)            # fully-masked rows -> 0 out
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "scale", "tq", "tk", "interpret"))
def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool = True, scale: float = 1.0,
                           tq: int = DEFAULT_TQ, tk: int = DEFAULT_TK,
                           interpret: bool = True) -> jnp.ndarray:
    b, h, s, d = q.shape
    kh = k.shape[1]
    assert h % kh == 0, (h, kh)
    g = h // kh
    assert s % tq == 0 and s % tk == 0, (s, tq, tk)
    n_q, n_k = s // tq, s // tk
    grid = (b, h, n_q, n_k)
    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               tq=tq, tk=tk, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, tq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, tk, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, tk, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
