"""Broker subsystem (paper §3.2, §4.1.2, Table 2).

Brokers are HTTP endpoints in the real platform; here they are simulated but
their *work* is real and measurable, mirroring Table 2's three stages:

  receive  -- proportional to platform->broker bytes (ChannelResult.broker_bytes)
  convert  -- "converting to JSON": materialize a wire payload buffer. For the
              original layout that is one record copy per subscription; for the
              aggregated layout one record copy per group + the sID list.
  send     -- per-subscriber dispatch; identical between layouts (Table 2).

Two delivery paths share the same single-channel kernels:

  per-channel -- ``pack_payloads`` / ``fanout_sids``: one channel's result,
                 one host call each (the Table 2 reference path).
  fused       -- ``pack_payloads_all`` / ``fanout_sids_all`` / ``deliver_all``:
                 every channel's convert+send in ONE jitted computation over
                 the stacked channel axis, with per-channel caps and one-hot
                 per-broker accounting, so delivery runs inside the SAME
                 device program as execution. The fused stages are
                 gather-formulated (each output slot binary-searches its
                 source pair in per-channel prefix sums), so the work is
                 proportional to the delivery capacity + total overflow, not
                 to the C x max-pending x member-cap padded grid. Overflowed
                 pairs/sIDs land in the device-resident ``RetryRing`` (when
                 the caller passes one — re-packed and re-delivered ahead of
                 the fresh result on the NEXT call, epoch-masked staleness)
                 and past its window in compacted flat channel-major spill
                 streams for the engine's host-side SpillQueue.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plans
from repro.core.plans import ChannelResult

HEADER_WORDS = 4  # [row_id, target_idx, member_count, payload_words]


@dataclasses.dataclass
class BrokerRegistry:
    names: Dict[str, int]

    @staticmethod
    def create(*names: str) -> "BrokerRegistry":
        return BrokerRegistry({n: i for i, n in enumerate(names)})

    @property
    def num_brokers(self) -> int:
        return len(self.names)


@dataclasses.dataclass(frozen=True)
class DeliveryStats:
    """Broker delivery accounting for one executed channel (opt-in via
    ``deliver=True``): result pairs packed by the convert stage and end
    subscribers fanned out by the send stage, vs captured into the spill
    queue vs dropped outright (spill buffers full).

    Conservation, per stage: delivered + spilled + dropped == produced.
    ``overflow_*`` keeps the pre-spill-queue view (everything that missed the
    delivery buffer, recoverable or not)."""

    delivered_pairs: int
    spilled_pairs: int
    dropped_pairs: int
    delivered_sids: int
    spilled_sids: int
    dropped_sids: int
    # convert-stage delivered pairs per broker (one-hot accounting); () when
    # the caller supplied no broker table
    delivered_pairs_broker: Tuple[int, ...] = ()
    # retry-ring entries RE-presented this call (they were counted as
    # spilled by an earlier call): produced == fresh + retried, so
    # delivered + spilled + dropped == produced still holds per call and
    # telescopes across ticks (ring-resident entries count as spilled)
    retried_pairs: int = 0
    retried_sids: int = 0
    # pairs (and their member sIDs) the enrichment stage's budget rank
    # dropped BEFORE the convert stage ran (core/enrich.py): the
    # lowest-scoring pairs past the per-channel budget. These are a subset
    # of dropped_* — ranked drops are intentional filtering, never
    # recoverable through the ring/queue — so the per-stage conservation
    # identity above is unchanged
    ranked_pairs: int = 0
    ranked_sids: int = 0

    @property
    def overflow_pairs(self) -> int:
        return self.spilled_pairs + self.dropped_pairs

    @property
    def overflow_sids(self) -> int:
        return self.spilled_sids + self.dropped_sids

    @property
    def overflow(self) -> int:
        return self.overflow_pairs + self.overflow_sids

    @property
    def produced_pairs(self) -> int:
        return self.delivered_pairs + self.overflow_pairs

    @property
    def produced_sids(self) -> int:
        return self.delivered_sids + self.overflow_sids

    def merged(self, other: "DeliveryStats") -> "DeliveryStats":
        return DeliveryStats(
            self.delivered_pairs + other.delivered_pairs,
            self.spilled_pairs + other.spilled_pairs,
            self.dropped_pairs + other.dropped_pairs,
            self.delivered_sids + other.delivered_sids,
            self.spilled_sids + other.spilled_sids,
            self.dropped_sids + other.dropped_sids,
            self.delivered_pairs_broker or other.delivered_pairs_broker,
            self.retried_pairs + other.retried_pairs,
            self.retried_sids + other.retried_sids,
            self.ranked_pairs + other.ranked_pairs,
            self.ranked_sids + other.ranked_sids)


# ---------------------------------------------------------------------------
# single-channel kernels (shared by the per-channel API and the vmapped path)
# ---------------------------------------------------------------------------


def _pack_one(result: ChannelResult, group_sids: jnp.ndarray,
              payload_words: int, max_pairs: int, cap):
    """Convert stage for ONE channel: compact the valid pairs, in ravel order,
    into a (max_pairs, HEADER + sid_cap + payload_words) wire buffer.

    ``cap`` (traced scalar, clamped to ``max_pairs``) is the per-channel
    delivery cap: valid pairs past it are never written — they surface in the
    returned ``spill_mask`` (flat ravel order) for spill capture. Returns
    (buffer, delivered, produced, spill_mask, delivered_mask)."""
    cap_eff = jnp.minimum(jnp.asarray(cap, jnp.int32), max_pairs)
    sid_cap = group_sids.shape[1] if group_sids.ndim == 2 else 1
    rows = result.pair_rows.ravel()
    tgts = result.pair_targets.ravel()
    valid = result.pair_valid.ravel()
    pos = jnp.cumsum(valid.astype(jnp.int32)) - 1
    within = pos < cap_eff
    dest = jnp.where(valid & within, pos, max_pairs)
    width = HEADER_WORDS + sid_cap + payload_words
    out = jnp.zeros((max_pairs + 1, width), dtype=jnp.int32)
    tgt_safe = jnp.maximum(tgts, 0)
    sids = group_sids[tgt_safe] if group_sids.ndim == 2 else tgt_safe[:, None]
    members = jnp.sum((sids >= 0).astype(jnp.int32), axis=-1)
    header = jnp.stack([rows, tgts, members,
                        jnp.full_like(rows, payload_words)], axis=-1)
    payload = jnp.broadcast_to(rows[:, None], (rows.shape[0], payload_words))
    line = jnp.concatenate([header, sids, payload], axis=-1)
    out = out.at[dest].set(jnp.where(valid[:, None], line, 0), mode="drop")
    produced = jnp.sum(valid.astype(jnp.int32))
    delivered = jnp.minimum(produced, cap_eff)
    return out[:max_pairs], delivered, produced, valid & ~within, valid & within


def _fanout_one(result: ChannelResult, group_sids: jnp.ndarray,
                max_notify: int, cap):
    """Send stage for ONE channel: the flat in-order list of end subscribers.
    Returns (buffer, delivered, produced, member_sids, spill_mask) where
    ``member_sids`` is the full flat member stream (-1 where invalid) and
    ``spill_mask`` flags members past the per-channel cap."""
    cap_eff = jnp.minimum(jnp.asarray(cap, jnp.int32), max_notify)
    tgts = result.pair_targets.ravel()
    valid = result.pair_valid.ravel()
    tgt_safe = jnp.maximum(tgts, 0)
    sids = group_sids[tgt_safe] if group_sids.ndim == 2 else tgt_safe[:, None]
    member_valid = (sids >= 0) & valid[:, None]
    flat = jnp.where(member_valid, sids, -1).ravel()
    mask = flat >= 0
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    within = pos < cap_eff
    dest = jnp.where(mask & within, pos, max_notify)
    out = jnp.full((max_notify + 1,), -1, dtype=jnp.int32)
    out = out.at[dest].set(flat, mode="drop")
    produced = jnp.sum(mask.astype(jnp.int32))
    delivered = jnp.minimum(produced, cap_eff)
    return out[:max_notify], delivered, produced, flat, mask & ~within


def payload_notifications(payload: np.ndarray, delivered: int,
                          payload_words: int) -> np.ndarray:
    """Expand a delivered wire buffer into its (row_id, sID) notification
    pairs — the partition-INDEPENDENT view of the convert stage.

    Group chopping depends on load order (and, on the sharded engine, on
    which shard owns each subscription), so delivered (row, group) pair
    counts differ between equivalent engines; the end-subscriber
    notifications each line fans out to do not. Each delivered line
    contributes one (row_id, sid) per live member sID (the -1 padding in
    the line's sID slots is skipped). Used by the sharded parity harness to
    compare engines whose group partitions differ."""
    buf = np.asarray(payload)[:int(delivered)]
    if buf.size == 0:
        return np.zeros((0, 2), np.int64)
    sid_cap = buf.shape[1] - HEADER_WORDS - payload_words
    sids = buf[:, HEADER_WORDS:HEADER_WORDS + sid_cap].astype(np.int64)
    rows = np.broadcast_to(buf[:, :1].astype(np.int64), sids.shape)
    live = sids >= 0
    return np.stack([rows[live], sids[live]], axis=1)


def resolve_pair_sids(table: np.ndarray, targets: np.ndarray) -> np.ndarray:
    """Resolve spilled pair TARGETS to their member sID rows against the
    producing call's own sID table (host side, numpy).

    This is the capture half of the SpillQueue's epoch-free resolved lane:
    the pipelined runtime materializes delivery stats ticks after dispatch,
    when control-plane churn may have moved the live table past the one the
    join actually used — resolving here, against the DISPATCH-time table,
    makes the spilled entry self-contained, so a deferred drain re-delivers
    the identical notification multiset as an immediate one.

    ``table`` is one channel's slice of the stacked delivery sID table:
    (tmax, cap) group tables resolve by row; the identity fanouts (0-width
    spatial / 1-wide flat) resolve to the target itself — mirroring
    ``_pack_one``'s ndim dispatch. Returns (n, w>=1) int32 rows, -1-padded."""
    targets = np.asarray(targets, np.int32)
    table = np.asarray(table)
    if table.ndim != 2 or table.shape[1] == 0:
        return targets[:, None].copy()
    if table.shape[0] == 0:
        return np.full((len(targets), 1), -1, np.int32)
    safe = np.clip(targets, 0, table.shape[0] - 1)
    return table[safe].astype(np.int32)


def pack_payloads(result: ChannelResult, group_sids: jnp.ndarray,
                  payload_words: int, max_pairs: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Materialize the wire payload: (max_pairs, HEADER + cap + payload_words).

    One row per *result pair* (group or subscription). This is the broker's
    "convert" work: in the aggregated layout there are far fewer rows, each
    carrying its sID list; in the original layout there is one row per
    subscription with cap == 1.

    Returns (buffer, delivered, overflow): pairs beyond ``max_pairs`` are
    dropped — never scattered over the last slot — and counted in overflow.
    """
    out, delivered, produced, _, _ = _pack_one(result, group_sids,
                                               payload_words, max_pairs,
                                               max_pairs)
    return out, delivered, produced - delivered


def fanout_sids(result: ChannelResult, group_sids: jnp.ndarray,
                max_notify: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The broker's "send" stage: the flat list of end subscribers to notify.
    Identical volume for original and aggregated layouts (Table 2, row 3).

    Returns (buffer, delivered, overflow) — overflow counts sIDs dropped
    because the notify buffer was full."""
    out, delivered, produced, _, _ = _fanout_one(result, group_sids,
                                                 max_notify, max_notify)
    return out, delivered, produced - delivered


# ---------------------------------------------------------------------------
# ---------------------------------------------------------------------------
# fused multi-channel delivery: one jitted call covers every channel's
# convert+send, so execution and delivery share a single device program.
#
# Formulation: GATHER, not scatter. Each output slot (payload line, notify
# slot, spill slot) locates its source pair by binary search over per-channel
# prefix sums, so the work is proportional to the DELIVERY CAPACITY
# (C x (max_pairs + max_notify) + spill) — never to the shape-bucketed
# C x max-pending x member-cap grid the stacked results are padded to. The
# only full-grid passes are O(C x P) elementwise counts/prefix sums.
# ---------------------------------------------------------------------------


class PackedDelivery(NamedTuple):
    """Stacked convert-stage output (leading channel axis C)."""

    payload: jnp.ndarray     # (C, max_pairs, width) int32 wire buffers
    delivered: jnp.ndarray   # (C,) int32 pairs written
    produced: jnp.ndarray    # (C,) int32 valid pairs (pre-cap)
    spill_mask: jnp.ndarray  # (C, Rm*maxT) bool: valid pairs past the cap
    per_broker: jnp.ndarray  # (C, B) int32 delivered pairs per broker


class FanoutDelivery(NamedTuple):
    """Stacked send-stage output (leading channel axis C)."""

    notify: jnp.ndarray       # (C, max_notify) int32 flat sID dispatch
    delivered: jnp.ndarray    # (C,) int32 sIDs written
    produced: jnp.ndarray     # (C,) int32 member sIDs (pre-cap)


class RetryRing(NamedTuple):
    """Device-resident retry state for fused delivery: per-channel windows
    (C, W) of overflowed pairs — with the subscription EPOCH each indexes,
    for staleness masking — and overflowed sIDs (never stale). Entries are
    stored as compacted prefixes (``*_count`` gives each channel's live
    prefix). The ring is an INPUT and an OUTPUT of ``deliver_all``: resident
    entries are re-packed and re-delivered ahead of the fresh result inside
    the next call, so sustained overflow never round-trips through the
    host."""

    pair_rows: jnp.ndarray      # (C, W) int32
    pair_targets: jnp.ndarray   # (C, W) int32
    pair_epochs: jnp.ndarray    # (C, W) int32
    pair_count: jnp.ndarray     # (C,) int32
    sid_values: jnp.ndarray     # (C, W) int32
    sid_count: jnp.ndarray      # (C,) int32

    @property
    def window(self) -> int:
        return self.pair_rows.shape[1]


def empty_ring(num_channels: int, window: int) -> RetryRing:
    # one buffer PER field: the engine donates rings into the fused call,
    # and XLA rejects donating the same buffer twice in one execute
    def neg():
        return jnp.full((num_channels, window), -1, jnp.int32)

    def z1():
        return jnp.zeros((num_channels,), jnp.int32)

    return RetryRing(neg(), neg(), jnp.zeros((num_channels, window),
                                             jnp.int32),
                     z1(), neg(), z1())


class RingCounters(NamedTuple):
    """Per-channel (C,) ring accounting of one ring-aware delivery call."""

    retried_pairs: jnp.ndarray   # ring pair entries re-presented (incl stale)
    stale_pairs: jnp.ndarray     # of those, dropped for an epoch mismatch
    ring_pairs: jnp.ndarray      # pairs resident in the OUTPUT ring
    retried_sids: jnp.ndarray    # ring sid entries re-presented
    ring_sids: jnp.ndarray       # sids resident in the OUTPUT ring


class FusedDelivery(NamedTuple):
    """Both stages plus the compacted flat spill streams (channel identity
    preserved) for the engine's SpillQueue. Ring-aware calls additionally
    carry the successor ``ring`` and its ``counters``; the spill streams
    then hold only what overflowed PAST the ring (the host queue as the
    ring's bounded last resort).

    LAZY-STATS CONTRACT: every field is a device-array handle valid the
    moment the producing jitted call RETURNS (dispatch), not when it
    completes — holding one costs nothing and forces no sync. The engine's
    pipelined runtime threads ``ring`` straight into the next dispatch and
    defers every host read (``np.asarray`` of the stats/spill/payload
    fields) to ``PendingExecution.sync()``, ticks later."""

    pack: PackedDelivery
    fan: FanoutDelivery
    pair_spill: plans.PairStream   # overflowed (row, channel, target) pairs
    sid_spill: plans.ValueStream   # overflowed (sid, channel) end subscribers
    ring: Optional[RetryRing] = None
    counters: Optional[RingCounters] = None


def _pair_layout(result: ChannelResult, caps, cap_limit: int):
    """Shared per-channel pair bookkeeping for the stacked delivery stages:
    (valid2, rows2, tgt2, cumv, produced, cap), all (C, P)-shaped. ``cumv``
    is the inclusive per-channel prefix count of valid pairs (ravel order) —
    slot q's source pair is ``searchsorted(cumv[c], q, 'right')``."""
    C = result.pair_valid.shape[0]
    valid2 = result.pair_valid.reshape(C, -1)
    rows2 = result.pair_rows.reshape(C, -1)
    tgt2 = result.pair_targets.reshape(C, -1)
    cumv = jnp.cumsum(valid2.astype(jnp.int32), axis=1)
    produced = cumv[:, -1]
    if caps is None:
        cap = jnp.full((C,), cap_limit, dtype=jnp.int32)
    else:
        cap = jnp.minimum(jnp.asarray(caps, jnp.int32), cap_limit)
    return valid2, rows2, tgt2, cumv, produced, cap


def _member_counts(group_sids: jnp.ndarray, valid2: jnp.ndarray,
                   tgt2: jnp.ndarray,
                   counts: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """(C, P) member count per pair. With ``counts`` (C, T) — the
    ``TargetArrays.counts`` the engine already maintains — the pass is ONE
    O(C*P) gather, fully capacity-proportional. Without it the table is
    re-derived by an O(C*T*cap) reduction over ``group_sids`` (the
    standalone-kernel fallback); either way never O(C*P*cap) per-pair
    reductions. Requires group rows to pack members as a -1-padded PREFIX
    (the layout every table builder in subscriptions.py produces, and what
    the maintained counts equal by construction)."""
    if group_sids.shape[-1] == 0:       # identity fanout: 1 member per pair
        return jnp.where(valid2 & (tgt2 >= 0), 1, 0).astype(jnp.int32)
    if counts is None:
        counts = jnp.sum((group_sids >= 0).astype(jnp.int32), axis=-1)
    ch = jnp.arange(valid2.shape[0], dtype=jnp.int32)[:, None]
    return jnp.where(valid2, counts[ch, jnp.maximum(tgt2, 0)], 0)


def _pack_lines(rows: jnp.ndarray, tgts: jnp.ndarray, ok: jnp.ndarray,
                ch: jnp.ndarray, group_sids: jnp.ndarray, counts,
                payload_words: int, target_brokers,
                num_brokers: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Assemble the convert-stage wire lines + one-hot per-broker accounting
    for already-resolved (C, Q) output slots (``rows``/``tgts`` masked to 0
    where not ``ok``) — the single definition of the wire format, shared by
    the plain and ring-aware fused convert stages."""
    tgt_safe = jnp.where(ok, jnp.maximum(tgts, 0), 0)
    if group_sids.shape[-1] == 0:       # identity fanout
        members = jnp.where(ok, 1, 0)
        sids = tgt_safe[..., None]
    else:
        m_table = (counts if counts is not None else
                   jnp.sum((group_sids >= 0).astype(jnp.int32), axis=-1))
        members = jnp.where(ok, m_table[ch, tgt_safe], 0)
        sids = group_sids[ch, tgt_safe]
    header = jnp.stack([rows, tgts, members,
                        jnp.where(ok, payload_words, 0)], axis=-1)
    payload = jnp.broadcast_to(rows[..., None],
                               rows.shape + (payload_words,))
    line = jnp.concatenate([header, jnp.where(ok[..., None], sids, 0),
                            payload], axis=-1)
    if target_brokers is None or num_brokers == 0:
        per_broker = jnp.zeros((rows.shape[0], 0), dtype=jnp.int32)
    else:
        bids = jnp.where(ok, target_brokers[ch, tgt_safe], num_brokers)
        one_hot = bids[..., None] == jnp.arange(num_brokers, dtype=jnp.int32)
        per_broker = jnp.sum(one_hot.astype(jnp.int32), axis=1)
    return jnp.where(ok[..., None], line, 0), per_broker


def _source_pair(cum: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Per-channel binary search: source index for each output rank. ``cum``
    (C, P) inclusive prefix counts, ``q`` (C, Q) target ranks -> (C, Q)."""
    return jax.vmap(lambda c, k: jnp.searchsorted(c, k, side="right"))(cum, q)


def _gather(arr2: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    return jnp.take_along_axis(arr2, p, axis=1)


def pack_payloads_all(result: ChannelResult, group_sids: jnp.ndarray,
                      payload_words: int, max_pairs: int,
                      caps: Optional[jnp.ndarray] = None,
                      target_brokers: Optional[jnp.ndarray] = None,
                      num_brokers: int = 0,
                      counts: Optional[jnp.ndarray] = None
                      ) -> PackedDelivery:
    """Convert stage for EVERY channel at once. ``result`` leaves carry a
    leading C axis (the fused join output); ``group_sids`` is (C, T, cap) for
    group/flat tables or (C, 0) to select the identity fanout (spatial
    channels). Each channel's delivered prefix is bit-identical to
    ``pack_payloads`` on its slice.

    ``caps`` (C,) bounds delivery per channel (default: the shared buffer
    size). ``target_brokers`` (C, T) — broker id by target index — enables
    one-hot per-broker accounting of *delivered* pairs, returned as
    (C, num_brokers); the masked reductions run over the (C, max_pairs)
    output slots, not the pending grid. ``counts`` (C, T) supplies the
    engine-maintained member counts so the pass never re-derives them from
    the sID table (see ``_member_counts``).
    """
    C = result.pair_valid.shape[0]
    valid2, rows2, tgt2, cumv, produced, cap_p = _pair_layout(
        result, caps, max_pairs)
    P = valid2.shape[1]
    ch = jnp.arange(C, dtype=jnp.int32)[:, None]
    delivered = jnp.minimum(produced, cap_p)
    q = jnp.broadcast_to(jnp.arange(max_pairs, dtype=jnp.int32), (C, max_pairs))
    p = jnp.minimum(_source_pair(cumv, q), P - 1)          # (C, max_pairs)
    ok = q < delivered[:, None]
    rows = jnp.where(ok, _gather(rows2, p), 0)
    tgts = jnp.where(ok, _gather(tgt2, p), 0)
    out, per_broker = _pack_lines(rows, tgts, ok, ch, group_sids, counts,
                                  payload_words, target_brokers, num_brokers)
    spill_mask = valid2 & (cumv - 1 >= cap_p[:, None])
    return PackedDelivery(out, delivered, produced, spill_mask, per_broker)


def _member_value(group_sids: jnp.ndarray, ch, tgt_safe: jnp.ndarray,
                  j: jnp.ndarray) -> jnp.ndarray:
    """sID of member ``j`` of the pair targeting ``tgt_safe``, per channel."""
    if group_sids.shape[-1] == 0:
        return tgt_safe                     # identity fanout, j is always 0
    return group_sids[ch, tgt_safe, jnp.minimum(j, group_sids.shape[-1] - 1)]


def fanout_sids_all(result: ChannelResult, group_sids: jnp.ndarray,
                    max_notify: int,
                    caps: Optional[jnp.ndarray] = None,
                    counts: Optional[jnp.ndarray] = None) -> FanoutDelivery:
    """Send stage for EVERY channel at once, with per-channel caps. Each
    notify slot binary-searches its source pair in the per-channel member
    prefix sums and gathers the sID directly — O(max_notify log P) per
    channel, no member grid. Delivered prefixes are bit-identical to
    ``fanout_sids`` per channel (tables pack members as a -1-padded prefix).
    ``counts`` (C, T): engine-maintained member counts (see
    ``_member_counts``)."""
    return _fanout_parts(result, group_sids, max_notify, caps, counts)[0]


def _fanout_parts(result: ChannelResult, group_sids: jnp.ndarray,
                  max_notify: int, caps,
                  counts: Optional[jnp.ndarray] = None):
    """The send stage plus its internal member bookkeeping, so ``deliver_all``
    can resolve spill slots against the same prefix sums without
    re-deriving them."""
    C = result.pair_valid.shape[0]
    valid2, _, tgt2, _, _, cap_n = _pair_layout(result, caps, max_notify)
    members = _member_counts(group_sids, valid2, tgt2, counts)  # (C, P)
    cumm = jnp.cumsum(members, axis=1)
    produced = cumm[:, -1]
    delivered = jnp.minimum(produced, cap_n)
    k = jnp.broadcast_to(jnp.arange(max_notify, dtype=jnp.int32),
                         (C, max_notify))
    notify = _member_lookup(group_sids, tgt2, members, cumm, k,
                            k < delivered[:, None])
    return FanoutDelivery(notify, delivered, produced), (tgt2, members, cumm,
                                                         cap_n)


def _member_lookup(group_sids, tgt2, members, cumm, k, ok) -> jnp.ndarray:
    """Resolve per-channel member ranks ``k`` (C, Q) to sIDs: binary-search
    the owning pair, derive the in-pair offset, gather. -1 where not ``ok``."""
    P = tgt2.shape[1]
    ch = jnp.arange(tgt2.shape[0], dtype=jnp.int32)[:, None]
    p = jnp.minimum(_source_pair(cumm, k), P - 1)
    j = k - (_gather(cumm, p) - _gather(members, p))           # rank in pair
    tgt_safe = jnp.maximum(_gather(tgt2, p), 0)
    return jnp.where(ok, _member_value(group_sids, ch, tgt_safe, j), -1)


def deliver_all(result: ChannelResult, group_sids: jnp.ndarray,
                payload_words: int, max_pairs: int, max_notify: int,
                spill_cap: int,
                caps_pairs: Optional[jnp.ndarray] = None,
                caps_notify: Optional[jnp.ndarray] = None,
                target_brokers: Optional[jnp.ndarray] = None,
                num_brokers: int = 0,
                counts: Optional[jnp.ndarray] = None,
                ring: Optional[RetryRing] = None,
                epochs: Optional[jnp.ndarray] = None) -> FusedDelivery:
    """The whole fused convert+send, plus spill capture: everything that
    missed a delivery buffer lands — with its channel identity — in a flat
    channel-major spill stream holding up to ``spill_cap`` entries PER
    CHANNEL per lane (the first ``spill_cap`` overflow entries of each
    channel are always captured; the rest are truncated for the caller to
    count as drops — one channel's overflow can never crowd out another's,
    which also makes the capture exactly what the per-channel path at C == 1
    would capture). Spill slots gather their entry straight from the
    per-channel overflow windows — spill work is O(C * spill_cap),
    independent of the pending grid. Pure and jit-compatible — the engine
    runs it inside the same jitted call as candidate discovery and the
    joins.

    With ``ring`` (+ ``epochs``, the (C,) current subscription epoch per
    channel) the call is RING-AWARE: resident ring entries whose epoch still
    matches are delivered FIRST (stale ones are dropped and counted), fresh
    result pairs follow, and the live overflow tail re-enters the output
    ring up to its window — only what overflows PAST the ring reaches the
    spill streams (the host queue as bounded last resort). ``counts``
    threads the engine-maintained member counts through both stages."""
    if ring is not None:
        return _deliver_with_ring(result, group_sids, payload_words,
                                  max_pairs, max_notify, spill_cap, ring,
                                  epochs, caps_pairs, caps_notify,
                                  target_brokers, num_brokers, counts)
    pack = pack_payloads_all(result, group_sids, payload_words, max_pairs,
                             caps_pairs, target_brokers, num_brokers, counts)
    valid2, rows2, tgt2, cumv, produced, cap_p = _pair_layout(
        result, caps_pairs, max_pairs)
    P = valid2.shape[1]

    # pairs lane: spill slot (c, i) -> in-channel pair rank cap_c + i ->
    # source pair, by binary search + gather
    ov_p = produced - pack.delivered                           # (C,)
    ch_r, k_r, valid_r, total_p = _spill_slots(ov_p, cap_p, spill_cap)
    pr = _row_search(cumv, P + 1, ch_r, k_r)
    take = lambda arr2: jnp.where(valid_r, arr2[ch_r, pr], -1)
    pair_spill = plans.PairStream(take(rows2), jnp.where(valid_r, ch_r, -1),
                                  take(tgt2), valid_r, total_p)

    # sids lane: same scheme over the send stage's member prefix sums
    fan, (tgt2, members, cumm, cap_n) = _fanout_parts(
        result, group_sids, max_notify, caps_notify, counts)
    ov_s = fan.produced - fan.delivered
    ch_s, k_s, valid_s, total_s = _spill_slots(ov_s, cap_n, spill_cap)
    sid_cap = 1 if group_sids.shape[-1] == 0 else group_sids.shape[-1]
    p_s = _row_search(cumm, P * sid_cap + 1, ch_s, k_s)
    j_s = k_s - (cumm[ch_s, p_s] - members[ch_s, p_s])
    tgt_s = jnp.maximum(tgt2[ch_s, p_s], 0)
    vals = jnp.where(valid_s,
                     _member_value(group_sids, ch_s, tgt_s, j_s), -1)
    sid_spill = plans.ValueStream(vals, jnp.where(valid_s, ch_s, -1),
                                  valid_s, total_s)
    return FusedDelivery(pack, fan, pair_spill, sid_spill)


def _deliver_with_ring(result: ChannelResult, group_sids: jnp.ndarray,
                       payload_words: int, max_pairs: int, max_notify: int,
                       spill_cap: int, ring: RetryRing, epochs: jnp.ndarray,
                       caps_pairs, caps_notify, target_brokers,
                       num_brokers: int, counts) -> FusedDelivery:
    """Ring-aware fused delivery. Per channel, the delivery order is: live
    (epoch-matching) ring entries in residence order, then the fresh valid
    pairs in ravel order. The live overflow tail — ranks past the cap —
    re-enters the output ring (first W entries), then the spill stream
    (next spill_cap), then truncates to counted drops. Everything is
    gather-formulated against the ring's live prefix sums and the fresh
    prefix sums, so the added work is O(C * (W + max_pairs + spill_cap))."""
    C = result.pair_valid.shape[0]
    W = ring.window
    epochs = jnp.asarray(epochs, jnp.int32)
    valid2, rows2, tgt2, cumv, nfresh, cap_p = _pair_layout(
        result, caps_pairs, max_pairs)
    P = valid2.shape[1]
    ch = jnp.arange(C, dtype=jnp.int32)[:, None]
    identity = group_sids.shape[-1] == 0

    # ---- pairs lane -----------------------------------------------------
    iw = jnp.arange(W, dtype=jnp.int32)[None, :]
    in_ring = iw < ring.pair_count[:, None]
    live_r = in_ring & (ring.pair_epochs == epochs[:, None])
    cumr = jnp.cumsum(live_r.astype(jnp.int32), axis=1)        # (C, W)
    nring = cumr[:, -1]
    stale = ring.pair_count - nring
    produced = ring.pair_count + nfresh
    delivered = jnp.minimum(nring + nfresh, cap_p)

    def comb_pairs(q, ok):
        """(rows, tgts) for combined-order ranks ``q`` (C, Q): ring entries
        first, fresh pairs after."""
        from_ring = q < nring[:, None]
        pr = jnp.minimum(_source_pair(cumr, q), W - 1)
        r_rows = _gather(ring.pair_rows, pr)
        r_tgts = _gather(ring.pair_targets, pr)
        qf = jnp.maximum(q - nring[:, None], 0)
        pf = jnp.minimum(_source_pair(cumv, qf), P - 1)
        rows = jnp.where(from_ring, r_rows, _gather(rows2, pf))
        tgts = jnp.where(from_ring, r_tgts, _gather(tgt2, pf))
        return jnp.where(ok, rows, -1), jnp.where(ok, tgts, -1)

    q = jnp.broadcast_to(jnp.arange(max_pairs, dtype=jnp.int32),
                         (C, max_pairs))
    ok = q < delivered[:, None]
    rows_q, tgts_q = comb_pairs(q, ok)
    out, per_broker = _pack_lines(
        jnp.where(ok, rows_q, 0), jnp.where(ok, tgts_q, 0), ok, ch,
        group_sids, counts, payload_words, target_brokers, num_brokers)
    pack = PackedDelivery(out, delivered, produced, jnp.zeros_like(valid2),
                          per_broker)

    # live overflow tail -> output ring window, then spill stream
    ov_live = nring + nfresh - delivered                       # (C,)
    i_new = jnp.broadcast_to(jnp.arange(W, dtype=jnp.int32), (C, W))
    ok_new = i_new < jnp.minimum(ov_live, W)[:, None]
    nrows, ntgts = comb_pairs(delivered[:, None] + i_new, ok_new)
    ring_p_count = jnp.minimum(ov_live, W)
    r = jnp.arange(C * spill_cap, dtype=jnp.int32)
    ch_r, i_r = r // spill_cap, r % spill_cap
    valid_r = (W + i_r) < ov_live[ch_r]
    # spill ranks start at delivered + W >= W >= nring, so spill slots are
    # always FRESH-sourced: ring entries either deliver or re-enter the
    # ring; they never demote to the host queue
    k_r = delivered[ch_r] + W + i_r                 # combined-order rank
    pf_r = _row_search(cumv, P + 1, ch_r, k_r - nring[ch_r])
    sp_rows = rows2[ch_r, pf_r]
    sp_tgts = tgt2[ch_r, pf_r]
    total_p = jnp.sum(jnp.maximum(ov_live - W, 0))
    pair_spill = plans.PairStream(
        jnp.where(valid_r, sp_rows, -1), jnp.where(valid_r, ch_r, -1),
        jnp.where(valid_r, sp_tgts, -1), valid_r, total_p)

    # ---- sids lane ------------------------------------------------------
    fan0, (tgt2, members, cumm, cap_n) = _fanout_parts(
        result, group_sids, max_notify, caps_notify, counts)
    rsc = ring.sid_count
    produced_s = rsc + fan0.produced
    delivered_s = jnp.minimum(produced_s, cap_n)

    def comb_sids(k, ok):
        """sIDs for combined-order ranks ``k`` (C, Q): resident ring sids
        (a compacted prefix: direct index) first, fresh members after."""
        from_ring = k < rsc[:, None]
        r_val = _gather(ring.sid_values, jnp.minimum(k, W - 1))
        kf = jnp.maximum(k - rsc[:, None], 0)
        f_val = _member_lookup(group_sids, tgt2, members, cumm, kf, ok)
        return jnp.where(ok, jnp.where(from_ring, r_val, f_val), -1)

    k = jnp.broadcast_to(jnp.arange(max_notify, dtype=jnp.int32),
                         (C, max_notify))
    notify = comb_sids(k, k < delivered_s[:, None])
    fan = FanoutDelivery(notify, delivered_s, produced_s)
    ov_s = produced_s - delivered_s
    ok_snew = i_new < jnp.minimum(ov_s, W)[:, None]
    nsids = comb_sids(delivered_s[:, None] + i_new, ok_snew)
    ring_s_count = jnp.minimum(ov_s, W)
    valid_s = (W + i_r) < ov_s[ch_r]
    # same invariant as the pairs lane: rsc <= W, so spill slots are always
    # fresh member lookups
    k_s = delivered_s[ch_r] + W + i_r
    sid_cap = 1 if identity else group_sids.shape[-1]
    kf_s = k_s - rsc[ch_r]
    p_s = _row_search(cumm, P * sid_cap + 1, ch_r, kf_s)
    j_s = kf_s - (cumm[ch_r, p_s] - members[ch_r, p_s])
    tgt_s = jnp.maximum(tgt2[ch_r, p_s], 0)
    vals = jnp.where(valid_s,
                     _member_value(group_sids, ch_r, tgt_s, j_s), -1)
    total_s = jnp.sum(jnp.maximum(ov_s - W, 0))
    sid_spill = plans.ValueStream(vals, jnp.where(valid_s, ch_r, -1),
                                  valid_s, total_s)

    new_ring = RetryRing(
        nrows, ntgts,
        jnp.broadcast_to(epochs[:, None], (C, W)).astype(jnp.int32),
        ring_p_count, nsids, ring_s_count)
    counters = RingCounters(ring.pair_count, stale, ring_p_count,
                            rsc, ring_s_count)
    return FusedDelivery(pack, fan, pair_spill, sid_spill, new_ring,
                         counters)


def _row_search(cum2: jnp.ndarray, offset: int, ch: jnp.ndarray,
                k: jnp.ndarray) -> jnp.ndarray:
    """``searchsorted(cum2[ch_i], k_i, 'right')`` for per-slot channels, as
    ONE global search over the offset-flattened prefix array (``offset`` >
    any row value makes it non-decreasing across row boundaries) — avoids a
    (slots x P) dynamic-row gather that a vmapped per-element search would
    materialize."""
    C, P = cum2.shape
    flat = (cum2 + offset * jnp.arange(C, dtype=jnp.int32)[:, None]).ravel()
    idx = jnp.searchsorted(flat, k + offset * ch, side="right")
    return jnp.clip(idx.astype(jnp.int32) - ch * P, 0, P - 1)


def _spill_slots(ov: jnp.ndarray, cap, spill_cap: int):
    """Per-channel spill windows flattened channel-major: slot r = c *
    spill_cap + i holds channel c's i-th overflow entry (in-channel rank
    cap_c + i), valid while i < min(ov_c, spill_cap). Identical capture to
    running the per-channel path with the same ``spill_cap`` — no
    cross-channel crowd-out. ``total`` is the full (pre-truncation) overflow
    across channels."""
    C = ov.shape[0]
    r = jnp.arange(C * spill_cap, dtype=jnp.int32)
    ch = r // spill_cap
    i = r % spill_cap
    return ch, cap[ch] + i, i < jnp.minimum(ov, spill_cap)[ch], jnp.sum(ov)


def broker_traffic_summary(result: ChannelResult,
                           delivery: Optional[DeliveryStats] = None
                           ) -> Dict[str, np.ndarray]:
    """Per-broker traffic view of one channel result. With ``delivery`` (the
    DeliveryStats of a deliver=True execution) the summary also carries the
    delivery accounting — delivered / spilled / dropped per stage and the
    per-broker delivered split — so benchmarks surface drops instead of only
    byte counts."""
    out = {
        "bytes_per_broker": np.asarray(result.broker_bytes),
        "results_per_broker": np.asarray(result.broker_results),
        "total_bytes": np.asarray(result.broker_bytes.sum()),
        "total_results": np.asarray(result.num_results),
        "total_notified": np.asarray(result.num_notified),
    }
    if delivery is not None:
        out.update({
            "delivered_pairs": np.asarray(delivery.delivered_pairs),
            "spilled_pairs": np.asarray(delivery.spilled_pairs),
            "dropped_pairs": np.asarray(delivery.dropped_pairs),
            "delivered_sids": np.asarray(delivery.delivered_sids),
            "spilled_sids": np.asarray(delivery.spilled_sids),
            "dropped_sids": np.asarray(delivery.dropped_sids),
            "delivered_pairs_per_broker":
                np.asarray(delivery.delivered_pairs_broker, dtype=np.int64),
        })
    return out
