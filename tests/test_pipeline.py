"""Asynchronous pipelined tick runtime (core/runtime.py).

``dispatch_all`` enqueues every plan-group's fused call and returns lazy
handles; ``PendingExecution.sync()`` materializes them ticks later. These
tests pin down the contract that makes the overlap safe:

  * content parity — a pipelined ``run_ticks`` (depth >= 2, batched
    drains through the SpillQueue's epoch-free resolved lane) delivers the
    identical per-channel (row, sID) pair / sID multisets as the
    synchronous path, under churn + sustained overflow, both layouts,
    padded and compact backends;
  * zero steady-state retraces at depth — the pipeline replays cached
    traces only;
  * warm-on-trace-miss — a timed ``execute_all`` executes each group's
    fused call exactly once when the trace is already warm (the
    double-execution regression);
  * host-derived ingest — ``size_host``/row ids mirror the device dataset
    with no per-tick sync, ring-buffer wraparound included;
  * buffer donation — steady-state ingest and delivery reuse the dataset /
    retry-ring device buffers in place;
  * the resolved spill lane — captures survive control-plane churn between
    dispatch and a deferred drain, where the epoch lane must drop.
"""
import numpy as np
import pytest

from repro.core.broker import payload_notifications
from repro.core.channel import tweets_about_crime, tweets_about_drugs
from repro.core.churn import ChurnWorkload, run_ticks
from repro.core.engine import BADEngine
from repro.core.plans import ChannelPlan, ExecutionFlags
from repro.core.runtime import TickPipeline

from conftest import check_delivery_conservation, make_tweets

PW = 8    # engine default deliver_payload_words

FLAGS_AGG = ExecutionFlags(scan_mode="window", aggregation=True,
                           param_pushdown=True)
FLAGS_FLAT = ExecutionFlags(scan_mode="window", aggregation=False,
                            param_pushdown=False)


def _overflow_engine(rng, ring_capacity=24, max_deliver_pairs=12,
                     max_notify=24, n_subs=200, spatial=False, **kw):
    """Tightly capped engine: every tick overflows through the ring and
    cascades into the host SpillQueue, so deferred drains carry content."""
    eng = BADEngine(dataset_capacity=4096, index_capacity=1024,
                    max_window=2048, max_candidates=512,
                    brokers=("B1", "B2"), group_cap=8,
                    max_deliver_pairs=max_deliver_pairs,
                    max_notify=max_notify, ring_capacity=ring_capacity, **kw)
    eng.create_channel(tweets_about_drugs())
    if spatial:
        eng.create_channel(tweets_about_crime(1))
        eng.set_user_locations(
            (rng.normal(size=(30, 2)) * 30).astype(np.float32),
            rng.integers(0, 2, 30))
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, n_subs),
                       rng.integers(0, 2, n_subs))
    return eng


def _collectors(pairs, sids):
    """(on_tick, on_drain) hooks folding delivered content — tick reports
    and DrainReports alike — into per-channel (row, sID) / sID multisets."""
    def on_tick(tick, reports):
        for name, rep in reports.items():
            o = rep.overflow
            if o is None or rep.payload is None:
                continue
            pairs.extend((name,) + tuple(x) for x in payload_notifications(
                np.asarray(rep.payload), o.delivered_pairs, PW).tolist())
            sids.extend((name, s) for s in
                        np.asarray(rep.notify)[:o.delivered_sids].tolist())

    def on_drain(drained):
        for name, dr in drained.items():
            if dr.payload is not None and dr.stats.delivered_pairs:
                pairs.extend((name,) + tuple(x) for x in
                             payload_notifications(
                                 np.asarray(dr.payload),
                                 dr.stats.delivered_pairs, PW).tolist())
            if dr.notify is not None and dr.stats.delivered_sids:
                sids.extend((name, s) for s in
                            dr.notify[:dr.stats.delivered_sids].tolist())
    return on_tick, on_drain


def _settle(eng, pairs, sids):
    """Flush ring residue through the queue and drain to empty (drops from
    ring-epoch staleness are dispatch-aligned, hence identical per seed)."""
    eng.flush_rings()
    rounds = 0
    while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
        rounds += 1
        assert rounds < 500, "drain did not converge"
        for name, dr in eng.drain_spilled().items():
            if dr.payload is not None and dr.stats.delivered_pairs:
                pairs.extend((name,) + tuple(x) for x in
                             payload_notifications(
                                 np.asarray(dr.payload),
                                 dr.stats.delivered_pairs, PW).tolist())
            if dr.notify is not None and dr.stats.delivered_sids:
                sids.extend((name, s) for s in
                            dr.notify[:dr.stats.delivered_sids].tolist())


def _churn_run(depth, backend, flags, seed=11, ticks=7):
    """One seeded churn-under-overflow run; returns (report, sorted pair
    multiset, sorted sID multiset)."""
    r = np.random.default_rng(seed)
    eng = _overflow_engine(np.random.default_rng(seed + 1), spatial=True)
    eng.debug_delivery_buffers = True
    use_channel_plans = backend is not None
    if use_channel_plans:
        plan = ChannelPlan.from_flags(flags, backend)
        for name in eng.channels:
            eng.set_plan(name, plan)
    wl = [ChurnWorkload("TweetsAboutDrugs", adds_per_tick=10,
                        removes_per_tick=6)]
    pairs, sids = [], []
    on_tick, on_drain = _collectors(pairs, sids)
    rep = run_ticks(
        eng, wl, ticks, r, flags=None if use_channel_plans else flags,
        deliver=True, ingest_per_tick=96,
        make_batch=lambda rr, n, t0: make_tweets(rr, n, t0=t0,
                                                 match_drugs=0.3),
        warmup=1, use_channel_plans=use_channel_plans,
        on_tick=on_tick, on_drain=on_drain, pipeline_depth=depth)
    _settle(eng, pairs, sids)
    return rep, sorted(pairs), sorted(sids)


@pytest.mark.parametrize("backend", [None, "compact"],
                         ids=["padded", "compact"])
@pytest.mark.parametrize("agg", [True, False], ids=["agg", "flat"])
def test_pipelined_content_parity_vs_sync(backend, agg):
    """Depth-3 pipelined run (batched resolved-lane drains) delivers the
    identical per-channel pair/sID multisets — and identical aggregate
    DeliveryStats — as the synchronous drain-every-tick path, under churn +
    sustained overflow, spatial channel included."""
    flags = FLAGS_AGG if agg else FLAGS_FLAT
    rep_sync, pairs_sync, sids_sync = _churn_run(1, backend, flags)
    rep_pipe, pairs_pipe, sids_pipe = _churn_run(3, backend, flags)
    assert pairs_pipe == pairs_sync
    assert sids_pipe == sids_sync
    assert rep_pipe.pipeline_depth >= 2
    assert rep_sync.pipeline_depth == 1
    # device results are dispatch-aligned: tick aggregates match exactly
    assert rep_pipe.results == rep_sync.results
    assert rep_pipe.spilled == rep_sync.spilled
    assert (rep_pipe.delivered_pairs + rep_pipe.delivered_sids
            == rep_sync.delivered_pairs + rep_sync.delivered_sids)
    assert rep_pipe.dropped == rep_sync.dropped
    # batching actually happened: fewer drain round-trips than sync
    assert rep_pipe.drain_calls <= rep_sync.drain_calls


def test_pipelined_zero_steady_state_retraces(rng):
    """After warmup the pipelined loop replays cached traces only: the
    maintenance trace counter delta over the timed ticks is zero and the
    measured in-flight depth reaches the requested one."""
    eng = _overflow_engine(rng, ring_capacity=1 << 10,
                           max_deliver_pairs=1 << 10, max_notify=1 << 12)
    wl = [ChurnWorkload("TweetsAboutDrugs", adds_per_tick=0,
                        removes_per_tick=0)]
    rep = run_ticks(eng, wl, 9, rng, flags=FLAGS_AGG, deliver=True,
                    ingest_per_tick=64,
                    make_batch=lambda rr, n, t0: make_tweets(
                        rr, n, t0=t0, match_drugs=0.3),
                    warmup=3, pipeline_depth=3)
    assert rep.maintenance.traces == 0
    assert rep.pipeline_depth == 3
    assert rep.dropped == 0


def test_tick_pipeline_window_and_flush(rng):
    """The raw TickPipeline: ``step`` returns nothing while the window
    fills, then exactly the tick the depth bound forces out (oldest first,
    numbered by dispatch tick); ``flush`` returns the stragglers; depth < 1
    is rejected."""
    eng = _overflow_engine(rng)
    with pytest.raises(ValueError):
        TickPipeline(eng, depth=0)
    pipe = TickPipeline(eng, depth=3)
    got = []
    for t in range(5):
        eng.ingest(make_tweets(rng, 64, t0=100 * (t + 1), match_drugs=0.3))
        got += pipe.step(FLAGS_AGG, deliver=True)
    assert [t for t, _ in got] == [0, 1, 2]     # 2 still in flight
    assert pipe.in_flight == 2
    rest = pipe.flush()
    assert [t for t, _ in rest] == [3, 4]
    assert pipe.in_flight == 0
    assert pipe.max_in_flight == 3
    for _, reports in got + rest:
        rep = reports["TweetsAboutDrugs"]
        check_delivery_conservation(rep.overflow, rep.num_results,
                                    rep.num_notified)
    # depth-K drain cadence: due every K-th dispatched tick
    assert pipe.drain_due() is False            # _tick == 5, drain_every 3
    pipe.step(FLAGS_AGG, deliver=True)
    assert pipe.drain_due() is True
    pipe.flush()


def test_timed_execute_warms_only_on_trace_miss(rng, monkeypatch):
    """The double-execution regression: a timed ``execute_all`` warms a
    fused call only on an actual trace-cache miss — steady state runs each
    group exactly ONCE per tick (counted via a wrapper around the compiled
    fn, which the shape-keyed warm bookkeeping must tolerate)."""
    eng = _overflow_engine(rng, ring_capacity=1 << 10)
    eng.ingest(make_tweets(rng, 200, match_drugs=0.3))
    calls = []
    orig = BADEngine._exec_all_fn

    def counting(self, *a, **kw):
        fn, key = orig(self, *a, **kw)

        def wrapped(*args):
            calls.append(key)
            return fn(*args)
        return wrapped, key

    monkeypatch.setattr(BADEngine, "_exec_all_fn", counting)
    eng.execute_all(FLAGS_AGG, timed=True, deliver=True)
    first = len(calls)
    assert first == 2          # one warm execution + the timed one
    eng.execute_all(FLAGS_AGG, timed=True, deliver=True)
    assert len(calls) - first == 1   # warm trace: exactly one execution


def test_compact_timed_warms_only_on_trace_miss(rng, monkeypatch):
    """Same regression on the compact grow-protocol path: once the stream
    bucket and trace are warm, a timed ``execute_all`` runs the group
    exactly once."""
    eng = _overflow_engine(rng, ring_capacity=1 << 10)
    eng.set_plan("TweetsAboutDrugs",
                 ChannelPlan.from_flags(FLAGS_AGG, "compact"))
    eng.ingest(make_tweets(rng, 200, match_drugs=0.3))
    calls = []
    orig = BADEngine._exec_all_fn

    def counting(self, *a, **kw):
        fn, key = orig(self, *a, **kw)

        def wrapped(*args):
            calls.append(key)
            return fn(*args)
        return wrapped, key

    monkeypatch.setattr(BADEngine, "_exec_all_fn", counting)
    eng.execute_all(timed=True, deliver=True)   # may grow + warm
    eng.execute_all(timed=True, deliver=True)   # bucket stable, trace warm
    before = len(calls)
    eng.execute_all(timed=True, deliver=True)
    assert len(calls) - before == 1


def test_size_host_mirrors_device_size(rng):
    """``ingest`` derives row ids and ``size_host`` on the host (no device
    sync); the mirror tracks the device counter exactly, ring-buffer
    wraparound past the dataset capacity included."""
    eng = BADEngine(dataset_capacity=256, index_capacity=256,
                    max_window=256, max_candidates=128,
                    brokers=("B1",), group_cap=8)
    eng.create_channel(tweets_about_drugs())
    total = 0
    for t in range(5):
        rows = eng.ingest(make_tweets(rng, 100, t0=100 * (t + 1)))
        assert rows.tolist() == list(range(total, total + 100))
        total += 100
        assert eng.size_host == total
        assert eng.size_host == int(eng.dataset.size)
    assert total > 256      # wrapped the 256-slot ring buffer


def _ptr(arr):
    return arr.unsafe_buffer_pointer()


def test_ingest_donates_dataset_buffers(rng):
    """Steady-state ingest updates the dataset/index in place: the donated
    field buffer is reused for the output (same device pointer)."""
    eng = _overflow_engine(rng)
    eng.ingest(make_tweets(rng, 64, t0=100))     # traces
    if not hasattr(eng.dataset.fields, "unsafe_buffer_pointer"):
        pytest.skip("jax.Array.unsafe_buffer_pointer unavailable")
    before = _ptr(eng.dataset.fields)
    eng.ingest(make_tweets(rng, 64, t0=200))
    assert _ptr(eng.dataset.fields) == before


def test_delivery_donates_ring_buffers(rng):
    """Steady-state fused delivery donates the retry-ring lanes: the
    successor ring's buffers come from the presented ring's allocation
    (XLA may permute same-shaped aliases, so assert on the pointer sets)."""
    eng = _overflow_engine(rng, ring_capacity=64)
    eng.ingest(make_tweets(rng, 300, match_drugs=0.3))
    eng.execute_all(FLAGS_AGG, deliver=True)     # traces + seeds the ring
    [(_, _, ring)] = list(eng._rings.values())
    if not hasattr(ring.pair_rows, "unsafe_buffer_pointer"):
        pytest.skip("jax.Array.unsafe_buffer_pointer unavailable")
    before = {_ptr(x) for x in ring}
    eng.ingest(make_tweets(rng, 64, t0=500, match_drugs=0.3))
    eng.execute_all(FLAGS_AGG, deliver=True)
    [(_, _, ring2)] = list(eng._rings.values())
    after = {_ptr(x) for x in ring2}
    assert before & after, "no ring buffer was reused in place"


def test_resolved_lane_survives_churn_before_deferred_drain(rng):
    """Pipelined captures go through the epoch-free resolved lane: churn
    between dispatch and the deferred drain must not stale them. The
    epoch-lane control run drops under the identical schedule."""
    outcomes = {}
    for lane in ("resolved", "epoch"):
        r = np.random.default_rng(3)
        eng = _overflow_engine(r, ring_capacity=4, max_deliver_pairs=8,
                               max_notify=16)
        eng.ingest(make_tweets(r, 300, match_drugs=0.3))
        if lane == "resolved":
            rep = eng.dispatch_all(FLAGS_AGG, deliver=True,
                                   resolve_spills=True).sync()
        else:
            rep = eng.execute_all(FLAGS_AGG, deliver=True)
        o = rep["TweetsAboutDrugs"].overflow
        check_delivery_conservation(o, rep["TweetsAboutDrugs"].num_results,
                                    rep["TweetsAboutDrugs"].num_notified)
        queued = eng.spill.pending_pairs()
        assert queued > 0        # ring overflowed into the host queue
        eng.subscribe("TweetsAboutDrugs", 3, "B1")      # epoch bump
        delivered = dropped = 0
        rounds = 0
        while eng.spill.pending_pairs() + eng.spill.pending_sids() > 0:
            rounds += 1
            assert rounds < 500
            for dr in eng.drain_spilled().values():
                delivered += dr.stats.delivered_pairs
                dropped += dr.stats.dropped_pairs
        outcomes[lane] = (queued, delivered, dropped)
    queued, delivered, dropped = outcomes["resolved"]
    assert dropped == 0 and delivered == queued
    # the control shows the gap is real: epoch-lane pairs went stale
    assert outcomes["epoch"][2] > 0


def test_run_ticks_depth_one_equals_sync_path(rng):
    """``pipeline_depth=1`` is rejected into the classic synchronous body:
    the report says depth 1 and drain cadence is per-tick."""
    eng = _overflow_engine(rng)
    wl = [ChurnWorkload("TweetsAboutDrugs", adds_per_tick=4,
                        removes_per_tick=2)]
    rep = run_ticks(eng, wl, 4, rng, flags=FLAGS_AGG, deliver=True,
                    ingest_per_tick=64,
                    make_batch=lambda rr, n, t0: make_tweets(
                        rr, n, t0=t0, match_drugs=0.3),
                    warmup=1, pipeline_depth=1)
    assert rep.pipeline_depth == 1
    assert rep.drain_calls > 0
