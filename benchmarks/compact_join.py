"""Compacted execution join vs the padded fused path at skewed selectivity.

The workload the compact backends exist for: several window-scan channels
whose fixed predicates pass only a few percent of the window, with
population-skewed flat subscriptions (a fat ``maxT`` join fan-out). The
padded fused join pays C x window x maxT regardless; the compacted join pays
~live x maxT after the CSR compaction. Both paths run the SAME discovery —
the ratio isolates the join + accounting stages the stream compresses.

Emits, per backend family (oracle and pallas), the padded and compact
per-tick steady walls and the padded/compact ratio (``x..`` rows guarded by
thresholds.json), asserting count parity and zero steady-state retraces for
the compact engines along the way. A dense control row shows the regime
where compaction buys nothing (stream ~ grid), which is why the planner
gates the proposal on observed selectivity.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import records as R
from repro.core.channel import tweets_about_drugs
from repro.core.engine import BADEngine
from repro.core.plans import ChannelPlan
from repro.data.synthetic import (drug_tweak, subscriptions_by_population,
                                  tweet_batch)
from benchmarks import common
from benchmarks.common import emit, fresh_rng, scale

N_CHANNELS = 6
FAMILIES = {"oracle": ("oracle", "compact"),
            "pallas": ("pallas", "compact_pallas")}


def build(backend: str, match: float) -> BADEngine:
    """N_CHANNELS drug-predicate channels pinned to ``backend`` on a window
    scan, flat layout, skewed subscriptions; identical data per (match)
    regardless of backend (fresh_rng) so the A/B measures the plan."""
    rng = fresh_rng(("compact_join", match))
    # every channel carries the full subscription load: the skewed flat
    # fan-out (population-weighted states) is what makes the padded
    # C x window x maxT join grid expensive — and what compaction skips
    n_subs = common.N_SUBS
    n_new = common.N_TWEETS_PERIOD
    eng = BADEngine(dataset_capacity=1 << 16, index_capacity=1 << 15,
                    max_window=scale(1 << 15, 2048),
                    max_candidates=1 << 12,
                    brokers=("B1", "B2", "B3", "B4"))
    base = tweets_about_drugs()
    plan = ChannelPlan("window", False, True, backend)
    for i in range(N_CHANNELS):
        name = f"SparseDrugs{i}"
        eng.create_channel(dataclasses.replace(base, name=name))
        params, brokers = subscriptions_by_population(rng, n_subs, 4)
        eng.subscribe_bulk(name, params % 50, brokers)
        eng.set_plan(name, plan)
    b = tweet_batch(rng, n_new, t0=100)
    fields = drug_tweak(np.asarray(b.fields).copy(), rng, match)
    eng.ingest(R.RecordBatch.from_numpy(fields, np.asarray(b.location)))
    return eng


def _steady_wall(eng: BADEngine, repeats: int = 3):
    """Converged per-tick fused wall (best of ``repeats``) + per-channel
    counts; asserts the steady state is retrace- and rebuild-free AFTER the
    warm call (which, for the compact backends, also converges the adaptive
    stream buckets)."""
    eng.execute_all(None, advance=False, timed=False)     # warm + converge
    snap = eng.maintenance.snapshot()
    best = float("inf")
    for _ in range(repeats):
        reps = eng.execute_all(None, advance=False, timed=True)
        best = min(best, sum(r.wall_time_s for r in reps.values()))
    d = eng.maintenance.since(snap)
    assert d.traces == 0 and d.rebuilds == 0, "steady state retraced"
    counts = {n: (r.num_results, r.num_notified, r.scanned,
                  int(r.broker_bytes.sum()))
              for n, r in reps.items()}
    return best, counts


def run(rng) -> None:
    match = 0.02                                          # skewed: ~2% live
    for fam, (padded, compact) in FAMILIES.items():
        walls, counts = {}, {}
        for backend in (padded, compact):
            eng = build(backend, match)
            walls[backend], counts[backend] = _steady_wall(eng)
        assert counts[padded] == counts[compact], fam     # exact parity
        total = sum(c[0] for c in counts[padded].values())
        emit(f"compact_join/{fam}/padded", walls[padded],
             f"results={total}")
        emit(f"compact_join/{fam}/speedup", walls[compact],
             f"x{walls[padded] / max(walls[compact], 1e-9):.2f}")
    # dense control (oracle family): live ~ grid, compaction buys ~nothing —
    # the regime the planner's compact_selectivity gate exists to avoid
    dense = {}
    for backend in FAMILIES["oracle"]:
        eng = build(backend, 0.5)
        dense[backend], _ = _steady_wall(eng)
    emit("compact_join/dense_control", dense["compact"],
         f"x{dense['oracle'] / max(dense['compact'], 1e-9):.2f}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        common.set_smoke()
    run(np.random.default_rng(0))
