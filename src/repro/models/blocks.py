"""Block registry + the scanned superlayer.

A superlayer applies ``cfg.block_pattern`` in order; the model scans
``cfg.superlayer_repeat`` stacked superlayers (params stacked on axis 0 via
vmap'd init). "shared_attn" blocks (zamba2) use one un-stacked parameter set
closed over by the scan body — weight sharing with per-depth activations and
caches, as in the paper.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partition import shard
from repro.models import attention, moe, ssm
from repro.models.config import ModelConfig
from repro.models.kvcache import kv_cache_shapes
from repro.models.layers import mlp_apply, mlp_init, rms_norm


# ---------------------------------------------------------------------------
# per-block init / train / decode / state-shape
# ---------------------------------------------------------------------------


def block_init(key, kind: str, cfg: ModelConfig) -> Dict[str, Any]:
    if kind in ("dense", "shared_attn"):
        k1, k2 = jax.random.split(key)
        return {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
                "attn": attention.attn_init(k1, cfg),
                "norm2": jnp.ones((cfg.d_model,), jnp.float32),
                "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.param_dtype)}
    if kind == "moe":
        k1, k2 = jax.random.split(key)
        return {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
                "attn": attention.attn_init(k1, cfg),
                "norm2": jnp.ones((cfg.d_model,), jnp.float32),
                "moe": moe.moe_init(k2, cfg)}
    if kind == "mamba":
        return {"norm": jnp.ones((cfg.d_model,), jnp.float32),
                "mamba": ssm.mamba2_init(key, cfg)}
    if kind == "mlstm":
        return {"norm": jnp.ones((cfg.d_model,), jnp.float32),
                "mlstm": ssm.mlstm_init(key, cfg)}
    if kind == "slstm":
        return {"norm": jnp.ones((cfg.d_model,), jnp.float32),
                "slstm": ssm.slstm_init(key, cfg)}
    raise ValueError(kind)


def block_train(p, kind: str, x: jnp.ndarray, cfg: ModelConfig, cos, sin
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence training forward. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "shared_attn", "moe"):
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        x = x + attention.attn_apply(p["attn"], h, cfg, cos, sin, causal=True)
        x = shard(x, "act_btd")
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if kind == "moe":
            out, aux = moe.moe_apply(p["moe"], h, cfg)
        else:
            out = mlp_apply(p["mlp"], h, cfg.compute_dtype)
        x = x + out
    elif kind == "mamba":
        out, _ = ssm.mamba2_apply(p["mamba"], rms_norm(x, p["norm"], cfg.norm_eps), cfg)
        x = x + out
    elif kind == "mlstm":
        out, _ = ssm.mlstm_apply(p["mlstm"], rms_norm(x, p["norm"], cfg.norm_eps), cfg)
        x = x + out
    elif kind == "slstm":
        out, _ = ssm.slstm_apply(p["slstm"], rms_norm(x, p["norm"], cfg.norm_eps), cfg)
        x = x + out
    else:
        raise ValueError(kind)
    return shard(x, "act_btd"), aux


def block_prefill(p, kind: str, x: jnp.ndarray, cfg: ModelConfig, cos, sin,
                  max_len: int) -> Tuple[jnp.ndarray, Any]:
    """Training-shaped forward that also materializes the serving state."""
    b, s, _ = x.shape
    if kind in ("dense", "shared_attn", "moe"):
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        out, kv = attention.attn_prefill(p["attn"], h, cfg, cos, sin)
        x = x + out
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if kind == "moe":
            o2, _ = moe.moe_apply(p["moe"], h, cfg)
        else:
            o2 = mlp_apply(p["mlp"], h, cfg.compute_dtype)
        x = x + o2
        pad = max_len - s
        cache = {"k": jnp.pad(kv["k"], ((0, 0), (0, 0), (0, pad), (0, 0))).astype(cfg.compute_dtype),
                 "v": jnp.pad(kv["v"], ((0, 0), (0, 0), (0, pad), (0, 0))).astype(cfg.compute_dtype)}
        cache = {"k": shard(cache["k"], "kv_cache"), "v": shard(cache["v"], "kv_cache")}
        return shard(x, "act_btd"), cache
    if kind == "mamba":
        out, st = ssm.mamba2_apply(p["mamba"], rms_norm(x, p["norm"], cfg.norm_eps), cfg)
        return shard(x + out, "act_btd"), st
    if kind == "mlstm":
        out, st = ssm.mlstm_apply(p["mlstm"], rms_norm(x, p["norm"], cfg.norm_eps), cfg)
        return shard(x + out, "act_btd"), st
    if kind == "slstm":
        out, st = ssm.slstm_apply(p["slstm"], rms_norm(x, p["norm"], cfg.norm_eps), cfg)
        return shard(x + out, "act_btd"), st
    raise ValueError(kind)


def block_decode(p, kind: str, x: jnp.ndarray, cfg: ModelConfig, cos, sin,
                 state, pos, kv_len) -> Tuple[jnp.ndarray, Any]:
    """One-token decode. x (B, D)."""
    if kind in ("dense", "shared_attn", "moe"):
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        out, state = attention.attn_decode(p["attn"], h, cfg, cos, sin,
                                           state, pos, kv_len)
        x = x + out
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if kind == "moe":
            o2, _ = moe.moe_apply(p["moe"], h[:, None, :], cfg)
            o2 = o2[:, 0]
        else:
            o2 = mlp_apply(p["mlp"], h, cfg.compute_dtype)
        return shard(x + o2, "act_bd"), state
    if kind == "mamba":
        out, state = ssm.mamba2_decode(p["mamba"], rms_norm(x, p["norm"], cfg.norm_eps), cfg, state)
        return shard(x + out, "act_bd"), state
    if kind == "mlstm":
        out, state = ssm.mlstm_decode(p["mlstm"], rms_norm(x, p["norm"], cfg.norm_eps), cfg, state)
        return shard(x + out, "act_bd"), state
    if kind == "slstm":
        out, state = ssm.slstm_decode(p["slstm"], rms_norm(x, p["norm"], cfg.norm_eps), cfg, state)
        return shard(x + out, "act_bd"), state
    raise ValueError(kind)


def block_state_shapes(kind: str, cfg: ModelConfig, batch: int, max_len: int):
    if kind in ("dense", "shared_attn", "moe"):
        return kv_cache_shapes(batch, cfg.n_kv_heads, max_len,
                               cfg.resolved_head_dim, cfg.compute_dtype)
    if kind == "mamba":
        return ssm.mamba2_state_shapes(cfg, batch)
    if kind == "mlstm":
        return ssm.mlstm_state_shapes(cfg, batch)
    if kind == "slstm":
        return ssm.slstm_state_shapes(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# superlayer (the scanned unit)
# ---------------------------------------------------------------------------


def _stacked_kinds(cfg: ModelConfig):
    return [(i, k) for i, k in enumerate(cfg.block_pattern) if k != "shared_attn"]


def superlayer_init(key, cfg: ModelConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, len(cfg.block_pattern))
    return {f"b{i}": block_init(keys[i], kind, cfg)
            for i, kind in _stacked_kinds(cfg)}


def superlayer_train(layer_p, shared_p, x, cfg: ModelConfig, cos, sin):
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.block_pattern):
        p = shared_p if kind == "shared_attn" else layer_p[f"b{i}"]
        x, a = block_train(p, kind, x, cfg, cos, sin)
        aux = aux + a
    return x, aux


def superlayer_prefill(layer_p, shared_p, x, cfg: ModelConfig, cos, sin,
                       max_len: int):
    states = {}
    for i, kind in enumerate(cfg.block_pattern):
        p = shared_p if kind == "shared_attn" else layer_p[f"b{i}"]
        x, st = block_prefill(p, kind, x, cfg, cos, sin, max_len)
        states[f"b{i}"] = st
    return x, states


def superlayer_decode(layer_p, shared_p, x, states, cfg: ModelConfig,
                      cos, sin, pos, kv_len):
    new_states = {}
    for i, kind in enumerate(cfg.block_pattern):
        p = shared_p if kind == "shared_attn" else layer_p[f"b{i}"]
        x, st = block_decode(p, kind, x, cfg, cos, sin, states[f"b{i}"], pos, kv_len)
        new_states[f"b{i}"] = st
    return x, new_states


def superlayer_state_shapes(cfg: ModelConfig, batch: int, max_len: int):
    return {f"b{i}": block_state_shapes(kind, cfg, batch, max_len)
            for i, kind in enumerate(cfg.block_pattern)}
