"""Fault tolerance runtime: watchdog, straggler detection, failure recovery,
elastic re-meshing.

On a real multi-host deployment each host runs the watchdog around its own
train loop; here hosts are simulated (the CPU container is one host) but the
logic — EMA step timing, deviation flags, checkpoint-restart, re-mesh on
shrunken device sets — is the production code path exercised by tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Tuple


@dataclasses.dataclass
class StepTimer:
    """Per-worker EMA of step durations; flags stragglers (> factor x median
    of peers) — the mitigation hook decides whether to drop/replace."""

    ema_alpha: float = 0.2
    straggler_factor: float = 2.0
    times: Dict[str, float] = dataclasses.field(default_factory=dict)

    def record(self, worker: str, seconds: float) -> None:
        prev = self.times.get(worker)
        self.times[worker] = (seconds if prev is None
                              else prev * (1 - self.ema_alpha)
                              + seconds * self.ema_alpha)

    def stragglers(self) -> List[str]:
        if len(self.times) < 2:
            return []
        vals = sorted(self.times.values())
        med = vals[len(vals) // 2]
        return [w for w, t in self.times.items()
                if t > self.straggler_factor * med]


class FailureInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    def __init__(self, fail_at: Tuple[int, ...] = ()):
        self.fail_at = set(fail_at)
        self.failures = 0

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise RuntimeError(f"injected failure at step {step}")


def largest_valid_mesh(n_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Elastic re-mesh policy: after losing devices, keep TP size (weights
    layout) and shrink the data axis to the largest multiple that fits."""
    if n_devices < model_parallel:
        raise ValueError("fewer devices than the model-parallel degree")
    data = n_devices // model_parallel
    # power-of-two data axis keeps batch divisibility simple
    data = 2 ** int(math.log2(data))
    return (data, model_parallel)


def run_with_recovery(train_loop: Callable[[int], int],
                      save_fn: Callable[[int], None],
                      restore_fn: Callable[[], int],
                      total_steps: int,
                      checkpoint_every: int,
                      max_restarts: int = 8) -> Dict[str, int]:
    """Drive a (resumable) train loop to completion through failures.

    train_loop(start_step) runs until failure or completion and returns the
    last completed step. restore_fn() -> step to resume from.
    """
    restarts = 0
    step = restore_fn()
    while step < total_steps:
        try:
            step = train_loop(step)
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
            step = restore_fn()
    return {"final_step": step, "restarts": restarts}
