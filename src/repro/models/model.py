"""ModelApi: uniform step builders over every architecture family.

Gives the launcher/dry-run one interface per arch:
  loss(params, batch)                      -- training objective
  prefill(params, batch)                   -- prompt -> (logits, caches, pos)
  decode(params, caches, pos, batch)       -- one token -> (logits, caches)
plus abstract parameter/cache trees and their PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed import param_specs as psp
from repro.models import encdec, lm
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


class ModelApi:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg.validate()

    # -- parameters --------------------------------------------------------

    def init(self, key):
        if self.cfg.is_encdec:
            return encdec.init_params(self.cfg, key)
        return lm.init_params(self.cfg, key)

    def abstract_params(self):
        return jax.eval_shape(lambda k: self.init(k), jax.random.key(0))

    def param_pspecs(self):
        if self.cfg.is_encdec:
            return psp.encdec_param_specs(self.cfg)
        return psp.lm_param_specs(self.cfg)

    def param_count(self) -> int:
        tree = self.abstract_params()
        import numpy as np
        return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))

    def active_param_count(self) -> int:
        """6*N*D accounting uses active params for MoE."""
        cfg = self.cfg
        if cfg.n_experts and cfg.moe_top_k:
            tree = self.abstract_params()
            import numpy as np
            total = 0
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
                n = int(np.prod(leaf.shape))
                if any(getattr(k, "key", None) in ("gate", "up", "down")
                       and "moe" in str(path) for k in path):
                    n = n * cfg.moe_top_k // cfg.n_experts
                total += n
            return total
        return self.param_count()

    # -- steps --------------------------------------------------------------

    def loss(self, params, batch):
        if self.cfg.is_encdec:
            return encdec.loss_fn(params, self.cfg, batch)
        return lm.loss_fn(params, self.cfg, batch)

    def prefill(self, params, batch, max_len: Optional[int] = None):
        cfg = self.cfg
        if cfg.is_encdec:
            return encdec.prefill(params, cfg, batch["embeds"], batch["tokens"],
                                  max_len or batch["tokens"].shape[1])
        return lm.prefill(params, cfg, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"), max_len=max_len)

    def decode(self, params, caches, pos, batch):
        cfg = self.cfg
        if cfg.is_encdec:
            return encdec.decode_step(params, cfg, caches, pos, batch["token"])
        return lm.decode_step(params, cfg, caches, pos,
                              token=batch.get("token"),
                              embed=batch.get("embed"))

    # -- abstract inputs ----------------------------------------------------

    def input_specs(self, shape_name: str) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every step input of this cell."""
        cfg = self.cfg
        sh = SHAPES[shape_name]
        b, s = sh.global_batch, sh.seq_len
        i32 = jnp.int32
        cd = cfg.compute_dtype

        if cfg.is_encdec:
            s_dec = min(s // 4, cfg.max_target_len * 32)  # target = frames/4
            if sh.kind == "train":
                return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cd),
                        "tokens": jax.ShapeDtypeStruct((b, s_dec), i32),
                        "labels": jax.ShapeDtypeStruct((b, s_dec), i32)}
            if sh.kind == "prefill":
                return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cd),
                        "tokens": jax.ShapeDtypeStruct((b, min(s_dec, 1024)), i32)}
            return {"token": jax.ShapeDtypeStruct((b,), i32)}

        if cfg.frontend == "embed":
            if sh.kind == "train":
                return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cd),
                        "labels": jax.ShapeDtypeStruct((b, s), i32)}
            if sh.kind == "prefill":
                return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cd)}
            return {"token": jax.ShapeDtypeStruct((b,), i32)}

        if sh.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if sh.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        return {"token": jax.ShapeDtypeStruct((b,), i32)}

    def cache_shapes(self, shape_name: str):
        cfg = self.cfg
        sh = SHAPES[shape_name]
        if cfg.is_encdec:
            # decoder self-cache capped at max_target_len; encoder memory = seq
            return encdec.cache_shapes(cfg, sh.global_batch,
                                       cfg.max_target_len, sh.seq_len)
        return lm.cache_shapes(cfg, sh.global_batch, sh.seq_len)

    def cache_pspecs(self, shape_name: str):
        return psp.cache_specs(self.cache_shapes(shape_name))

    def supports(self, shape_name: str) -> bool:
        sh = SHAPES[shape_name]
        if sh.name == "long_500k" and not self.cfg.sub_quadratic:
            return False
        return True
