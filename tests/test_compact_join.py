"""Compacted execution join ("compact" / "compact_pallas" backends).

Covers: pair-for-pair parity with the padded fused path (4 scan modes x
{agg, flat} x both compact backends, param AND spatial channels), delivery
identity under tight caps (DeliveryStats + retry-ring behavior + drained
content multisets), the adaptive stream-capacity protocol (grow on a burst,
halve after sustained idleness, zero retraces at steady state), the
single-channel backend override, join_compact kernel-vs-ref bit parity, and
the integer broker-byte accounting regression."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channel import (most_threatening_tweets,
                                trending_tweets_in_country, tweets_about_crime,
                                tweets_about_drugs)
from repro.core.engine import _STREAM_FLOOR, _STREAM_PATIENCE, BADEngine
from repro.core.plans import SCAN_MODES, ChannelPlan, ExecutionFlags
from repro.kernels.join_compact import ops as jc_ops
from repro.kernels.join_compact import ref as jc_ref

from conftest import check_delivery_conservation, make_tweets

COMPACT = ("compact", "compact_pallas")


def _mixed_engine(seed, use_pallas=False, n_tweets=700, **kw):
    """3 param channels (distinct domains/payloads) + 1 spatial, the same
    data for equal seeds — the padded-vs-compact reference pair."""
    rng = np.random.default_rng(seed)
    args = dict(dataset_capacity=2048, index_capacity=1024, max_window=1024,
                max_candidates=256, brokers=("Broker1", "Broker2"),
                use_pallas=use_pallas)
    args.update(kw)
    eng = BADEngine(**args)
    eng.create_channel(tweets_about_drugs())
    eng.create_channel(most_threatening_tweets())
    eng.create_channel(trending_tweets_in_country(0, "EnglishTrending"))
    eng.create_channel(tweets_about_crime(3))
    eng.set_user_locations((rng.normal(size=(40, 2)) * 30).astype(np.float32),
                           rng.integers(0, 2, 40))
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, 300),
                       rng.integers(0, 2, 300))
    eng.subscribe_bulk("MostThreateningTweets", rng.integers(0, 50, 200),
                       rng.integers(0, 2, 200))
    eng.subscribe_bulk("EnglishTrending", rng.integers(0, 200, 250),
                       rng.integers(0, 2, 250))
    if n_tweets:
        eng.ingest(make_tweets(rng, n_tweets))
    return eng


def _assert_pair_identical(got, want, ctx):
    """Counts, per-broker bytes, and the exact valid (row, target) pair
    sequences — compaction must preserve the padded ravel order."""
    assert got.num_results == want.num_results, ctx
    assert got.num_notified == want.num_notified, ctx
    assert got.scanned == want.scanned, ctx
    np.testing.assert_array_equal(got.broker_bytes, want.broker_bytes,
                                  err_msg=str(ctx))
    gv = np.asarray(got.result.pair_valid)
    wv = np.asarray(want.result.pair_valid)
    np.testing.assert_array_equal(
        np.asarray(got.result.pair_rows)[gv],
        np.asarray(want.result.pair_rows)[wv], err_msg=str(ctx))
    np.testing.assert_array_equal(
        np.asarray(got.result.pair_targets)[gv],
        np.asarray(want.result.pair_targets)[wv], err_msg=str(ctx))


@pytest.mark.parametrize("scan", SCAN_MODES)
def test_compact_matches_padded_fused(scan):
    """Every channel of a mixed engine, per scan mode x layout x compact
    backend, is pair-for-pair identical to the padded oracle path (which the
    padded pallas path already matches, see test_multi_channel)."""
    ref_eng = _mixed_engine(7)
    engs = {b: _mixed_engine(7, use_pallas=(b == "compact_pallas"))
            for b in COMPACT}
    for agg in (False, True):
        flags = ExecutionFlags(scan_mode=scan, aggregation=agg,
                               param_pushdown=agg)
        want = ref_eng.execute_all(flags, advance=False, timed=False)
        for backend, eng in engs.items():
            plan = ChannelPlan.from_flags(flags, backend)
            for name in eng.channels:
                eng.set_plan(name, plan)
            got = eng.execute_all(advance=False, timed=False)
            assert set(got) == set(want)
            for name in want:
                _assert_pair_identical(got[name], want[name],
                                       (scan, agg, backend, name))
            assert got["TweetsAboutCrime3"].num_results > 0


def test_execute_channel_backend_override():
    """``execute_channel(..., backend=...)`` runs the foreign backend (the
    plan-search timing fix) and the compact result matches the padded one."""
    eng = _mixed_engine(3)
    flags = ExecutionFlags(scan_mode="window")
    want = eng.execute_channel("TweetsAboutDrugs", flags, advance=False,
                               timed=False)
    assert want.num_results > 0
    for backend in COMPACT:
        got = eng.execute_channel("TweetsAboutDrugs", flags, advance=False,
                                  timed=False, backend=backend)
        _assert_pair_identical(got, want, backend)
    got = eng.execute_channel("TweetsAboutCrime3", flags, advance=False,
                              timed=False, backend="compact")
    want = eng.execute_channel("TweetsAboutCrime3", flags, advance=False,
                               timed=False)
    _assert_pair_identical(got, want, "spatial")


def _delivery_engine(seed, backend, **kw):
    rng = np.random.default_rng(seed)
    args = dict(dataset_capacity=4096, index_capacity=1024, max_window=1024,
                max_candidates=256, brokers=("B1", "B2"), group_cap=8,
                max_deliver_pairs=8, max_notify=16, ring_capacity=64)
    args.update(kw)
    eng = BADEngine(**args)
    eng.debug_delivery_buffers = True
    eng.create_channel(tweets_about_drugs())
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, 40),
                       rng.integers(0, 2, 40))
    eng.set_plan("TweetsAboutDrugs",
                 ChannelPlan("window", False, True, backend))
    return eng


def _delivered(rep):
    o = rep.overflow
    pairs = [tuple(p) for p in
             np.asarray(rep.payload)[:o.delivered_pairs, :2].tolist()]
    return pairs, np.asarray(rep.notify)[:o.delivered_sids].tolist()


def test_compact_delivery_stats_and_ring_identical():
    """Under caps tight enough to spill into the retry ring every tick, the
    compact path's DeliveryStats (including retried_*), delivered wire
    content, and conservation identity are tick-for-tick identical to the
    padded path: ``stream_to_stacked`` hands ``deliver_all`` the exact
    padded pair order, so capped prefixes agree pair for pair."""
    padded = _delivery_engine(11, "oracle")
    compact = _delivery_engine(11, "compact")
    data_rng = np.random.default_rng(12)
    for tick in range(4):
        batch = make_tweets(data_rng, 120, t0=1 + 100 * tick,
                            match_drugs=0.4)
        reps = {}
        for eng in (padded, compact):
            eng.ingest(batch)
            rep = eng.execute_all(None, timed=False, deliver=True)
            reps[id(eng)] = rep["TweetsAboutDrugs"]
        w, g = reps[id(padded)], reps[id(compact)]
        check_delivery_conservation(g.overflow, g.num_results,
                                    g.num_notified)
        assert g.overflow == w.overflow, tick
        assert _delivered(g) == _delivered(w), tick
    assert compact.ring_pending_pairs() == padded.ring_pending_pairs()
    assert compact.ring_pending_pairs() > 0      # the ring was exercised


def test_stream_capacity_grows_on_burst_and_shrinks_after_idle():
    """The adaptive capacity protocol: a burst overflows the stream and the
    bucket jumps straight to the live total's power of two (results still
    exact — the truncated run is discarded); ``_STREAM_PATIENCE`` quiet
    ticks later the bucket halves back."""
    eng = _mixed_engine(5, n_tweets=0)
    ref = _mixed_engine(5, n_tweets=0)
    plan = ChannelPlan("window", False, True, "compact")
    names = [n for n in eng.channels
             if eng.channels[n].spec.join == "param"]
    for name in names:
        eng.set_plan(name, plan)
    key = ("param", plan, tuple(names))
    floor = 1 << _STREAM_FLOOR
    data_rng = np.random.default_rng(6)

    def tick(n, match, t0):
        # advancing ticks: each execution sees only the new records, so the
        # quiet ticks after the burst really are near-empty streams
        batch = make_tweets(data_rng, n, t0=t0, match_drugs=match)
        eng.ingest(batch)
        ref.ingest(batch)
        got = eng.execute_all(timed=False)
        want = ref.execute_all(plan.flags, timed=False)
        for name in names:
            _assert_pair_identical(got[name], want[name], name)

    tick(30, 0.1, 1)                             # tiny: floor bucket
    assert eng._stream_buckets[key] == floor
    tick(900, 0.9, 100)                          # burst: > floor live cands
    grown = eng._stream_buckets[key]
    assert grown > floor
    for i in range(_STREAM_PATIENCE):            # quiet run halves it once
        assert eng._stream_buckets[key] == grown
        tick(5, 0.1, 2000 + 10 * i)
    assert eng._stream_buckets[key] == grown // 2


def test_compact_steady_state_is_zero_retrace():
    """Once the stream bucket converges, same-shaped ticks reuse the cached
    fused trace: no retraces, no rebuilds — the compacted path preserves the
    executor's steady-state contract."""
    eng = _mixed_engine(9)
    plan = ChannelPlan("window", False, True, "compact")
    for name in eng.channels:
        eng.set_plan(name, plan)
    data_rng = np.random.default_rng(10)
    for tick in range(2):                        # converge buckets + traces
        eng.ingest(make_tweets(data_rng, 64, t0=1 + 100 * tick,
                               match_drugs=0.3))
        eng.execute_all(None, timed=False, deliver=True)
    snap = eng.maintenance.snapshot()
    for tick in range(3):
        eng.ingest(make_tweets(data_rng, 64, t0=500 + 100 * tick,
                               match_drugs=0.3))
        eng.execute_all(None, timed=False, deliver=True)
    d = eng.maintenance.since(snap)
    assert d.traces == 0 and d.rebuilds == 0


def test_join_compact_kernel_matches_ref():
    """ops.join_pairs (Pallas, interpret on CPU) is bit-identical to the jnp
    ref on random streams — including a non-tile-multiple S (padding path)
    and both layout modes."""
    rng = np.random.default_rng(0)
    for s, max_t, ts in ((37, 5, 16), (64, 8, 16), (130, 3, 64)):
        tgt = rng.integers(-1, 20, (s, max_t)).astype(np.int32)
        tgt_n = rng.integers(0, max_t + 1, s).astype(np.int32)
        members = rng.integers(0, 9, (s, max_t)).astype(np.int32)
        brokers = rng.integers(0, 2, (s, max_t)).astype(np.int32)
        valid = rng.random(s) < 0.7
        payload = rng.integers(1, 4000, s).astype(np.int32)
        for aggregated in (False, True):
            want = jc_ref.join_pairs(jnp.asarray(tgt), jnp.asarray(tgt_n),
                                     jnp.asarray(members),
                                     jnp.asarray(brokers),
                                     jnp.asarray(valid),
                                     jnp.asarray(payload), 2, aggregated)
            got = jc_ops.join_pairs(jnp.asarray(tgt), jnp.asarray(tgt_n),
                                    jnp.asarray(members),
                                    jnp.asarray(brokers), jnp.asarray(valid),
                                    jnp.asarray(payload), 2, aggregated,
                                    ts=ts)
            for g, w in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_broker_bytes_integer_exact_at_large_volume():
    """Regression: per-broker byte totals accumulated in float32 silently
    round once a channel-broker tick crosses 2^24 bytes with a payload that
    is not a power-of-two multiple. An ODD payload and ~10^8 bytes/tick must
    still satisfy bytes == num_results * payload exactly, in an integer
    dtype end-to-end."""
    payload = 30 * 1024 + 3                      # odd: float32 sums DO round
    rng = np.random.default_rng(1)
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=2048, max_candidates=2048, brokers=("B1",))
    eng.create_channel(dataclasses.replace(tweets_about_drugs(),
                                           payload_bytes=payload))
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 50, 600),
                       np.zeros(600, np.int64))
    eng.ingest(make_tweets(rng, 1024, match_drugs=0.6))
    flags = ExecutionFlags(scan_mode="window")   # flat: bytes = pairs * payload
    for backend in ("oracle", "compact"):
        rep = eng.execute_channel("TweetsAboutDrugs", flags, advance=False,
                                  timed=False, backend=backend)
        assert np.issubdtype(rep.broker_bytes.dtype, np.integer), backend
        want = rep.num_results * payload
        assert want > 2 ** 24                    # past float32 exactness
        assert int(rep.broker_bytes.sum()) == want, backend
