"""Unit tests: records, predicates, subscriptions, BAD index, user params."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bad_index as bidx
from repro.core import records as R
from repro.core.predicates import (EQ, GE, Predicate, compile_conditions,
                                   evaluate_conditions, evaluate_single)
from repro.core.subscriptions import (Aggregator, SubscriptionTable, aggregate,
                                      cap_from_frame_bytes, param_to_targets)
from repro.core.user_params import UserParameters, semi_join


def test_ring_buffer_append_and_wrap(rng):
    ds = R.ActiveDataset.create(16)
    b1 = R.RecordBatch.from_numpy(rng.integers(0, 5, (10, 10)).astype(np.int32))
    ds, ids1 = R.append(ds, b1)
    assert ids1.tolist() == list(range(10))
    b2 = R.RecordBatch.from_numpy(rng.integers(0, 5, (10, 10)).astype(np.int32))
    ds, ids2 = R.append(ds, b2)
    assert ids2.tolist() == list(range(10, 20))
    assert int(ds.size) == 20
    # rows 4..19 are live; gather a live row and check contents
    got = R.gather_rows(ds, jnp.asarray([19]))
    assert np.array_equal(np.asarray(got.fields)[0], np.asarray(b2.fields)[9])


def test_predicate_ops_exhaustive():
    fields = jnp.asarray(np.arange(10, dtype=np.int32)[:, None])
    for op, fn in [("==", np.equal), ("!=", np.not_equal), ("<", np.less),
                   ("<=", np.less_equal), (">", np.greater),
                   (">=", np.greater_equal)]:
        m = evaluate_single(fields, [Predicate.parse(0, op, 5)])
        assert np.array_equal(np.asarray(m), fn(np.arange(10), 5))


def test_conditions_list_multi_channel(rng):
    fields = jnp.asarray(rng.integers(0, 10, (64, 10)).astype(np.int32))
    chans = [[Predicate.parse(0, ">", 4)],
             [Predicate.parse(1, "==", 3), Predicate.parse(2, "<", 7)],
             []]
    conds = compile_conditions(chans)
    m = np.asarray(evaluate_conditions(fields, conds))
    f = np.asarray(fields)
    assert np.array_equal(m[:, 0], f[:, 0] > 4)
    assert np.array_equal(m[:, 1], (f[:, 1] == 3) & (f[:, 2] < 7))
    assert m[:, 2].all()          # empty conjunction == always true


def test_algorithm1_grouping_semantics():
    agg = Aggregator(cap=3)
    for i, (p, b) in enumerate([(1, 0), (1, 0), (1, 0), (1, 0), (2, 0), (1, 1)]):
        agg.add_subscription(p, b, sid=i)
    g = agg.build()
    # (1,0) has 4 subs -> 2 groups (cap 3); (2,0) and (1,1) one each
    assert g.num_groups == 4
    assert g.num_subscriptions == 6
    key_counts = {}
    for i in range(g.num_groups):
        key = (int(g.group_params[i]), int(g.group_brokers[i]))
        key_counts[key] = key_counts.get(key, 0) + 1
        assert int(g.group_counts[i]) <= 3
    assert key_counts[(1, 0)] == 2


def test_bulk_aggregate_matches_incremental(rng):
    params = rng.integers(0, 5, 200).astype(np.int32)
    brokers = rng.integers(0, 2, 200).astype(np.int32)
    table = SubscriptionTable.build(params, brokers)
    bulk = aggregate(table, cap=7)
    inc = Aggregator(cap=7)
    for s, p, b in zip(table.sids, params, brokers):
        inc.add_subscription(int(p), int(b), int(s))
    g2 = inc.build()
    assert bulk.num_subscriptions == g2.num_subscriptions == 200
    # same multiset of (param, broker, count)
    def sig(g):
        return sorted((int(g.group_params[i]), int(g.group_brokers[i]),
                       int(g.group_counts[i])) for i in range(g.num_groups))
    assert sig(bulk) == sig(g2)


def test_cap_from_frame_bytes_lane_alignment():
    assert cap_from_frame_bytes(40 * 1024) == 10240       # 128-aligned
    assert cap_from_frame_bytes(100) == 25                # below one lane
    assert cap_from_frame_bytes(40 * 1024, align=False) == 10240


def test_param_to_targets_map():
    params = np.asarray([3, 1, 3, 3, 0], dtype=np.int32)
    mp, counts = param_to_targets(params, domain=5)
    assert counts.tolist() == [1, 1, 0, 3, 0]
    assert set(mp[3][mp[3] >= 0].tolist()) == {0, 2, 3}


def test_bad_index_insert_window_watermark(rng):
    st = bidx.BADIndexState.create(2, 32)
    ids = jnp.arange(10, dtype=jnp.int32)
    matches = jnp.asarray(np.stack([np.arange(10) % 2 == 0,
                                    np.arange(10) % 5 == 0], 1))
    st = bidx.insert(st, ids, matches)
    assert st.counts.tolist() == [5, 2]
    rows, valid = bidx.new_entries(st, 0, 8)
    assert rows[np.asarray(valid)].tolist() == [0, 2, 4, 6, 8]
    st = bidx.advance_watermark(st, 0)
    rows, valid = bidx.new_entries(st, 0, 8)
    assert int(valid.sum()) == 0
    # channel 1 unaffected by channel 0's watermark
    rows, valid = bidx.new_entries(st, 1, 8)
    assert rows[np.asarray(valid)].tolist() == [0, 5]


def test_bad_index_overflow_flag():
    st = bidx.BADIndexState.create(1, 4)
    ids = jnp.arange(6, dtype=jnp.int32)
    st = bidx.insert(st, ids, jnp.ones((6, 1), bool))
    assert bool(st.overflowed[0])
    assert int(st.counts[0]) == 4


def test_bad_index_compact():
    st = bidx.BADIndexState.create(1, 8)
    st = bidx.insert(st, jnp.arange(6, dtype=jnp.int32), jnp.ones((6, 1), bool))
    st = bidx.advance_watermark(st, 0)
    st = bidx.insert(st, jnp.arange(6, 8, dtype=jnp.int32), jnp.ones((2, 1), bool))
    st = bidx.compact(st)
    rows, valid = bidx.new_entries(st, 0, 8)
    assert rows[np.asarray(valid)].tolist() == [6, 7]


def test_user_parameters_refcount_and_semijoin():
    up = UserParameters.create(10)
    up.add(3)
    up.add(3)
    up.add(7)
    up.remove(3)
    assert up.num_distinct == 2
    vals = jnp.asarray([3, 7, 1, 12, -1], dtype=jnp.int32)
    keep = np.asarray(semi_join(vals, up.mask()))
    assert keep.tolist() == [True, True, False, False, False]
    with pytest.raises(ValueError):
        up.remove(1)


def test_bad_index_shape_bucketing(rng):
    """The engine sizes candidate buffers from the watermark delta (the
    beyond-paper 'early result filtering enables tight shapes' step)."""
    from repro.core.channel import tweets_about_drugs
    from repro.core.engine import BADEngine
    from repro.core.plans import ExecutionFlags
    from conftest import make_tweets

    eng = BADEngine(dataset_capacity=4096, index_capacity=2048,
                    max_window=2048, max_candidates=1024)
    eng.create_channel(tweets_about_drugs())
    eng.subscribe("TweetsAboutDrugs", 3, "BrokerA")
    eng.ingest(make_tweets(rng, 1024, match_drugs=0.01))
    rep = eng.execute_channel("TweetsAboutDrugs",
                              ExecutionFlags(scan_mode="bad_index"),
                              advance=False)
    # buffer bucket = next pow2 of the true match count, >= 64
    assert rep.result.matched_rows.shape[0] <= 128
    base = eng.execute_channel("TweetsAboutDrugs", ExecutionFlags.original(),
                               advance=False)
    assert rep.num_notified == base.num_notified
