import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_tweets(rng, n, t0=1, match_drugs=0.1):
    from repro.core import records as R
    from repro.data.synthetic import drug_tweak, tweet_batch
    batch = tweet_batch(rng, n, t0)
    fields = np.asarray(batch.fields).copy()
    fields = drug_tweak(fields, rng, match_drugs)
    return R.RecordBatch.from_numpy(fields, np.asarray(batch.location))


# --- shared broker-buffer fuzz helpers (test_property + test_multi_channel;
# --- they cannot import each other: test_property importorskips hypothesis)


def random_broker_result(rng, n_rows, max_t, n_groups, cap):
    """Random ChannelResult + group-sID table: arbitrary validity mask,
    arbitrary targets, groups with 1..cap members (-1 padded). Also returns
    the expected delivery order (valid pairs in ravel order)."""
    import jax.numpy as jnp
    from repro.core.plans import ChannelResult
    valid = rng.random((n_rows, max_t)) < 0.5
    tgts = rng.integers(0, n_groups, (n_rows, max_t)).astype(np.int32)
    rows = rng.integers(0, 1000, (n_rows, max_t)).astype(np.int32)
    counts = rng.integers(1, cap + 1, n_groups)
    group_sids = np.full((n_groups, cap), -1, np.int32)
    for g in range(n_groups):
        group_sids[g, :counts[g]] = rng.integers(0, 10000, counts[g])
    z = jnp.zeros((), jnp.int32)
    res = ChannelResult(jnp.asarray(rows), jnp.asarray(tgts),
                        jnp.asarray(valid), jnp.asarray(rows[:, 0]),
                        jnp.asarray(valid[:, 0]), z, z, z,
                        jnp.zeros((1,), jnp.float32),
                        jnp.zeros((1,), jnp.int32))
    flat = valid.ravel()
    return res, group_sids, rows.ravel()[flat], tgts.ravel()[flat]


def check_pack_invariants(res, group_sids, exp_rows, exp_tgts, max_pairs):
    """Conservation (delivered + overflow == valid pairs), exact in-order
    prefix, header member counts, and no overflow pair scattered over the
    last slot (the pre-PR-1 clamping bug aliased overflow onto the tail)."""
    import jax.numpy as jnp
    from repro.core.broker import pack_payloads
    out, delivered, overflow = pack_payloads(res, jnp.asarray(group_sids),
                                             payload_words=2,
                                             max_pairs=max_pairs)
    total = exp_rows.size
    d = int(delivered)
    assert d + int(overflow) == total
    assert d == min(total, max_pairs)
    got = np.asarray(out)
    assert got.shape[0] == max_pairs
    np.testing.assert_array_equal(got[:d, 0], exp_rows[:d])
    np.testing.assert_array_equal(got[:d, 1], exp_tgts[:d])
    members = (group_sids[exp_tgts[:d]] >= 0).sum(axis=1) if d else []
    np.testing.assert_array_equal(got[:d, 2], members)
    assert (got[d:] == 0).all()


def check_fanout_invariants(res, group_sids, exp_tgts, max_notify):
    """Conservation over member sIDs, exact in-order prefix, every delivered
    sID exists in the group table (none invented from -1 padding), tail
    stays -1 (no last-slot aliasing)."""
    import jax.numpy as jnp
    from repro.core.broker import fanout_sids
    exp_sids = group_sids[exp_tgts]
    exp_sids = exp_sids[exp_sids >= 0]
    out, delivered, overflow = fanout_sids(res, jnp.asarray(group_sids),
                                           max_notify=max_notify)
    d = int(delivered)
    assert d + int(overflow) == exp_sids.size
    assert d == min(exp_sids.size, max_notify)
    got = np.asarray(out)
    assert got.shape[0] == max_notify
    np.testing.assert_array_equal(got[:d], exp_sids[:d])
    assert (got[d:] == -1).all()
    assert set(got[:d].tolist()) <= set(group_sids[group_sids >= 0].tolist())
