"""Error-feedback int8 gradient compression for cross-pod reduction.

Pod-to-pod (DCI) links are the scarcest bandwidth at 1000+-node scale; this
module compresses the gradient all-reduce on a chosen mesh axis to int8 with
per-tensor scales and keeps the quantization residual as error feedback
(Seide et al. 2014 / 1-bit Adam lineage: the residual is added back before
the next quantization, so the *accumulated* gradient signal is unbiased).

``compressed_psum``: shard_map collective — quantize local shard, psum int32,
dequantize. 4x less DCI traffic than bf16 all-reduce (8x vs fp32).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress(x: jnp.ndarray, residual: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Error-feedback quantization: returns (q, scale, new_residual)."""
    target = x + residual
    q, scale = quantize_int8(target)
    new_residual = target - dequantize_int8(q, scale)
    return q, scale, new_residual


def compressed_psum_tree(tree: Any, residuals: Any, mesh: Mesh, axis: str
                         ) -> Tuple[Any, Any]:
    """Mean-reduce a pytree over ``axis`` with int8 EF compression.

    tree leaves must be replicated over the other mesh axes or sharded
    consistently; the collective itself moves int8. Returns (reduced tree,
    new residuals).
    """
    n = mesh.shape[axis]

    def reduce_leaf(x, r):
        def local(xs, rs):
            q, scale, new_r = ef_compress(xs.astype(jnp.float32), rs)
            qsum = jax.lax.psum(q.astype(jnp.int32), axis)
            ssum = jax.lax.psum(scale, axis)  # shared scale ~ mean of scales
            out = qsum.astype(jnp.float32) * (ssum / n) / n
            return out.astype(xs.dtype), new_r

        spec = P(*((None,) * x.ndim))
        fn = shard_map(local, mesh=mesh,
                       in_specs=(spec, spec), out_specs=(spec, spec))
        return fn(x, r)

    out = jax.tree.map(lambda x, r: reduce_leaf(x, r), tree, residuals)
    reduced = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return reduced, new_res


def init_residuals(tree: Any) -> Any:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)
