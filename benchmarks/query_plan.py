"""Fig. 14: plan augmentation (UserParameters early semi-join) under varying
fractions of tweets that match some subscriber (10/15/20%).

The subscription sets cover only a subset of states; incoming tweets are
drawn so the stated fraction matches at least one subscription.
"""
from __future__ import annotations

import numpy as np

from repro.core import records as R
from repro.core.channel import most_threatening_tweets
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags
from repro.data.synthetic import tweet_batch
from benchmarks.common import emit, exec_time, scale


def build(rng, match_frac: float, n_subs=None, n_new=None):
    n_subs = scale(20_000, 1024) if n_subs is None else n_subs
    n_new = scale(16_384, 1024) if n_new is None else n_new
    eng = BADEngine(dataset_capacity=1 << 16, index_capacity=1 << 15,
                    max_window=1 << 15, max_candidates=1 << 12)
    eng.create_channel(most_threatening_tweets())
    # subscribers concentrated on 5 states
    sub_states = rng.integers(0, 5, n_subs).astype(np.int32)
    eng.subscribe_bulk("MostThreateningTweets", sub_states,
                       np.zeros(n_subs, np.int32))
    b = tweet_batch(rng, n_new, t0=100)
    f = np.asarray(b.fields).copy()
    # all records pass the fixed predicate; match_frac land on subscribed states
    f[:, R.THREATENING_RATE] = 10
    hit = rng.random(n_new) < match_frac
    f[hit, R.STATE] = rng.integers(0, 5, int(hit.sum()))
    f[~hit, R.STATE] = rng.integers(5, 50, int((~hit).sum()))
    eng.ingest(R.RecordBatch.from_numpy(f, np.asarray(b.location)))
    return eng


def run(rng) -> None:
    for frac in (0.10, 0.15, 0.20):
        eng = build(rng, frac)
        t_orig, i_o = exec_time(eng, "MostThreateningTweets",
                                ExecutionFlags(scan_mode="window"))
        t_push, i_p = exec_time(eng, "MostThreateningTweets",
                                ExecutionFlags(scan_mode="window",
                                               param_pushdown=True))
        assert i_o["notified"] == i_p["notified"]
        emit(f"fig14/set{int(frac*100)}/original", t_orig,
             f"results={i_o['results']}")
        emit(f"fig14/set{int(frac*100)}/augmented", t_push,
             f"x{t_orig/max(t_push,1e-9):.2f}")


if __name__ == "__main__":
    run(np.random.default_rng(0))
