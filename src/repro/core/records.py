"""Record model: fixed-width struct-of-arrays records + the ActiveDataset.

The paper's EnrichedTweets are semi-structured documents in AsterixDB. On TPU
we encode them columnar / fixed-width: every predicate-addressable field is an
int32 column (categorical fields are dictionary-encoded on the host), spatial
locations are a float32 (N, 2) column, and free-text payloads live out-of-band
(token ids consumed by the enrichment model, never by predicates).

The ActiveDataset is the TPU analogue of an ACTIVE LSM dataset: a preallocated
ring buffer sharded over the `data` mesh axis. `size` counts records ever
ingested; `row_id = size_at_ingest + offset` is the stable primary key ("tid")
used by BAD indexes, and `timestamp` provides the LSM-style time filter.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Schema:
    """Names -> int-column index. All predicate fields are int32 columns."""

    fields: Tuple[str, ...]
    has_location: bool = True

    @property
    def num_fields(self) -> int:
        return len(self.fields)

    def index(self, name: str) -> int:
        return self.fields.index(name)


# The paper's running example (Fig. 2), dictionary-encoded.
ENRICHED_TWEET_SCHEMA = Schema(
    fields=(
        "state",            # 0..49 (dictionary: US states)
        "about_country",    # 0 == "US"
        "retweet_count",
        "threatening_rate",  # 0..10
        "hate_speech_rate",  # 0..10
        "weapon_mentioned",  # 0/1
        "drug_activity",     # categorical; 3 == "Manufacturing Drugs"
        "lang",              # 0 en, 1 pt, ... (for the real-world channels)
        "country",           # world country code (real-world channels)
        "timestamp",         # ingestion timestamp (seconds)
    ),
    has_location=True,
)

STATE, ABOUT_COUNTRY, RETWEET_COUNT, THREATENING_RATE, HATE_SPEECH_RATE, \
    WEAPON_MENTIONED, DRUG_ACTIVITY, LANG, COUNTRY, TIMESTAMP = range(10)


# ---------------------------------------------------------------------------
# RecordBatch
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RecordBatch:
    """A batch of fixed-width records (struct of arrays).

    fields:   (N, F) int32
    location: (N, 2) float32 (zeros when schema has no location)
    """

    fields: jnp.ndarray
    location: jnp.ndarray

    @property
    def num_records(self) -> int:
        return self.fields.shape[0]

    def tree_flatten(self):
        return (self.fields, self.location), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @staticmethod
    def from_numpy(fields: np.ndarray, location: Optional[np.ndarray] = None) -> "RecordBatch":
        fields = jnp.asarray(fields, dtype=jnp.int32)
        if location is None:
            location = jnp.zeros((fields.shape[0], 2), dtype=jnp.float32)
        else:
            location = jnp.asarray(location, dtype=jnp.float32)
        return RecordBatch(fields, location)


# ---------------------------------------------------------------------------
# ActiveDataset: ring buffer with stable row ids
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ActiveDataset:
    """Preallocated ring buffer of records.

    fields:   (C, F) int32
    location: (C, 2) float32
    size:     () int32 -- total records ever ingested (monotone)

    Row id r lives at slot ``r % C`` and is valid iff ``size - C <= r < size``.
    """

    fields: jnp.ndarray
    location: jnp.ndarray
    size: jnp.ndarray

    @property
    def capacity(self) -> int:
        return self.fields.shape[0]

    def tree_flatten(self):
        return (self.fields, self.location, self.size), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @staticmethod
    def create(capacity: int, schema: Schema = ENRICHED_TWEET_SCHEMA) -> "ActiveDataset":
        return ActiveDataset(
            fields=jnp.zeros((capacity, schema.num_fields), dtype=jnp.int32),
            location=jnp.zeros((capacity, 2), dtype=jnp.float32),
            size=jnp.zeros((), dtype=jnp.int32),
        )


@partial(jax.jit, donate_argnums=(0,))
def append(ds: ActiveDataset, batch: RecordBatch) -> Tuple[ActiveDataset, jnp.ndarray]:
    """Append a batch; returns (new dataset, row_ids of the appended records)."""
    n = batch.num_records
    cap = ds.capacity
    row_ids = ds.size + jnp.arange(n, dtype=jnp.int32)
    slots = row_ids % cap
    fields = ds.fields.at[slots].set(batch.fields)
    location = ds.location.at[slots].set(batch.location)
    return ActiveDataset(fields, location, ds.size + n), row_ids


def gather_rows(ds: ActiveDataset, row_ids: jnp.ndarray) -> RecordBatch:
    """Gather records by stable row id (caller guarantees ids are live)."""
    slots = row_ids % ds.capacity
    return RecordBatch(ds.fields[slots], ds.location[slots])


# ---------------------------------------------------------------------------
# Host-side dictionary encoding helpers (control plane)
# ---------------------------------------------------------------------------


class Dictionary:
    """String -> dense int code, grown on first sight (host side only)."""

    def __init__(self) -> None:
        self._codes: Dict[str, int] = {}

    def encode(self, value: str) -> int:
        if value not in self._codes:
            self._codes[value] = len(self._codes)
        return self._codes[value]

    def decode(self, code: int) -> str:
        for k, v in self._codes.items():
            if v == code:
                return k
        raise KeyError(code)

    def __len__(self) -> int:
        return len(self._codes)
