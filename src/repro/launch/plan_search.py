"""§Offline plan search: time every (scan x layout) plan per channel and
persist the winning assignment — the hillclimb idiom applied to channel
plans instead of lowering variants.

  PYTHONPATH=src python -m repro.launch.plan_search --subs 2000 \
      --tweets 4096 --match 0.05 --out experiments/plan_search

The JSON it writes round-trips through ``planner.load_plans`` /
``planner.apply_plans`` to seed an engine before the runtime planner takes
over (or instead of it, for a frozen deployment).
"""
import argparse
import json
import os

import numpy as np

from repro.core import planner as qp
from repro.core import records as R
from repro.core.channel import most_threatening_tweets, tweets_about_drugs
from repro.core.engine import BADEngine
from repro.data.synthetic import drug_tweak, tweet_batch


def build_engine(rng, n_subs: int, n_tweets: int, match: float,
                 use_pallas: bool) -> BADEngine:
    """Two param-join channels with opposed selectivities (the planner's
    bread and butter: one wants the BAD index, one a window scan)."""
    eng = BADEngine(brokers=("BrokerA", "BrokerB"), use_pallas=use_pallas)
    eng.create_channel(tweets_about_drugs())
    eng.create_channel(most_threatening_tweets())
    for name in eng.channels:
        eng.subscribe_bulk(
            name, rng.integers(0, 50, n_subs).astype(np.int32),
            rng.integers(0, 2, n_subs).astype(np.int32))
    batch = tweet_batch(rng, n_tweets, 1)
    fields = drug_tweak(np.asarray(batch.fields).copy(), rng, match)
    eng.ingest(R.RecordBatch.from_numpy(fields, np.asarray(batch.location)))
    return eng


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--subs", type=int, default=2000)
    ap.add_argument("--tweets", type=int, default=4096)
    ap.add_argument("--match", type=float, default=0.05)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--out", default="experiments/plan_search")
    args = ap.parse_args()
    rng = np.random.default_rng(args.seed)
    eng = build_engine(rng, args.subs, args.tweets, args.match, args.pallas)
    res = qp.search_plans(eng, repeats=args.repeats)
    os.makedirs(args.out, exist_ok=True)
    raw = os.path.join(args.out, "search.json")
    with open(raw, "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    best = {n: qp.ChannelPlan.from_dict(r["best"]) for n, r in res.items()}
    plan_file = os.path.join(args.out, "plans.json")
    qp.save_plans(plan_file, best,
                  meta=dict(subs=args.subs, tweets=args.tweets,
                            match=args.match, seed=args.seed))
    for name, r in res.items():
        worst = r["candidates"][-1]
        print(f"{name}: best={r['best']} "
              f"({r['candidates'][0]['wall_s'] * 1e3:.2f} ms) "
              f"worst={worst['plan']} ({worst['wall_s'] * 1e3:.2f} ms)")
    print(f"wrote {raw} and {plan_file}")


if __name__ == "__main__":
    main()
