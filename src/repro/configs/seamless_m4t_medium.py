"""seamless-m4t-medium [audio] — enc-dec 12L d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206. [arXiv:2308.11596; hf]

Backbone only: the speech frontend is a stub — ``input_specs()`` supplies
precomputed frame embeddings for the encoder; the decoder is a standard
causal transformer with cross-attention. Decoder target length = frames/4.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
        vocab_size=256206, head_dim=64, qkv_bias=False, rope_theta=1e4,
        block_pattern=("dense",), superlayer_repeat=12,   # decoder layers
        is_encdec=True, n_enc_layers=12, frontend="embed",
        max_target_len=1024,
        param_dtype=jnp.bfloat16, grad_accum=16, optimizer="adamw",
        sub_quadratic=False,
    ).validate()
