"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct; hf]

16 experts == the 16-way `model` axis: one expert per chip (EP).
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
        vocab_size=32064, head_dim=128, qkv_bias=False, rope_theta=1e4,
        n_experts=16, moe_top_k=2,
        block_pattern=("moe",), superlayer_repeat=32,
        param_dtype=jnp.bfloat16, grad_accum=16, optimizer="adafactor",
        sub_quadratic=False, weight_stationary_decode=True,
    ).validate()
