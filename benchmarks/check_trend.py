"""Bench trend check: compare a BENCH_*.json dump against committed floors.

CI's ``bench-smoke`` job runs the suite with ``--smoke --json
BENCH_smoke.json``; this tool then compares the *ratio* rows (speedup lines
whose ``derived`` column carries an ``xN.N`` multiplier) against
``benchmarks/thresholds.json`` and exits non-zero when any tracked row
regresses more than ``tolerance`` (default 30%) below its committed
baseline. The job is non-blocking (``continue-on-error``), so a failure
flags the PR without gating it — absolute CI timings are noisy, but the
RATIOS (fused vs sequential, incremental vs rebuild, aggregated vs
original) are stable enough to trend.

Usage:
    python -m benchmarks.check_trend BENCH_smoke.json \
        [--thresholds benchmarks/thresholds.json] [--tolerance 0.30]

thresholds.json format — ``baseline`` is the ratio measured when the row
was committed; a row is healthy while ``measured >= baseline * (1 -
tolerance)``. Missing rows fail (a deleted/renamed suite must update the
thresholds file consciously).
"""
from __future__ import annotations

import argparse
import json
import re
import sys

RATIO_RE = re.compile(r"x(\d+(?:\.\d+)?)")
# pipelined rows carry the MEASURED in-flight depth (``depth=N``) next to
# the ratio: a depth that collapsed to 1 explains a ratio regression as a
# pipelining failure rather than a kernel slowdown
DEPTH_RE = re.compile(r"depth=(\d+)")


def parse_ratio(derived: str):
    m = RATIO_RE.search(derived)
    return float(m.group(1)) if m else None


def parse_depth(derived: str):
    m = DEPTH_RE.search(derived)
    return int(m.group(1)) if m else None


def check(results, thresholds, tolerance: float):
    by_name, depth_of = {}, {}
    for row in results:
        if "name" not in row:
            continue                     # malformed emit row: not trackable
        derived = str(row.get("derived", ""))
        r = parse_ratio(derived)
        if r is not None:
            by_name[row["name"]] = r
            d = parse_depth(derived)
            if d is not None:
                depth_of[row["name"]] = d
    failures, report = [], []
    for i, entry in enumerate(thresholds):
        name, baseline = entry.get("name"), entry.get("baseline")
        if name is None or baseline is None:
            failures.append(
                f"MALFORMED  thresholds entry #{i} needs 'name' and "
                f"'baseline': {json.dumps(entry)}")
            continue
        baseline = float(baseline)
        floor = baseline * (1.0 - tolerance)
        got = by_name.get(name)
        if got is None:
            # a deleted/renamed suite must update thresholds.json
            # consciously — say what the dump DID contain so the rename is
            # obvious from the CI log alone
            have = sorted(by_name)
            near = [n for n in have if n.split("/")[0] == name.split("/")[0]]
            failures.append(
                f"MISSING  {name} (baseline x{baseline:g}) — not among the "
                f"{len(have)} ratio rows the bench dump contained; "
                + (f"rows under '{name.split('/')[0]}/': {near}" if near
                   else f"ratio rows present: {have}"))
            continue
        status = "ok" if got >= floor else "REGRESSED"
        depth = depth_of.get(name)
        report.append(f"{status:>9}  {name}: x{got:g} "
                      f"(baseline x{baseline:g}, floor x{floor:.2f})"
                      + (f" [measured pipeline depth {depth}]"
                         if depth is not None else ""))
        if got < floor:
            failures.append(report[-1])
    return failures, report


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_json", help="BENCH_*.json produced by "
                    "`python -m benchmarks.run --json`")
    ap.add_argument("--thresholds", default="benchmarks/thresholds.json")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional regression below baseline")
    args = ap.parse_args()
    with open(args.bench_json) as f:
        bench = json.load(f)
    with open(args.thresholds) as f:
        thresholds = json.load(f)
    failures, report = check(bench.get("results", []), thresholds,
                             args.tolerance)
    for line in report:
        print(line)
    if failures:
        print(f"\n{len(failures)} tracked ratio(s) regressed >"
              f"{args.tolerance:.0%} or went missing:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"\nall {len(thresholds)} tracked ratios within "
          f"{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
