"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import bad_index as bidx
from repro.core.predicates import Predicate, compile_conditions, evaluate_conditions
from repro.core.subscriptions import Aggregator, SubscriptionTable, aggregate
from repro.kernels.flash_decode import ref as fd_ref
from repro.kernels.predicate_filter import ops as pf_ops

from conftest import (check_deliver_all_invariants, check_fanout_invariants,
                      check_pack_invariants, random_broker_result,
                      random_stacked_broker_result)

SETTINGS = dict(max_examples=25, deadline=None)


pred_st = st.builds(
    Predicate.parse,
    st.integers(0, 9),
    st.sampled_from(["==", "<", "<=", ">", ">="]),
    st.integers(-20, 20),
)


@given(st.lists(st.lists(pred_st, min_size=1, max_size=4), min_size=1,
                max_size=5),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_kernel_equals_general_evaluator(channels, seed):
    """Interval-canonicalized Pallas kernel == padded general evaluator, for
    any conjunction without conflicting != (none generated here)."""
    rng = np.random.default_rng(seed)
    fields = jnp.asarray(rng.integers(-25, 25, (37, 10)).astype(np.int32))
    conds = compile_conditions(channels)
    want = np.asarray(evaluate_conditions(fields, conds))
    got = np.asarray(pf_ops.predicate_filter(fields, conds))
    assert np.array_equal(want, got)


@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 2)), min_size=1,
                max_size=200),
       st.integers(1, 9))
@settings(**SETTINGS)
def test_aggregation_partition_invariants(subs, cap):
    """Algorithm 1 output is a partition: every sID in exactly one group,
    groups never exceed cap, and group members share (param, broker)."""
    agg = Aggregator(cap)
    for i, (p, b) in enumerate(subs):
        agg.add_subscription(p, b, sid=i)
    g = agg.build()
    seen = []
    for gi in range(g.num_groups):
        n = int(g.group_counts[gi])
        assert 1 <= n <= cap
        members = g.group_sids[gi][:n]
        assert (g.group_sids[gi][n:] == -1).all()
        seen.extend(members.tolist())
        for sid in members.tolist():
            assert subs[sid] == (int(g.group_params[gi]), int(g.group_brokers[gi]))
    assert sorted(seen) == list(range(len(subs)))


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 1)), min_size=1,
                max_size=120),
       st.integers(1, 8))
@settings(**SETTINGS)
def test_bulk_aggregate_equivalent_to_incremental(subs, cap):
    params = np.asarray([p for p, _ in subs], np.int32)
    brokers = np.asarray([b for _, b in subs], np.int32)
    bulk = aggregate(SubscriptionTable.build(params, brokers), cap)
    inc = Aggregator(cap)
    for i, (p, b) in enumerate(subs):
        inc.add_subscription(p, b, sid=i)
    g = inc.build()
    def sig(x):
        return sorted((int(x.group_params[i]), int(x.group_brokers[i]),
                       tuple(sorted(x.group_sids[i][x.group_sids[i] >= 0].tolist())))
                      for i in range(x.num_groups))
    # same partition up to group-boundary choices with equal sizes multiset
    def sizes(x):
        return sorted((int(x.group_params[i]), int(x.group_brokers[i]),
                       int(x.group_counts[i])) for i in range(x.num_groups))
    assert sizes(bulk) == sizes(g)
    assert bulk.num_subscriptions == g.num_subscriptions


@given(st.lists(st.booleans(), min_size=1, max_size=64))
@settings(**SETTINGS)
def test_bad_index_membership_invariant(mask):
    """BAD index contents == exactly the rows whose predicate mask was true
    (in arrival order), as long as capacity is not exceeded."""
    n = len(mask)
    st_ = bidx.BADIndexState.create(1, 64)
    ids = jnp.arange(n, dtype=jnp.int32)
    st_ = bidx.insert(st_, ids, jnp.asarray(mask)[:, None])
    rows, valid = bidx.new_entries(st_, 0, 64)
    got = rows[np.asarray(valid)].tolist()
    want = [i for i, m in enumerate(mask) if m]
    assert got == want


broker_shapes = (st.integers(0, 2 ** 31 - 1), st.integers(1, 40),
                 st.integers(1, 5), st.integers(1, 8), st.integers(1, 4))


@given(*broker_shapes, st.integers(1, 16))
@settings(**SETTINGS)
def test_pack_payloads_invariants(seed, n_rows, max_t, n_groups, cap,
                                  max_pairs):
    """Conservation (delivered + overflow == valid pairs), exact in-order
    prefix, and no overflow pair scattered over the last slot (the pre-PR-1
    clamping bug aliased overflowing pairs onto slot max_pairs - 1)."""
    res, group_sids, exp_rows, exp_tgts = random_broker_result(
        np.random.default_rng(seed), n_rows, max_t, n_groups, cap)
    check_pack_invariants(res, group_sids, exp_rows, exp_tgts, max_pairs)


@given(*broker_shapes, st.integers(1, 24))
@settings(**SETTINGS)
def test_fanout_sids_invariants(seed, n_rows, max_t, n_groups, cap,
                                max_notify):
    """Conservation over member sIDs, exact in-order prefix, every delivered
    sID exists in the group table (none invented from padding), tail stays
    -1 (no last-slot aliasing)."""
    res, group_sids, _, exp_tgts = random_broker_result(
        np.random.default_rng(seed), n_rows, max_t, n_groups, cap)
    check_fanout_invariants(res, group_sids, exp_tgts, max_notify)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4), st.integers(1, 16),
       st.integers(1, 3), st.integers(1, 5), st.integers(1, 3),
       st.integers(1, 10), st.integers(1, 14), st.integers(1, 24))
@settings(max_examples=20, deadline=None)
def test_deliver_all_invariants(seed, n_channels, n_rows, max_t, n_groups,
                                cap, max_pairs, max_notify, spill_cap):
    """Fused (vmapped) delivery == the single-channel kernels per channel:
    identical buffers/counts, conservation per stage, channel-major flat
    spill streams carrying exactly each channel's overflow tail (truncated
    only by the shared spill buffer), one-hot per-broker sums."""
    stacked, group_sids, exp_rows, exp_tgts = random_stacked_broker_result(
        np.random.default_rng(seed), n_channels, n_rows, max_t, n_groups, cap)
    check_deliver_all_invariants(stacked, group_sids, exp_rows, exp_tgts,
                                 max_pairs, max_notify, spill_cap)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4), st.integers(1, 12),
       st.integers(1, 3), st.integers(1, 40))
@settings(**SETTINGS)
def test_flatten_pairs_stream_invariants(seed, n_channels, n_rows, max_t,
                                         max_total):
    """The compacted flat (row, channel, target) stream is exactly the
    channel-major masked pairs: in-order prefix, conservation of ``total``,
    -1 tail (no last-slot aliasing)."""
    from repro.core.plans import flatten_pairs_all
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, 999, (n_channels, n_rows, max_t)).astype(np.int32)
    tgts = rng.integers(0, 99, (n_channels, n_rows, max_t)).astype(np.int32)
    mask = rng.random((n_channels, n_rows, max_t)) < 0.5
    s = flatten_pairs_all(jnp.asarray(rows), jnp.asarray(tgts),
                          jnp.asarray(mask), max_total)
    flat = mask.reshape(n_channels, -1)
    want_rows = rows.reshape(n_channels, -1)[flat]
    want_ch = np.broadcast_to(
        np.arange(n_channels)[:, None], flat.shape)[flat]
    total = int(mask.sum())
    assert int(s.total) == total
    k = min(total, max_total)
    assert int(np.asarray(s.valid).sum()) == k
    np.testing.assert_array_equal(np.asarray(s.rows)[:k], want_rows[:k])
    np.testing.assert_array_equal(np.asarray(s.channels)[:k], want_ch[:k])
    np.testing.assert_array_equal(
        np.asarray(s.targets)[:k], tgts.reshape(n_channels, -1)[flat][:k])
    assert (np.asarray(s.rows)[k:] == -1).all()
    assert (np.asarray(s.channels)[k:] == -1).all()


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4), st.integers(1, 12),
       st.integers(1, 3), st.integers(0, 40))
@settings(**SETTINGS)
def test_stream_total_is_pretruncation(seed, n_channels, n_rows, max_t,
                                       max_total):
    """``total`` is the PRE-truncation live count for both stream types —
    ``sum(valid) == min(total, max_total)``, never clamped to the buffer —
    including the ``max_total=0`` edge (a counting-only stream with empty
    buffers), and the valid prefix is channel-major (non-decreasing channel
    ids). The compacted execution join's grow-on-overflow protocol reads
    exactly this contract: ``total > capacity`` means re-run bigger."""
    from repro.core.plans import flatten_pairs_all, flatten_values_all
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, 999, (n_channels, n_rows, max_t)).astype(np.int32)
    tgts = rng.integers(0, 99, (n_channels, n_rows, max_t)).astype(np.int32)
    mask = rng.random((n_channels, n_rows, max_t)) < 0.5
    total = int(mask.sum())
    k = min(total, max_total)
    ps = flatten_pairs_all(jnp.asarray(rows), jnp.asarray(tgts),
                           jnp.asarray(mask), max_total)
    vs = flatten_values_all(jnp.asarray(rows).reshape(n_channels, -1),
                            jnp.asarray(mask).reshape(n_channels, -1),
                            max_total)
    for s in (ps, vs):
        assert int(s.total) == total
        v = np.asarray(s.valid)
        assert v.shape == (max_total,)
        assert int(v.sum()) == k
        ch = np.asarray(s.channels)[v]
        assert (np.diff(ch) >= 0).all()          # channel-major order
    np.testing.assert_array_equal(np.asarray(vs.values)[np.asarray(vs.valid)],
                                  np.asarray(ps.rows)[np.asarray(ps.valid)])


@given(st.integers(1, 6), st.integers(2, 5), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_flash_merge_associativity(n_parts, kh, seed):
    """Split-KV softmax merge gives the same answer for any shard count."""
    rng = np.random.default_rng(seed)
    b, g, d, per = 2, 2, 16, 32
    h = kh * g
    s = per * n_parts
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, kh, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, kh, s, d)), jnp.float32)
    kv_len = jnp.asarray(rng.integers(1, s + 1, (b,)), jnp.int32)
    want = fd_ref.decode_attention(q, k, v, kv_len)
    parts = []
    for i in range(n_parts):
        sl = slice(i * per, (i + 1) * per)
        parts.append(fd_ref.decode_attention_partial(
            q, k[:, :, sl], v[:, :, sl],
            jnp.clip(kv_len - i * per, 0, per)))
    acc, m, l = parts[0]
    for p in parts[1:]:
        acc, m, l = fd_ref.merge_partials(acc, m, l, *p)
    got = fd_ref.normalize(acc, l, q.dtype)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_gla_chunked_equals_stepwise(seed, n_chunks):
    """chunked_gla == sequential gla_step recurrence (any chunking)."""
    from repro.models.ssm import chunked_gla, gla_step
    rng = np.random.default_rng(seed)
    b, h, dk, dv, chunk = 1, 2, 8, 8, 8
    t = chunk * n_chunks
    q = jnp.asarray(rng.normal(size=(b, h, t, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, t, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, t, dv)), jnp.float32)
    log_a = jnp.asarray(-np.abs(rng.normal(size=(b, h, t))) * 0.1, jnp.float32)
    o_chunk, s_fin = chunked_gla(q, k, v, log_a, chunk)
    state = jnp.zeros((b, h, dk, dv), jnp.float32)
    outs = []
    for i in range(t):
        o, state = gla_step(q[:, :, i], k[:, :, i], v[:, :, i],
                            log_a[:, :, i], state)
        outs.append(o)
    o_seq = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(o_chunk), np.asarray(o_seq),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(state), atol=2e-4)


# --- sharded-engine partition hash (distributed/partition.py) --------------
# The mesh-sharded engine routes every subscription/user/broker through
# these maps; re-partitioning correctness (reshard, drop/re-create) rests on
# them being pure elementwise functions of the GLOBAL id.


@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=1, max_size=300),
       st.integers(1, 16))
@settings(**SETTINGS)
def test_shard_partition_exact_cover(ids, num_shards):
    """Every id lands on exactly one shard, in range [0, num_shards)."""
    from repro.distributed import partition as dpart
    ids = np.asarray(ids, np.int64)
    for fn in (dpart.shard_for_sids, dpart.shard_for_users,
               dpart.broker_owner):
        owner = fn(ids, num_shards)
        assert owner.shape == ids.shape
        assert ((owner >= 0) & (owner < num_shards)).all()
        hits = np.sum([(owner == s) for s in range(num_shards)], axis=0)
        assert hits.tolist() == [1] * len(ids)
        if num_shards == 1:
            assert (owner == 0).all()


@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=1, max_size=120),
       st.lists(st.integers(0, 2 ** 31 - 1), max_size=120),
       st.integers(1, 16),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_shard_assignment_stable_under_churn_deltas(ids, others, num_shards,
                                                    seed):
    """An id's shard is a pure function of the id: independent of what else
    is in the batch, the batch order, and the call (so churn — arbitrary
    adds/removes around a surviving subscription — can never migrate it)."""
    from repro.distributed import partition as dpart
    ids = np.asarray(ids, np.int64)
    others = np.asarray(others, np.int64)
    alone = dpart.shard_for_sids(ids, num_shards)
    np.testing.assert_array_equal(alone, dpart.shard_for_sids(ids, num_shards))
    mixed = dpart.shard_for_sids(np.concatenate([ids, others]), num_shards)
    np.testing.assert_array_equal(mixed[:len(ids)], alone)
    perm = np.random.default_rng(seed).permutation(len(ids))
    np.testing.assert_array_equal(
        dpart.shard_for_sids(ids[perm], num_shards), alone[perm])
    for i in range(min(len(ids), 5)):    # singleton == batched
        assert dpart.shard_for_sids(ids[i:i + 1], num_shards)[0] == alone[i]


@given(st.integers(1, 16))
@settings(**SETTINGS)
def test_shard_partition_rejects_negative_ids(num_shards):
    """Negative ids are allocator bugs, not hashable population."""
    from repro.distributed import partition as dpart
    with pytest.raises(ValueError):
        dpart.shard_for_sids(np.asarray([3, -1, 5]), num_shards)
