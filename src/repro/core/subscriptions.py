"""Subscriptions + Algorithm 1 subscription aggregation (paper §4.1).

Control plane (this module) is host-side numpy — subscriptions arrive one at a
time between channel executions, exactly as in the paper ("all grouping is
completed before the execution of the next channel begins"). The data plane
consumes the dense, padded arrays produced here.

TPU adaptation of the frame-size rule: AsterixDB frames hold whole records, so
the paper caps a subscription-group record at the frame size ``f``. Our frames
are tensor tiles; the analogous rule is a per-group sID capacity ``cap``
rounded to the 128-lane register width so one group occupies whole vector
registers. ``cap_from_frame_bytes`` reproduces the paper's rule (group record
size ~ frame size), ``lane_align`` applies the TPU rounding.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

SID_BYTES = 4          # sIDs are int32
LANE = 128             # TPU vector lane count


def cap_from_frame_bytes(frame_bytes: int, align: bool = True) -> int:
    """Paper rule: optimal subgroup record size == frame size (Figs. 12-13)."""
    cap = max(1, frame_bytes // SID_BYTES)
    return lane_align(cap) if align else cap


def lane_align(cap: int) -> int:
    if cap <= LANE:
        return cap
    return (cap // LANE) * LANE


@dataclasses.dataclass
class SubscriptionTable:
    """Flat (un-aggregated) subscriptions — the *original* BAD layout."""

    sids: np.ndarray      # (S,) int32
    params: np.ndarray    # (S,) int32 -- encoded channel parameter
    brokers: np.ndarray   # (S,) int32 -- broker id

    @property
    def num_subscriptions(self) -> int:
        return int(self.sids.shape[0])

    @staticmethod
    def empty() -> "SubscriptionTable":
        z = np.zeros((0,), dtype=np.int32)
        return SubscriptionTable(z.copy(), z.copy(), z.copy())

    @staticmethod
    def build(params: np.ndarray, brokers: np.ndarray) -> "SubscriptionTable":
        params = np.asarray(params, dtype=np.int32)
        brokers = np.asarray(brokers, dtype=np.int32)
        sids = np.arange(params.shape[0], dtype=np.int32)
        return SubscriptionTable(sids, params, brokers)


@dataclasses.dataclass
class SubscriptionGroups:
    """Aggregated subscription-group records (paper Fig. 7b).

    group_params: (G,) int32     -- the shared parameter
    group_brokers: (G,) int32
    group_sids:   (G, cap) int32 -- member sIDs, padded with -1
    group_counts: (G,) int32
    """

    group_params: np.ndarray
    group_brokers: np.ndarray
    group_sids: np.ndarray
    group_counts: np.ndarray
    cap: int

    @property
    def num_groups(self) -> int:
        return int(self.group_params.shape[0])

    @property
    def num_subscriptions(self) -> int:
        return int(self.group_counts.sum())


class Aggregator:
    """Incremental Algorithm 1: place each arriving subscription in an open
    group with matching (params, broker), else open a new group."""

    def __init__(self, cap: int):
        if cap < 1:
            raise ValueError("group capacity must be >= 1")
        self.cap = cap
        # (param, broker) -> list of group indices; groups as python lists.
        self._by_key: Dict[Tuple[int, int], List[int]] = {}
        self._params: List[int] = []
        self._brokers: List[int] = []
        self._members: List[List[int]] = []
        self._next_sid = 0

    def add_subscription(self, param: int, broker: int,
                         sid: Optional[int] = None) -> int:
        """Paper Algorithm 1. Returns the sID assigned."""
        if sid is None:
            sid = self._next_sid
        self._next_sid = max(self._next_sid, sid + 1)
        key = (int(param), int(broker))
        for gi in self._by_key.get(key, ()):           # AddToExistingGroup
            if len(self._members[gi]) < self.cap:
                self._members[gi].append(sid)
                return sid
        gi = len(self._params)                          # open a new group
        self._params.append(int(param))
        self._brokers.append(int(broker))
        self._members.append([sid])
        self._by_key.setdefault(key, []).append(gi)
        return sid

    def remove_subscription(self, param: int, broker: int, sid: int) -> bool:
        key = (int(param), int(broker))
        for gi in self._by_key.get(key, ()):
            if sid in self._members[gi]:
                self._members[gi].remove(sid)
                return True
        return False

    def build(self) -> SubscriptionGroups:
        live = [i for i, m in enumerate(self._members) if m]
        g = len(live)
        group_params = np.zeros((g,), dtype=np.int32)
        group_brokers = np.zeros((g,), dtype=np.int32)
        group_sids = np.full((g, self.cap), -1, dtype=np.int32)
        group_counts = np.zeros((g,), dtype=np.int32)
        for out, gi in enumerate(live):
            m = self._members[gi]
            group_params[out] = self._params[gi]
            group_brokers[out] = self._brokers[gi]
            group_sids[out, : len(m)] = m
            group_counts[out] = len(m)
        return SubscriptionGroups(group_params, group_brokers, group_sids,
                                  group_counts, self.cap)


def aggregate(table: SubscriptionTable, cap: int) -> SubscriptionGroups:
    """Bulk aggregation (vectorized equivalent of replaying Algorithm 1)."""
    if table.num_subscriptions == 0:
        return SubscriptionGroups(*(np.zeros((0,), np.int32),) * 2,
                                  np.zeros((0, cap), np.int32),
                                  np.zeros((0,), np.int32), cap)
    # Sort by (param, broker) then chop runs into cap-sized subgroups.
    order = np.lexsort((table.brokers, table.params))
    p = table.params[order]
    b = table.brokers[order]
    s = table.sids[order]
    new_run = np.empty(p.shape[0], dtype=bool)
    new_run[0] = True
    new_run[1:] = (p[1:] != p[:-1]) | (b[1:] != b[:-1])
    run_id = np.cumsum(new_run) - 1
    pos_in_run = np.arange(p.shape[0]) - np.maximum.accumulate(
        np.where(new_run, np.arange(p.shape[0]), 0))
    sub_id = pos_in_run // cap
    # group key = (run_id, sub_id)
    new_group = new_run | ((sub_id != np.roll(sub_id, 1)) & (run_id == np.roll(run_id, 1)))
    new_group[0] = True
    gid = np.cumsum(new_group) - 1
    g = int(gid[-1]) + 1
    group_params = np.zeros((g,), dtype=np.int32)
    group_brokers = np.zeros((g,), dtype=np.int32)
    group_sids = np.full((g, cap), -1, dtype=np.int32)
    group_counts = np.zeros((g,), dtype=np.int32)
    group_params[gid[new_group]] = p[new_group]
    group_brokers[gid[new_group]] = b[new_group]
    slot = pos_in_run % cap
    group_sids[gid, slot] = s
    np.add.at(group_counts, gid, 1)
    return SubscriptionGroups(group_params, group_brokers, group_sids,
                              group_counts, cap)


def param_to_targets(params: np.ndarray, domain: int,
                     pad: int = -1) -> Tuple[np.ndarray, np.ndarray]:
    """Dense join map: param value -> row indices of targets holding it.

    Returns (map (domain, maxd) int32 padded, counts (domain,) int32). This is
    the TPU realization of the index nested-loop join in the augmented plan —
    the join against a small categorical domain becomes a gather.
    """
    counts = np.bincount(params, minlength=domain).astype(np.int32)
    maxd = max(1, int(counts.max()) if counts.size else 1)
    out = np.full((domain, maxd), pad, dtype=np.int32)
    cursor = np.zeros((domain,), dtype=np.int64)
    for i, v in enumerate(params):
        out[v, cursor[v]] = i
        cursor[v] += 1
    return out, counts
