"""Shared layers: RMSNorm, RoPE, SwiGLU MLP, init helpers, sharding hooks.

Sharding is expressed through ``shard(x, spec_name)`` which consults the
active logical-axis rules (distributed/partition.py). Outside a mesh context
it is a no-op, so the same model code runs in smoke tests and in the
production-mesh dry-run.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partition import shard


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def init_dense(key, shape, dtype, scale: Optional[float] = None) -> jnp.ndarray:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rope_frequencies(head_dim: int, max_pos: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    pos = jnp.arange(max_pos, dtype=jnp.float32)
    ang = jnp.outer(pos, inv)                      # (S, D/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
               positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x (..., S, D); cos/sin (Smax, D/2); positions (..., S) optional."""
    if positions is not None:
        cos = cos[positions]
        sin = sin[positions]
    else:
        cos = cos[: x.shape[-2]]
        sin = sin[: x.shape[-2]]
    while cos.ndim < x.ndim:
        cos = cos[None]
        sin = sin[None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, (d_model, d_ff), dtype),
        "up": init_dense(k2, (d_model, d_ff), dtype),
        "down": init_dense(k3, (d_ff, d_model), dtype),
    }


def mlp_apply(p: dict, x: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    x = x.astype(compute_dtype)
    h = jax.nn.silu(x @ p["gate"].astype(compute_dtype)) * (x @ p["up"].astype(compute_dtype))
    h = shard(h, "act_ff")
    return h @ p["down"].astype(compute_dtype)
