"""Broker packing/fan-out + end-to-end train loop with failure recovery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.broker import fanout_sids, pack_payloads
from repro.core.channel import tweets_about_drugs
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags

from conftest import make_tweets


def _engine_with_results(_rng, aggregated):
    rng = np.random.default_rng(42)   # identical data for both layouts
    eng = BADEngine(dataset_capacity=2048, index_capacity=1024,
                    max_window=1024, max_candidates=256, group_cap=64)
    eng.create_channel(tweets_about_drugs())
    eng.subscribe_bulk("TweetsAboutDrugs", rng.integers(0, 10, 500),
                       np.zeros(500, np.int32))
    eng.ingest(make_tweets(rng, 512, match_drugs=0.05))
    flags = ExecutionFlags(scan_mode="bad_index", aggregation=aggregated)
    rep = eng.execute_channel("TweetsAboutDrugs", flags, advance=False)
    sids = eng.group_sids_array("TweetsAboutDrugs", aggregated)
    return eng, rep, sids


def test_broker_fanout_identical_subscriber_set(rng):
    """Aggregated and original layouts notify the same end subscribers
    (paper Table 2: 'Sending Out' identical)."""
    _, rep_o, sids_o = _engine_with_results(rng, aggregated=False)
    _, rep_a, sids_a = _engine_with_results(rng, aggregated=True)
    out_o, n_o, _ = fanout_sids(rep_o.result, sids_o, max_notify=1 << 14)
    out_a, n_a, _ = fanout_sids(rep_a.result, sids_a, max_notify=1 << 14)
    assert int(n_o) == int(n_a)
    a = np.sort(np.asarray(out_o[:int(n_o)]))
    b = np.sort(np.asarray(out_a[:int(n_a)]))
    np.testing.assert_array_equal(a, b)


def test_broker_pack_fewer_rows_when_aggregated(rng):
    _, rep_o, sids_o = _engine_with_results(rng, aggregated=False)
    _, rep_a, sids_a = _engine_with_results(rng, aggregated=True)
    _, n_o, _ = pack_payloads(rep_o.result, sids_o, payload_words=8,
                              max_pairs=1 << 14)
    _, n_a, _ = pack_payloads(rep_a.result, sids_a, payload_words=8,
                              max_pairs=1 << 14)
    assert int(n_a) < int(n_o)


def test_train_loop_checkpoint_restart(tmp_path, rng):
    """Kill the training at a step, restart from checkpoint, reach the end;
    the resumed run produces finite losses and monotone step count."""
    from repro.configs import get_reduced
    from repro.launch.train import train
    from repro.runtime.failure import FailureInjector

    cfg = get_reduced("tinyllama-1.1b")
    inj = FailureInjector(fail_at=(7,))
    with pytest.raises(RuntimeError):
        train(cfg, steps=12, batch=4, seq=32, ckpt_dir=str(tmp_path),
              ckpt_every=5, injector=inj, log_every=100)
    # restart resumes from step 5 checkpoint
    _, _, losses = train(cfg, steps=12, batch=4, seq=32,
                         ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100)
    assert len(losses) == 7            # steps 5..11
    assert all(np.isfinite(l) for l in losses)


def test_train_loop_loss_decreases(tmp_path):
    from repro.configs import get_reduced
    from repro.launch.train import train

    cfg = get_reduced("xlstm-125m")
    _, _, losses = train(cfg, steps=15, batch=8, seq=32,
                         ckpt_dir=str(tmp_path), ckpt_every=100,
                         log_every=100, resume=False)
    assert np.mean(losses[-3:]) < np.mean(losses[:3])
