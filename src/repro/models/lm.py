"""Decoder-only causal LM over scanned superlayers: train / prefill / decode."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.partition import shard
from repro.models import blocks
from repro.models.config import ModelConfig
from repro.models.layers import init_dense, rms_norm, rope_frequencies

AUX_WEIGHT = 0.01


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, 4 + cfg.superlayer_repeat)
    layer_keys = keys[4:]
    layers = jax.vmap(lambda k: blocks.superlayer_init(k, cfg))(layer_keys)
    params = {
        "embed": init_dense(keys[0], (cfg.padded_vocab, cfg.d_model),
                            cfg.param_dtype, scale=1.0),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if "shared_attn" in cfg.block_pattern:
        params["shared"] = blocks.block_init(keys[1], "shared_attn", cfg)
    if not cfg.tie_embeddings:
        params["head"] = init_dense(keys[2], (cfg.d_model, cfg.padded_vocab),
                                    cfg.param_dtype)
    return params


def _rope(cfg: ModelConfig, max_pos: int):
    return rope_frequencies(cfg.resolved_head_dim, max_pos, cfg.rope_theta)


def _embed_in(params, cfg: ModelConfig, tokens=None, embeds=None):
    if embeds is not None:
        x = embeds.astype(cfg.compute_dtype)
    else:
        x = params["embed"][tokens].astype(cfg.compute_dtype)
    return shard(x, "act_btd")


def _head_out(params, cfg: ModelConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        head = params["embed"].T
        x = x * cfg.d_model ** -0.5       # tied head: rescale (Gemma-style)
    else:
        head = params["head"]
    logits = x @ head.astype(cfg.compute_dtype)
    return shard(logits, "act_btv")


def forward(params, cfg: ModelConfig, tokens=None, embeds=None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence training forward -> (logits (B, S, V), aux ())"""
    x = _embed_in(params, cfg, tokens, embeds)
    s = x.shape[1]
    cos, sin = _rope(cfg, s)
    shared = params.get("shared")

    def body(carry, layer_p):
        h, aux = carry
        h, a = blocks.superlayer_train(layer_p, shared, h, cfg, cos, sin)
        return (h, aux + a), ()

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    logits = _head_out(params, cfg, x)
    return logits, aux / max(1, cfg.superlayer_repeat)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = forward(params, cfg,
                          tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"))
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:     # mask vocab padding
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + AUX_WEIGHT * aux
    return total, {"loss": loss, "aux": aux, "ntokens": jnp.sum(mask)}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None,
            max_len: Optional[int] = None):
    """Process the full prompt; returns (last-token logits, caches, pos)."""
    x = _embed_in(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    max_len = max_len or s
    cos, sin = _rope(cfg, s)
    shared = params.get("shared")

    def body(h, layer_p):
        h, states = blocks.superlayer_prefill(layer_p, shared, h, cfg, cos, sin,
                                              max_len)
        return h, states

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, caches = jax.lax.scan(body_fn, x, params["layers"])
    logits = _head_out(params, cfg, x[:, -1:, :])[:, 0, :cfg.vocab_size]
    return logits, caches, jnp.asarray(s, jnp.int32)


def decode_step(params, cfg: ModelConfig, caches, pos: jnp.ndarray,
                token=None, embed=None):
    """One decode step at position ``pos`` (same for all rows).

    token (B,) int32 or embed (B, D). Returns (logits (B, V), new caches).
    """
    if embed is not None:
        x = embed.astype(cfg.compute_dtype)
    else:
        x = params["embed"][token].astype(cfg.compute_dtype)
    x = shard(x, "act_bd")
    b = x.shape[0]
    max_pos = _cache_max_len(cfg, caches)
    cos, sin = _rope(cfg, max_pos)
    kv_len = jnp.full((b,), pos + 1, jnp.int32)
    shared = params.get("shared")

    if cfg.decode_loop == "carry":
        # Carry the cache tree through a fori_loop: the while-loop aliases
        # carry buffers in place, eliminating the scan-ys double buffer
        # (2x cache memory for big-cache archs). §Perf hillclimb.
        def body(i, carry):
            h, cc = carry
            layer_p = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                params["layers"])
            states = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                cc)
            h, new_states = blocks.superlayer_decode(
                layer_p, shared, h, states, cfg, cos, sin, pos, kv_len)
            cc = jax.tree.map(
                lambda c, s: jax.lax.dynamic_update_index_in_dim(
                    c, s.astype(c.dtype), i, 0), cc, new_states)
            return h, cc

        x, new_caches = jax.lax.fori_loop(0, cfg.superlayer_repeat, body,
                                          (x, caches))
    else:
        def body(h, xs):
            layer_p, states = xs
            h, new_states = blocks.superlayer_decode(layer_p, shared, h, states,
                                                     cfg, cos, sin, pos, kv_len)
            return h, new_states

        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    logits = _head_out(params, cfg, x[:, None, :])[:, 0, :cfg.vocab_size]
    return logits, new_caches


def _cache_max_len(cfg: ModelConfig, caches) -> int:
    """Static max cache length from any attention cache; fallback 1."""
    for i, kind in enumerate(cfg.block_pattern):
        if kind in ("dense", "shared_attn", "moe"):
            return caches[f"b{i}"]["k"].shape[3]   # (R, B, KH, S, D)
    return 2


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Zeroed serving state stacked over superlayers (R, ...)."""
    shapes = blocks.superlayer_state_shapes(cfg, batch, max_len)

    def alloc(sds: jax.ShapeDtypeStruct):
        return jnp.zeros((cfg.superlayer_repeat,) + sds.shape, sds.dtype)

    return jax.tree.map(alloc, shapes)


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int):
    shapes = blocks.superlayer_state_shapes(cfg, batch, max_len)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.superlayer_repeat,) + s.shape, s.dtype),
        shapes)
