"""Multi-channel scaling: vectorized control plane + fused execution.

Two measurements the single-channel figures cannot show:

  control plane -- 100k-subscription bulk load through the vectorized
      ``aggregate`` path vs replaying Algorithm 1 one Python call per
      subscription (the paper's broker-side ingest bottleneck).
  data plane    -- one fused ``execute_all`` jitted call driving every
      channel vs the per-channel host loop, at several channel counts.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.channel import (most_threatening_tweets,
                                trending_tweets_in_country, tweets_about_drugs)
from repro.core.engine import BADEngine
from repro.core.plans import ExecutionFlags
from repro.data.synthetic import tweet_batch
from benchmarks.common import emit, timeit

N_BULK = 100_000
LANGS = ["En", "Pt", "Es", "Ar", "Ja"]


def _replay_load(eng: BADEngine, channel: str, params: np.ndarray,
                 brokers: np.ndarray) -> None:
    """The pre-vectorization path: one Algorithm-1 call per subscription."""
    st = eng.channels[channel]
    for p, b in zip(params.tolist(), brokers.tolist()):
        st.aggregator.add_subscription(p, b)
        st.user_params.add(p)
    st.invalidate_targets()


def _fresh_drug_engine() -> BADEngine:
    eng = BADEngine(dataset_capacity=1 << 16, index_capacity=1 << 14,
                    max_window=1 << 14, max_candidates=1 << 12,
                    brokers=("B1", "B2", "B3", "B4"))
    eng.create_channel(tweets_about_drugs())
    return eng


def bench_bulk_load(rng, repeats: int = 3) -> None:
    params = rng.integers(0, 50, N_BULK).astype(np.int32)
    brokers = rng.integers(0, 4, N_BULK).astype(np.int32)
    t_replay = t_bulk = float("inf")
    for _ in range(repeats):
        eng = _fresh_drug_engine()
        t0 = time.perf_counter()
        _replay_load(eng, "TweetsAboutDrugs", params, brokers)
        t_replay = min(t_replay, time.perf_counter() - t0)
        g_replay = eng.channels["TweetsAboutDrugs"].aggregator.build()

        eng = _fresh_drug_engine()
        t0 = time.perf_counter()
        eng.subscribe_bulk("TweetsAboutDrugs", params, brokers)
        t_bulk = min(t_bulk, time.perf_counter() - t0)
        g_bulk = eng.channels["TweetsAboutDrugs"].aggregator.build()
    assert g_bulk.num_subscriptions == g_replay.num_subscriptions == N_BULK
    assert g_bulk.num_groups == g_replay.num_groups
    emit("multi_channel/bulk_load/replay", t_replay, f"subs={N_BULK}")
    emit("multi_channel/bulk_load/vectorized", t_bulk,
         f"subs={N_BULK};groups={g_bulk.num_groups}")
    emit("multi_channel/bulk_load/speedup", 0.0,
         f"x{t_replay / t_bulk:.1f} (target >= 10x)")


def _channel_set(n: int):
    specs = [tweets_about_drugs(), most_threatening_tweets()]
    specs += [trending_tweets_in_country(i, f"{LANGS[i]}Trending")
              for i in range(len(LANGS))]
    return specs[:n]


def bench_fused_execution(rng, n_channels: int, n_subs: int = 20_000,
                          n_tweets: int = 16_384) -> None:
    eng = BADEngine(dataset_capacity=1 << 16, index_capacity=1 << 14,
                    max_window=1 << 14, max_candidates=1 << 12,
                    brokers=("B1", "B2", "B3", "B4"))
    specs = _channel_set(n_channels)
    for spec in specs:
        eng.create_channel(spec)
        eng.subscribe_bulk(spec.name,
                           rng.integers(0, spec.param_domain, n_subs),
                           rng.integers(0, 4, n_subs))
    eng.ingest(tweet_batch(rng, n_tweets, t0=1))
    flags = ExecutionFlags.fully_optimized()

    def sequential():
        return [eng.execute_channel(s.name, flags, advance=False, timed=False)
                for s in specs]

    def fused():
        return eng.execute_all(flags, advance=False, timed=False)

    seq_reports = sequential()          # warm every per-channel trace
    fused_reports = fused()             # warm the fused trace
    for s in specs:                     # counts must agree exactly
        r = next(r for r in seq_reports if r.channel == s.name)
        assert fused_reports[s.name].num_results == r.num_results
        assert fused_reports[s.name].num_notified == r.num_notified
    t_seq = timeit(sequential)
    t_fused = timeit(fused)
    total = sum(r.num_results for r in seq_reports)
    emit(f"multi_channel/exec/c{n_channels}/sequential", t_seq,
         f"results={total}")
    emit(f"multi_channel/exec/c{n_channels}/fused", t_fused,
         f"results={total}")
    emit(f"multi_channel/exec/c{n_channels}/speedup", 0.0,
         f"x{t_seq / t_fused:.2f}")


def run(rng) -> None:
    bench_bulk_load(rng)
    for n in (2, 4, 7):
        bench_fused_execution(rng, n)


if __name__ == "__main__":
    run(np.random.default_rng(0))
