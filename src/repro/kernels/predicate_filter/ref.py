"""Pure-jnp oracle for the predicate_filter kernel.

The kernel consumes *canonicalized* interval conditions: each channel's fixed
conjunction is rewritten per field as  lo[c,f] <= x < = hi[c,f]  plus at most
one  x != neq[c,f]  (sentinel NEQ_NONE = INT32_MIN means "no exclusion").
Canonicalization keeps the kernel free of dynamic gathers — a TPU adaptation:
field selection becomes a dense (C, F) broadcast instead of an index gather.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.predicates import (EQ, GE, GT, LE, LT, NE, CompiledConditions)

INT32_MIN = -(2 ** 31)
INT32_MAX = 2 ** 31 - 1
NEQ_NONE = INT32_MIN


@dataclasses.dataclass(frozen=True)
class IntervalConditions:
    lo: np.ndarray    # (C, F) int32
    hi: np.ndarray    # (C, F) int32
    neq: np.ndarray   # (C, F) int32, NEQ_NONE = unused

    @property
    def num_channels(self) -> int:
        return self.lo.shape[0]


def canonicalize(conds: CompiledConditions, num_fields: int) -> IntervalConditions:
    C = conds.num_channels
    lo = np.full((C, num_fields), INT32_MIN, dtype=np.int64)
    hi = np.full((C, num_fields), INT32_MAX, dtype=np.int64)
    neq = np.full((C, num_fields), NEQ_NONE, dtype=np.int64)
    for c in range(C):
        for p in range(int(conds.npreds[c])):
            f = int(conds.field_idx[c, p])
            op = int(conds.op[c, p])
            v = int(conds.value[c, p])
            if op == EQ:
                lo[c, f] = max(lo[c, f], v)
                hi[c, f] = min(hi[c, f], v)
            elif op == GE:
                lo[c, f] = max(lo[c, f], v)
            elif op == GT:
                lo[c, f] = max(lo[c, f], v + 1)
            elif op == LE:
                hi[c, f] = min(hi[c, f], v)
            elif op == LT:
                hi[c, f] = min(hi[c, f], v - 1)
            elif op == NE:
                if neq[c, f] != NEQ_NONE and neq[c, f] != v:
                    raise ValueError("at most one != predicate per (channel, field)")
                neq[c, f] = v
            else:
                raise ValueError(f"unknown op {op}")
    lo = np.clip(lo, INT32_MIN, INT32_MAX).astype(np.int32)
    hi = np.clip(hi, INT32_MIN, INT32_MAX).astype(np.int32)
    return IntervalConditions(lo, hi, neq.astype(np.int32))


def predicate_filter(fields: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray,
                     neq: jnp.ndarray) -> jnp.ndarray:
    """(N, F) int32 x (C, F) intervals -> (N, C) bool. Pure-jnp oracle."""
    x = fields[:, None, :]                      # (N, 1, F)
    ok = (x >= lo[None]) & (x <= hi[None])      # (N, C, F)
    ok &= (x != neq[None]) | (neq[None] == NEQ_NONE)
    return jnp.all(ok, axis=-1)
